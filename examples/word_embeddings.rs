//! Word-embedding PCA over a sparse co-occurrence matrix (paper §5.3).
//!
//! Builds a synthetic Zipfian corpus, forms the m×n conditional
//! probability matrix p(target | context), and computes 100-dim PCA
//! representations with S-RSVD — the sparse matrix is never densified.
//! Then demonstrates the embeddings with nearest-neighbor queries and
//! reports the Table-1 statistics.
//!
//! ```sh
//! cargo run --release --example word_embeddings
//! ```

use srsvd::data::{cooccurrence_matrix, CorpusSpec};
use srsvd::experiments::table1;
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{Pca, SvdConfig};

fn main() {
    let spec = CorpusSpec {
        contexts: 1000,
        targets: 8000,
        pairs: 1_500_000,
        zipf_s: 1.05,
        topics: 24,
    };
    let mut rng = Xoshiro256pp::seed_from_u64(11);
    println!(
        "sampling corpus: {} contexts x {} targets, {} pairs ...",
        spec.contexts, spec.targets, spec.pairs
    );
    let x = cooccurrence_matrix(spec, &mut rng);
    println!(
        "co-occurrence matrix: {}x{}, nnz = {} (density {:.4}) — centering \
         explicitly would allocate {} dense entries\n",
        x.rows(),
        x.cols(),
        x.nnz(),
        x.density(),
        x.rows() * x.cols()
    );

    // 100-dim PCA without densification.
    let k = 100;
    let cfg = SvdConfig::paper(k);
    let t = srsvd::util::timer::Timer::start();
    let pca = Pca::fit(&x, cfg, &mut rng).unwrap();
    println!(
        "fitted {k}-dim PCA via S-RSVD in {} (sparse path, implicit shift)",
        srsvd::util::timer::fmt_duration(t.elapsed_secs())
    );

    // Embed all target words: columns of the score matrix.
    let y = pca.transform(&x); // (k, n)
    println!("embeddings: {} words x {} dims", y.cols(), y.rows());

    // Nearest neighbors of a few head words by cosine similarity.
    let cos = |a: usize, b: usize| -> f64 {
        let (mut dot, mut na, mut nb) = (0.0, 0.0, 0.0);
        for d in 0..k {
            let (va, vb) = (y[(d, a)], y[(d, b)]);
            dot += va * vb;
            na += va * va;
            nb += vb * vb;
        }
        dot / (na.sqrt() * nb.sqrt()).max(1e-300)
    };
    for &w in &[0usize, 1, 2] {
        let mut sims: Vec<(usize, f64)> = (0..x.cols().min(2000))
            .filter(|&o| o != w)
            .map(|o| (o, cos(w, o)))
            .collect();
        sims.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let top: Vec<String> = sims[..5]
            .iter()
            .map(|(o, s)| format!("w{o}({s:.2})"))
            .collect();
        println!("  nearest to w{w}: {}", top.join(" "));
    }

    // Table-1-right statistics at this scale.
    println!("\nTable-1 protocol (10 runs):");
    let stats = table1::words_stats(4000, 800_000, 64, 10, 17);
    println!("{}", table1::render(&[stats]));
    println!("paper (n=1e4): MSE 235e-5 vs 236e-5, p=0.00, WR 73%/27%");
}
