//! Image PCA (paper §5.2): digits and faces, with the Table-1 protocol —
//! MSE, paired t-tests (H₀¹/H₀²) and per-image win-rates.
//!
//! ```sh
//! cargo run --release --example pca_images            # reduced scale
//! cargo run --release --example pca_images -- --full  # paper-sized digits
//! ```

use srsvd::data::{digits_matrix, DigitsSpec, FacesSpec};
use srsvd::experiments::{run_rsvd, run_srsvd, table1};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::SvdConfig;

/// Paper Figure 2 analog: render an 8×8 digit column as ASCII shades.
fn render_digit(col: &[f64], ink: f64) -> Vec<String> {
    const SHADES: [char; 5] = [' ', '.', 'o', 'O', '#'];
    (0..8)
        .map(|r| {
            (0..8)
                .map(|c| {
                    let v = (col[r * 8 + c] / ink).clamp(0.0, 1.0);
                    SHADES[(v * (SHADES.len() - 1) as f64).round() as usize]
                })
                .collect()
        })
        .collect()
}

/// Figure 2: originals vs S-RSVD vs RSVD reconstructions with per-image
/// errors on top, for the first few digits.
fn figure2(count: usize, seed: u64) {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let spec = DigitsSpec { count: 200, ..Default::default() };
    let x = digits_matrix(spec, &mut rng);
    let cfg = SvdConfig::paper(10);
    let s = run_srsvd(&x, cfg, seed);
    let r = run_rsvd(&x, cfg, seed);
    // Reconstructions for rendering.
    let mu = x.row_means();
    let mut srng = Xoshiro256pp::seed_from_u64(seed);
    let fs = srsvd::svd::ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut srng).unwrap();
    let mut rrng = Xoshiro256pp::seed_from_u64(seed);
    let fr = srsvd::svd::Rsvd::new(cfg).factorize(&x, &mut rrng).unwrap();
    let rec_s = fs.reconstruct(); // of Xbar — add mu back
    let rec_r = fr.reconstruct(); // of X directly

    println!("Figure 2 analog — original / S-RSVD / RSVD (per-image sq. error on top):");
    for j in 0..count {
        let orig = x.col(j);
        let srec: Vec<f64> = (0..64).map(|i| rec_s[(i, j)] + mu[i]).collect();
        let rrec: Vec<f64> = (0..64).map(|i| rec_r[(i, j)]).collect();
        println!(
            "  digit {:<2}      err(S-RSVD)={:<10.1} err(RSVD)={:<10.1}",
            j % 10,
            s.col_errors[j],
            r.col_errors[j]
        );
        let (a, b, c) = (
            render_digit(&orig, 16.0),
            render_digit(&srec, 16.0),
            render_digit(&rrec, 16.0),
        );
        for row in 0..8 {
            println!("    {}   {}   {}", a[row], b[row], c[row]);
        }
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let runs = if full { 30 } else { 10 };

    figure2(3, 7);
    println!();

    // Digits: 64×N stacked 8×8 glyphs (paper: 1979 UCI digits; ours is a
    // procedural substitute — see DESIGN.md §Substitutions), k = 10.
    let digit_count = if full { 1979 } else { 600 };
    println!(
        "digits: 64x{digit_count}, k=10, K=20, q=0, {runs} runs ..."
    );
    let digits = table1::digits_stats(digit_count, runs, 42);

    // Faces: side²×N eigenface-style synthetic (paper: 62500×13233 LFW).
    let spec = if full {
        FacesSpec { side: 48, count: 800, rank: 32, noise: 6.0 }
    } else {
        FacesSpec { side: 24, count: 240, rank: 16, noise: 6.0 }
    };
    println!(
        "faces:  {}x{}, k=10, K=20, q=0, {runs} runs ...\n",
        spec.side * spec.side,
        spec.count
    );
    let faces = table1::faces_stats(spec, runs, 43);

    println!("{}", table1::render(&[digits.clone(), faces.clone()]));

    println!("paper (Table 1 left): digits MSE 415.7 vs 430.6, WR 66%/34%;");
    println!("                      faces  MSE 15.3e7 vs 16.1e7, WR 82%/18%");
    println!(
        "ours:                 digits WR {:.0}%/{:.0}%; faces WR {:.0}%/{:.0}%",
        digits.wr_srsvd * 100.0,
        digits.wr_rsvd() * 100.0,
        faces.wr_srsvd * 100.0,
        faces.wr_rsvd() * 100.0
    );
    println!("(absolute MSEs differ — synthetic data — but the winner, the");
    println!(" significance (p≈0) and the win-rate ordering reproduce.)");
}
