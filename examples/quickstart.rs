//! Quickstart: factorize an off-center random matrix with S-RSVD and
//! the RSVD baseline, and see why mean-centering matters.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use srsvd::data::{random_matrix, DataSpec, Distribution};
use srsvd::experiments::{run_rsvd, run_srsvd};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{deterministic, SvdConfig};

fn main() {
    // 1. An off-center data matrix: 100 features × 1000 samples, each
    //    entry uniform in [0, 1) — so every feature has mean ≈ 0.5.
    let mut rng = Xoshiro256pp::seed_from_u64(7);
    let x = random_matrix(
        DataSpec { m: 100, n: 1000, dist: Distribution::Uniform },
        &mut rng,
    );
    println!("data: 100x1000 uniform(0,1), grand mean ≈ 0.5 (off-center)\n");

    // 2. PCA with k components via S-RSVD (implicit mean-centering) and
    //    plain RSVD (no centering) — the paper's headline comparison.
    println!(
        "{:<6} {:>14} {:>14} {:>14}",
        "k", "S-RSVD mse", "RSVD mse", "optimal mse"
    );
    let mu = x.row_means();
    let xbar = x.subtract_column(&mu);
    for k in [1, 2, 5, 10, 25, 50] {
        let cfg = SvdConfig::paper(k); // K = 2k, q = 0, as in the paper
        let s = run_srsvd(&x, cfg, 1);
        let r = run_rsvd(&x, cfg, 1);
        let opt = deterministic::optimal_mse(&xbar, k);
        println!("{k:<6} {:>14.5} {:>14.5} {:>14.5}", s.mse, r.mse, opt);
    }

    // 3. The same factorization through the public engine API.
    let cfg = SvdConfig::paper(10).with_fixed_power(1);
    let engine = srsvd::svd::ShiftedRsvd::new(cfg);
    let mut rng = Xoshiro256pp::seed_from_u64(2);
    let fact = engine.factorize_mean_centered(&x, &mut rng).unwrap();
    println!("\ntop-5 singular values of the centered matrix (q=1):");
    println!("  srsvd:         {:?}", &fact.s[..5]);
    println!(
        "  deterministic: {:?}",
        &deterministic::deterministic_svd(&xbar, 5).s[..5]
    );
    println!("\nS-RSVD computed these without ever materializing X - mu*1^T.");
}
