//! Out-of-core PCA: factorize a matrix whose dense form is far larger
//! than the streaming memory budget.
//!
//! The demo (1) spills a synthetic off-center matrix to the on-disk
//! binary format block-by-block — the matrix is never resident — then
//! (2) factorizes it through `Streamed<FileSource>` under a small
//! block budget with both pass schedules, printing each run's source
//! pass/byte counters and the exact-vs-fused wall-clock (the fused
//! Gram sweeps cut `2 + 2q` disk passes to `q + 2`), and (3) for
//! modest shapes verifies the exact-schedule streamed factors are
//! byte-identical to the in-memory dense path.
//!
//! ```sh
//! cargo run --release --example out_of_core -- --m 4000 --n 2500 --budget-mb 4
//! ```

use srsvd::cli::ArgSpec;
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, FileSource, GeneratorSource, MatrixSource, StreamConfig, Streamed,
};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{MatVecOps, PassPolicy, ShiftedRsvd, SvdConfig};
use srsvd::util::timer::{fmt_duration, Timer};

fn main() {
    let spec = ArgSpec::new("Out-of-core S-RSVD on a spilled matrix")
        .opt("m", "4000", "rows (features)")
        .opt("n", "2500", "columns (samples)")
        .opt("k", "10", "target rank")
        .opt("budget-mb", "4", "resident-block budget (MiB)")
        .opt("dist", "uniform", "uniform | normal | exponential")
        .opt("seed", "7", "rng seed")
        .flag("skip-verify", "skip the in-memory parity check (large shapes)");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if a.help {
        print!("{}", spec.usage("out_of_core"));
        return;
    }
    run(&a).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
}

fn run(a: &srsvd::cli::Args) -> srsvd::util::Result<()> {
    let (m, n) = (a.get_usize("m")?, a.get_usize("n")?);
    let k = a.get_usize("k")?;
    let budget_mb = a.get_usize("budget-mb")?.max(1);
    let seed = a.get_u64("seed")?;
    let dist = Distribution::parse(a.get("dist"))
        .ok_or_else(|| srsvd::util::Error::Invalid(format!("unknown dist {:?}", a.get("dist"))))?;

    let dense_mib = (m * n * 8) as f64 / (1 << 20) as f64;
    println!(
        "matrix: {m}x{n} {} — dense size {dense_mib:.1} MiB, budget {budget_mb} MiB",
        dist.name()
    );

    // 1. Spill to disk block-by-block: peak memory is one block.
    let gen = GeneratorSource::new(m, n, dist, seed)?;
    let stream_cfg = StreamConfig { block_rows: 0, budget_mb, prefetch: true };
    let block_rows = stream_cfg.resolve_block_rows(m, n);
    let path = std::env::temp_dir().join(format!("srsvd_out_of_core_{m}x{n}_{seed}.bin"));
    let t = Timer::start();
    let file = spill_to_file(&gen, &path, block_rows)?;
    println!(
        "spilled to {} in {} ({block_rows} rows/block, {:.1} MiB resident)",
        path.display(),
        fmt_duration(t.elapsed_secs()),
        (block_rows * n * 8) as f64 / (1 << 20) as f64
    );

    // 2. Factorize out-of-core under both pass schedules: every product
    //    is a (prefetched) block sweep; the fused schedule services a
    //    whole power-iteration leg from one sweep.
    let cfg = SvdConfig::paper(k).with_fixed_power(1);
    let x = Streamed::new(file, &stream_cfg);
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
    let fact = ShiftedRsvd::new(cfg).factorize_mean_centered(&x, &mut rng)?;
    let exact_s = t.elapsed_secs();
    let exact_io = x.stats();
    println!(
        "exact streamed factorization (k={k}, q=1) in {}: {} source passes, \
         {} blocks, {:.1} MiB read",
        fmt_duration(exact_s),
        exact_io.passes,
        exact_io.blocks,
        exact_io.bytes_read as f64 / (1 << 20) as f64
    );

    let x_fused = Streamed::new(FileSource::open(&path)?, &stream_cfg);
    let fused_cfg = cfg.with_pass_policy(PassPolicy::Fused);
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
    let fact_fused = ShiftedRsvd::new(fused_cfg).factorize_mean_centered(&x_fused, &mut rng)?;
    let fused_s = t.elapsed_secs();
    let fused_io = x_fused.stats();
    println!(
        "fused streamed factorization (k={k}, q=1) in {}: {} source passes, \
         {} blocks, {:.1} MiB read",
        fmt_duration(fused_s),
        fused_io.passes,
        fused_io.blocks,
        fused_io.bytes_read as f64 / (1 << 20) as f64
    );
    println!(
        "pass-efficiency win: {} -> {} passes, {:.2}x wall-clock \
         (fused top sv {:.4} vs exact {:.4})",
        exact_io.passes,
        fused_io.passes,
        exact_s / fused_s.max(1e-12),
        fact_fused.s[0],
        fact.s[0]
    );
    println!("top singular values: {:?}", &fact.s[..k.min(5)]);

    // 2b. Accuracy control: the tolerance criterion lets the
    //     dynamic-shift loop pick the sweep count instead of q.
    let x_adaptive = Streamed::new(FileSource::open(&path)?, &stream_cfg);
    let mu = MatVecOps::row_means(&x_adaptive);
    let adaptive_cfg = SvdConfig::paper(k).with_tolerance(1e-3, 32);
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
    let (fact_adaptive, report) =
        ShiftedRsvd::new(adaptive_cfg).factorize_with_report(&x_adaptive, &mu, &mut rng)?;
    println!(
        "adaptive streamed factorization (k={k}, pve_tol=1e-3) in {}: \
         fixed q=1 ran 1 sweep, accuracy control ran {} (achieved pve {}); \
         {} source passes, top sv {:.4}",
        fmt_duration(t.elapsed_secs()),
        report.sweeps_used,
        report
            .achieved_pve
            .map(|p| format!("{p:.4}"))
            .unwrap_or_else(|| "n/a".into()),
        x_adaptive.stats().passes,
        fact_adaptive.s[0]
    );

    // 3. Parity: the exact-schedule streamed factors must be
    //    byte-identical to the in-memory dense path on the same seed.
    if !a.has_flag("skip-verify") && dense_mib <= 512.0 {
        let dense = gen.materialize()?;
        let t = Timer::start();
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        let fact_mem = ShiftedRsvd::new(cfg).factorize_mean_centered(&dense, &mut rng)?;
        println!(
            "in-memory factorization in {}",
            fmt_duration(t.elapsed_secs())
        );
        let identical = fact.s.iter().zip(&fact_mem.s).all(|(a, b)| a.to_bits() == b.to_bits())
            && fact
                .u
                .data()
                .iter()
                .zip(fact_mem.u.data())
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && fact
                .v
                .data()
                .iter()
                .zip(fact_mem.v.data())
                .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(identical, "streamed factors diverged from the dense path");
        println!("parity: streamed u/s/v byte-identical to the in-memory path ✓");
    }
    let stored = MatVecOps::stored_entries(&x);
    println!(
        "done — {stored} logical entries, at most {} resident at any point \
         (two {}-row blocks: one in flight, one in the GEMM)",
        2 * x.block_rows() * n,
        x.block_rows()
    );
    let _ = std::fs::remove_file(&path);
    Ok(())
}
