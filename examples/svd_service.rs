//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Starts the coordinator with both engines — the PJRT runtime executing
//! the AOT-compiled JAX/Pallas pipeline, and the native rust engine —
//! then drives a mixed stream of factorization jobs through it:
//! grid-shaped dense PCA jobs (served by the compiled artifact), off-grid
//! dense jobs and sparse co-occurrence jobs (served natively). Reports
//! per-engine latency, throughput, and cross-engine accuracy agreement.
//!
//! This is deliverable (e) of DESIGN.md: it proves Layer 1 (Pallas
//! kernels) → Layer 2 (JAX pipeline) → AOT HLO → rust runtime → Layer 3
//! coordinator all compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example svd_service
//! ```

use std::time::Instant;

use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::{cooccurrence_matrix, random_matrix, CorpusSpec, DataSpec, Distribution};
use srsvd::rng::Xoshiro256pp;
use srsvd::stats::{mean, quantile};
use srsvd::svd::{SvdConfig, SvdEngine};
use srsvd::util::timer::fmt_duration;

fn main() {
    srsvd::util::logging::init();
    if !cfg!(feature = "pjrt") {
        eprintln!(
            "this driver needs the PJRT artifact engine: the default build \
             ships a stub Executor. Enabling the `pjrt` feature additionally \
             requires vendoring the external `xla` PJRT wrapper crate (not \
             available in the offline environment — see runtime/executor.rs)."
        );
        std::process::exit(1);
    }
    let artifact_dir = std::path::PathBuf::from("artifacts");
    if !artifact_dir.join("manifest.json").exists() {
        eprintln!("artifacts not built — run `make artifacts` first");
        std::process::exit(1);
    }

    let coord = Coordinator::start(CoordinatorConfig {
        native_workers: 2,
        queue_capacity: 64,
        artifact_dir: Some(artifact_dir),
        pool_threads: None, // shared linalg pool: SRSVD_THREADS / all cores
    })
    .expect("coordinator");

    // ---- build the workload ------------------------------------------------
    let n_jobs = std::env::var("SRSVD_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30usize);
    println!("submitting {n_jobs} mixed jobs ...\n");

    let mut handles = Vec::new();
    let t0 = Instant::now();
    for j in 0..n_jobs as u64 {
        let spec = match j % 3 {
            // Artifact-served: the 100×1000 grid shape from aot.py.
            0 => {
                let mut rng = Xoshiro256pp::seed_from_u64(100 + j);
                let x = random_matrix(
                    DataSpec { m: 100, n: 1000, dist: Distribution::Uniform },
                    &mut rng,
                );
                JobSpec::pca(MatrixInput::Dense(x), 10, 1000 + j)
            }
            // Native dense: off-grid shape.
            1 => {
                let mut rng = Xoshiro256pp::seed_from_u64(200 + j);
                let x = random_matrix(
                    DataSpec { m: 80, n: 600, dist: Distribution::Exponential },
                    &mut rng,
                );
                JobSpec::pca(MatrixInput::Dense(x), 8, 2000 + j)
            }
            // Native sparse: word co-occurrence (never densified).
            _ => {
                let mut rng = Xoshiro256pp::seed_from_u64(300 + j);
                let x = cooccurrence_matrix(
                    CorpusSpec {
                        contexts: 300,
                        targets: 3000,
                        pairs: 120_000,
                        zipf_s: 1.05,
                        topics: 12,
                    },
                    &mut rng,
                );
                JobSpec {
                    input: MatrixInput::Sparse(x),
                    config: SvdConfig::paper(32),
                    shift: ShiftSpec::MeanCenter,
                    engine: EnginePreference::Auto,
                    seed: 3000 + j,
                    score: true,
                }
            }
        };
        handles.push(coord.submit(spec).expect("submit"));
    }

    // ---- collect ------------------------------------------------------------
    let mut art_lat = Vec::new();
    let mut nat_lat = Vec::new();
    let mut art_mses = Vec::new();
    for h in handles {
        let r = h.wait().expect("result");
        let out = r.outcome.expect("job failed");
        match r.engine {
            SvdEngine::Artifact => {
                art_lat.push(r.exec_s);
                art_mses.push(out.mse.unwrap());
            }
            SvdEngine::Native => nat_lat.push(r.exec_s),
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    // ---- report --------------------------------------------------------------
    println!("all {n_jobs} jobs completed in {} wall-clock", fmt_duration(wall));
    println!("throughput: {:.1} jobs/s\n", n_jobs as f64 / wall);
    let report = |name: &str, lat: &[f64]| {
        if lat.is_empty() {
            return;
        }
        println!(
            "{name:<18} n={:<4} mean={} p50={} p95={}",
            lat.len(),
            fmt_duration(mean(lat)),
            fmt_duration(quantile(lat, 0.5)),
            fmt_duration(quantile(lat, 0.95)),
        );
    };
    report("artifact engine", &art_lat);
    report("native engine", &nat_lat);
    println!("\nservice metrics: {}", coord.metrics());

    // ---- cross-engine verification -------------------------------------------
    // The same job on both engines must agree (f32 artifact vs f64 native).
    let mut rng = Xoshiro256pp::seed_from_u64(999);
    let x = random_matrix(DataSpec { m: 100, n: 1000, dist: Distribution::Uniform }, &mut rng);
    let mut a_spec = JobSpec::pca(MatrixInput::Dense(x.clone()), 10, 77);
    a_spec.engine = EnginePreference::ArtifactOnly;
    let mut n_spec = JobSpec::pca(MatrixInput::Dense(x), 10, 77);
    n_spec.engine = EnginePreference::Native;
    let ma = coord.submit_blocking(a_spec).unwrap().outcome.unwrap().mse.unwrap();
    let mn = coord.submit_blocking(n_spec).unwrap().outcome.unwrap().mse.unwrap();
    println!("\ncross-engine check (same seed): artifact mse={ma:.6} native mse={mn:.6}");
    let rel = (ma - mn).abs() / mn.max(1e-12);
    assert!(rel < 5e-3, "engines disagree: rel err {rel}");
    println!("agreement within {:.3}% — PASS", rel * 100.0);

    coord.shutdown();
}
