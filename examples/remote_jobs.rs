//! Factorization jobs over the wire: the network service layer end to
//! end, using the std-only blocking [`srsvd::server::Client`].
//!
//! By default the demo self-hosts — it starts a coordinator plus the
//! HTTP server on a loopback port — then drives it exactly like a
//! remote client would: a dense payload job, a generator-streamed job
//! (the wire carries a *seed*, the server sweeps the matrix
//! out-of-core), and a sparse CSR job, finishing with `/metrics`.
//! Point it at a running `srsvd serve --listen ADDR` with `--connect`.
//!
//! ```sh
//! cargo run --release --example remote_jobs
//! cargo run --release --example remote_jobs -- --connect 127.0.0.1:7878
//! ```

use std::sync::Arc;

use srsvd::cli::ArgSpec;
use srsvd::coordinator::Coordinator;
use srsvd::data::Distribution;
use srsvd::linalg::stream::StreamConfig;
use srsvd::linalg::{Csr, Dense};
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::server::client::{SubmitOutcome, WaitOutcome};
use srsvd::server::protocol::{csr_input, dense_input, generator_input, JobRequest, WireResult};
use srsvd::server::{Client, Server, ServerConfig};
use srsvd::util::timer::fmt_duration;

fn main() {
    let spec = ArgSpec::new("Submit factorization jobs to the srsvd HTTP service")
        .opt("connect", "", "host:port of a running server (empty = self-host)")
        .opt("m", "2000", "streamed job rows")
        .opt("n", "1500", "streamed job columns")
        .opt("k", "10", "target rank")
        .opt("seed", "7", "rng seed");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let a = match spec.parse(&args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if a.help {
        print!("{}", spec.usage("remote_jobs"));
        return;
    }
    run(&a).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
}

fn print_result(label: &str, r: &WireResult) -> srsvd::util::Result<()> {
    let out = r
        .outcome
        .as_ref()
        .map_err(|e| srsvd::util::Error::Service(format!("{label}: {e}")))?;
    let top: Vec<String> = out.s.iter().take(5).map(|s| format!("{s:.4}")).collect();
    println!(
        "{label}: job-{} engine={} exec={} queue={} mse={:.6}",
        r.id,
        r.engine,
        fmt_duration(r.exec_s),
        fmt_duration(r.queue_s),
        out.mse.unwrap_or(f64::NAN)
    );
    println!("  top singular values: [{}]", top.join(", "));
    Ok(())
}

fn run(a: &srsvd::cli::Args) -> srsvd::util::Result<()> {
    let (m, n) = (a.get_usize("m")?, a.get_usize("n")?);
    let k = a.get_usize("k")?;
    let seed = a.get_u64("seed")?;

    // Self-host unless --connect points at a running server.
    let hosted = if a.get("connect").is_empty() {
        let coord = Arc::new(Coordinator::start_native_only(2)?);
        let server = Server::bind(
            coord,
            &ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() },
            StreamConfig::default(),
        )?;
        println!("self-hosted service on http://{}", server.local_addr());
        Some(server)
    } else {
        None
    };
    let addr = match &hosted {
        Some(s) => s.local_addr().to_string(),
        None => a.get("connect").to_string(),
    };

    let mut client = Client::connect(&addr)?;
    client.health()?;

    // 1. Dense payload: the only input kind that ships the matrix.
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let x = Dense::from_fn(100, 400, |_, _| rng.next_uniform());
    let mut req = JobRequest::new(dense_input(&x), k.min(20));
    req.seed = seed;
    print_result("dense 100x400 (payload over the wire)", &client.submit_wait(&req)?)?;

    // 2. Generator-streamed: the job spec is ~100 bytes, the matrix is
    //    generated and swept block-at-a-time on the server, never
    //    resident. Submitted fire-and-forget, then claimed by a
    //    blocking GET — the two-step flow a remote pipeline would use.
    let mut req = JobRequest::new(
        generator_input(m, n, Distribution::Uniform, seed, None, Some(8)),
        k,
    );
    req.seed = seed ^ 0xFA;
    let id = match client.submit(&req)? {
        SubmitOutcome::Queued(id) => id,
        SubmitOutcome::Done(_) => unreachable!("wait=false"),
    };
    println!(
        "queued generator job {id}: {m}x{n} uniform under an 8 MiB sweep budget \
         ({:.1} MiB dense)",
        (m * n * 8) as f64 / (1 << 20) as f64
    );
    let r = loop {
        match client.wait(id)? {
            WaitOutcome::Done(r) => break r,
            WaitOutcome::Running => println!("  still running..."),
        }
    };
    print_result("generator streamed (spec over the wire)", &r)?;

    // 3. Sparse CSR: indices + values only, never densified server-side.
    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0x5B);
    let sp = Csr::random(200, 1000, 0.02, &mut rng, |r| r.next_uniform() + 0.1);
    let mut req = JobRequest::new(csr_input(&sp), k);
    req.seed = seed ^ 0x5C;
    print_result(
        &format!("sparse 200x1000 ({} nnz over the wire)", sp.nnz()),
        &client.submit_wait(&req)?,
    )?;

    println!("\nservice metrics: {}", client.metrics()?.to_string_pretty());

    if let Some(server) = hosted {
        server.shutdown();
        println!("self-hosted server drained and stopped");
    }
    Ok(())
}
