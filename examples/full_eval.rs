//! Regenerate every table and figure of the paper in one run, writing
//! the report that EXPERIMENTS.md quotes.
//!
//! ```sh
//! cargo run --release --example full_eval -- --quick   # thinned grids
//! cargo run --release --example full_eval              # full grids
//! ```

use srsvd::experiments::{efficiency, fig1, k_grid, table1};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || srsvd::experiments::quick_mode();
    let seed = 42;
    let ks = k_grid(100, quick);
    let runs = if quick { 5 } else { 30 };
    println!(
        "srsvd full evaluation (quick={quick}, seed={seed}, ks={} points, runs={runs})\n",
        ks.len()
    );

    // ---------------- Figure 1 -------------------------------------------
    println!("== Fig 1a: MSE vs number of principal components ==");
    let rows = fig1::fig1a(if quick { &[1, 2, 5, 10, 25, 50, 100] } else { &ks }, seed);
    print!("{}", fig1::render_k_table("(100x1000 uniform)", &rows));

    println!("\n== Fig 1b: MSE-SUM vs sample size ==");
    let ns: &[usize] = if quick {
        &[200, 1000, 5000]
    } else {
        &[100, 200, 500, 1000, 2000, 5000, 10000]
    };
    let mut t = srsvd::bench::Table::new(&["n", "S-RSVD", "RSVD"]);
    for (n, s, r) in fig1::fig1b(ns, &ks, seed) {
        t.row(&[n.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
    }
    print!("{}", t.render());

    println!("\n== Fig 1c: MSE-SUM vs data distribution ==");
    let mut t = srsvd::bench::Table::new(&["distribution", "S-RSVD", "RSVD"]);
    for (d, s, r) in fig1::fig1c(&ks, seed) {
        t.row(&[d.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
    }
    print!("{}", t.render());

    println!("\n== Fig 1d: implicit vs explicit centering (must coincide) ==");
    let mut t = srsvd::bench::Table::new(&["k", "implicit", "explicit", "|diff|"]);
    for (k, i, e) in fig1::fig1d(if quick { &[1, 5, 20, 80] } else { &ks }, seed) {
        t.row(&[
            k.to_string(),
            format!("{i:.6}"),
            format!("{e:.6}"),
            format!("{:.2e}", (i - e).abs()),
        ]);
    }
    print!("{}", t.render());

    println!("\n== Fig 1e: MSE-SUM vs power iterations q ==");
    let qs: &[usize] = if quick { &[0, 1, 2, 4] } else { &[0, 1, 2, 3, 4, 6, 8] };
    let mut t = srsvd::bench::Table::new(&["q", "S-RSVD", "RSVD"]);
    for (q, s, r) in fig1::fig1e(qs, &ks, seed) {
        t.row(&[q.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
    }
    print!("{}", t.render());

    println!("\n== Fig 1f: MSE-SUM difference vs q, per distribution ==");
    println!("(negative = S-RSVD more accurate)");
    for (dist, series) in fig1::fig1f(qs, &ks, seed) {
        let cells: Vec<String> = series
            .iter()
            .map(|(q, d)| format!("q={q}:{d:+.3}"))
            .collect();
        println!("  {dist:<12} {}", cells.join("  "));
    }

    // ---------------- Table 1 --------------------------------------------
    println!("\n== Table 1 (left): image data ==");
    let digits = table1::digits_stats(if quick { 400 } else { 1979 }, runs, seed);
    let faces = table1::faces_stats(
        if quick {
            srsvd::data::FacesSpec { side: 16, count: 120, rank: 12, noise: 5.0 }
        } else {
            srsvd::data::FacesSpec::default()
        },
        runs,
        seed,
    );
    print!("{}", table1::render(&[digits, faces]));

    println!("\n== Table 1 (right): word data ==");
    let ns: &[usize] = if quick { &[1000, 4000] } else { &[1000, 10_000, 100_000, 300_000] };
    let stats: Vec<_> = ns
        .iter()
        .map(|&n| {
            let pairs = (n * 50).min(4_000_000);
            let k = 100.min(n / 4);
            table1::words_stats(n, pairs, k, runs.min(10), seed)
        })
        .collect();
    print!("{}", table1::render(&stats));

    // ---------------- §4 efficiency --------------------------------------
    println!("\n== §4 efficiency: sparse S-RSVD vs densified RSVD ==");
    let points: &[(usize, f64)] = if quick {
        &[(2000, 0.01), (8000, 0.005)]
    } else {
        &[(2000, 0.01), (8000, 0.005), (20_000, 0.002), (50_000, 0.001)]
    };
    let rows = efficiency::sweep(500, points, 10, seed);
    print!("{}", efficiency::render(&rows));

    println!("\ndone — paste the sections above into EXPERIMENTS.md");
}
