"""AOT exporter: lower the S-RSVD pipeline to HLO text artifacts.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the pinned
runtime (xla_extension 0.5.1, bound by the rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

One artifact is lowered per static configuration in the grid below
(shapes are static under AOT). The rust coordinator routes factorization
jobs to artifacts via ``artifacts/manifest.json``; configurations
outside the grid fall back to the native rust engine.

Run: ``cd python && python -m compile.aot --out ../artifacts``
(wired as ``make artifacts``; a no-op when inputs are unchanged thanks
to the Makefile dependency rule).
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import matmul_rank1, row_mean


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange).

    ``as_hlo_text(True)`` forces *full* printing of large constants:
    the default elides arrays with more than 10 elements as
    ``constant({...})``, which the 0.5.1 text parser silently turns
    into garbage — the Jacobi pair-index tables (190 entries at K=20)
    came back as zeros and the in-graph SVD never converged. See
    DESIGN.md "HLO-text interchange pitfalls" and
    python/tests/test_aot.py::test_no_elided_constants.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32 if dtype == "f32" else dtype)


# ---------------------------------------------------------------------------
# Artifact grid.
#
# Each entry describes one compiled pipeline. `method`/`sweeps` pick the
# small-SVD backend (jacobi = accurate, gram = cheap when n >> K).
# Shapes mirror the paper's experiment regimes at artifact-friendly
# sizes; the native rust engine covers arbitrary shapes (e.g. the k- and
# q-sweeps of Figure 1).
# ---------------------------------------------------------------------------
GRID = [
    # name                      m     n     k    K    q  sweeps method
    ("uniform_100x1000_k10_q0", 100, 1000, 10, 20, 0, 8, "jacobi"),
    ("uniform_100x1000_k10_q1", 100, 1000, 10, 20, 1, 8, "jacobi"),
    ("uniform_100x1000_k25_q0", 100, 1000, 25, 50, 0, 8, "jacobi"),
    ("digits_64x1979_k10_q0",   64,  1979, 10, 20, 0, 8, "jacobi"),
    ("faces_1024x1024_k10_q0",  1024, 1024, 10, 20, 0, 8, "jacobi"),
    ("words_1000x4000_k64_q0",  1000, 4000, 64, 128, 0, 6, "gram"),
]


def build_artifacts(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []

    for name, m, n, k, K, q, sweeps, method in GRID:
        fn = lambda x, mu, om: model.srsvd_scored(
            x, mu, om, k=k, q=q, sweeps=sweeps, method=method
        )
        lowered = jax.jit(fn).lower(
            _spec((m, n)), _spec((m,)), _spec((n, K))
        )
        text = to_hlo_text(lowered)
        fname = f"srsvd_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "file": fname,
                "op": "srsvd_scored",
                "m": m,
                "n": n,
                "k": k,
                "K": K,
                "q": q,
                "sweeps": sweeps,
                "method": method,
                "dtype": "f32",
                "inputs": [
                    {"name": "x", "shape": [m, n]},
                    {"name": "mu", "shape": [m]},
                    {"name": "omega", "shape": [n, K]},
                ],
                "outputs": [
                    {"name": "u", "shape": [m, k]},
                    {"name": "s", "shape": [k]},
                    {"name": "v", "shape": [n, k]},
                    {"name": "mse", "shape": []},
                ],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"lowered {fname}: {len(text)} chars")

    # Row-mean artifact (computing the shifting vector rust-side via the
    # pallas kernel) for each distinct m, n in the grid.
    seen = set()
    for _, m, n, *_ in GRID:
        if (m, n) in seen:
            continue
        seen.add((m, n))
        lowered = jax.jit(lambda x: (row_mean(x),)).lower(_spec((m, n)))
        text = to_hlo_text(lowered)
        fname = f"rowmean_{m}x{n}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append(
            {
                "name": f"rowmean_{m}x{n}",
                "file": fname,
                "op": "row_mean",
                "m": m,
                "n": n,
                "k": 0,
                "K": 0,
                "q": 0,
                "sweeps": 0,
                "method": "-",
                "dtype": "f32",
                "inputs": [{"name": "x", "shape": [m, n]}],
                "outputs": [{"name": "mu", "shape": [m]}],
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"lowered {fname}: {len(text)} chars")

    # Smoke artifact: the raw rank-1 matmul primitive at a tiny shape,
    # used by rust runtime unit tests (fast to compile + execute).
    lowered = jax.jit(lambda a, b, u, v: (matmul_rank1(a, b, u, v),)).lower(
        _spec((8, 16)), _spec((16, 4)), _spec((8,)), _spec((4,))
    )
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "smoke_matmul_rank1.hlo.txt"), "w") as f:
        f.write(text)
    entries.append(
        {
            "name": "smoke_matmul_rank1",
            "file": "smoke_matmul_rank1.hlo.txt",
            "op": "matmul_rank1",
            "m": 8,
            "n": 16,
            "k": 4,
            "K": 4,
            "q": 0,
            "sweeps": 0,
            "method": "-",
            "dtype": "f32",
            "inputs": [
                {"name": "a", "shape": [8, 16]},
                {"name": "b", "shape": [16, 4]},
                {"name": "u", "shape": [8]},
                {"name": "v", "shape": [4]},
            ],
            "outputs": [{"name": "c", "shape": [8, 4]}],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        }
    )
    print("lowered smoke_matmul_rank1.hlo.txt")

    manifest = {"version": 1, "dtype": "f32", "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(entries)} artifacts to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    build_artifacts(args.out)


if __name__ == "__main__":
    main()
