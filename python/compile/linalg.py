"""Pure-jax dense linear algebra that lowers to plain HLO.

Why this exists: ``jnp.linalg.qr`` / ``jnp.linalg.svd`` lower to LAPACK
custom-calls (``lapack_*geqrf_ffi`` etc.) that the pinned runtime
(xla_extension 0.5.1, what the rust ``xla`` crate binds) cannot execute.
Everything in this module is built from matmuls, ``lax.fori_loop`` and
dynamic slices, so the whole S-RSVD pipeline exports as self-contained
HLO text.

Algorithms:
  * ``mgs_qr``      — Modified Gram–Schmidt with one re-orthogonalization
                      pass ("twice is enough", Giraud et al. 2005).
  * ``jacobi_svd``  — one-sided Jacobi (Hestenes) with a fixed number of
                      cyclic sweeps; orthogonalizes columns by plane
                      rotations. Fixed sweep count keeps the HLO static.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax


def _mgs_pass(a):
    """One modified-Gram–Schmidt pass over the columns of ``a`` (m, k).

    Returns Q with orthonormal columns (rank-deficient columns map to
    zero vectors rather than NaN — the randomized sampling upstream makes
    exact deficiency measure-zero, but padding tiles can hit it).
    """
    m, k = a.shape
    eps = jnp.asarray(1e-30, a.dtype)

    def body(j, q):
        col = lax.dynamic_slice(q, (0, j), (m, 1))
        # Project out all previous columns: one matvec against the already
        # orthonormalized prefix. Columns >= j are masked out of the
        # projection by zeroing their coefficients.
        coeff = q.T @ col  # (k, 1)
        mask = (jnp.arange(k) < j).astype(a.dtype)[:, None]
        col = col - q @ (coeff * mask)
        nrm = jnp.sqrt(jnp.sum(col * col))
        col = jnp.where(nrm > eps, col / nrm, jnp.zeros_like(col))
        return lax.dynamic_update_slice(q, col, (0, j))

    return lax.fori_loop(0, k, body, a)


@jax.jit
def mgs_qr(a):
    """Orthonormal basis of the columns of ``a`` (m, k), m >= k.

    Two MGS passes: the second pass restores orthogonality lost to
    cancellation (classical "twice is enough" result), which matters here
    because the power-iteration matrices are deliberately ill-conditioned
    (singular values decay like sigma^(2q+1)).
    """
    return _mgs_pass(_mgs_pass(a))


def _jacobi_pairs(k):
    """Static (p, q) index arrays covering all column pairs, p < q."""
    ps, qs = [], []
    for p in range(k - 1):
        for q in range(p + 1, k):
            ps.append(p)
            qs.append(q)
    return jnp.array(ps, jnp.int32), jnp.array(qs, jnp.int32)


@functools.partial(jax.jit, static_argnames=("sweeps",))
def jacobi_svd(w, sweeps: int = 10):
    """One-sided Jacobi SVD of ``w`` (n, k) with n >= k.

    Returns (u, s, v) with ``w = u @ diag(s) @ v.T``; u is (n, k) with
    orthonormal columns, s is (k,) descending, v is (k, k) orthogonal.

    Method: right-multiply by plane rotations until columns are
    orthogonal: ``w J1 J2 ... = b`` with b's columns orthogonal; then
    s = ||b_j||, u = b / s, and v accumulates the rotations.
    """
    n, k = w.shape
    dtype = w.dtype
    eps0 = jnp.asarray(1e-30, dtype)
    if k < 2:
        # No column pairs to rotate: the SVD is just the column norm.
        s = jnp.sqrt(jnp.sum(w * w, axis=0))
        u = w / jnp.where(s > eps0, s, eps0)[None, :]
        return u, s, jnp.eye(k, dtype=dtype)
    ps, qs = _jacobi_pairs(k)
    n_pairs = ps.shape[0]
    eps = jnp.asarray(1e-30, dtype)

    def rotate(carry, idx):
        b, v = carry
        p = ps[idx]
        q = qs[idx]
        bp = lax.dynamic_slice(b, (0, p), (n, 1))
        bq = lax.dynamic_slice(b, (0, q), (n, 1))
        app = jnp.sum(bp * bp)
        aqq = jnp.sum(bq * bq)
        apq = jnp.sum(bp * bq)

        # Rotation angle zeroing the (p, q) Gram entry.
        tau = (aqq - app) / (2.0 * jnp.where(jnp.abs(apq) > eps, apq, eps))
        t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        c = 1.0 / jnp.sqrt(1.0 + t * t)
        s_ = c * t
        # Skip (identity rotation) when already orthogonal.
        no_op = jnp.abs(apq) <= eps * jnp.sqrt(app * aqq) + eps
        c = jnp.where(no_op, jnp.asarray(1.0, dtype), c.astype(dtype))
        s_ = jnp.where(no_op, jnp.asarray(0.0, dtype), s_.astype(dtype))

        new_bp = c * bp - s_ * bq
        new_bq = s_ * bp + c * bq
        b = lax.dynamic_update_slice(b, new_bp, (0, p))
        b = lax.dynamic_update_slice(b, new_bq, (0, q))

        vp = lax.dynamic_slice(v, (0, p), (k, 1))
        vq = lax.dynamic_slice(v, (0, q), (k, 1))
        new_vp = c * vp - s_ * vq
        new_vq = s_ * vp + c * vq
        v = lax.dynamic_update_slice(v, new_vp, (0, p))
        v = lax.dynamic_update_slice(v, new_vq, (0, q))
        return (b, v)

    def sweep_body(_, carry):
        def pair_body(i, carry):
            return rotate(carry, i)

        return lax.fori_loop(0, n_pairs, pair_body, carry)

    b, v = lax.fori_loop(0, sweeps, sweep_body, (w, jnp.eye(k, dtype=dtype)))

    s = jnp.sqrt(jnp.sum(b * b, axis=0))
    order = jnp.argsort(-s)
    s = s[order]
    b = b[:, order]
    v = v[:, order]
    u = b / jnp.where(s > eps, s, eps)[None, :]
    return u, s, v


@functools.partial(jax.jit, static_argnames=("sweeps",))
def svd_small(y, sweeps: int = 10):
    """SVD of a short-fat ``y`` (K, n), K <= n — the paper's Line 13.

    Runs one-sided Jacobi on y^T (n, K): ``y^T = u_t s v_t^T`` gives
    ``y = v_t s u_t^T``, so the left factors of y are ``v_t`` (K, K) and
    the right factors are ``u_t`` (n, K).

    Returns (u1, s, v): y = u1 @ diag(s) @ v.T with u1 (K, K), v (n, K).
    """
    ut, s, vt = jacobi_svd(y.T, sweeps=sweeps)
    return vt, s, ut
