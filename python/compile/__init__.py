"""Build-time compile path for srsvd.

Layer 2 (JAX pipeline) + Layer 1 (Pallas kernels), AOT-lowered to HLO
text artifacts consumed by the rust runtime. Python is never on the
request path: ``make artifacts`` runs once and the rust binary is
self-contained afterwards.
"""
