"""Pallas reconstruction-error kernel with the shift fused.

The paper's comparison metric is the mean of squared L2 reconstruction
errors over columns,

    MSE = (1/n) * || (X - mu 1^T) - R ||_F^2

where R = U S V^T is the rank-k reconstruction. Fusing the shift means
the dense Xbar is never materialized even while *scoring* — the kernel
streams X and R tile-by-tile and subtracts the broadcast mu on the fly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mse_kernel(x_ref, mu_ref, r_ref, o_ref, *, grid_m: int, grid_n: int, n_true: int):
    i = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when((i == 0) & (s == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    d = x_ref[...] - mu_ref[...] - r_ref[...]
    o_ref[0, 0] += jnp.sum(d * d) / n_true


def _pad_to(x, mult, axis):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def shifted_mse(x, mu, r, *, bm: int = 128, bn: int = 512):
    """``mean_j || (X - mu 1^T - R)[:, j] ||^2`` without forming X - mu 1^T.

    x, r: (m, n); mu: (m,). Returns a scalar.

    Padding note: mu broadcasts across every column of a block, so
    zero-padding x would make padded columns contribute ``(-mu)^2``.
    Instead the padded columns of x are filled with mu itself, making
    ``x - mu - r = 0`` there; padded *rows* are all-zero in x, r and mu,
    so they contribute nothing either.
    """
    m, n = x.shape
    assert r.shape == (m, n) and mu.shape == (m,)
    bm = min(bm, m)
    bn = min(bn, n)
    col_pad = (-n) % bn
    if col_pad:
        fill = jnp.broadcast_to(mu[:, None], (m, col_pad))
        x = jnp.concatenate([x, fill], axis=1)
        r = jnp.concatenate([r, jnp.zeros((m, col_pad), r.dtype)], axis=1)
    xp = _pad_to(x, bm, 0)
    rp = _pad_to(r, bm, 0)
    mup = _pad_to(mu[:, None], bm, 0)
    mp_, np_ = xp.shape

    out = pl.pallas_call(
        functools.partial(
            _mse_kernel, grid_m=mp_ // bm, grid_n=np_ // bn, n_true=n
        ),
        grid=(mp_ // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, s: (i, s)),
            pl.BlockSpec((bm, 1), lambda i, s: (i, 0)),
            pl.BlockSpec((bm, bn), lambda i, s: (i, s)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, s: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        interpret=True,
    )(xp, mup, rp)
    return out[0, 0]
