"""Tiled Pallas matmul with a fused rank-1 downdate: C = A @ B - outer(u, v).

This is the compute hot-spot of S-RSVD: every product against the
implicitly-shifted matrix ``Xbar = X - mu 1^T`` is a plain product
against ``X`` plus a rank-1 correction (paper Eqs. 7, 8, 10). Fusing the
correction into the matmul epilogue means one pass over ``A`` in HBM and
no densified ``Xbar`` anywhere.

TPU mapping (DESIGN.md section Hardware-adaptation): the grid is
(M/bm, P/bp, N/bn); each (i, j) output tile lives in a VMEM accumulator
across the n-loop, and the rank-1 term costs a (bm, bp) outer product
applied once on the final n-step — rank-1 data (u tile, v tile) is tiny
and VMEM-resident. Block defaults (128, 128, 128) keep the working set
(3 tiles + 2 vectors, f32) well under the ~16 MiB VMEM budget; the MXU
sees plain (bm, bn) x (bn, bp) contractions.

All kernels run ``interpret=True``: the CPU PJRT runtime used by the
rust layer cannot execute Mosaic custom-calls, and interpret mode lowers
to plain HLO while preserving the block structure.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_rank1_kernel(a_ref, b_ref, u_ref, v_ref, o_ref, *, n_steps: int):
    """One (i, j, s) grid step: accumulate a_tile @ b_tile into o_ref.

    On the first n-step the accumulator is initialized; on the last the
    rank-1 downdate ``- u_tile @ v_tile`` is applied (u is (bm, 1),
    v is (1, bp), so the correction is a tiny outer product).
    """
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(s == n_steps - 1)
    def _epilogue():
        o_ref[...] -= u_ref[...] * v_ref[...]


def _pad_to(x, mult, axis):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bp"))
def matmul_rank1(a, b, u, v, *, bm: int = 128, bn: int = 128, bp: int = 128):
    """Compute ``a @ b - outer(u, v)`` without materializing the update.

    Args:
      a: (M, N) left operand.
      b: (N, P) right operand.
      u: (M,) left rank-1 factor.
      v: (P,) right rank-1 factor.
      bm, bn, bp: VMEM tile sizes (block of M, contraction N, and P).

    Returns:
      (M, P) array equal to ``a @ b - u[:, None] * v[None, :]``.
    """
    m, n = a.shape
    n2, p = b.shape
    assert n == n2, f"contraction mismatch {n} != {n2}"
    assert u.shape == (m,) and v.shape == (p,)
    dtype = jnp.result_type(a.dtype, b.dtype)

    # Shrink blocks to the (padded) problem; pad operands to block
    # multiples so BlockSpecs tile exactly. Zero padding is exact for
    # both the contraction and the rank-1 term.
    bm = min(bm, m)
    bn = min(bn, n)
    bp = min(bp, p)
    ap = _pad_to(_pad_to(a.astype(dtype), bm, 0), bn, 1)
    bpad = _pad_to(_pad_to(b.astype(dtype), bn, 0), bp, 1)
    up = _pad_to(u.astype(dtype)[:, None], bm, 0)
    vp = _pad_to(v.astype(dtype)[None, :], bp, 1)
    mp_, np_ = ap.shape
    _, pp_ = bpad.shape
    n_steps = np_ // bn

    out = pl.pallas_call(
        functools.partial(_matmul_rank1_kernel, n_steps=n_steps),
        grid=(mp_ // bm, pp_ // bp, n_steps),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, s: (i, s)),
            pl.BlockSpec((bn, bp), lambda i, j, s: (s, j)),
            pl.BlockSpec((bm, 1), lambda i, j, s: (i, 0)),
            pl.BlockSpec((1, bp), lambda i, j, s: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bp), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp_, pp_), dtype),
        interpret=True,
    )(ap, bpad, up, vp)
    return out[:m, :p]


def shifted_right(x, omega, mu):
    """``(X - mu 1^T) @ Omega`` = X Omega - mu (1^T Omega).   [paper Eq. 8]

    x: (m, n), omega: (n, K), mu: (m,)  ->  (m, K).
    """
    colsum = jnp.sum(omega, axis=0)
    return matmul_rank1(x, omega, mu, colsum)


def shifted_left(x, q, mu):
    """``(X - mu 1^T)^T @ Q`` = X^T Q - 1 (mu^T Q).   [paper Eq. 7]

    x: (m, n), q: (m, K), mu: (m,)  ->  (n, K).
    """
    n = x.shape[1]
    muq = mu @ q
    ones = jnp.ones((n,), x.dtype)
    return matmul_rank1(x.T, q, ones, muq)


def shifted_project(x, q, mu):
    """``Q^T (X - mu 1^T)`` = Q^T X - (Q^T mu) 1^T.   [paper Eq. 10]

    x: (m, n), q: (m, K), mu: (m,)  ->  (K, n).
    """
    n = x.shape[1]
    qtmu = q.T @ mu
    ones = jnp.ones((n,), x.dtype)
    return matmul_rank1(q.T, x, qtmu, ones)
