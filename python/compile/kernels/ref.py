"""Pure-jnp oracles for the Pallas kernels.

Each function is the direct, unfused jnp expression of what the
corresponding kernel must compute. pytest (python/tests/test_kernels.py)
asserts allclose between kernel and oracle across a hypothesis sweep of
shapes and dtypes — this is the core L1 correctness signal.
"""

import jax.numpy as jnp


def matmul_rank1_ref(a, b, u, v):
    """a @ b - outer(u, v)."""
    return a @ b - jnp.outer(u, v)


def shifted_right_ref(x, omega, mu):
    """(X - mu 1^T) @ Omega, by explicit densification."""
    return (x - mu[:, None]) @ omega


def shifted_left_ref(x, q, mu):
    """(X - mu 1^T)^T @ Q, by explicit densification."""
    return (x - mu[:, None]).T @ q


def shifted_project_ref(x, q, mu):
    """Q^T (X - mu 1^T), by explicit densification."""
    return q.T @ (x - mu[:, None])


def row_mean_ref(x):
    """mean(X, axis=1)."""
    return jnp.mean(x, axis=1)


def shifted_mse_ref(x, mu, r):
    """mean over columns of squared L2 reconstruction error."""
    d = x - mu[:, None] - r
    return jnp.sum(d * d) / x.shape[1]
