"""Pallas row-mean kernel: mu = mean(X, axis=1).

The shifting vector of S-RSVD in the PCA use case is the mean of the
column observations, i.e. the per-row mean of the (m, n) data matrix.
The kernel reduces over column tiles so X streams HBM->VMEM once; the
(bm, 1) accumulator stays VMEM-resident across the column loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row_mean_kernel(x_ref, o_ref, *, n_steps: int, n_true: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.sum(x_ref[...], axis=1, keepdims=True)

    @pl.when(s == n_steps - 1)
    def _finish():
        o_ref[...] = o_ref[...] / n_true


def _pad_to(x, mult, axis):
    rem = (-x.shape[axis]) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def row_mean(x, *, bm: int = 128, bn: int = 512):
    """Per-row mean of an (m, n) matrix, tiled. Returns (m,)."""
    m, n = x.shape
    bm = min(bm, m)
    bn = min(bn, n)
    xp = _pad_to(_pad_to(x, bm, 0), bn, 1)
    mp_, np_ = xp.shape
    n_steps = np_ // bn

    out = pl.pallas_call(
        functools.partial(_row_mean_kernel, n_steps=n_steps, n_true=n),
        grid=(mp_ // bm, n_steps),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, s: (i, s))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, s: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp_, 1), x.dtype),
        interpret=True,
    )(xp)
    return out[:m, 0]
