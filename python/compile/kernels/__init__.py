"""Layer-1 Pallas kernels for the Shifted Randomized SVD pipeline.

Every shifted product in Basirat (2019) Algorithm 1 reduces to a single
primitive: a matmul with a fused rank-1 downdate,

    C = A @ B - outer(u, v)

which is exactly what lets the algorithm avoid materializing the dense
shifted matrix  X-bar = X - mu 1^T:

    Xbar Omega  = X Omega - mu (1^T Omega) -> matmul_rank1(X,   Omega, u=mu,    v=colsum(Omega))
    Xbar^T Q    = X^T Q   - 1 (mu^T Q)     -> matmul_rank1(X^T, Q,     u=1,     v=mu^T Q)
    Q^T Xbar    = Q^T X   - (Q^T mu) 1^T   -> matmul_rank1(Q^T, X,     u=Q^T mu, v=1)

The kernels here are tiled for TPU VMEM (see DESIGN.md
section Hardware-adaptation) and run under ``interpret=True`` so they
lower to plain HLO executable on the CPU PJRT client.
"""

from .shifted_matmul import matmul_rank1, shifted_right, shifted_left, shifted_project
from .colmean import row_mean
from .mse import shifted_mse

__all__ = [
    "matmul_rank1",
    "shifted_right",
    "shifted_left",
    "shifted_project",
    "row_mean",
    "shifted_mse",
]
