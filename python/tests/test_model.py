"""L2 pipeline: S-RSVD vs numpy ground truth and the paper's identities."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import srsvd, srsvd_scored, reconstruction_mse, pca_transform


def _data(m=60, n=400, seed=0, dist="uniform"):
    r = np.random.default_rng(seed)
    if dist == "uniform":
        x = r.uniform(0, 1, size=(m, n))
    elif dist == "normal":
        x = r.normal(2.0, 1.0, size=(m, n))
    elif dist == "exponential":
        x = r.exponential(1.0, size=(m, n))
    else:
        raise ValueError(dist)
    return x.astype(np.float32)


def _optimal_err(xbar, k):
    s = np.linalg.svd(xbar, compute_uv=False)
    return np.sqrt((s[k:] ** 2).sum())


@pytest.mark.parametrize("dist", ["uniform", "normal", "exponential"])
@pytest.mark.parametrize("q", [0, 1, 2])
def test_srsvd_near_optimal_reconstruction(dist, q):
    """Frobenius error within Halko's bound regime of the optimal rank-k."""
    x = _data(dist=dist, seed=42)
    mu = x.mean(axis=1)
    k, K = 8, 16
    r = np.random.default_rng(1)
    om = r.normal(size=(x.shape[1], K)).astype(np.float32)
    u, s, v = srsvd(jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om), k=k, q=q)
    xbar = x - mu[:, None]
    rec = (np.asarray(u) * np.asarray(s)) @ np.asarray(v).T
    err = np.linalg.norm(xbar - rec)
    opt = _optimal_err(xbar, k)
    # q=0 randomized error is loose; power iteration tightens it.
    limit = {0: 2.0, 1: 1.25, 2: 1.1}[q]
    assert err <= limit * opt, (err, opt)


def test_srsvd_equals_rsvd_on_explicitly_centered_matrix():
    """Paper Fig. 1d: S-RSVD(X, mu) == RSVD(Xbar) for the same Omega."""
    x = _data(seed=7)
    mu = x.mean(axis=1)
    xbar = x - mu[:, None]
    K = 16
    om = np.random.default_rng(3).normal(size=(x.shape[1], K)).astype(np.float32)
    zero = jnp.zeros_like(jnp.asarray(mu))
    u1, s1, v1 = srsvd(jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om), k=8, q=1)
    u2, s2, v2 = srsvd(jnp.asarray(xbar), zero, jnp.asarray(om), k=8, q=1)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-3)
    # Subspaces agree (columns up to sign): compare projectors.
    p1 = np.asarray(u1) @ np.asarray(u1).T
    p2 = np.asarray(u2) @ np.asarray(u2).T
    np.testing.assert_allclose(p1, p2, atol=5e-3)


def test_zero_shift_reduces_to_plain_rsvd():
    """mu = 0 must factorize X itself (the Halko algorithm)."""
    x = _data(seed=11)
    K, k = 16, 8
    om = np.random.default_rng(5).normal(size=(x.shape[1], K)).astype(np.float32)
    zero = jnp.zeros((x.shape[0],), jnp.float32)
    u, s, v = srsvd(jnp.asarray(x), zero, jnp.asarray(om), k=k, q=1)
    rec = (np.asarray(u) * np.asarray(s)) @ np.asarray(v).T
    err = np.linalg.norm(x - rec)
    opt = _optimal_err(x, k)
    assert err <= 1.25 * opt


def test_scored_mse_matches_standalone_scorer():
    x = _data(seed=13)
    mu = x.mean(axis=1)
    K = 16
    om = np.random.default_rng(7).normal(size=(x.shape[1], K)).astype(np.float32)
    u, s, v, mse = srsvd_scored(
        jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om), k=8, q=0
    )
    mse2 = reconstruction_mse(jnp.asarray(x), jnp.asarray(mu), u, s, v)
    np.testing.assert_allclose(float(mse), float(mse2), rtol=1e-5)
    # And equals the explicit numpy computation.
    xbar = x - mu[:, None]
    rec = (np.asarray(u) * np.asarray(s)) @ np.asarray(v).T
    want = (np.linalg.norm(xbar - rec) ** 2) / x.shape[1]
    np.testing.assert_allclose(float(mse), want, rtol=2e-3)


def test_mean_centering_beats_no_centering_on_offcenter_data():
    """The paper's core experimental claim, at test scale."""
    x = _data(seed=17, dist="uniform")  # mean ~0.5, strongly off-center
    mu = x.mean(axis=1)
    k, K = 4, 8
    r = np.random.default_rng(19)
    xbar = x - mu[:, None]
    mses_s, mses_r = [], []
    for t in range(5):
        om = r.normal(size=(x.shape[1], K)).astype(np.float32)
        # S-RSVD factorizes Xbar implicitly.
        *_, mse_s = srsvd_scored(
            jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om), k=k, q=0
        )
        # RSVD factorizes the off-center X, scored against Xbar-optimal PCA:
        # reconstruction of Xbar from factors of X (paper's protocol scores
        # both against the centered data).
        zero = jnp.zeros((x.shape[0],), jnp.float32)
        u, s, v = srsvd(jnp.asarray(x), zero, jnp.asarray(om), k=k, q=0)
        # PCA-style reconstruction with the (uncentered) basis U:
        # project Xbar on U then reconstruct.
        u_np = np.asarray(u)
        rec = u_np @ (u_np.T @ xbar)
        mse_r = (np.linalg.norm(xbar - rec) ** 2) / x.shape[1]
        mses_s.append(float(mse_s))
        mses_r.append(float(mse_r))
    assert np.mean(mses_s) < np.mean(mses_r), (np.mean(mses_s), np.mean(mses_r))


def test_pca_transform_matches_svt():
    """Paper Eq. 3: Y = U^T Xbar = S V^T."""
    x = _data(seed=23)
    mu = x.mean(axis=1)
    k, K = 6, 12
    om = np.random.default_rng(29).normal(size=(x.shape[1], K)).astype(np.float32)
    u, s, v = srsvd(jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om), k=k, q=2)
    y = pca_transform(jnp.asarray(x), jnp.asarray(mu), u, s, k=k)
    svt = np.asarray(s)[:, None] * np.asarray(v).T
    np.testing.assert_allclose(np.asarray(y), svt, atol=2e-2, rtol=1e-2)
