"""Pure-jax linalg: MGS QR and one-sided Jacobi SVD vs numpy.linalg."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (optional extra)")
from hypothesis import given, settings, strategies as st

from compile.linalg import mgs_qr, jacobi_svd, svd_small
from compile.model import _svd_small_gram

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


@given(
    m=st.integers(2, 120),
    k=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_mgs_qr_orthonormal_and_span(m, k, seed):
    k = min(k, m)
    r = np.random.default_rng(seed)
    a = r.normal(size=(m, k)).astype(np.float32)
    q = np.asarray(mgs_qr(jnp.asarray(a)))
    # Orthonormal columns.
    np.testing.assert_allclose(q.T @ q, np.eye(k), atol=2e-5)
    # Span preserved: projecting A onto Q loses nothing.
    np.testing.assert_allclose(q @ (q.T @ a), a, atol=1e-3, rtol=1e-3)


def test_mgs_qr_rank_deficient_no_nan():
    a = np.zeros((10, 4), np.float32)
    a[:, 0] = 1.0
    a[:, 1] = 1.0  # duplicate column -> rank deficient
    q = np.asarray(mgs_qr(jnp.asarray(a)))
    assert np.isfinite(q).all()


def test_mgs_qr_ill_conditioned_reorthogonalization():
    """Second MGS pass must hold orthogonality on a kappa~1e6 matrix."""
    r = np.random.default_rng(0)
    u, _ = np.linalg.qr(r.normal(size=(80, 8)))
    s = np.logspace(0, -6, 8)
    v, _ = np.linalg.qr(r.normal(size=(8, 8)))
    a = (u * s) @ v
    q = np.asarray(mgs_qr(jnp.asarray(a.astype(np.float32))))
    assert np.max(np.abs(q.T @ q - np.eye(8))) < 5e-4


@given(
    n=st.integers(2, 100),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_jacobi_svd_matches_numpy(n, k, seed):
    k = min(k, n)
    r = np.random.default_rng(seed)
    w = r.normal(size=(n, k)).astype(np.float32)
    u, s, v = (np.asarray(t) for t in jacobi_svd(jnp.asarray(w)))
    s_np = np.linalg.svd(w, compute_uv=False)
    scale = max(1.0, s_np[0])
    np.testing.assert_allclose(s, s_np, atol=2e-4 * scale, rtol=2e-4)
    # Factorization reconstructs w.
    np.testing.assert_allclose((u * s) @ v.T, w, atol=2e-4 * scale)
    # u has orthonormal columns where s > 0.
    nz = s > 1e-5 * scale
    g = (u[:, nz]).T @ u[:, nz]
    np.testing.assert_allclose(g, np.eye(int(nz.sum())), atol=2e-3)
    # v orthogonal.
    np.testing.assert_allclose(v.T @ v, np.eye(k), atol=2e-3)


def test_jacobi_svd_descending_order():
    r = np.random.default_rng(5)
    w = r.normal(size=(50, 9)).astype(np.float32)
    _, s, _ = jacobi_svd(jnp.asarray(w))
    s = np.asarray(s)
    assert (np.diff(s) <= 1e-6).all()


@given(
    K=st.integers(2, 12),
    n=st.integers(12, 120),
    seed=st.integers(0, 2**31 - 1),
)
def test_svd_small_short_fat(K, n, seed):
    K = min(K, n)
    r = np.random.default_rng(seed)
    y = r.normal(size=(K, n)).astype(np.float32)
    u1, s, v = (np.asarray(t) for t in svd_small(jnp.asarray(y)))
    scale = max(1.0, float(np.max(np.abs(y))) * np.sqrt(n))
    np.testing.assert_allclose((u1 * s) @ v.T, y, atol=5e-4 * scale)
    np.testing.assert_allclose(u1.T @ u1, np.eye(K), atol=2e-3)


@given(
    K=st.integers(2, 10),
    n=st.integers(16, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_svd_small_gram_matches_jacobi_on_singvals(K, n, seed):
    K = min(K, n)
    r = np.random.default_rng(seed)
    y = r.normal(size=(K, n)).astype(np.float32)
    _, s_j, _ = svd_small(jnp.asarray(y))
    u_g, s_g, v_g = _svd_small_gram(jnp.asarray(y), sweeps=10)
    s_np = np.linalg.svd(y, compute_uv=False)
    scale = max(1.0, s_np[0])
    np.testing.assert_allclose(np.asarray(s_j), s_np, atol=5e-4 * scale, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_g), s_np, atol=5e-3 * scale, rtol=5e-3)
    # Gram route also reconstructs.
    rec = (np.asarray(u_g) * np.asarray(s_g)) @ np.asarray(v_g).T
    np.testing.assert_allclose(rec, y, atol=1e-2 * scale)
