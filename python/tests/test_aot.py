"""AOT artifact integrity: manifest consistency + runtime-safe HLO.

The critical invariant is that no artifact contains a custom-call — the
pinned xla_extension 0.5.1 runtime on the rust side can only execute
plain HLO ops (LAPACK custom-calls from jnp.linalg, or Mosaic calls from
non-interpret Pallas, would fail at compile time in the coordinator).
"""

import hashlib
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_lists_all_grid_configs(manifest):
    from compile.aot import GRID

    names = {e["name"] for e in manifest["artifacts"]}
    for name, *_ in GRID:
        assert name in names


def test_artifact_files_exist_and_hash_match(manifest):
    for e in manifest["artifacts"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            text = f.read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"], e["name"]


def test_no_custom_calls_anywhere(manifest):
    for e in manifest["artifacts"]:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "custom-call" not in text and "custom_call" not in text, e["name"]


def test_no_elided_constants(manifest):
    """`as_hlo_text()` without print_large_constants=True abbreviates
    >10-element constants as `constant({...})`; the 0.5.1 parser turns
    those into garbage (observed: Jacobi pair tables of zeros → the
    in-graph SVD silently never converges). Guard against regression."""
    for e in manifest["artifacts"]:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "{...}" not in text, e["name"]


def test_hlo_entry_signature_matches_manifest(manifest):
    """ENTRY parameter count and shapes line up with declared inputs."""
    for e in manifest["artifacts"]:
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        assert "ENTRY" in text, e["name"]
        for i, inp in enumerate(e["inputs"]):
            dims = ",".join(str(d) for d in inp["shape"])
            want = f"f32[{dims}]" if inp["shape"] else "f32[]"
            assert want in text, (e["name"], inp["name"], want)


def test_srsvd_artifacts_declare_consistent_ranks(manifest):
    for e in manifest["artifacts"]:
        if e["op"] != "srsvd_scored":
            continue
        assert e["k"] < e["K"] <= e["m"], e["name"]
        assert e["m"] <= e["n"], e["name"]
        u_shape = e["outputs"][0]["shape"]
        assert u_shape == [e["m"], e["k"]]


def test_no_dense_xbar_materialization(manifest):
    """Structural perf audit (EXPERIMENTS.md §Perf L1/L2): the whole point
    of S-RSVD is that the dense centered matrix X - mu 1^T never exists.
    In HLO that would appear as a subtract producing a full f32[m,n]
    tensor; the fused kernels only subtract tile-shaped or (m,K)/(K,n)
    intermediates. Assert no full-size subtract in any srsvd artifact."""
    import re

    for e in manifest["artifacts"]:
        if e["op"] != "srsvd_scored":
            continue
        m, n = e["m"], e["n"]
        with open(os.path.join(ART, e["file"])) as f:
            text = f.read()
        full = f"f32[{m},{n}]"
        for line in text.splitlines():
            if "subtract(" in line and line.lstrip().startswith(
                tuple(f"{p}{full}" for p in ("", "ROOT "))
            ) or re.match(rf"^\s*\S+\s*=\s*{re.escape(full)}.*subtract\(", line):
                raise AssertionError(
                    f"{e['name']}: dense Xbar materialized: {line.strip()}"
                )


def test_artifacts_roundtrip_numerics_in_jax():
    """Execute one lowered artifact via jax itself and compare to direct
    pipeline output — guards against lowering-time divergence."""
    import numpy as np
    import jax
    import jax.numpy as jnp
    from compile import model

    m, n, k, K, q = 40, 200, 5, 10, 0
    r = np.random.default_rng(0)
    x = r.uniform(0, 1, size=(m, n)).astype(np.float32)
    mu = x.mean(axis=1)
    om = r.normal(size=(n, K)).astype(np.float32)

    fn = lambda x, mu, om: model.srsvd_scored(x, mu, om, k=k, q=q)
    direct = fn(jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om))
    compiled = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m,), jnp.float32),
        jax.ShapeDtypeStruct((n, K), jnp.float32),
    ).compile()
    aot = compiled(jnp.asarray(x), jnp.asarray(mu), jnp.asarray(om))
    for d, a in zip(direct, aot):
        np.testing.assert_allclose(np.asarray(d), np.asarray(a), rtol=1e-5, atol=1e-5)
