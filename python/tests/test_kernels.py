"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (including non-tile-multiple, degenerate and
tall/fat extremes) and block sizes; this is the core signal that the
fused rank-1 downdate is exact.
"""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (optional extra)")
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    matmul_rank1,
    shifted_right,
    shifted_left,
    shifted_project,
    row_mean,
    shifted_mse,
)
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


def _assert_close(got, want, tol=None):
    got = np.asarray(got)
    want = np.asarray(want)
    scale = max(1.0, float(np.max(np.abs(want))))
    tol = tol if tol is not None else 5e-5 * scale
    np.testing.assert_allclose(got, want, atol=tol, rtol=5e-4)


dims = st.integers(min_value=1, max_value=90)


@given(m=dims, n=dims, p=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_matmul_rank1_matches_ref(m, n, p, seed):
    r = _rng(seed)
    a = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    b = jnp.asarray(r.normal(size=(n, p)), jnp.float32)
    u = jnp.asarray(r.normal(size=(m,)), jnp.float32)
    v = jnp.asarray(r.normal(size=(p,)), jnp.float32)
    _assert_close(matmul_rank1(a, b, u, v), ref.matmul_rank1_ref(a, b, u, v))


@given(
    m=st.integers(1, 50),
    n=st.integers(1, 70),
    p=st.integers(1, 20),
    bm=st.sampled_from([1, 3, 8, 32, 128]),
    bn=st.sampled_from([2, 16, 64, 256]),
    bp=st.sampled_from([1, 4, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_rank1_block_size_invariance(m, n, p, bm, bn, bp, seed):
    """The result must not depend on the VMEM tiling."""
    r = _rng(seed)
    a = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    b = jnp.asarray(r.normal(size=(n, p)), jnp.float32)
    u = jnp.asarray(r.normal(size=(m,)), jnp.float32)
    v = jnp.asarray(r.normal(size=(p,)), jnp.float32)
    got = matmul_rank1(a, b, u, v, bm=bm, bn=bn, bp=bp)
    _assert_close(got, ref.matmul_rank1_ref(a, b, u, v))


def test_matmul_rank1_zero_rank1_is_plain_matmul():
    r = _rng(0)
    a = jnp.asarray(r.normal(size=(17, 23)), jnp.float32)
    b = jnp.asarray(r.normal(size=(23, 5)), jnp.float32)
    z_u = jnp.zeros((17,), jnp.float32)
    z_v = jnp.zeros((5,), jnp.float32)
    _assert_close(matmul_rank1(a, b, z_u, z_v), a @ b)


@given(m=dims, n=dims, K=st.integers(1, 30), seed=st.integers(0, 2**31 - 1))
def test_shifted_right_never_materializes_but_matches(m, n, K, seed):
    r = _rng(seed)
    x = jnp.asarray(r.uniform(0, 1, size=(m, n)), jnp.float32)
    om = jnp.asarray(r.normal(size=(n, K)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    _assert_close(shifted_right(x, om, mu), ref.shifted_right_ref(x, om, mu))


@given(m=dims, n=dims, K=st.integers(1, 30), seed=st.integers(0, 2**31 - 1))
def test_shifted_left_matches(m, n, K, seed):
    r = _rng(seed)
    x = jnp.asarray(r.uniform(0, 1, size=(m, n)), jnp.float32)
    q = jnp.asarray(r.normal(size=(m, K)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    _assert_close(shifted_left(x, q, mu), ref.shifted_left_ref(x, q, mu))


@given(m=dims, n=dims, K=st.integers(1, 30), seed=st.integers(0, 2**31 - 1))
def test_shifted_project_matches(m, n, K, seed):
    r = _rng(seed)
    x = jnp.asarray(r.uniform(0, 1, size=(m, n)), jnp.float32)
    q = jnp.asarray(r.normal(size=(m, K)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    _assert_close(shifted_project(x, q, mu), ref.shifted_project_ref(x, q, mu))


@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_row_mean_matches(m, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    _assert_close(row_mean(x), ref.row_mean_ref(x))


@given(
    m=dims,
    n=dims,
    bm=st.sampled_from([1, 8, 128]),
    bn=st.sampled_from([4, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_row_mean_block_invariance(m, n, bm, bn, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    _assert_close(row_mean(x, bm=bm, bn=bn), ref.row_mean_ref(x))


@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_shifted_mse_matches(m, n, seed):
    r = _rng(seed)
    x = jnp.asarray(r.uniform(0, 1, size=(m, n)), jnp.float32)
    rec = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    got = shifted_mse(x, mu, rec)
    want = ref.shifted_mse_ref(x, mu, rec)
    np.testing.assert_allclose(float(got), float(want), rtol=2e-3)


def test_shifted_mse_perfect_reconstruction_is_zero():
    r = _rng(3)
    x = jnp.asarray(r.uniform(0, 1, size=(30, 80)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    rec = x - mu[:, None]
    assert float(shifted_mse(x, mu, rec)) < 1e-8


def test_shift_identity_three_forms_consistent():
    """The three shifted products agree with each other via transposes."""
    r = _rng(7)
    x = jnp.asarray(r.uniform(0, 1, size=(25, 60)), jnp.float32)
    q = jnp.asarray(r.normal(size=(25, 6)), jnp.float32)
    mu = jnp.mean(x, axis=1)
    left = shifted_left(x, q, mu)      # (n, K) = Xbar^T Q
    proj = shifted_project(x, q, mu)   # (K, n) = Q^T Xbar
    _assert_close(left.T, proj)
