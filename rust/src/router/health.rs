//! The background health loop: bounded `/healthz` probes on an
//! interval, the N-consecutive-failures mark-down, and re-admission on
//! recovery.
//!
//! Probe state machine (per replica, state lives on
//! [`super::replica::Replica`]):
//!
//! ```text
//!            probe ok (streak := 0)
//!          ┌──────────────┐
//!          ▼              │
//!      [healthy] ──fail──▶ streak += 1 ──streak == N──▶ [unhealthy]
//!          ▲                                                │
//!          └────────────── one probe ok ◀───────────────────┘
//! ```
//!
//! Scheduling is separated from probing so tests can drive both
//! without sleeping: [`ProbeSchedule::due`] decides *when* against the
//! injectable [`Clock`](crate::server::Clock), and
//! [`probe_round`] (exposed as `Router::probe_now`) does one
//! synchronous round *now*. The background thread is just the trivial
//! composition of the two.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use crate::server::Client;

use super::{RouterShared, LOOP_SLICE};

/// Decides when probe rounds are due against a millisecond clock.
///
/// The first round is due one full interval after construction, so a
/// router bound with a far-future interval (or a fake clock pinned at
/// zero) never probes in the background — the seam the loopback tests
/// use to drive every round by hand via `Router::probe_now`.
#[derive(Debug)]
pub struct ProbeSchedule {
    interval_ms: u64,
    next_at_ms: u64,
}

impl ProbeSchedule {
    /// A schedule firing every `interval_ms` (clamped to ≥ 1 ms).
    pub fn new(interval_ms: u64) -> ProbeSchedule {
        let interval_ms = interval_ms.max(1);
        ProbeSchedule { interval_ms, next_at_ms: interval_ms }
    }

    /// True when a round is due at `now_ms`; advances the schedule one
    /// interval past `now_ms` when it is (late ticks don't bunch up).
    pub fn due(&mut self, now_ms: u64) -> bool {
        if now_ms >= self.next_at_ms {
            self.next_at_ms = now_ms.saturating_add(self.interval_ms);
            true
        } else {
            false
        }
    }
}

/// One synchronous probe round over every replica: a fresh connection
/// (bounded by `connect_timeout`) and a `GET /healthz` (bounded by
/// `probe_timeout`) each. Success resets the failure streak and
/// re-admits a down replica; failure ticks `probe_failures` and marks
/// the replica unhealthy once the streak reaches `unhealthy_after`.
pub(crate) fn probe_round(shared: &RouterShared) {
    for replica in &shared.replicas {
        // Probes stay fail-fast (no retry policy): a probe *is* the
        // failure detector, and retries would blur mark-down timing.
        let probed = Client::with_policy(
            &replica.addr,
            Some(shared.connect_timeout),
            shared.probe_timeout,
            crate::util::retry::RetryPolicy::none(),
        )
        .and_then(|mut c| c.health());
        match probed {
            Ok(()) => {
                if replica.record_success() {
                    crate::log_info!("router: replica {} re-admitted", replica.addr);
                }
            }
            Err(e) => {
                shared.metrics.probe_failures.fetch_add(1, Ordering::Relaxed);
                if replica.record_failure(shared.unhealthy_after) {
                    crate::log_warn!(
                        "router: replica {} marked unhealthy after {} consecutive failures ({e})",
                        replica.addr,
                        shared.unhealthy_after
                    );
                }
            }
        }
    }
}

/// The background loop: sleep in short slices (so shutdown is honored
/// promptly), probing whenever the schedule says a round is due.
pub(crate) fn health_loop(shared: Arc<RouterShared>) {
    let mut schedule = ProbeSchedule::new(shared.probe_interval_ms);
    let slice = Duration::from_millis(shared.probe_interval_ms.clamp(10, LOOP_SLICE));
    while !shared.shutdown.load(Ordering::SeqCst) {
        if schedule.due(shared.clock.now_ms()) {
            probe_round(&shared);
        }
        std::thread::sleep(slice);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_round_waits_one_interval_then_fires_per_interval() {
        let mut s = ProbeSchedule::new(100);
        assert!(!s.due(0), "no round before the first interval elapses");
        assert!(!s.due(99));
        assert!(s.due(100), "first round due at one interval");
        assert!(!s.due(150), "not due again mid-interval");
        assert!(s.due(200));
    }

    #[test]
    fn late_ticks_do_not_bunch_up() {
        let mut s = ProbeSchedule::new(100);
        // The clock jumps far past several missed rounds: exactly one
        // fires, and the next is a full interval out from *now*.
        assert!(s.due(1_000));
        assert!(!s.due(1_050));
        assert!(s.due(1_100));
    }

    #[test]
    fn far_future_interval_never_fires_at_time_zero() {
        let mut s = ProbeSchedule::new(u64::MAX / 2);
        for now in [0u64, 1, 1_000_000] {
            assert!(!s.due(now));
        }
    }
}
