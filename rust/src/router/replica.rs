//! Replica bookkeeping: rendezvous placement, health flags, and the
//! replica tag carried inside router-issued job ids.
//!
//! Placement is highest-random-weight (rendezvous) hashing: every
//! replica scores each canonical spec hash independently of the other
//! replicas, so the winner — and the full failover order behind it —
//! depends only on `(spec hash, replica address)`. Reordering the
//! configured replica list, or adding/removing a sibling, never
//! reshuffles the specs the surviving replicas already own, which is
//! exactly what keeps their result caches warm (pinned as a property in
//! `tests/props.rs`).

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};

use crate::server::cache;

/// Low bits of a router-issued job id reserved for the replica tag.
///
/// A routed id is `upstream_id << TAG_BITS | replica_index`, so the
/// router can send `GET`/`DELETE /v1/jobs/{id}` straight to the replica
/// that owns the job. Ids travel as JSON numbers (exact below 2^53),
/// which still leaves upstream counters 2^45 submissions of headroom.
pub const TAG_BITS: u32 = 8;

/// Most replicas one router can front — the tag must fit [`TAG_BITS`].
pub const MAX_REPLICAS: usize = 1 << TAG_BITS;

/// Tag `upstream` (a replica-local job id) with the replica's index.
pub fn encode_job_id(upstream: u64, replica: usize) -> u64 {
    debug_assert!(replica < MAX_REPLICAS);
    (upstream << TAG_BITS) | replica as u64
}

/// Split a router-issued id into `(upstream_id, replica_index)`.
pub fn decode_job_id(routed: u64) -> (u64, usize) {
    (routed >> TAG_BITS, (routed & (MAX_REPLICAS as u64 - 1)) as usize)
}

/// One backend coordinator replica plus its probe-driven health state.
///
/// The state machine is deliberately asymmetric: `unhealthy_after`
/// *consecutive* failures mark a replica down (one flaky probe must not
/// eject a replica mid-burst), while a single success re-admits it (a
/// recovered replica should take traffic on the next round, not after
/// N confirmations).
#[derive(Debug)]
pub struct Replica {
    /// `host:port` of the replica's HTTP server.
    pub addr: String,
    /// Position in the configured replica list — the tag encoded into
    /// router-issued job ids ([`encode_job_id`]).
    pub index: usize,
    /// Rendezvous identity: a stable hash of the address, mixed with
    /// each spec hash by [`Replica::score`].
    seed: u64,
    healthy: AtomicBool,
    consecutive_failures: AtomicU32,
}

impl Replica {
    /// A replica at `addr`, tagged `index`, starting healthy (a router
    /// must be able to route before its first probe round completes).
    pub fn new(index: usize, addr: &str) -> Replica {
        Replica {
            addr: addr.to_string(),
            index,
            seed: cache::content_hash(addr.as_bytes()),
            healthy: AtomicBool::new(true),
            consecutive_failures: AtomicU32::new(0),
        }
    }

    /// Whether the health loop currently considers this replica usable.
    pub fn is_healthy(&self) -> bool {
        self.healthy.load(Ordering::Relaxed)
    }

    /// Record a failed probe or connect attempt. Returns `true` when
    /// this call crossed the `unhealthy_after` threshold and flipped
    /// the replica from healthy to unhealthy.
    pub fn record_failure(&self, unhealthy_after: u32) -> bool {
        let n = self.consecutive_failures.fetch_add(1, Ordering::Relaxed) + 1;
        n >= unhealthy_after.max(1) && self.healthy.swap(false, Ordering::Relaxed)
    }

    /// Record a successful probe or exchange. Returns `true` when this
    /// call re-admitted a previously unhealthy replica.
    pub fn record_success(&self) -> bool {
        self.consecutive_failures.store(0, Ordering::Relaxed);
        !self.healthy.swap(true, Ordering::Relaxed)
    }

    /// Rendezvous score of this replica for a canonical spec hash:
    /// the shared SplitMix64-style mixer ([`cache::content_hash`]) over
    /// the spec hash concatenated with the address hash. Depends only
    /// on the pair, never on the rest of the replica set.
    pub fn score(&self, spec_hash: u64) -> u64 {
        let mut key = [0u8; 16];
        key[..8].copy_from_slice(&spec_hash.to_le_bytes());
        key[8..].copy_from_slice(&self.seed.to_le_bytes());
        cache::content_hash(&key)
    }
}

/// Replica indices in descending rendezvous-score order for
/// `spec_hash`: element 0 is the owner, the rest the failover order.
/// Ties (score collisions) break on the address so the order stays
/// permutation-stable.
pub fn rendezvous_order(spec_hash: u64, replicas: &[Replica]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..replicas.len()).collect();
    order.sort_by(|&a, &b| {
        let (ra, rb) = (&replicas[a], &replicas[b]);
        rb.score(spec_hash)
            .cmp(&ra.score(spec_hash))
            .then_with(|| ra.addr.cmp(&rb.addr))
    });
    order
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_id_tag_round_trips() {
        for upstream in [0u64, 1, 7, 1 << 20, (1 << 45) - 1] {
            for replica in [0usize, 1, 5, MAX_REPLICAS - 1] {
                let routed = encode_job_id(upstream, replica);
                assert_eq!(decode_job_id(routed), (upstream, replica));
            }
        }
    }

    #[test]
    fn three_failures_mark_down_and_one_success_readmits() {
        let r = Replica::new(0, "127.0.0.1:7878");
        assert!(r.is_healthy());
        assert!(!r.record_failure(3));
        assert!(!r.record_failure(3));
        assert!(r.record_failure(3), "third consecutive failure must flip");
        assert!(!r.is_healthy());
        assert!(!r.record_failure(3), "already down: no second flip");
        assert!(r.record_success(), "one success must re-admit");
        assert!(r.is_healthy());
        assert!(!r.record_success(), "already up: no second flip");
        // The success reset the streak: marking down takes 3 again.
        assert!(!r.record_failure(3));
        assert!(!r.record_failure(3));
        assert!(r.record_failure(3));
    }

    #[test]
    fn rendezvous_order_ignores_list_permutation() {
        let addrs = ["10.0.0.1:7878", "10.0.0.2:7878", "10.0.0.3:7878", "10.0.0.4:7878"];
        let set_a: Vec<Replica> =
            addrs.iter().enumerate().map(|(i, a)| Replica::new(i, a)).collect();
        let permuted = [addrs[2], addrs[0], addrs[3], addrs[1]];
        let set_b: Vec<Replica> =
            permuted.iter().enumerate().map(|(i, a)| Replica::new(i, a)).collect();
        for hash in [0u64, 1, 42, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            let by_addr_a: Vec<&str> = rendezvous_order(hash, &set_a)
                .into_iter()
                .map(|i| set_a[i].addr.as_str())
                .collect();
            let by_addr_b: Vec<&str> = rendezvous_order(hash, &set_b)
                .into_iter()
                .map(|i| set_b[i].addr.as_str())
                .collect();
            assert_eq!(by_addr_a, by_addr_b, "placement must not depend on list order");
        }
    }
}
