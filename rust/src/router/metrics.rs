//! Router-local counters, rendered into the router's `GET /metrics`
//! alongside the per-replica snapshots it aggregates.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::Json;

/// Counters the routing tier maintains itself (replica-side counters
/// come from proxying each replica's own `/metrics`). All plain
/// `Relaxed` atomics: monotone counts, no cross-field invariants.
#[derive(Debug, Default)]
pub struct RouterMetrics {
    /// Requests successfully forwarded to a replica (any method).
    pub routed: AtomicU64,
    /// Submit attempts moved past a dead, unreachable, or saturated
    /// candidate to the next one in placement order.
    pub failovers: AtomicU64,
    /// Idempotent `GET` forwards retried on a fresh connection after a
    /// transport failure (`POST`s are never retried — see the module
    /// docs on the double-run risk).
    pub retries: AtomicU64,
    /// Failed health probes (bounded connect, transport, or non-200).
    pub probe_failures: AtomicU64,
}

impl RouterMetrics {
    /// The `"router"` object of the aggregated `/metrics` response.
    /// `replicas_healthy` is a gauge computed from the live replica
    /// set at render time, not stored here.
    pub fn to_json(&self, replicas_healthy: u64, replicas: u64) -> Json {
        let c = |a: &AtomicU64| Json::num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("routed", c(&self.routed)),
            ("failovers", c(&self.failovers)),
            ("retries", c(&self.retries)),
            ("probe_failures", c(&self.probe_failures)),
            ("replicas_healthy", Json::num(replicas_healthy as f64)),
            ("replicas", Json::num(replicas as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_json_carries_every_counter() {
        let m = RouterMetrics::default();
        m.routed.store(7, Ordering::Relaxed);
        m.failovers.store(2, Ordering::Relaxed);
        m.probe_failures.store(5, Ordering::Relaxed);
        let j = m.to_json(3, 4);
        assert_eq!(j.get("routed").unwrap().as_u64().unwrap(), 7);
        assert_eq!(j.get("failovers").unwrap().as_u64().unwrap(), 2);
        assert_eq!(j.get("retries").unwrap().as_u64().unwrap(), 0);
        assert_eq!(j.get("probe_failures").unwrap().as_u64().unwrap(), 5);
        assert_eq!(j.get("replicas_healthy").unwrap().as_u64().unwrap(), 3);
        assert_eq!(j.get("replicas").unwrap().as_u64().unwrap(), 4);
    }
}
