//! The routing tier: a sharding reverse proxy in front of coordinator
//! replicas — `srsvd route --listen ADDR --replicas a,b,c`.
//!
//! One coordinator process bounds serve throughput; the paper's
//! workload (many independent large-matrix PCA jobs, Halko et al.,
//! arXiv 1007.5510) scales horizontally instead: N replica processes
//! (`srsvd serve`) behind one router. The router speaks the same
//! HTTP/1.1 wire protocol on the front ([`crate::server::http`]) and
//! fans out over the blocking client ([`crate::server::Client`]) on
//! the back, so clients, replicas, and router compose without any new
//! dependency.
//!
//! ## Placement
//!
//! * **Cacheable specs** (everything with a canonical spec hash,
//!   [`cache::spec_hash`]) are sharded by **rendezvous hashing**
//!   ([`replica::rendezvous_order`]): identical specs always land on
//!   the same replica, so its content-addressed result cache replays
//!   warm submits byte-for-byte and sibling caches aren't polluted
//!   with duplicates.
//! * **Uncacheable specs** (server-side `file` inputs, whose cache key
//!   is `None`) go **round-robin** over healthy replicas.
//!
//! ## Failover rules
//!
//! A submit to a dead or saturated replica moves to the next candidate
//! in rendezvous (or ring) order, under the same safety rule the
//! client uses: a **bounded connect failure** and a definitive **503**
//! are provably pre-acceptance, so trying the next replica cannot
//! double-run the job; a transport failure *after* the request was
//! written is ambiguous and surfaces as `502 Bad Gateway` instead of a
//! blind resubmit. Idempotent routed `GET`s retry on fresh connections
//! under the `[retry]` policy ([`RouterConfig::retry`]); `POST`s never
//! do. When every candidate is dead or saturated, the `503` carries a
//! `Retry-After` hint sized to the probe interval.
//!
//! ## Job ids
//!
//! Router-issued ids carry the owning replica in their low
//! [`replica::TAG_BITS`] bits (`upstream_id << 8 | replica_index`), so
//! blocking `GET /v1/jobs/{id}` and `DELETE /v1/jobs/{id}` route
//! straight to the replica that owns the job — no shared state between
//! router and replicas beyond the id itself.
//!
//! ## Endpoints
//!
//! | Method | Path | Meaning |
//! |--------|------|---------|
//! | `POST` | `/v1/jobs` | Parse + hash the spec, forward to the owner (failing over as above); `202` bodies come back with the router-tagged id. |
//! | `GET` | `/v1/jobs/{id}` | Proxied to the replica tagged in the id (query string preserved). |
//! | `DELETE` | `/v1/jobs/{id}` | Proxied to the replica tagged in the id. |
//! | `GET` | `/metrics` | Router counters (`routed`, `failovers`, `retries`, `probe_failures`, `replicas_healthy`) plus each replica's own `/metrics` snapshot. |
//! | `GET` | `/healthz` | Router liveness. |
//! | `GET` | `/readyz` | `200` while ≥ 1 replica is healthy, else `503`. |
//!
//! The health loop ([`health`]) probes every replica's `/healthz` on
//! `probe_interval_ms`, marks a replica unhealthy after
//! `unhealthy_after` consecutive failures, and re-admits it on the
//! first success. Probe scheduling runs against the injectable
//! [`Clock`], and [`Router::probe_now`] runs one round synchronously —
//! the loopback tests drive mark-down and re-admission without
//! sleeping.

pub mod health;
pub mod metrics;
pub mod replica;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::linalg::stream::StreamConfig;
use crate::server::http::{self, HttpError, HttpLimits, ReadOutcome, Request, Response};
use crate::server::{cache, protocol, Client, Clock, MonotonicClock};
use crate::util::json::Json;
use crate::util::{Error, Result};

use self::metrics::RouterMetrics;
use self::replica::Replica;

/// How often idle front-end connections poll for data / shutdown.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Longest background-loop sleep slice, ms (shutdown latency bound).
pub(crate) const LOOP_SLICE: u64 = 100;

/// Routing-tier configuration — the `[router]` config section.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Front-end listen address (`host:port`; port 0 picks a free one).
    pub listen: String,
    /// Replica addresses (`host:port` of each `srsvd serve`). Order
    /// fixes each replica's id tag; placement itself is order-free.
    pub replicas: Vec<String>,
    /// Front-end connection worker threads.
    pub workers: usize,
    /// Maximum accepted request body, bytes (`[router] max_body_mb`).
    pub max_body_bytes: usize,
    /// Front-end per-request timeout, seconds (read + keep-alive idle
    /// limit). Keep it at or above the replicas' `request_timeout_s`:
    /// proxied blocking `GET`s are given this plus a fixed grace.
    pub request_timeout_s: u64,
    /// Bound on every back-end TCP connect, milliseconds — probes,
    /// forwards, and failover decisions all wait at most this long on
    /// a dead replica.
    pub connect_timeout_ms: u64,
    /// Health-probe period, milliseconds.
    pub probe_interval_ms: u64,
    /// Per-probe IO timeout, milliseconds.
    pub probe_timeout_ms: u64,
    /// Consecutive probe failures before a replica is marked
    /// unhealthy (one success re-admits it).
    pub unhealthy_after: u32,
    /// Retry/backoff policy for proxied idempotent `GET`s toward a
    /// job's owning replica (the `[retry]` config section). Health
    /// probes deliberately stay fail-fast — a probe *is* the failure
    /// detector.
    pub retry: crate::util::retry::RetryPolicy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            listen: "127.0.0.1:7979".into(),
            replicas: Vec::new(),
            workers: 4,
            max_body_bytes: 64 << 20,
            request_timeout_s: 30,
            connect_timeout_ms: 1_000,
            probe_interval_ms: 1_000,
            probe_timeout_ms: 500,
            unhealthy_after: 3,
            retry: crate::util::retry::RetryPolicy::default(),
        }
    }
}

/// State shared by the accept loop, connection workers, and the
/// health loop.
pub(crate) struct RouterShared {
    pub(crate) replicas: Vec<Replica>,
    /// Ring cursor for uncacheable (round-robin) submits.
    rr_cursor: AtomicUsize,
    pub(crate) metrics: RouterMetrics,
    pub(crate) shutdown: AtomicBool,
    limits: HttpLimits,
    /// Front-end request/idle timeout.
    request_timeout: Duration,
    /// Back-end connect bound (probes and forwards alike).
    pub(crate) connect_timeout: Duration,
    /// Back-end IO timeout for forwarded requests; sized
    /// `request_timeout` + grace so a replica answering a blocking
    /// `GET` at *its* request timeout is never cut off mid-wait.
    upstream_timeout: Duration,
    /// Back-end IO timeout for health probes and metrics scrapes.
    pub(crate) probe_timeout: Duration,
    pub(crate) probe_interval_ms: u64,
    pub(crate) unhealthy_after: u32,
    pub(crate) clock: Arc<dyn Clock>,
    stream_defaults: StreamConfig,
    /// Retry policy for proxied idempotent `GET`s (see
    /// [`RouterConfig::retry`]).
    retry: crate::util::retry::RetryPolicy,
}

impl RouterShared {
    fn healthy_count(&self) -> usize {
        self.replicas.iter().filter(|r| r.is_healthy()).count()
    }

    /// Ring order for uncacheable specs: start at the cursor, wrap
    /// once around. Element 0 is the primary; the rest is the
    /// failover order, same as a rendezvous ranking.
    fn round_robin_order(&self) -> Vec<usize> {
        let n = self.replicas.len();
        let start = self.rr_cursor.fetch_add(1, Ordering::Relaxed) % n;
        (0..n).map(|k| (start + k) % n).collect()
    }
}

/// A running routing tier bound to a front-end socket.
pub struct Router {
    local_addr: SocketAddr,
    shared: Arc<RouterShared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
    health_handle: Option<std::thread::JoinHandle<()>>,
}

impl Router {
    /// Bind `config.listen` and start the accept loop, connection
    /// workers, and the background health loop. `stream_defaults`
    /// only affects spec *parsing* (cacheability detection); block
    /// policy on the replicas is theirs.
    pub fn bind(config: &RouterConfig, stream_defaults: StreamConfig) -> Result<Router> {
        Router::bind_with_clock(config, stream_defaults, Arc::new(MonotonicClock::default()))
    }

    /// [`Router::bind`] with an explicit [`Clock`] driving the probe
    /// schedule — the seam the tests use: a fake clock plus a
    /// far-future `probe_interval_ms` parks the background loop, and
    /// [`Router::probe_now`] drives every round by hand.
    pub fn bind_with_clock(
        config: &RouterConfig,
        stream_defaults: StreamConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Router> {
        crate::util::logging::init();
        // Chaos entry point: arm fail-points from SRSVD_FAULTS (no-op
        // when unset, hard error on a malformed spec).
        crate::util::faults::init_from_env()?;
        crate::ensure!(!config.replicas.is_empty(), "router needs at least one replica");
        crate::ensure!(
            config.replicas.len() <= replica::MAX_REPLICAS,
            "router supports at most {} replicas (the id tag is {} bits)",
            replica::MAX_REPLICAS,
            replica::TAG_BITS
        );
        let listener = TcpListener::bind(config.listen.as_str())
            .map_err(|e| Error::Service(format!("bind {}: {e}", config.listen)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::Service(format!("local_addr: {e}")))?;
        let shared = Arc::new(RouterShared {
            replicas: config
                .replicas
                .iter()
                .enumerate()
                .map(|(i, a)| Replica::new(i, a))
                .collect(),
            rr_cursor: AtomicUsize::new(0),
            metrics: RouterMetrics::default(),
            shutdown: AtomicBool::new(false),
            limits: HttpLimits {
                max_body_bytes: config.max_body_bytes,
                ..Default::default()
            },
            request_timeout: Duration::from_secs(config.request_timeout_s.max(1)),
            connect_timeout: Duration::from_millis(config.connect_timeout_ms.max(1)),
            upstream_timeout: Duration::from_secs(config.request_timeout_s.max(1) + 15),
            probe_timeout: Duration::from_millis(config.probe_timeout_ms.max(1)),
            probe_interval_ms: config.probe_interval_ms.max(1),
            unhealthy_after: config.unhealthy_after.max(1),
            clock,
            stream_defaults,
            retry: config.retry,
        });

        let workers = config.workers.max(1);
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(workers * 2);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut worker_handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&conn_rx);
            let sh = Arc::clone(&shared);
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("srsvd-route-worker-{w}"))
                    .spawn(move || worker_loop(&rx, &sh))
                    .map_err(|e| Error::Service(format!("spawn route worker: {e}")))?,
            );
        }
        let sh = Arc::clone(&shared);
        let health_handle = std::thread::Builder::new()
            .name("srsvd-route-health".into())
            .spawn(move || health::health_loop(sh))
            .map_err(|e| Error::Service(format!("spawn health loop: {e}")))?;
        let sh = Arc::clone(&shared);
        let accept_handle = std::thread::Builder::new()
            .name("srsvd-route-accept".into())
            .spawn(move || accept_loop(listener, conn_tx, sh))
            .map_err(|e| Error::Service(format!("spawn accept loop: {e}")))?;

        crate::log_info!(
            "router: listening on http://{local_addr} in front of {} replica(s)",
            config.replicas.len()
        );
        Ok(Router {
            local_addr,
            shared,
            accept_handle: Some(accept_handle),
            worker_handles,
            health_handle: Some(health_handle),
        })
    }

    /// The bound front-end address (actual port when `listen` used 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Run one synchronous probe round over every replica, exactly as
    /// the background health loop would. Test seam: combined with
    /// [`Router::bind_with_clock`] and a far-future interval it makes
    /// mark-down/re-admission fully deterministic, zero sleeps.
    pub fn probe_now(&self) {
        health::probe_round(&self.shared);
    }

    /// Graceful shutdown: stop accepting, finish in-flight exchanges,
    /// stop the health loop, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    /// Block until the router stops (another thread calling shutdown,
    /// or a fatal listener error). `srsvd route` runs on this.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        self.join_rest();
    }

    fn shutdown_inner(&mut self) {
        if self.accept_handle.is_none() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocked accept() with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        self.join_rest();
    }

    fn join_rest(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // The accept thread owned the connection sender; its exit
        // closed the channel, so workers drain what was queued.
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.health_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, tx: SyncSender<TcpStream>, shared: Arc<RouterShared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => {
                // Same EMFILE back-off as the server's accept loop.
                crate::log_warn!("router accept: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn worker_loop(rx: &Arc<Mutex<Receiver<TcpStream>>>, shared: &Arc<RouterShared>) {
    loop {
        let stream = {
            let guard = rx.lock().expect("connection queue mutex");
            guard.recv()
        };
        let Ok(stream) = stream else { return };
        handle_connection(shared, stream);
    }
}

/// Serve one front-end connection: the same keep-alive loop as the
/// server's (`idle_wait` between requests honors shutdown; one hard
/// deadline per request read), minus the TTL reaper — the router
/// parks nothing.
fn handle_connection(shared: &RouterShared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let _ = stream.set_write_timeout(Some(shared.request_timeout));
    loop {
        let mut probe = [0u8; 1];
        let idle = http::idle_wait(
            &mut || stream.peek(&mut probe),
            IDLE_POLL,
            shared.request_timeout,
            &mut || shared.shutdown.load(Ordering::SeqCst),
        );
        if idle == http::IdleOutcome::Close {
            break;
        }
        let deadline = Some(Instant::now() + shared.request_timeout);
        match http::read_request(&mut stream, &shared.limits, deadline) {
            Ok(ReadOutcome::Closed) => break,
            Ok(ReadOutcome::Request(req)) => {
                let response = route_request(shared, &req);
                let keep = req.keep_alive && !shared.shutdown.load(Ordering::SeqCst);
                if response.write_to(&mut stream, keep).is_err() || !keep {
                    break;
                }
            }
            Err(HttpError::Respond { status, msg }) => {
                let _ = Response::error(status, &msg).write_to(&mut stream, false);
                break;
            }
            Err(HttpError::Drop(_)) => break,
        }
    }
}

fn route_request(shared: &RouterShared, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            Response::json(200, &Json::obj(vec![("status", Json::str("ok"))]))
        }
        ("GET", "/readyz") => readyz(shared),
        ("GET", "/metrics") => aggregate_metrics(shared),
        ("POST", "/v1/jobs") => submit(shared, req),
        ("GET" | "DELETE", path) if path.strip_prefix("/v1/jobs/").is_some() => {
            proxy_job(shared, req)
        }
        (_, "/healthz" | "/readyz" | "/metrics" | "/v1/jobs") => {
            Response::error(405, "method not allowed")
        }
        (_, path) if path.strip_prefix("/v1/jobs/").is_some() => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, "no such endpoint"),
    }
}

/// Router readiness: at least one replica must be healthy to take a
/// submit at all.
fn readyz(shared: &RouterShared) -> Response {
    let healthy = shared.healthy_count();
    let status = if healthy == 0 { 503 } else { 200 };
    let state = if healthy == 0 { "no healthy replicas" } else { "ready" };
    let response = Response::json(
        status,
        &Json::obj(vec![
            ("status", Json::str(state)),
            ("replicas_healthy", Json::num(healthy as f64)),
            ("replicas", Json::num(shared.replicas.len() as f64)),
        ]),
    );
    if status == 503 {
        // The soonest a dead fleet can change state is the next probe
        // round; hint clients to come back then.
        response.with_retry_after((shared.probe_interval_ms / 1000).max(1))
    } else {
        response
    }
}

/// `GET /metrics`: router-local counters plus each replica's own
/// snapshot (scraped live under the probe timeouts; an unreachable
/// replica contributes `null`).
fn aggregate_metrics(shared: &RouterShared) -> Response {
    let mut entries = Vec::with_capacity(shared.replicas.len());
    for r in &shared.replicas {
        let snapshot = Client::with_policy(
            &r.addr,
            Some(shared.connect_timeout),
            shared.probe_timeout,
            crate::util::retry::RetryPolicy::none(),
        )
        .and_then(|mut c| c.metrics())
        .unwrap_or(Json::Null);
        entries.push(Json::obj(vec![
            ("addr", Json::str(&r.addr)),
            ("healthy", Json::Bool(r.is_healthy())),
            ("metrics", snapshot),
        ]));
    }
    let healthy = shared.healthy_count() as u64;
    Response::json(
        200,
        &Json::obj(vec![
            ("router", shared.metrics.to_json(healthy, shared.replicas.len() as u64)),
            ("replicas", Json::arr(entries)),
        ]),
    )
}

/// `POST /v1/jobs`: parse the spec (a malformed submit 400s here
/// without touching any replica), pick the placement order, and
/// forward with failover.
fn submit(shared: &RouterShared, req: &Request) -> Response {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return Response::error(400, "request body must be UTF-8 JSON");
    };
    let parsed =
        Json::parse(text).and_then(|j| protocol::parse_submit(&j, &shared.stream_defaults));
    let sub = match parsed {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e}")),
    };
    let order = match cache::spec_hash(&sub.spec) {
        // Cacheable: rendezvous placement, so an identical spec always
        // lands on the same replica's result cache.
        Some(hash) => replica::rendezvous_order(hash, &shared.replicas),
        // Uncacheable (server-side file inputs): spread round-robin.
        None => shared.round_robin_order(),
    };
    forward_submit(shared, &req.body, &order)
}

/// Forward a submit body down the candidate order: healthy replicas in
/// placement order first, marked-down ones as a last resort. Failover
/// only on provably pre-acceptance failures (bounded connect error, or
/// a definitive 503); an ambiguous mid-exchange failure is `502`, never
/// a resubmit.
fn forward_submit(shared: &RouterShared, body: &[u8], order: &[usize]) -> Response {
    let primary = order.first().copied();
    let mut candidates: Vec<usize> =
        order.iter().copied().filter(|&i| shared.replicas[i].is_healthy()).collect();
    candidates.extend(order.iter().copied().filter(|&i| !shared.replicas[i].is_healthy()));
    let mut last = String::from("no replicas configured");
    for i in candidates {
        let r = &shared.replicas[i];
        if Some(i) != primary {
            // Reaching a non-primary candidate means the preferred
            // owner was dead, marked down, or saturated.
            shared.metrics.failovers.fetch_add(1, Ordering::Relaxed);
        }
        // The failover loop *is* the router's retry mechanism, so the
        // inner client stays single-shot; a `router.connect` fail-point
        // injects dead-replica behaviour without needing a dead socket.
        let connected = crate::util::faults::check("router.connect")
            .map_err(Error::from)
            .and_then(|()| {
                Client::with_policy(
                    &r.addr,
                    Some(shared.connect_timeout),
                    shared.upstream_timeout,
                    crate::util::retry::RetryPolicy::none(),
                )
            });
        let mut client = match connected {
            Ok(c) => c,
            Err(e) => {
                // The replica never saw the submit; moving on is
                // safe, and the failed connect doubles as a probe.
                if r.record_failure(shared.unhealthy_after) {
                    crate::log_warn!("router: replica {} marked unhealthy (connect failed)", r.addr);
                }
                last = format!("{e}");
                continue;
            }
        };
        match client.request_raw("POST", "/v1/jobs", Some(body)) {
            // A 503 is a definitive "not accepted": shed to the next
            // candidate. The replica answered, so it is alive.
            Ok((503, _)) => {
                r.record_success();
                last = format!("replica {} is saturated (503)", r.addr);
            }
            Ok((status, bytes)) => {
                r.record_success();
                shared.metrics.routed.fetch_add(1, Ordering::Relaxed);
                return tag_submit_response(status, bytes, i, &r.addr);
            }
            // The request left the socket but the exchange died: the
            // replica may have accepted the job, so a blind resubmit
            // could run it twice. Surface the ambiguity.
            Err(e) => return Response::error(502, &format!("replica {}: {e}", r.addr)),
        }
    }
    // Every candidate was dead or saturated; the soonest that changes
    // is the next health-probe round.
    Response::error(503, &last).with_retry_after((shared.probe_interval_ms / 1000).max(1))
}

/// Tag the id inside a replica's `202` body with the replica index so
/// follow-up `GET`/`DELETE`s route back to the owner. Every other
/// status passes through byte-identical — which is what keeps cached
/// `200` replays exact across the router.
fn tag_submit_response(status: u16, bytes: Vec<u8>, index: usize, addr: &str) -> Response {
    if status != 202 {
        return Response::json_bytes(status, bytes);
    }
    let tagged = std::str::from_utf8(&bytes).ok().and_then(|text| {
        let mut j = Json::parse(text).ok()?;
        let upstream = j.get("id").ok()?.as_u64().ok()?;
        let routed = replica::encode_job_id(upstream, index);
        let Json::Obj(map) = &mut j else { return None };
        map.insert("id".to_string(), Json::num(routed as f64));
        Some(j.to_string().into_bytes())
    });
    match tagged {
        Some(body) => Response::json_bytes(202, body),
        None => Response::error(502, &format!("replica {addr}: malformed 202 body")),
    }
}

/// `GET`/`DELETE /v1/jobs/{id}`: decode the replica tag and proxy to
/// the owner. Idempotent `GET`s retry on fresh connections under the
/// router's [`RetryPolicy`](crate::util::retry::RetryPolicy); the job
/// has exactly one owner, so there is no failover here — an
/// unreachable owner is `502`.
fn proxy_job(shared: &RouterShared, req: &Request) -> Response {
    let tail = req.path.strip_prefix("/v1/jobs/").expect("caller matched the prefix");
    let Ok(routed_id) = tail.parse::<u64>() else {
        return Response::error(400, "job id must be an unsigned integer");
    };
    let (upstream, tag) = replica::decode_job_id(routed_id);
    let Some(r) = shared.replicas.get(tag) else {
        return Response::error(404, &format!("unknown job {routed_id}"));
    };
    let mut path = format!("/v1/jobs/{upstream}");
    if !req.query.is_empty() {
        path.push('?');
        path.push_str(&req.query);
    }
    // A blocking GET may legitimately hold the line for the client's
    // requested wait; give the upstream socket that long plus grace.
    let mut io_timeout = shared.upstream_timeout;
    if let Some(wait_s) = requested_wait_s(&req.query) {
        io_timeout = io_timeout.max(Duration::from_secs_f64(wait_s) + Duration::from_secs(15));
    }
    // The router owns the retry loop, so the inner client is
    // single-shot; backoff is seeded by the job id for determinism.
    let mut attempt = 0;
    loop {
        attempt += 1;
        let outcome = Client::with_policy(
            &r.addr,
            Some(shared.connect_timeout),
            io_timeout,
            crate::util::retry::RetryPolicy::none(),
        )
        .and_then(|mut c| c.request_raw(&req.method, &path, None));
        match outcome {
            Ok((status, bytes)) => {
                r.record_success();
                shared.metrics.routed.fetch_add(1, Ordering::Relaxed);
                // A 202 ("still running") body carries the upstream id;
                // re-tag it so the client polls through the router.
                return tag_submit_response(status, bytes, tag, &r.addr);
            }
            Err(e) => {
                if req.method == "GET" && shared.retry.allows(attempt) {
                    shared.metrics.retries.fetch_add(1, Ordering::Relaxed);
                    shared.retry.sleep_backoff(attempt, routed_id ^ 0x9E37_79B9);
                    continue;
                }
                return Response::error(502, &format!("replica {}: {e}", r.addr));
            }
        }
    }
}

/// `timeout_s` out of a raw query string, when present and sane.
fn requested_wait_s(query: &str) -> Option<f64> {
    let v: f64 = query
        .split('&')
        .find_map(|kv| kv.strip_prefix("timeout_s="))?
        .parse()
        .ok()?;
    (v.is_finite() && (0.0..=86_400.0).contains(&v)).then_some(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requested_wait_parses_sane_values_only() {
        assert_eq!(requested_wait_s("timeout_s=2.5"), Some(2.5));
        assert_eq!(requested_wait_s("foo=1&timeout_s=0"), Some(0.0));
        assert_eq!(requested_wait_s(""), None);
        assert_eq!(requested_wait_s("timeout_s=-1"), None);
        assert_eq!(requested_wait_s("timeout_s=1e9"), None);
        assert_eq!(requested_wait_s("timeout_s=nope"), None);
    }

    #[test]
    fn router_refuses_empty_and_oversized_replica_sets() {
        let cfg = RouterConfig { listen: "127.0.0.1:0".into(), ..Default::default() };
        assert!(Router::bind(&cfg, StreamConfig::default()).is_err());
        let cfg = RouterConfig {
            listen: "127.0.0.1:0".into(),
            replicas: (0..=replica::MAX_REPLICAS)
                .map(|i| format!("127.0.0.1:{}", 10_000 + i))
                .collect(),
            ..Default::default()
        };
        assert!(Router::bind(&cfg, StreamConfig::default()).is_err());
    }
}
