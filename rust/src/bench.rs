//! Mini benchmark harness (criterion is unavailable offline).
//!
//! Usage pattern in `rust/benches/*.rs` (all `harness = false`):
//!
//! ```no_run
//! use srsvd::bench::{Bencher, Table};
//! let mut b = Bencher::from_env();
//! let stats = b.run("matmul 256", || { /* work */ });
//! println!("{stats}");
//! ```
//!
//! Provides warmup, adaptive iteration counts, mean/median/p95 and a
//! fixed-width table printer whose rows mirror the paper's tables.

use std::time::Instant;

use crate::stats::{mean, median, quantile, std_dev};

/// Timing statistics for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Case name as passed to [`Bencher::run`].
    pub name: String,
    /// Measured iterations (after warmup).
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds per iteration.
    pub median_s: f64,
    /// 95th-percentile seconds per iteration.
    pub p95_s: f64,
    /// Standard deviation of the per-iteration seconds.
    pub std_s: f64,
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10} {:>12} {:>12} {:>12}  (n={})",
            self.name,
            crate::util::timer::fmt_duration(self.mean_s),
            crate::util::timer::fmt_duration(self.median_s),
            crate::util::timer::fmt_duration(self.p95_s),
            crate::util::timer::fmt_duration(self.std_s),
            self.iters
        )
    }
}

/// Benchmark runner with a time budget per case.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum measured iterations.
    pub min_iters: usize,
    /// Maximum measured iterations.
    pub max_iters: usize,
    /// Target wall-clock budget per case (seconds).
    pub budget_s: f64,
    /// Warmup iterations.
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_iters: 3, max_iters: 50, budget_s: 2.0, warmup: 1 }
    }
}

impl Bencher {
    /// Honor `SRSVD_BENCH_QUICK=1` (CI smoke) and `SRSVD_BENCH_BUDGET`
    /// (seconds per case).
    pub fn from_env() -> Self {
        let mut b = Bencher::default();
        if std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1") {
            b.min_iters = 1;
            b.max_iters = 3;
            b.budget_s = 0.3;
            b.warmup = 0;
        }
        if let Ok(s) = std::env::var("SRSVD_BENCH_BUDGET") {
            if let Ok(v) = s.parse::<f64>() {
                b.budget_s = v;
            }
        }
        b
    }

    /// Measure `f`, returning aggregate stats. The closure's return value
    /// is passed through `std::hint::black_box` to keep the work alive.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut times = Vec::new();
        let start = Instant::now();
        while times.len() < self.min_iters
            || (times.len() < self.max_iters
                && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
        }
        BenchStats {
            name: name.to_string(),
            iters: times.len(),
            mean_s: mean(&times),
            median_s: median(&times),
            p95_s: quantile(&times, 0.95),
            std_s: std_dev(&times),
        }
    }
}

/// Fixed-width table printer for experiment/bench reports.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "table arity");
        self.rows.push(cells.to_vec());
    }

    /// Render with per-column auto-width.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float in compact scientific-ish style for table cells.
pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 0.01 && x.abs() < 1e5 {
        format!("{x:.4}")
    } else {
        format!("{x:.3e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_respects_min_iters() {
        let b = Bencher { min_iters: 4, max_iters: 5, budget_s: 0.0, warmup: 0 };
        let s = b.run("noop", || 1 + 1);
        assert_eq!(s.iters, 4);
        assert!(s.mean_s >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["k", "mse"]);
        t.row(&["1".into(), "0.5".into()]);
        t.row(&["100".into(), "0.25".into()]);
        let r = t.render();
        assert!(r.contains("k"));
        assert!(r.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "table arity")]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn fmt_sci_ranges() {
        assert_eq!(fmt_sci(0.0), "0");
        assert_eq!(fmt_sci(0.5), "0.5000");
        assert!(fmt_sci(1.95e-5).contains('e'));
    }
}
