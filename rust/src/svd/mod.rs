//! The paper's algorithms: S-RSVD (Algorithm 1), the RSVD baseline
//! (Halko et al. 2011), and a deterministic Jacobi-SVD oracle, all over
//! a common operator abstraction so dense and sparse inputs share one
//! code path.

pub mod checkpoint;
pub mod deterministic;
pub mod ops;
pub mod pca;
pub mod rsvd;
pub mod shifted;

pub use checkpoint::Checkpointer;
pub use deterministic::deterministic_svd;
pub use ops::{shifted_low_rank_mse, MatVecOps};
pub use pca::{column_errors, Pca};
pub use rsvd::Rsvd;
pub use shifted::{BasisMethod, PassPolicy, ShiftedRsvd, SmallSvdMethod, SweepReport};

/// Kernel arithmetic tier — defined next to the GEMM dispatch it
/// controls, re-exported here because it is configured per job through
/// [`SvdConfig`].
pub use crate::linalg::gemm::Precision;

use crate::linalg::{gemm, Dense};

/// A rank-k factorization `X̄ ≈ U·diag(s)·Vᵀ`.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// Left singular vectors, m×k.
    pub u: Dense,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors, n×k.
    pub v: Dense,
}

impl Factorization {
    /// Number of retained factors k.
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Dense reconstruction `U·diag(s)·Vᵀ` (m×n — tests/small inputs).
    pub fn reconstruct(&self) -> Dense {
        gemm::matmul(&self.u.scale_cols(&self.s), &self.v.transpose())
    }

    /// Truncate to the leading `k` factors.
    pub fn truncate(&self, k: usize) -> Factorization {
        assert!(k <= self.rank());
        Factorization {
            u: self.u.truncate_cols(k),
            s: self.s[..k].to_vec(),
            v: self.v.truncate_cols(k),
        }
    }

    /// Mean squared column reconstruction error against an explicit
    /// target matrix (the paper's MSE; target is `X̄`).
    pub fn mse_against(&self, target: &Dense) -> f64 {
        let d = crate::linalg::fro_diff(&self.reconstruct(), target);
        d * d / target.cols() as f64
    }
}

/// Which execution engine a factorization request should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdEngine {
    /// Native rust implementation (any shape).
    Native,
    /// AOT-compiled HLO artifact via the PJRT runtime (grid shapes only).
    Artifact,
}

/// When the power-sweep loop of a factorization stops.
///
/// This is the typed replacement for the former `power_iters: usize`
/// field that was duplicated across `SvdConfig`, the `[svd]` config
/// section, the `--q` CLI flag, and the wire protocol's `power_iters`
/// submit field. All of those surfaces now funnel into this enum
/// through one conversion point, [`crate::config::stop_criterion`].
///
/// ## Migration
///
/// | Before (≤ PR 5)                          | Now                                        |
/// |------------------------------------------|--------------------------------------------|
/// | `SvdConfig { power_iters: q, .. }`       | `SvdConfig { stop: StopCriterion::FixedPower { q }, .. }` |
/// | `cfg.with_power(q)` *(shim, removed)*    | `cfg.with_fixed_power(q)`                  |
/// | *(no equivalent)*                        | `cfg.with_tolerance(pve_tol, max_sweeps)`  |
///
/// `FixedPower` preserves the pre-redesign semantics exactly — same
/// operation sequence, byte-identical factors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StopCriterion {
    /// Run exactly `q` power sweeps (the legacy `power_iters` knob).
    /// Deterministic pass budget; no accuracy feedback.
    FixedPower {
        /// Power-iteration count q.
        q: usize,
    },
    /// dashSVD-style accuracy control (arXiv 2404.09276): run dynamic-
    /// shift Gram sweeps until the per-eigenvalue estimates move by at
    /// most `pve_tol · ‖X̄‖²_F` between consecutive sweeps (the PVE
    /// stopping rule), or `max_sweeps` is reached. The engine reports
    /// the sweeps actually used via [`SweepReport`].
    Tolerance {
        /// Relative tolerance on the proportion-of-variance-explained
        /// movement between sweeps (e.g. `1e-2` coarse, `1e-4` tight).
        pve_tol: f64,
        /// Hard sweep ceiling; the loop stops here even if the
        /// tolerance was never met.
        max_sweeps: usize,
    },
}

impl StopCriterion {
    /// Default sweep ceiling for [`StopCriterion::Tolerance`] when a
    /// caller supplies only a tolerance.
    pub const DEFAULT_MAX_SWEEPS: usize = 32;

    /// The fixed sweep count, when this criterion is static.
    /// `None` for the adaptive [`StopCriterion::Tolerance`] mode —
    /// used by the artifact router, which can only match compiled
    /// fixed-`q` pipelines.
    pub fn fixed_q(&self) -> Option<usize> {
        match self {
            StopCriterion::FixedPower { q } => Some(*q),
            StopCriterion::Tolerance { .. } => None,
        }
    }

    /// Whether the sweep count is decided at run time.
    pub fn is_adaptive(&self) -> bool {
        matches!(self, StopCriterion::Tolerance { .. })
    }
}

impl Default for StopCriterion {
    fn default() -> Self {
        StopCriterion::FixedPower { q: 0 }
    }
}

/// Configuration shared by RSVD and S-RSVD.
#[derive(Debug, Clone, Copy)]
pub struct SvdConfig {
    /// Target rank k.
    pub k: usize,
    /// Oversampling: the sampling parameter is `K = k + oversample`.
    /// The paper uses K = 2k, i.e. `oversample = k`.
    pub oversample: usize,
    /// When the power-sweep loop stops: a fixed `q` (the paper's knob)
    /// or a PVE tolerance with dynamic shifts (dashSVD). Replaces the
    /// former `power_iters: usize` field — see [`StopCriterion`] for
    /// the migration table.
    pub stop: StopCriterion,
    /// How the shifted basis is obtained (Alg. 1 L4-6).
    pub basis: BasisMethod,
    /// Backend for the small projected SVD (Alg. 1 L13).
    pub small_svd: SmallSvdMethod,
    /// Source-pass schedule of the sweep stages: `Exact` (2 + 2q
    /// passes, streamed results byte-identical to dense) or `Fused`
    /// (Gram-chain power passes, ≤ q + 2 passes). The wall-clock lever
    /// for out-of-core inputs. Ignored by the adaptive
    /// [`StopCriterion::Tolerance`] mode, which always runs the fused
    /// Gram-sweep schedule (one source pass per sweep).
    pub pass_policy: PassPolicy,
    /// Kernel arithmetic tier: `Exact` (default — factors byte-identical
    /// across simd on/off and every pool size) or `Fast` (packed
    /// AVX2/FMA microkernels; deterministic, but the contraction
    /// rounding differs from scalar in the last ulps).
    pub precision: Precision,
}

impl Default for SvdConfig {
    fn default() -> Self {
        SvdConfig {
            k: 10,
            oversample: 10,
            stop: StopCriterion::default(),
            basis: BasisMethod::Direct,
            small_svd: SmallSvdMethod::Jacobi,
            pass_policy: PassPolicy::Exact,
            precision: Precision::Exact,
        }
    }
}

impl SvdConfig {
    /// The paper's parameterization: K = 2k, q = 0.
    pub fn paper(k: usize) -> Self {
        SvdConfig { k, oversample: k, ..Default::default() }
    }

    /// The sampling width K.
    pub fn sample_width(&self) -> usize {
        self.k + self.oversample
    }

    /// Builder-style override of the stopping criterion.
    pub fn with_stop(mut self, stop: StopCriterion) -> Self {
        self.stop = stop;
        self
    }

    /// Builder-style fixed power-iteration count q (the pre-redesign
    /// `power_iters` semantics, byte-identical factors).
    pub fn with_fixed_power(self, q: usize) -> Self {
        self.with_stop(StopCriterion::FixedPower { q })
    }

    /// Builder-style dashSVD accuracy control: dynamic shifts + PVE
    /// stopping at `pve_tol`, capped at `max_sweeps` sweeps.
    pub fn with_tolerance(self, pve_tol: f64, max_sweeps: usize) -> Self {
        self.with_stop(StopCriterion::Tolerance { pve_tol, max_sweeps })
    }

    /// Builder-style override of the source-pass schedule.
    pub fn with_pass_policy(mut self, policy: PassPolicy) -> Self {
        self.pass_policy = policy;
        self
    }

    /// Builder-style override of the kernel arithmetic tier.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn factorization_truncate_and_reconstruct() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::gaussian(20, 30, &mut rng);
        let f = deterministic_svd(&x, 10);
        let t = f.truncate(4);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.u.shape(), (20, 4));
        assert_eq!(t.v.shape(), (30, 4));
        // Truncation can only increase error.
        assert!(t.mse_against(&x) >= f.mse_against(&x) - 1e-12);
    }

    #[test]
    fn paper_config_uses_double_k() {
        let c = SvdConfig::paper(25);
        assert_eq!(c.sample_width(), 50);
        assert_eq!(c.stop, StopCriterion::FixedPower { q: 0 });
    }

    #[test]
    fn stop_criterion_builders_and_accessors() {
        let c = SvdConfig::paper(5).with_fixed_power(3);
        assert_eq!(c.stop.fixed_q(), Some(3));
        assert!(!c.stop.is_adaptive());
        let c = SvdConfig::paper(5).with_tolerance(1e-3, 12);
        assert_eq!(c.stop, StopCriterion::Tolerance { pve_tol: 1e-3, max_sweeps: 12 });
        assert_eq!(c.stop.fixed_q(), None);
        assert!(c.stop.is_adaptive());
    }

    #[test]
    fn with_fixed_power_keeps_power_iters_semantics() {
        // `with_fixed_power` carries the exact pre-redesign semantics
        // of the removed `with_power` shim (a fixed sweep count).
        let c = SvdConfig::paper(4).with_fixed_power(2);
        assert_eq!(c.stop, StopCriterion::FixedPower { q: 2 });
    }
}
