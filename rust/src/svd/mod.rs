//! The paper's algorithms: S-RSVD (Algorithm 1), the RSVD baseline
//! (Halko et al. 2011), and a deterministic Jacobi-SVD oracle, all over
//! a common operator abstraction so dense and sparse inputs share one
//! code path.

pub mod deterministic;
pub mod ops;
pub mod pca;
pub mod rsvd;
pub mod shifted;

pub use deterministic::deterministic_svd;
pub use ops::{shifted_low_rank_mse, MatVecOps};
pub use pca::{column_errors, Pca};
pub use rsvd::Rsvd;
pub use shifted::{BasisMethod, PassPolicy, ShiftedRsvd, SmallSvdMethod};

use crate::linalg::{gemm, Dense};

/// A rank-k factorization `X̄ ≈ U·diag(s)·Vᵀ`.
#[derive(Debug, Clone)]
pub struct Factorization {
    /// Left singular vectors, m×k.
    pub u: Dense,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors, n×k.
    pub v: Dense,
}

impl Factorization {
    /// Number of retained factors k.
    pub fn rank(&self) -> usize {
        self.s.len()
    }

    /// Dense reconstruction `U·diag(s)·Vᵀ` (m×n — tests/small inputs).
    pub fn reconstruct(&self) -> Dense {
        gemm::matmul(&self.u.scale_cols(&self.s), &self.v.transpose())
    }

    /// Truncate to the leading `k` factors.
    pub fn truncate(&self, k: usize) -> Factorization {
        assert!(k <= self.rank());
        Factorization {
            u: self.u.truncate_cols(k),
            s: self.s[..k].to_vec(),
            v: self.v.truncate_cols(k),
        }
    }

    /// Mean squared column reconstruction error against an explicit
    /// target matrix (the paper's MSE; target is `X̄`).
    pub fn mse_against(&self, target: &Dense) -> f64 {
        let d = crate::linalg::fro_diff(&self.reconstruct(), target);
        d * d / target.cols() as f64
    }
}

/// Which execution engine a factorization request should use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdEngine {
    /// Native rust implementation (any shape).
    Native,
    /// AOT-compiled HLO artifact via the PJRT runtime (grid shapes only).
    Artifact,
}

/// Configuration shared by RSVD and S-RSVD.
#[derive(Debug, Clone, Copy)]
pub struct SvdConfig {
    /// Target rank k.
    pub k: usize,
    /// Oversampling: the sampling parameter is `K = k + oversample`.
    /// The paper uses K = 2k, i.e. `oversample = k`.
    pub oversample: usize,
    /// Power-iteration count q.
    pub power_iters: usize,
    /// How the shifted basis is obtained (Alg. 1 L4-6).
    pub basis: BasisMethod,
    /// Backend for the small projected SVD (Alg. 1 L13).
    pub small_svd: SmallSvdMethod,
    /// Source-pass schedule of the sweep stages: `Exact` (2 + 2q
    /// passes, streamed results byte-identical to dense) or `Fused`
    /// (Gram-chain power passes, ≤ q + 2 passes). The wall-clock lever
    /// for out-of-core inputs.
    pub pass_policy: PassPolicy,
}

impl Default for SvdConfig {
    fn default() -> Self {
        SvdConfig {
            k: 10,
            oversample: 10,
            power_iters: 0,
            basis: BasisMethod::Direct,
            small_svd: SmallSvdMethod::Jacobi,
            pass_policy: PassPolicy::Exact,
        }
    }
}

impl SvdConfig {
    /// The paper's parameterization: K = 2k, q = 0.
    pub fn paper(k: usize) -> Self {
        SvdConfig { k, oversample: k, ..Default::default() }
    }

    /// The sampling width K.
    pub fn sample_width(&self) -> usize {
        self.k + self.oversample
    }

    /// Builder-style override of the power-iteration count q.
    pub fn with_power(mut self, q: usize) -> Self {
        self.power_iters = q;
        self
    }

    /// Builder-style override of the source-pass schedule.
    pub fn with_pass_policy(mut self, policy: PassPolicy) -> Self {
        self.pass_policy = policy;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn factorization_truncate_and_reconstruct() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::gaussian(20, 30, &mut rng);
        let f = deterministic_svd(&x, 10);
        let t = f.truncate(4);
        assert_eq!(t.rank(), 4);
        assert_eq!(t.u.shape(), (20, 4));
        assert_eq!(t.v.shape(), (30, 4));
        // Truncation can only increase error.
        assert!(t.mse_against(&x) >= f.mse_against(&x) - 1e-12);
    }

    #[test]
    fn paper_config_uses_double_k() {
        let c = SvdConfig::paper(25);
        assert_eq!(c.sample_width(), 50);
        assert_eq!(c.power_iters, 0);
    }
}
