//! Deterministic SVD oracle via one-sided Jacobi.
//!
//! Slow (O(max·min²) per sweep) but LAPACK-free and accurate; this is
//! the ground truth the randomized algorithms are scored against in
//! tests and the "optimal rank-k" reference in the experiment reports.

use crate::linalg::{jacobi_svd, Dense, JacobiOpts};

use super::Factorization;

/// Rank-k deterministic SVD of a dense matrix (any aspect ratio).
pub fn deterministic_svd(x: &Dense, k: usize) -> Factorization {
    let (m, n) = x.shape();
    let k = k.min(m).min(n);
    if m <= n {
        // Jacobi wants tall input: factorize Xᵀ = U Σ Vᵀ → X = V Σ Uᵀ.
        let (ut, s, vt) = jacobi_svd(&x.transpose(), JacobiOpts::default());
        Factorization {
            u: vt.truncate_cols(k),
            s: s[..k].to_vec(),
            v: ut.truncate_cols(k),
        }
    } else {
        let (u, s, v) = jacobi_svd(x, JacobiOpts::default());
        Factorization {
            u: u.truncate_cols(k),
            s: s[..k].to_vec(),
            v: v.truncate_cols(k),
        }
    }
}

/// Frobenius norm of the optimal rank-k residual: √(Σ_{j>k} σⱼ²).
pub fn optimal_residual(x: &Dense, k: usize) -> f64 {
    let (m, n) = x.shape();
    let full = m.min(n);
    let f = deterministic_svd(x, full);
    f.s[k.min(full)..].iter().map(|s| s * s).sum::<f64>().sqrt()
}

/// The paper's MSE for the *optimal* rank-k approximation of `x`.
pub fn optimal_mse(x: &Dense, k: usize) -> f64 {
    let r = optimal_residual(x, k);
    r * r / x.cols() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_diff;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn full_rank_reconstructs_exactly() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for (m, n) in [(12, 20), (20, 12), (8, 8)] {
            let x = Dense::gaussian(m, n, &mut rng);
            let f = deterministic_svd(&x, m.min(n));
            assert!(fro_diff(&f.reconstruct(), &x) < 1e-9, "{m}x{n}");
        }
    }

    #[test]
    fn rank_k_is_best_possible() {
        // Compare against a known-rank construction.
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Dense::gaussian(15, 3, &mut rng);
        let b = Dense::gaussian(3, 25, &mut rng);
        let x = crate::linalg::matmul(&a, &b); // exact rank 3
        let f = deterministic_svd(&x, 3);
        assert!(fro_diff(&f.reconstruct(), &x) < 1e-8);
        assert!(optimal_residual(&x, 3) < 1e-8);
        assert!(optimal_residual(&x, 2) > 1e-3);
    }

    #[test]
    fn singular_values_descending_and_match_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = Dense::gaussian(10, 40, &mut rng);
        let f1 = deterministic_svd(&x, 10);
        let f2 = deterministic_svd(&x.transpose(), 10);
        for (a, b) in f1.s.iter().zip(&f2.s) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!(f1.s.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn optimal_mse_decreases_with_k() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x = Dense::from_fn(12, 50, |_, _| rng.next_uniform());
        let mut prev = f64::INFINITY;
        for k in [1, 3, 6, 12] {
            let m = optimal_mse(&x, k);
            assert!(m <= prev + 1e-12);
            prev = m;
        }
    }
}
