//! Operator abstraction: everything Algorithm 1 needs from the data
//! matrix, implemented by [`Dense`] and [`Csr`].
//!
//! The abstraction is the point of the paper: the algorithm only ever
//! multiplies against `X` (plus rank-1 corrections), so a sparse matrix
//! stays sparse end-to-end.
//!
//! It is also the parallelism seam: both impls route through the
//! pool-aware kernels in [`crate::linalg`] (panel-parallel GEMM,
//! row-parallel CSR), so every S-RSVD stage — sampling, power
//! iteration, projection — runs on the shared [`crate::parallel`] pool
//! with thread-count-invariant (bit-identical) results.

use crate::linalg::{gemm, Csr, Dense};

/// Products and reductions against the (un-shifted) data matrix.
pub trait MatVecOps: Sync {
    fn shape(&self) -> (usize, usize);

    /// `X · B`.
    fn mm(&self, b: &Dense) -> Dense;

    /// `Xᵀ · B`.
    fn tmm(&self, b: &Dense) -> Dense;

    /// `X·B − u·vᵀ` fused (`u` len m, `v` len b.cols()).
    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense;

    /// `Xᵀ·B − u·vᵀ` fused (`u` len n, `v` len b.cols()).
    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense;

    /// Per-row means (the PCA shifting vector).
    fn row_means(&self) -> Vec<f64>;

    /// Squared Frobenius norm of X.
    fn sq_fro(&self) -> f64;

    /// Number of stored entries (m·n for dense).
    fn stored_entries(&self) -> usize;
}

impl MatVecOps for Dense {
    fn shape(&self) -> (usize, usize) {
        Dense::shape(self)
    }

    fn mm(&self, b: &Dense) -> Dense {
        gemm::matmul(self, b)
    }

    fn tmm(&self, b: &Dense) -> Dense {
        gemm::tmatmul(self, b)
    }

    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        gemm::matmul_rank1(self, b, u, v)
    }

    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        gemm::tmatmul_rank1(self, b, u, v)
    }

    fn row_means(&self) -> Vec<f64> {
        Dense::row_means(self)
    }

    fn sq_fro(&self) -> f64 {
        self.data().iter().map(|x| x * x).sum()
    }

    fn stored_entries(&self) -> usize {
        self.rows() * self.cols()
    }
}

impl MatVecOps for Csr {
    fn shape(&self) -> (usize, usize) {
        Csr::shape(self)
    }

    fn mm(&self, b: &Dense) -> Dense {
        self.matmul_dense(b)
    }

    fn tmm(&self, b: &Dense) -> Dense {
        self.tmatmul_dense(b)
    }

    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        Csr::matmul_rank1(self, b, u, v)
    }

    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        Csr::tmatmul_rank1(self, b, u, v)
    }

    fn row_means(&self) -> Vec<f64> {
        Csr::row_means(self)
    }

    fn sq_fro(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.rows() {
            for (_, v) in self.row_iter(i) {
                s += v * v;
            }
        }
        s
    }

    fn stored_entries(&self) -> usize {
        self.nnz()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn dense_and_sparse_agree_through_the_trait() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let sp = Csr::random(25, 60, 0.08, &mut rng, |r| r.next_uniform() + 0.2);
        let de = sp.to_dense();
        let b = Dense::gaussian(60, 5, &mut rng);
        let bt = Dense::gaussian(25, 5, &mut rng);
        let u_m: Vec<f64> = (0..25).map(|_| rng.next_gaussian()).collect();
        let u_n: Vec<f64> = (0..60).map(|_| rng.next_gaussian()).collect();
        let v5: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();

        let pairs = [
            (MatVecOps::mm(&sp, &b), MatVecOps::mm(&de, &b)),
            (MatVecOps::tmm(&sp, &bt), MatVecOps::tmm(&de, &bt)),
            (sp.mm_rank1(&b, &u_m, &v5), de.mm_rank1(&b, &u_m, &v5)),
            (sp.tmm_rank1(&bt, &u_n, &v5), de.tmm_rank1(&bt, &u_n, &v5)),
        ];
        for (a, b) in &pairs {
            assert!(crate::linalg::fro_diff(a, b) < 1e-10);
        }
        assert!((MatVecOps::sq_fro(&sp) - MatVecOps::sq_fro(&de)).abs() < 1e-10);
        assert_eq!(MatVecOps::row_means(&sp), MatVecOps::row_means(&de));
        assert!(sp.stored_entries() < de.stored_entries());
    }
}
