//! Operator abstraction: everything Algorithm 1 needs from the data
//! matrix, implemented by [`Dense`], [`Csr`], and the out-of-core
//! [`crate::linalg::Streamed`] wrapper.
//!
//! The abstraction is the point of the paper: the algorithm only ever
//! multiplies against `X` (plus rank-1 corrections), so a sparse matrix
//! stays sparse end-to-end — and a streamed matrix never needs to be
//! resident at all.
//!
//! It is also the parallelism seam: both impls route through the
//! pool-aware kernels in [`crate::linalg`] (panel-parallel GEMM,
//! row-parallel CSR), so every S-RSVD stage — sampling, power
//! iteration, projection — runs on the shared [`crate::parallel`] pool
//! with thread-count-invariant (bit-identical) results.

use crate::linalg::{gemm, Csr, Dense};

/// Products and reductions against the (un-shifted) data matrix.
pub trait MatVecOps: Sync {
    /// Matrix dimensions `(m, n)`.
    fn shape(&self) -> (usize, usize);

    /// `X · B`.
    fn mm(&self, b: &Dense) -> Dense;

    /// `Xᵀ · B`.
    fn tmm(&self, b: &Dense) -> Dense;

    /// `X·B − u·vᵀ` fused (`u` len m, `v` len b.cols()).
    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense;

    /// `Xᵀ·B − u·vᵀ` fused (`u` len n, `v` len b.cols()).
    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense;

    /// One fused power-iteration leg: `Z = X̄ᵀ·(X̄·W)` with the μ-shift
    /// folded in as rank-1 downdates (`X̄ = X − μ·1ᵀ`; `W` is n×l, `Z`
    /// is n×l). This is the unit the `PassPolicy::Fused` schedule
    /// iterates — see [`crate::svd::shifted`].
    ///
    /// The default implementation composes the two trait products
    /// (`mm_rank1` then `tmm_rank1`), which costs **two** passes for an
    /// out-of-core input; [`crate::linalg::Streamed`] overrides it with
    /// a single fused sweep where each resident block services both
    /// products. All implementations agree mathematically but not
    /// bit-for-bit (different accumulation orders).
    fn gram_sweep(&self, w: &Dense, mu: &[f64]) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(w.rows(), n, "gram_sweep shape mismatch");
        assert_eq!(mu.len(), m, "gram_sweep mu length");
        if mu.iter().any(|&v| v != 0.0) {
            let colsum = colsums(w);
            let y = self.mm_rank1(w, mu, &colsum); // X̄·W (m×l)
            let muy = y.tmatvec(mu); // μᵀY (l)
            let ones_n = vec![1.0; n];
            self.tmm_rank1(&y, &ones_n, &muy) // X̄ᵀ·Y (n×l)
        } else {
            self.tmm(&self.mm(w))
        }
    }

    /// Per-row means (the PCA shifting vector).
    fn row_means(&self) -> Vec<f64>;

    /// Squared Frobenius norm of X.
    fn sq_fro(&self) -> f64;

    /// Squared Frobenius norm of the shifted matrix,
    /// `‖X̄‖²_F = ‖X − μ·1ᵀ‖²_F` — the normalizer of the PVE stopping
    /// rule ([`crate::svd::StopCriterion::Tolerance`]).
    ///
    /// The default expands the square so no implementation materializes
    /// `X̄`: `‖X̄‖² = ‖X‖² − 2n·Σᵢ μᵢ·m̄ᵢ + n·Σᵢ μᵢ²` with `m̄` the row
    /// means. For [`Dense`] that is one data pass (`sq_fro` +
    /// `row_means` both touch resident memory); [`Csr`] overrides with
    /// a single stored-entry loop, and [`crate::linalg::Streamed`]
    /// overrides with one fused source sweep.
    fn sq_fro_shifted(&self, mu: &[f64]) -> f64 {
        let (m, n) = self.shape();
        assert_eq!(mu.len(), m, "sq_fro_shifted mu length");
        if mu.iter().all(|&v| v == 0.0) {
            return self.sq_fro();
        }
        let means = self.row_means();
        let cross: f64 = mu.iter().zip(&means).map(|(a, b)| a * b).sum();
        let mu_sq: f64 = mu.iter().map(|v| v * v).sum();
        (self.sq_fro() - 2.0 * n as f64 * cross + n as f64 * mu_sq).max(0.0)
    }

    /// Number of stored entries (m·n for dense).
    fn stored_entries(&self) -> usize;
}

impl MatVecOps for Dense {
    fn shape(&self) -> (usize, usize) {
        Dense::shape(self)
    }

    fn mm(&self, b: &Dense) -> Dense {
        gemm::matmul(self, b)
    }

    fn tmm(&self, b: &Dense) -> Dense {
        gemm::tmatmul(self, b)
    }

    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        gemm::matmul_rank1(self, b, u, v)
    }

    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        gemm::tmatmul_rank1(self, b, u, v)
    }

    fn row_means(&self) -> Vec<f64> {
        Dense::row_means(self)
    }

    fn sq_fro(&self) -> f64 {
        self.data().iter().map(|x| x * x).sum()
    }

    fn sq_fro_shifted(&self, mu: &[f64]) -> f64 {
        // One resident pass in row-major element order — the same
        // carried-accumulator order the Streamed override replays, so
        // streamed and in-memory runs agree bit-for-bit.
        assert_eq!(mu.len(), self.rows(), "sq_fro_shifted mu length");
        let mut s = 0.0;
        for i in 0..self.rows() {
            let m = mu[i];
            for &x in self.row(i) {
                let d = x - m;
                s += d * d;
            }
        }
        s
    }

    fn stored_entries(&self) -> usize {
        self.rows() * self.cols()
    }
}

impl MatVecOps for Csr {
    fn shape(&self) -> (usize, usize) {
        Csr::shape(self)
    }

    fn mm(&self, b: &Dense) -> Dense {
        self.matmul_dense(b)
    }

    fn tmm(&self, b: &Dense) -> Dense {
        self.tmatmul_dense(b)
    }

    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        Csr::matmul_rank1(self, b, u, v)
    }

    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        Csr::tmatmul_rank1(self, b, u, v)
    }

    fn row_means(&self) -> Vec<f64> {
        Csr::row_means(self)
    }

    fn sq_fro(&self) -> f64 {
        let mut s = 0.0;
        for i in 0..self.rows() {
            for (_, v) in self.row_iter(i) {
                s += v * v;
            }
        }
        s
    }

    fn sq_fro_shifted(&self, mu: &[f64]) -> f64 {
        // Stored entries contribute (v − μᵢ)²; the (n − nnzᵢ) implicit
        // zeros of row i each contribute μᵢ². Rearranged to one loop
        // over stored entries plus an O(m) closed-form term:
        // Σ_stored((v−μᵢ)² − μᵢ²) + n·Σᵢ μᵢ².
        assert_eq!(mu.len(), self.rows(), "sq_fro_shifted mu length");
        let n = self.cols() as f64;
        let mut s = 0.0;
        for i in 0..self.rows() {
            let m = mu[i];
            for (_, v) in self.row_iter(i) {
                let d = v - m;
                s += d * d - m * m;
            }
        }
        s + n * mu.iter().map(|v| v * v).sum::<f64>()
    }

    fn stored_entries(&self) -> usize {
        self.nnz()
    }
}

/// Column sums of a dense matrix (`Bᵀ·1`), in the fixed row-major
/// accumulation order every shift epilogue shares — the byte-identity
/// contract between the one-shot and streamed paths depends on this
/// being computed exactly one way everywhere.
pub(crate) fn colsums(b: &Dense) -> Vec<f64> {
    let (rows, cols) = b.shape();
    let mut out = vec![0.0; cols];
    for i in 0..rows {
        for (o, &x) in out.iter_mut().zip(b.row(i)) {
            *o += x;
        }
    }
    out
}

/// The paper's MSE of a rank-k factorization `U·diag(s)·Vᵀ` against the
/// implicitly shifted matrix `X̄ = X − μ·1ᵀ`, computed from [`MatVecOps`]
/// products only — `X̄` is never formed and `X` itself is touched in two
/// sweeps (row sums/norm + one k-column product), so it works for
/// streamed sources larger than RAM as well as dense and sparse inputs.
///
/// Same expansion as [`Csr::shifted_mse`]:
/// `‖X̄ − R‖² = ‖X‖² − 2⟨X, M⟩ + ‖M‖²` with `M = μ1ᵀ + R`.
pub fn shifted_low_rank_mse(
    x: &dyn MatVecOps,
    mu: &[f64],
    u: &Dense,
    s: &[f64],
    v: &Dense,
) -> f64 {
    let (m, n) = x.shape();
    let k = s.len();
    assert_eq!(u.shape(), (m, k), "U shape");
    assert_eq!(v.shape(), (n, k), "V shape");
    assert_eq!(mu.len(), m, "mu length");

    // ‖X‖²
    let x_sq = x.sq_fro();

    // us = U·diag(s)
    let us = u.scale_cols(s);

    // ⟨X, μ1ᵀ⟩ = Σᵢ μᵢ·rowsumᵢ = n · Σᵢ μᵢ·rowmeanᵢ
    let means = x.row_means();
    let x_dot_shift: f64 =
        mu.iter().zip(&means).map(|(a, b)| a * b).sum::<f64>() * n as f64;

    // ⟨X, R⟩ = Σⱼₗ (XᵀUS)ⱼₗ · Vⱼₗ — one streamed k-column product.
    let w = x.tmm(&us); // n×k
    let x_dot_r: f64 = w
        .data()
        .iter()
        .zip(v.data())
        .map(|(a, b)| a * b)
        .sum();

    // ‖M‖² = ‖μ1ᵀ‖² + 2⟨μ1ᵀ, R⟩ + ‖R‖² — all small dense ops.
    let mu_sq: f64 = mu.iter().map(|x| x * x).sum::<f64>() * n as f64;
    let mu_us = us.tmatvec(mu); // k
    let v_colsum: Vec<f64> = (0..k).map(|l| (0..n).map(|j| v[(j, l)]).sum()).collect();
    let cross: f64 = mu_us.iter().zip(&v_colsum).map(|(a, b)| a * b).sum();
    let ug = gemm::tmatmul(&us, &us); // k×k
    let vg = gemm::tmatmul(v, v); // k×k
    let mut r_sq = 0.0;
    for i in 0..k {
        for j in 0..k {
            r_sq += ug[(i, j)] * vg[(i, j)];
        }
    }

    let total = x_sq - 2.0 * (x_dot_shift + x_dot_r) + mu_sq + 2.0 * cross + r_sq;
    total.max(0.0) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn dense_and_sparse_agree_through_the_trait() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let sp = Csr::random(25, 60, 0.08, &mut rng, |r| r.next_uniform() + 0.2);
        let de = sp.to_dense();
        let b = Dense::gaussian(60, 5, &mut rng);
        let bt = Dense::gaussian(25, 5, &mut rng);
        let u_m: Vec<f64> = (0..25).map(|_| rng.next_gaussian()).collect();
        let u_n: Vec<f64> = (0..60).map(|_| rng.next_gaussian()).collect();
        let v5: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();

        let pairs = [
            (MatVecOps::mm(&sp, &b), MatVecOps::mm(&de, &b)),
            (MatVecOps::tmm(&sp, &bt), MatVecOps::tmm(&de, &bt)),
            (sp.mm_rank1(&b, &u_m, &v5), de.mm_rank1(&b, &u_m, &v5)),
            (sp.tmm_rank1(&bt, &u_n, &v5), de.tmm_rank1(&bt, &u_n, &v5)),
        ];
        for (a, b) in &pairs {
            assert!(crate::linalg::fro_diff(a, b) < 1e-10);
        }
        assert!((MatVecOps::sq_fro(&sp) - MatVecOps::sq_fro(&de)).abs() < 1e-10);
        assert_eq!(MatVecOps::row_means(&sp), MatVecOps::row_means(&de));
        assert!(sp.stored_entries() < de.stored_entries());
    }

    #[test]
    fn gram_sweep_default_matches_explicit_centering() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let sp = Csr::random(20, 50, 0.2, &mut rng, |r| r.next_uniform() + 0.1);
        let de = sp.to_dense();
        let w = Dense::gaussian(50, 4, &mut rng);
        let mu = Csr::row_means(&sp);
        // Reference: materialize X̄ and apply the Gram chain explicitly.
        let xbar = de.subtract_column(&mu);
        let want = gemm::tmatmul(&xbar, &gemm::matmul(&xbar, &w));
        let cases: [(&dyn MatVecOps, &str); 2] = [(&sp, "sparse"), (&de, "dense")];
        for (ops, what) in cases {
            let got = ops.gram_sweep(&w, &mu);
            assert!(
                crate::linalg::fro_diff(&got, &want) < 1e-9,
                "{what} gram_sweep diverged"
            );
        }
        // μ = 0 reduces to Xᵀ(XW).
        let want0 = gemm::tmatmul(&de, &gemm::matmul(&de, &w));
        let got0 = MatVecOps::gram_sweep(&de, &w, &vec![0.0; 20]);
        assert!(crate::linalg::fro_diff(&got0, &want0) < 1e-10);
    }

    #[test]
    fn sq_fro_shifted_agrees_across_implementations() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let sp = Csr::random(25, 70, 0.12, &mut rng, |r| r.next_uniform() + 0.3);
        let de = sp.to_dense();
        let mu = Csr::row_means(&sp);
        // Reference: materialize X̄.
        let want = MatVecOps::sq_fro(&de.subtract_column(&mu));
        let got_dense = MatVecOps::sq_fro_shifted(&de, &mu);
        let got_sparse = sp.sq_fro_shifted(&mu);
        // The trait default (expand-the-square) on the dense input.
        struct DefaultOnly<'a>(&'a Dense);
        impl MatVecOps for DefaultOnly<'_> {
            fn shape(&self) -> (usize, usize) {
                self.0.shape()
            }
            fn mm(&self, b: &Dense) -> Dense {
                MatVecOps::mm(self.0, b)
            }
            fn tmm(&self, b: &Dense) -> Dense {
                MatVecOps::tmm(self.0, b)
            }
            fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
                self.0.mm_rank1(b, u, v)
            }
            fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
                self.0.tmm_rank1(b, u, v)
            }
            fn row_means(&self) -> Vec<f64> {
                MatVecOps::row_means(self.0)
            }
            fn sq_fro(&self) -> f64 {
                MatVecOps::sq_fro(self.0)
            }
            fn stored_entries(&self) -> usize {
                self.0.stored_entries()
            }
        }
        let got_default = DefaultOnly(&de).sq_fro_shifted(&mu);
        for (what, got) in [
            ("dense", got_dense),
            ("sparse", got_sparse),
            ("default", got_default),
        ] {
            assert!(
                (got - want).abs() < 1e-8 * want.max(1.0),
                "{what}: {got} vs {want}"
            );
        }
        // μ = 0 reduces to sq_fro exactly.
        let zeros = vec![0.0; 25];
        assert_eq!(
            MatVecOps::sq_fro_shifted(&de, &zeros).to_bits(),
            MatVecOps::sq_fro(&de).to_bits()
        );
    }

    #[test]
    fn generic_mse_matches_dense_and_sparse_scorers() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let sp = Csr::random(30, 90, 0.15, &mut rng, |r| r.next_uniform() + 0.2);
        let de = sp.to_dense();
        let mu = Csr::row_means(&sp);
        let cfg = crate::svd::SvdConfig { k: 4, oversample: 4, ..Default::default() };
        let f = crate::svd::ShiftedRsvd::new(cfg)
            .factorize(&de, &mu, &mut Xoshiro256pp::seed_from_u64(2))
            .unwrap();
        // Dense reference: explicit centering + reconstruction.
        let want = f.mse_against(&de.subtract_column(&mu));
        let got_dense = shifted_low_rank_mse(&de, &mu, &f.u, &f.s, &f.v);
        let got_sparse_scorer = sp.shifted_mse(&mu, &f.u, &f.s, &f.v);
        assert!(
            (got_dense - want).abs() < 1e-8 * want.max(1.0),
            "generic {got_dense} vs dense {want}"
        );
        assert!(
            (got_dense - got_sparse_scorer).abs() < 1e-8 * want.max(1.0),
            "generic {got_dense} vs sparse scorer {got_sparse_scorer}"
        );
    }
}
