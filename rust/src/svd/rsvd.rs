//! The RSVD baseline (Halko, Martinsson & Tropp 2011) — the comparator
//! in every experiment.
//!
//! Implemented as S-RSVD with μ = 0 (the paper notes the reduction is
//! exact), plus the *explicit centering* entry point that demonstrates
//! what the shifted algorithm avoids: `factorize_centered` really
//! subtracts the mean — densifying a sparse input — before factorizing.
//!
//! Like S-RSVD, every product runs through the pool-aware [`MatVecOps`]
//! kernels, so the baseline is parallelized identically (same shared
//! pool, same bit-exact thread-count invariance) and timing comparisons
//! between the two algorithms stay apples-to-apples.

use crate::linalg::{Csr, Dense};
use crate::rng::Rng;
use crate::util::Result;

use super::{Factorization, MatVecOps, ShiftedRsvd, SvdConfig};

/// The randomized SVD of Halko et al. (2011).
#[derive(Debug, Clone, Copy)]
pub struct Rsvd {
    /// Rank / oversampling / power-iteration configuration.
    pub config: SvdConfig,
}

impl Rsvd {
    /// Build an engine with the given configuration.
    pub fn new(config: SvdConfig) -> Self {
        Rsvd { config }
    }

    /// Plain RSVD of `x` (no shift — the off-center factorization the
    /// paper's experiments compare against).
    pub fn factorize(&self, x: &dyn MatVecOps, rng: &mut dyn Rng) -> Result<Factorization> {
        let (m, _) = x.shape();
        ShiftedRsvd::new(self.config).factorize(x, &vec![0.0; m], rng)
    }

    /// RSVD of the **explicitly** mean-centered dense matrix: materialize
    /// `X̄ = X − μ1ᵀ`, then factorize. This is the baseline protocol in
    /// Fig. 1d and the efficiency comparison of §4 — O(mn) memory.
    pub fn factorize_centered_dense(
        &self,
        x: &Dense,
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        self.factorize(&xbar, rng)
    }

    /// RSVD of an explicitly centered *sparse* matrix: densify first
    /// (the memory blow-up S-RSVD exists to avoid), then factorize.
    /// Kept deliberately: the efficiency bench measures exactly this.
    pub fn factorize_centered_sparse(
        &self,
        x: &Csr,
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        let mu = x.row_means();
        let dense = x.to_dense().subtract_column(&mu);
        self.factorize(&dense, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_diff;
    use crate::rng::Xoshiro256pp;
    use crate::svd::deterministic::optimal_residual;

    #[test]
    fn rsvd_near_optimal_with_power_iterations() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::from_fn(40, 200, |_, _| rng.next_uniform());
        let cfg = SvdConfig::paper(8).with_fixed_power(2);
        let f = Rsvd::new(cfg).factorize(&x, &mut rng).unwrap();
        let err = fro_diff(&f.reconstruct(), &x);
        assert!(err <= 1.15 * optimal_residual(&x, 8));
    }

    #[test]
    fn centered_dense_matches_shifted_with_same_seed() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let x = Dense::from_fn(30, 90, |_, _| rng.next_uniform());
        let cfg = SvdConfig::paper(5);
        let f_rsvd = Rsvd::new(cfg)
            .factorize_centered_dense(&x, &mut Xoshiro256pp::seed_from_u64(2))
            .unwrap();
        let f_srsvd = ShiftedRsvd::new(cfg)
            .factorize_mean_centered(&x, &mut Xoshiro256pp::seed_from_u64(2))
            .unwrap();
        for (a, b) in f_rsvd.s.iter().zip(&f_srsvd.s) {
            assert!((a - b).abs() < 1e-9 * f_rsvd.s[0].max(1.0));
        }
    }

    #[test]
    fn centered_sparse_densifies_but_agrees() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let sp = crate::linalg::Csr::random(25, 80, 0.1, &mut rng, |r| r.next_uniform());
        let cfg = SvdConfig::paper(4);
        let f1 = Rsvd::new(cfg)
            .factorize_centered_sparse(&sp, &mut Xoshiro256pp::seed_from_u64(4))
            .unwrap();
        let f2 = ShiftedRsvd::new(cfg)
            .factorize_mean_centered(&sp, &mut Xoshiro256pp::seed_from_u64(4))
            .unwrap();
        for (a, b) in f1.s.iter().zip(&f2.s) {
            assert!((a - b).abs() < 1e-8);
        }
    }
}
