//! Sweep-granular crash-safe checkpointing for factorizations.
//!
//! A multi-sweep job over an out-of-core source runs for minutes; a
//! worker crash mid-run used to throw away every completed pass. With a
//! checkpoint directory configured (`[svd] checkpoint_dir` /
//! `--checkpoint-dir`), the engine spills its sweep state after every
//! completed power/adaptive sweep and resumes from the latest valid
//! checkpoint on the next run of the *same* spec — producing factors
//! **byte-identical** to an uninterrupted run (pinned by
//! `rust/tests/faults.rs`).
//!
//! ## What a checkpoint holds
//!
//! * the evolving panel — `Q` (m×K, exact power) or `W` (n×K,
//!   fused/adaptive) — spilled losslessly through the crate's on-disk
//!   matrix format ([`FileWriter`]: raw f64 bit patterns, no text
//!   round-trip);
//! * the sweep counter, and for the adaptive schedule the dynamic
//!   shift `α`, `‖X̄‖²_F`, and the previous Ritz estimates — every f64
//!   stored as its exact bit pattern in the JSON sidecar;
//! * the **spec tag**: the job's canonical content hash (the cache
//!   layer's [`crate::server::cache::checkpoint_spec_hash`]), so a
//!   checkpoint from a different matrix, config, or seed is refused by
//!   construction (it lives under a different file name *and* the tag
//!   inside the sidecar must match).
//!
//! ## Crash-safety protocol
//!
//! Both files are written temp-then-rename, panel first, sidecar last;
//! the sidecar carries a content hash of the panel bytes. Every load
//! failure — missing file, torn write, corrupt JSON, hash mismatch,
//! stage/shape mismatch — makes [`Checkpointer::load`] return `None`
//! and the factorization simply starts cold: a checkpoint is an
//! optimization, never a correctness dependency. Saves are best-effort
//! for the same reason (a full disk degrades to no checkpointing, it
//! does not fail jobs).
//!
//! RNG safety: Ω is drawn before the first sweep and nothing after
//! that draw consumes the job RNG, so restoring a panel and skipping
//! completed sweeps replays the uninterrupted operation sequence
//! exactly — the byte-identity contract extends across crashes.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::linalg::stream::{FileSource, FileWriter, MatrixSource};
use crate::linalg::Dense;
use crate::server::cache::content_hash;
use crate::util::json::Json;
use crate::util::{faults, Result};

/// Sidecar format version.
const META_VERSION: f64 = 1.0;

/// Checkpoints successfully written since process start (the
/// `checkpoints_written` metric).
static WRITTEN: AtomicU64 = AtomicU64::new(0);
/// Factorizations resumed from a valid checkpoint since process start
/// (the `checkpoints_resumed` metric).
static RESUMED: AtomicU64 = AtomicU64::new(0);

/// Checkpoints successfully written since process start.
pub fn checkpoints_written() -> u64 {
    WRITTEN.load(Ordering::Relaxed)
}

/// Factorizations resumed from a valid checkpoint since process start.
pub fn checkpoints_resumed() -> u64 {
    RESUMED.load(Ordering::Relaxed)
}

/// Which sweep loop produced a checkpoint. A checkpoint only resumes
/// the exact stage that wrote it (the spec tag already pins the
/// configuration; this guards against tag collisions and hand-moved
/// files).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// `exact_power`: panel is the m×K basis Q.
    ExactPower,
    /// `fused_range`: panel is the n×K sample W.
    FusedRange,
    /// `adaptive_range`: panel is W plus the dynamic-shift state.
    AdaptiveRange,
}

impl Stage {
    fn name(&self) -> &'static str {
        match self {
            Stage::ExactPower => "exact_power",
            Stage::FusedRange => "fused_range",
            Stage::AdaptiveRange => "adaptive_range",
        }
    }

    fn parse(s: &str) -> Option<Stage> {
        match s {
            "exact_power" => Some(Stage::ExactPower),
            "fused_range" => Some(Stage::FusedRange),
            "adaptive_range" => Some(Stage::AdaptiveRange),
            _ => None,
        }
    }
}

/// The engine's between-sweep state: everything needed to re-enter the
/// sweep loop as if the completed sweeps had just run.
#[derive(Debug, Clone)]
pub struct SweepState {
    /// Which sweep loop this state belongs to.
    pub stage: Stage,
    /// Completed sweeps.
    pub sweep: usize,
    /// Whether the sweep loop already finished (the adaptive schedule
    /// can converge before its ceiling; a crash *after* the loop then
    /// resumes straight into range capture).
    pub done: bool,
    /// The evolving panel (Q or W), exact bytes.
    pub panel: Dense,
    /// Adaptive dynamic shift α (0 for fixed-power stages).
    pub alpha: f64,
    /// Adaptive `‖X̄‖²_F` (0 for fixed-power stages).
    pub fro2: f64,
    /// Adaptive previous Ritz estimates, if a sweep has completed.
    pub prev: Option<Vec<f64>>,
}

impl SweepState {
    /// State for the fixed-power stages, which carry only a panel and
    /// a counter.
    pub fn fixed(stage: Stage, sweep: usize, panel: Dense) -> SweepState {
        SweepState {
            stage,
            sweep,
            done: false,
            panel,
            alpha: 0.0,
            fro2: 0.0,
            prev: None,
        }
    }
}

/// Writer/loader of one job's checkpoint pair (`ckpt-<tag>.panel` +
/// `ckpt-<tag>.meta`) under a checkpoint directory. Cheap to clone;
/// carried by [`crate::svd::ShiftedRsvd`] when checkpointing is on.
#[derive(Debug, Clone)]
pub struct Checkpointer {
    dir: PathBuf,
    tag: u64,
}

/// Exact f64 → hex bit-pattern string (lossless, unlike a decimal text
/// round-trip — resumed runs must replay to the last ulp).
fn bits_str(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

/// Inverse of [`bits_str`].
fn parse_bits(s: &str) -> Option<f64> {
    u64::from_str_radix(s, 16).ok().map(f64::from_bits)
}

/// Content hash of a panel's exact bytes (the sidecar's torn-write
/// detector).
fn panel_hash(panel: &Dense) -> u64 {
    let mut bytes = Vec::with_capacity(panel.data().len() * 8);
    for &v in panel.data() {
        bytes.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    content_hash(&bytes)
}

impl Checkpointer {
    /// A checkpointer for the job identified by `tag` (the canonical
    /// spec hash) under `dir`.
    pub fn new(dir: &Path, tag: u64) -> Checkpointer {
        Checkpointer { dir: dir.to_path_buf(), tag }
    }

    /// The spec tag this checkpointer reads and writes.
    pub fn tag(&self) -> u64 {
        self.tag
    }

    fn panel_path(&self) -> PathBuf {
        self.dir.join(format!("ckpt-{:016x}.panel", self.tag))
    }

    fn meta_path(&self) -> PathBuf {
        self.dir.join(format!("ckpt-{:016x}.meta", self.tag))
    }

    /// Best-effort save: a failure is logged and swallowed (a job must
    /// never fail because its *checkpoint* could not be written).
    pub fn save(&self, state: &SweepState) {
        match self.try_save(state) {
            Ok(()) => {
                WRITTEN.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) => {
                crate::log_warn!(
                    "checkpoint save failed for tag {:016x} (sweep {}): {e}",
                    self.tag,
                    state.sweep
                );
            }
        }
    }

    fn try_save(&self, state: &SweepState) -> Result<()> {
        fs::create_dir_all(&self.dir)?;
        // Panel first, temp-then-rename: the final name never holds a
        // half-written matrix (FileWriter::finish re-validates it).
        let panel_tmp = self.panel_path().with_extension("panel.tmp");
        let mut w = FileWriter::create(&panel_tmp, state.panel.rows(), state.panel.cols())?;
        w.append_rows(state.panel.data())?;
        w.finish()?;
        fs::rename(&panel_tmp, self.panel_path())?;

        // Sidecar last: its presence (with a matching panel hash) is
        // what declares the pair valid.
        let mut fields = vec![
            ("version", Json::num(META_VERSION)),
            ("tag", Json::str(&format!("{:016x}", self.tag))),
            ("stage", Json::str(state.stage.name())),
            ("sweep", Json::num(state.sweep as f64)),
            ("done", Json::Bool(state.done)),
            ("rows", Json::num(state.panel.rows() as f64)),
            ("cols", Json::num(state.panel.cols() as f64)),
            ("alpha", Json::str(&bits_str(state.alpha))),
            ("fro2", Json::str(&bits_str(state.fro2))),
            (
                "panel_hash",
                Json::str(&format!("{:016x}", panel_hash(&state.panel))),
            ),
        ];
        if let Some(prev) = &state.prev {
            fields.push((
                "prev",
                Json::arr(prev.iter().map(|&v| Json::str(&bits_str(v)))),
            ));
        }
        let text = Json::obj(fields).to_string();
        let bytes = text.as_bytes();
        // Fail-point: chaos runs tear the sidecar here; the stale/torn
        // pair must be detected and ignored on load.
        let take = faults::write_len("ckpt.meta", bytes.len())?;
        let meta_tmp = self.meta_path().with_extension("meta.tmp");
        fs::write(&meta_tmp, &bytes[..take])?;
        crate::ensure!(
            take == bytes.len(),
            "short checkpoint sidecar write: {take} of {} bytes",
            bytes.len()
        );
        fs::rename(&meta_tmp, self.meta_path())?;
        Ok(())
    }

    /// Load the checkpoint for this tag, or `None` when there is no
    /// valid one for the given `stage` and panel `shape` — missing
    /// files, torn writes, corrupt JSON, a foreign tag, or a hash
    /// mismatch all land on `None` (start cold), never on an error.
    pub fn load(&self, stage: Stage, shape: (usize, usize)) -> Option<SweepState> {
        let state = self.try_load(stage, shape)?;
        RESUMED.fetch_add(1, Ordering::Relaxed);
        crate::log_info!(
            "resuming tag {:016x} from checkpoint at sweep {} ({})",
            self.tag,
            state.sweep,
            state.stage.name()
        );
        Some(state)
    }

    fn try_load(&self, stage: Stage, shape: (usize, usize)) -> Option<SweepState> {
        let text = fs::read_to_string(self.meta_path()).ok()?;
        let meta = Json::parse(&text).ok()?;
        if meta.get("version").ok()?.as_f64().ok()? != META_VERSION {
            return None;
        }
        let tag = u64::from_str_radix(meta.get("tag").ok()?.as_str().ok()?, 16).ok()?;
        if tag != self.tag {
            return None;
        }
        let st = Stage::parse(meta.get("stage").ok()?.as_str().ok()?)?;
        if st != stage {
            return None;
        }
        let rows = meta.get("rows").ok()?.as_usize().ok()?;
        let cols = meta.get("cols").ok()?.as_usize().ok()?;
        if (rows, cols) != shape {
            return None;
        }
        let sweep = meta.get("sweep").ok()?.as_usize().ok()?;
        let done = meta.get("done").ok()?.as_bool().ok()?;
        let alpha = parse_bits(meta.get("alpha").ok()?.as_str().ok()?)?;
        let fro2 = parse_bits(meta.get("fro2").ok()?.as_str().ok()?)?;
        let prev = match meta.get("prev") {
            Ok(arr) => Some(
                arr.as_arr()
                    .ok()?
                    .iter()
                    .map(|v| v.as_str().ok().and_then(parse_bits))
                    .collect::<Option<Vec<f64>>>()?,
            ),
            Err(_) => None,
        };
        let want_hash = u64::from_str_radix(meta.get("panel_hash").ok()?.as_str().ok()?, 16).ok()?;
        let src = FileSource::open(&self.panel_path()).ok()?;
        if src.shape() != shape {
            return None;
        }
        let panel = src.materialize().ok()?;
        if panel_hash(&panel) != want_hash {
            return None;
        }
        Some(SweepState { stage: st, sweep, done, panel, alpha, fro2, prev })
    }

    /// Remove this tag's checkpoint pair (called once the factorization
    /// completes; also best-effort).
    pub fn clear(&self) {
        let _ = fs::remove_file(self.meta_path());
        let _ = fs::remove_file(self.panel_path());
        let _ = fs::remove_file(self.panel_path().with_extension("panel.tmp"));
        let _ = fs::remove_file(self.meta_path().with_extension("meta.tmp"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn tmp_dir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("srsvd_ckpt_{name}"));
        let _ = fs::create_dir_all(&d);
        d
    }

    fn panel(seed: u64) -> Dense {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Dense::gaussian(9, 4, &mut rng)
    }

    fn bits(x: &Dense) -> Vec<u64> {
        x.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn round_trips_every_field_bit_exactly() {
        let dir = tmp_dir("round_trip");
        let c = Checkpointer::new(&dir, 0xDEAD_BEEF);
        let state = SweepState {
            stage: Stage::AdaptiveRange,
            sweep: 3,
            done: false,
            panel: panel(1),
            alpha: 0.1 + 0.2, // a value that would not survive decimal text
            fro2: 123.456789,
            prev: Some(vec![1.5, f64::MIN_POSITIVE, 0.0]),
        };
        c.save(&state);
        let got = c
            .load(Stage::AdaptiveRange, (9, 4))
            .expect("fresh checkpoint must load");
        assert_eq!(got.sweep, 3);
        assert!(!got.done);
        assert_eq!(bits(&got.panel), bits(&state.panel));
        assert_eq!(got.alpha.to_bits(), state.alpha.to_bits());
        assert_eq!(got.fro2.to_bits(), state.fro2.to_bits());
        let prev = got.prev.expect("prev survives");
        assert_eq!(prev.len(), 3);
        assert_eq!(prev[1].to_bits(), f64::MIN_POSITIVE.to_bits());
        c.clear();
        assert!(c.load(Stage::AdaptiveRange, (9, 4)).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fixed_state_omits_adaptive_fields() {
        let dir = tmp_dir("fixed");
        let c = Checkpointer::new(&dir, 7);
        c.save(&SweepState::fixed(Stage::ExactPower, 2, panel(2)));
        let got = c.load(Stage::ExactPower, (9, 4)).expect("loads");
        assert_eq!(got.sweep, 2);
        assert_eq!(got.alpha, 0.0);
        assert!(got.prev.is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn stage_shape_and_tag_mismatches_are_refused() {
        let dir = tmp_dir("mismatch");
        let c = Checkpointer::new(&dir, 11);
        c.save(&SweepState::fixed(Stage::FusedRange, 1, panel(3)));
        assert!(c.load(Stage::ExactPower, (9, 4)).is_none(), "stage");
        assert!(c.load(Stage::FusedRange, (9, 5)).is_none(), "shape");
        assert!(
            Checkpointer::new(&dir, 12).load(Stage::FusedRange, (9, 4)).is_none(),
            "tag"
        );
        assert!(c.load(Stage::FusedRange, (9, 4)).is_some(), "control");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_sidecar_or_panel_is_ignored() {
        let dir = tmp_dir("corrupt");
        let c = Checkpointer::new(&dir, 21);
        c.save(&SweepState::fixed(Stage::FusedRange, 1, panel(4)));
        // Torn sidecar.
        let meta = fs::read_to_string(c.meta_path()).unwrap();
        fs::write(c.meta_path(), &meta[..meta.len() / 2]).unwrap();
        assert!(c.load(Stage::FusedRange, (9, 4)).is_none(), "torn sidecar");
        fs::write(c.meta_path(), &meta).unwrap();
        assert!(c.load(Stage::FusedRange, (9, 4)).is_some(), "restored");
        // Panel bytes flipped under a valid sidecar: hash must catch it.
        let mut p = fs::read(c.panel_path()).unwrap();
        let last = p.len() - 1;
        p[last] ^= 0xFF;
        fs::write(c.panel_path(), &p).unwrap();
        assert!(c.load(Stage::FusedRange, (9, 4)).is_none(), "flipped panel");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_sidecar_writes_never_produce_a_valid_pair() {
        let _g = faults::test_lock();
        let dir = tmp_dir("torn_write");
        let c = Checkpointer::new(&dir, 31);
        faults::arm("ckpt.meta=partial_write:1@1.0").unwrap();
        c.save(&SweepState::fixed(Stage::FusedRange, 2, panel(5)));
        faults::disarm();
        // The torn save was swallowed (best-effort) and must not have
        // left a loadable pair behind.
        assert!(c.load(Stage::FusedRange, (9, 4)).is_none());
        // The next clean save recovers.
        c.save(&SweepState::fixed(Stage::FusedRange, 2, panel(5)));
        assert!(c.load(Stage::FusedRange, (9, 4)).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn written_and_resumed_counters_move() {
        let dir = tmp_dir("counters");
        let w0 = checkpoints_written();
        let r0 = checkpoints_resumed();
        let c = Checkpointer::new(&dir, 41);
        c.save(&SweepState::fixed(Stage::ExactPower, 1, panel(6)));
        assert!(checkpoints_written() > w0);
        let _ = c.load(Stage::ExactPower, (9, 4)).expect("loads");
        assert!(checkpoints_resumed() > r0);
        let _ = fs::remove_dir_all(&dir);
    }
}
