//! PCA built on the shifted factorization (paper §2): fit, transform,
//! reconstruct, and the error metrics of §5 (MSE, per-column errors for
//! win-rates and the H₀² t-test).

use crate::linalg::{gemm, Csr, Dense};
use crate::rng::Rng;
use crate::util::Result;

use super::{Factorization, MatVecOps, ShiftedRsvd, SvdConfig};

/// A fitted PCA model: the shifting vector and the principal axes.
#[derive(Debug, Clone)]
pub struct Pca {
    /// Mean vector μ (length m).
    pub mean: Vec<f64>,
    /// Principal axes U (m×k, columns = eigenvectors of the covariance).
    pub components: Dense,
    /// Singular values of X̄ (scale of each component).
    pub singular_values: Vec<f64>,
}

impl Pca {
    /// Fit by S-RSVD on the implicitly centered matrix (one pass, no
    /// densification).
    pub fn fit(x: &dyn MatVecOps, config: SvdConfig, rng: &mut dyn Rng) -> Result<Pca> {
        let mu = x.row_means();
        let f = ShiftedRsvd::new(config).factorize(x, &mu, rng)?;
        Ok(Pca { mean: mu, components: f.u, singular_values: f.s })
    }

    /// Number of fitted components.
    pub fn k(&self) -> usize {
        self.singular_values.len()
    }

    /// Project new columns: `Y = Uᵀ(X − μ1ᵀ)` (paper Eq. 1/3), computed
    /// through the rank-1 trick — `X` itself is never centered.
    pub fn transform(&self, x: &dyn MatVecOps) -> Dense {
        // Y = UᵀX − (Uᵀμ)1ᵀ. Compute transposed: Yᵀ = XᵀU − 1(μᵀU).
        let (_, n) = x.shape();
        let mtu = self.components.tmatvec(&self.mean);
        let yt = x.tmm_rank1(&self.components, &vec![1.0; n], &mtu);
        yt.transpose()
    }

    /// Reconstruct columns from scores: `X̂ = U·Y + μ1ᵀ` (m×n dense).
    pub fn inverse_transform(&self, y: &Dense) -> Dense {
        let mut rec = gemm::matmul(&self.components, y);
        for i in 0..rec.rows() {
            let m = self.mean[i];
            for v in rec.row_mut(i) {
                *v += m;
            }
        }
        rec
    }

    /// Mean squared column reconstruction error on `x` (dense path).
    pub fn mse(&self, x: &Dense) -> f64 {
        let errs = self.column_errors_dense(x);
        errs.iter().sum::<f64>() / errs.len() as f64
    }

    /// Per-column squared reconstruction errors ‖x̄ⱼ − UUᵀx̄ⱼ‖² (dense).
    pub fn column_errors_dense(&self, x: &Dense) -> Vec<f64> {
        let xbar = x.subtract_column(&self.mean);
        let y = gemm::tmatmul(&self.components, &xbar); // k×n scores
        let rec = gemm::matmul(&self.components, &y);
        (0..x.cols())
            .map(|j| {
                (0..x.rows())
                    .map(|i| {
                        let d = xbar[(i, j)] - rec[(i, j)];
                        d * d
                    })
                    .sum()
            })
            .collect()
    }

    /// Per-column squared errors for a sparse input, O(nnz·k + nk²):
    /// ‖x̄ⱼ − UUᵀx̄ⱼ‖² = ‖x̄ⱼ‖² − ‖Uᵀx̄ⱼ‖² (U orthonormal).
    pub fn column_errors_sparse(&self, x: &Csr) -> Vec<f64> {
        let (m, n) = x.shape();
        let k = self.k();
        // Scores Yᵀ = XᵀU − 1(μᵀU): n×k.
        let mtu = self.components.tmatvec(&self.mean);
        let yt = x.tmm_rank1(&self.components, &vec![1.0; n], &mtu);
        // ‖x̄ⱼ‖² = ‖xⱼ‖² − 2 μᵀxⱼ + ‖μ‖².
        let mu_sq: f64 = self.mean.iter().map(|v| v * v).sum();
        let mut col_sq = vec![0.0; n];
        let mut mu_dot = vec![0.0; n];
        for i in 0..m {
            let mi = self.mean[i];
            for (j, v) in x.row_iter(i) {
                col_sq[j] += v * v;
                mu_dot[j] += mi * v;
            }
        }
        (0..n)
            .map(|j| {
                let xbar_sq = col_sq[j] - 2.0 * mu_dot[j] + mu_sq;
                let proj_sq: f64 = (0..k).map(|l| yt[(j, l)] * yt[(j, l)]).sum();
                (xbar_sq - proj_sq).max(0.0)
            })
            .collect()
    }
}

/// Per-column squared errors of an arbitrary factorization against the
/// centered matrix — used to score RSVD (whose U spans the *uncentered*
/// range) under the paper's PCA protocol.
pub fn column_errors(x: &Dense, mu: &[f64], f: &Factorization) -> Vec<f64> {
    let xbar = x.subtract_column(mu);
    let y = gemm::tmatmul(&f.u, &xbar);
    let rec = gemm::matmul(&f.u, &y);
    (0..x.cols())
        .map(|j| {
            (0..x.rows())
                .map(|i| {
                    let d = xbar[(i, j)] - rec[(i, j)];
                    d * d
                })
                .sum()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;
    use crate::svd::deterministic::optimal_mse;

    fn uniform(m: usize, n: usize, seed: u64) -> Dense {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Dense::from_fn(m, n, |_, _| rng.next_uniform())
    }

    #[test]
    fn fit_transform_reconstruct_cycle() {
        let x = uniform(20, 120, 0);
        let cfg = SvdConfig::paper(6).with_fixed_power(2);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let pca = Pca::fit(&x, cfg, &mut rng).unwrap();
        let y = pca.transform(&x);
        assert_eq!(y.shape(), (6, 120));
        let rec = pca.inverse_transform(&y);
        // Reconstruction error ≈ MSE·n; both near the k=6 optimum.
        let mse = pca.mse(&x);
        let opt = optimal_mse(&x.subtract_column(&x.row_means()), 6);
        assert!(mse <= 1.3 * opt + 1e-12, "mse {mse} opt {opt}");
        let err = crate::linalg::fro_diff(&rec, &x);
        assert!((err * err / 120.0 - mse).abs() < 1e-8);
    }

    #[test]
    fn mse_is_mean_of_column_errors() {
        let x = uniform(15, 60, 2);
        let cfg = SvdConfig::paper(4);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let pca = Pca::fit(&x, cfg, &mut rng).unwrap();
        let errs = pca.column_errors_dense(&x);
        assert_eq!(errs.len(), 60);
        let mse = pca.mse(&x);
        assert!((mse - errs.iter().sum::<f64>() / 60.0).abs() < 1e-12);
        assert!(errs.iter().all(|&e| e >= 0.0));
    }

    #[test]
    fn sparse_column_errors_match_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let sp = Csr::random(20, 70, 0.15, &mut rng, |r| r.next_uniform() + 0.3);
        let de = sp.to_dense();
        let cfg = SvdConfig::paper(4).with_fixed_power(1);
        let pca = Pca::fit(&sp, cfg, &mut Xoshiro256pp::seed_from_u64(5)).unwrap();
        let es = pca.column_errors_sparse(&sp);
        let ed = pca.column_errors_dense(&de);
        for (a, b) in es.iter().zip(&ed) {
            assert!((a - b).abs() < 1e-8 * b.max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn column_errors_for_external_factorization() {
        let x = uniform(18, 50, 6);
        let mu = x.row_means();
        let cfg = SvdConfig::paper(4);
        let f = crate::svd::Rsvd::new(cfg)
            .factorize(&x, &mut Xoshiro256pp::seed_from_u64(7))
            .unwrap();
        let errs = column_errors(&x, &mu, &f);
        assert_eq!(errs.len(), 50);
        // The centered model must beat the uncentered one on average.
        let pca = Pca::fit(&x, cfg, &mut Xoshiro256pp::seed_from_u64(8)).unwrap();
        let errs_pca = pca.column_errors_dense(&x);
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        assert!(mean(&errs_pca) < mean(&errs));
    }
}
