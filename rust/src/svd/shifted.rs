//! S-RSVD — the paper's Algorithm 1.
//!
//! Rank-k SVD of `X̄ = X − μ·1ᵀ` without materializing `X̄`:
//!
//! ```text
//! 1. Ω ~ N(0,1)^{n×K}
//! 2. basis Q of X̄Ω            (L2-7: sample + QR, shift via rank-1)
//! 3. q power iterations        (L8-11: Q ← qr(X̄ qr(X̄ᵀQ)))
//! 4. Y = QᵀX̄                  (L12: projection, shift via rank-1)
//! 5. Y = U₁ΣVᵀ, U = QU₁       (L13-14: small SVD + back-projection)
//! ```
//!
//! Every product against `X̄` is a product against `X` plus a rank-1
//! downdate (Eqs. 7/8/10), dispatched through [`MatVecOps`] so sparse
//! inputs stay sparse — the complexity drops from O(mnk) to
//! O(nnz·k + (m+n)k²) (paper Eq. 15).
//!
//! All those products — the sampling pass, each power-iteration leg
//! (L8-11) and the projection (L12) — execute on the shared
//! [`crate::parallel`] pool via the pool-aware [`MatVecOps`] kernels.
//! The parallel kernels partition output rows, so a factorization is
//! bit-identical for every pool size: seeded runs replay exactly.
//!
//! ## Sweep stages and the pass schedule
//!
//! The engine is organized as explicit sweep stages, each of which
//! touches the data matrix a known number of times — the currency that
//! matters for out-of-core ([`crate::linalg::Streamed`]) inputs, where
//! every product is a full disk sweep:
//!
//! | Stage | [`PassPolicy::Exact`] | [`PassPolicy::Fused`] |
//! |-------|-----------------------|------------------------|
//! | sampling basis (L2-7)    | 1 | — (folded into range capture) |
//! | power iteration ×q (L8-11) | 2 per iteration | 1 per iteration ([`MatVecOps::gram_sweep`]) |
//! | range capture            | — | 1 (`H = X̄W`, then QR) |
//! | projection (L12)         | 1 | 1 |
//! | **total source passes**  | **2 + 2q** | **q + 2** |
//!
//! `Exact` runs the paper's literal chain (`Q ← qr(X̄·qr(X̄ᵀQ))`) and is
//! byte-identical to the in-memory path for streamed sources. `Fused`
//! runs the Gram-chain variant of Halko et al. (arXiv:1007.5510 §4.5 /
//! Li et al. arXiv:1412.3510): each iteration computes `X̄ᵀ(X̄·W)` in
//! one pass and renormalizes with an n×K Householder QR — which needs
//! no data pass at all — so the subspace is mathematically the same
//! (`range((X̄X̄ᵀ)^q X̄Ω)` either way) but the factors are not
//! bit-identical to `Exact`.

use crate::linalg::{
    gemm, householder_qr, jacobi_svd, qr_rank1_update, sym_jacobi_eig, Dense, JacobiOpts,
};
use crate::rng::Rng;
use crate::util::Result;

use super::ops::colsums;
use super::{Factorization, MatVecOps, SvdConfig};

/// How the basis of the shifted sample matrix is computed (Alg. 1 L4-6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BasisMethod {
    /// Fuse the shift into the sampling product and QR once:
    /// `Q = qr(XΩ − μ(1ᵀΩ))`. Mathematically the exact shifted sample;
    /// O(mK²). This is the default.
    Direct,
    /// The paper's literal Line 4-6: `Q₁R₁ = qr(XΩ)` then rank-1
    /// QR-update with `u = −μ, v = 1` (K ones). Note `XΩ − μ1ᵀ` is not
    /// exactly `X̄Ω`; both bases contain span{μ} so accuracy matches —
    /// quantified by the `ablation_qr_update` bench.
    QrUpdatePaper,
    /// QR-update with the exact right factor `v = Ωᵀ1` (column sums),
    /// making the updated factorization exactly `qr(X̄Ω)`.
    QrUpdateExact,
}

/// Source-pass schedule of the sweep stages: how many passes over the
/// data matrix one factorization performs. The dominant wall-clock
/// lever for out-of-core inputs, where every pass is a disk sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassPolicy {
    /// One sweep per product — sampling, two per power iteration,
    /// projection: `2 + 2q` passes. Streamed factorizations stay
    /// **byte-identical** to the in-memory [`Dense`] path (the
    /// `rust/tests/stream.rs` contract). The default.
    Exact,
    /// Fused Gram-chain power passes: each iteration computes
    /// `X̄ᵀ(X̄·W)` in one sweep ([`MatVecOps::gram_sweep`]) with an n×K
    /// Householder QR renormalization between passes (no data pass),
    /// for `q + 2` passes total. Same subspace in exact arithmetic and
    /// the same accuracy bound in tests, but *not* bit-identical to
    /// `Exact`. [`BasisMethod`] is not consulted — the fused schedule
    /// has no separate sampling QR to rank-1-update (its capture pass
    /// is always the exact shifted product).
    Fused,
}

impl PassPolicy {
    /// Canonical lowercase name (`"exact"` / `"fused"`) — the inverse
    /// of [`crate::config::parse_pass_policy`], shared by the wire
    /// protocol and the bench JSON schema so they cannot desynchronize.
    pub fn name(&self) -> &'static str {
        match self {
            PassPolicy::Exact => "exact",
            PassPolicy::Fused => "fused",
        }
    }
}

/// Backend for the small K×n SVD (Alg. 1 L13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SmallSvdMethod {
    /// One-sided Jacobi on Yᵀ (n×K): accurate, O(nK²·sweeps).
    Jacobi,
    /// Eigendecomposition of the K×K Gram matrix YYᵀ: faster for large
    /// n, squares the condition number (fine for top-k factors).
    GramEig,
}

/// The shifted randomized SVD engine.
#[derive(Debug, Clone, Copy)]
pub struct ShiftedRsvd {
    /// Rank / oversampling / power-iteration configuration.
    pub config: SvdConfig,
}

impl ShiftedRsvd {
    /// Build an engine with the given configuration.
    pub fn new(config: SvdConfig) -> Self {
        ShiftedRsvd { config }
    }

    /// Factorize `X − μ·1ᵀ`. `mu` may be any m-vector; zeros reduce the
    /// algorithm to plain RSVD on `X` (Halko et al. 2011).
    pub fn factorize(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        let (m, n) = x.shape();
        crate::ensure!(mu.len() == m, "mu length {} != m {}", mu.len(), m);
        let k = self.config.k;
        let kk = self.config.sample_width().min(m).min(n);
        crate::ensure!(k >= 1, "rank k must be >= 1");
        crate::ensure!(k <= kk, "k {} exceeds sample width {}", k, kk);

        let shifted = mu.iter().any(|&v| v != 0.0);
        let ones_n = vec![1.0; n];

        // ---- Stage 1+2: range finding (L2-11) -----------------------------
        // Sampling + power schedule, dispatched on the pass policy. The
        // Exact stages replay the original operation sequence verbatim,
        // so streamed byte-identity is preserved.
        let omega = Dense::gaussian(n, kk, rng);
        let q = match self.config.pass_policy {
            PassPolicy::Exact => {
                let q0 = self.exact_basis(x, mu, &omega, shifted, kk);
                self.exact_power(x, mu, q0, &ones_n)
            }
            PassPolicy::Fused => self.fused_range(x, mu, omega, shifted),
        };

        // ---- Stage 3: project (L12) ---------------------------------------
        // Yᵀ = X̄ᵀQ (n×K) — computed transposed so the sparse path streams
        // CSR rows once; Y itself is never formed.
        let mtq = q.tmatvec(mu);
        let yt = x.tmm_rank1(&q, &ones_n, &mtq);

        // ---- Stage 4: small SVD + back-projection (L13-14) ----------------
        let (u1, s, v) = match self.config.small_svd {
            SmallSvdMethod::Jacobi => {
                // Yᵀ = U_t Σ V_tᵀ → Y = V_t Σ U_tᵀ: left factors V_t (K×K),
                // right factors U_t (n×K).
                let (ut, s, vt) = jacobi_svd(&yt, JacobiOpts::default());
                (vt, s, ut)
            }
            SmallSvdMethod::GramEig => {
                // G = YYᵀ = YtᵀYt (K×K) = U₁ Σ² U₁ᵀ; V = Yt U₁ Σ⁻¹.
                let g = gemm::tmatmul(&yt, &yt);
                let (evecs, evals) = sym_jacobi_eig(&g, JacobiOpts::default());
                let s: Vec<f64> = evals.iter().map(|&l| l.max(0.0).sqrt()).collect();
                let inv: Vec<f64> = s
                    .iter()
                    .map(|&x| if x > 1e-300 { 1.0 / x } else { 0.0 })
                    .collect();
                let v = gemm::matmul(&yt, &evecs).scale_cols(&inv);
                (evecs, s, v)
            }
        };

        let u = gemm::matmul(&q, &u1); // m×K
        Ok(Factorization {
            u: u.truncate_cols(k),
            s: s[..k].to_vec(),
            v: v.truncate_cols(k),
        })
    }

    /// Exact sampling stage (L2-7): basis of `X̄Ω`, one source pass.
    /// Replays the pre-stage-refactor operation sequence verbatim (the
    /// streamed byte-identity contract pins this).
    fn exact_basis(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        omega: &Dense,
        shifted: bool,
        kk: usize,
    ) -> Dense {
        match (self.config.basis, shifted) {
            (_, false) => {
                // mu = 0: plain RSVD sampling.
                householder_qr(&x.mm(omega)).0
            }
            (BasisMethod::Direct, true) => {
                let colsum: Vec<f64> = colsums(omega);
                householder_qr(&x.mm_rank1(omega, mu, &colsum)).0
            }
            (BasisMethod::QrUpdatePaper, true) => {
                let (q1, r1) = householder_qr(&x.mm(omega));
                let neg_mu: Vec<f64> = mu.iter().map(|v| -v).collect();
                let v1 = vec![1.0; kk]; // the paper's v = 1
                qr_rank1_update(&q1, &r1, &neg_mu, &v1).q
            }
            (BasisMethod::QrUpdateExact, true) => {
                let (q1, r1) = householder_qr(&x.mm(omega));
                let neg_mu: Vec<f64> = mu.iter().map(|v| -v).collect();
                let v1 = colsums(omega); // exact: v = Ωᵀ1
                qr_rank1_update(&q1, &r1, &neg_mu, &v1).q
            }
        }
    }

    /// Exact power stage (L8-11): `Q ← qr(X̄·qr(X̄ᵀQ))`, two source
    /// passes per iteration.
    fn exact_power(&self, x: &dyn MatVecOps, mu: &[f64], mut q: Dense, ones_n: &[f64]) -> Dense {
        for _ in 0..self.config.power_iters {
            // Q' = qr(X̄ᵀQ) = qr(XᵀQ − 1(μᵀQ))
            let mtq = q.tmatvec(mu); // μᵀQ, length K
            let qp = householder_qr(&x.tmm_rank1(&q, ones_n, &mtq)).0;
            // Q = qr(X̄Q') = qr(XQ' − μ(1ᵀQ'))
            let colsum_qp = colsums(&qp);
            q = householder_qr(&x.mm_rank1(&qp, mu, &colsum_qp)).0;
        }
        q
    }

    /// Fused range finding: `q` Gram sweeps (`W ← qr(X̄ᵀ(X̄·W))`, one
    /// source pass each — the between-pass QR is an n×K Householder
    /// factorization that touches no data), then one capture pass
    /// `Q = qr(X̄·W)`. Total `q + 1` source passes; with the projection
    /// stage the whole factorization does `q + 2` (vs `2 + 2q` Exact).
    fn fused_range(&self, x: &dyn MatVecOps, mu: &[f64], omega: Dense, shifted: bool) -> Dense {
        let mut w = omega; // n×K, the evolving right-side sample
        for _ in 0..self.config.power_iters {
            let z = x.gram_sweep(&w, mu);
            w = householder_qr(&z).0; // renormalize: no data pass
        }
        let h = if shifted {
            let colsum = colsums(&w);
            x.mm_rank1(&w, mu, &colsum) // H = X̄·W, one pass
        } else {
            x.mm(&w)
        };
        householder_qr(&h).0
    }

    /// Convenience: factorize the mean-centered matrix (μ = row means) —
    /// the PCA use case of §2.
    pub fn factorize_mean_centered(
        &self,
        x: &dyn MatVecOps,
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        let mu = x.row_means();
        self.factorize(x, &mu, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_diff, Csr};
    use crate::rng::Xoshiro256pp;
    use crate::svd::deterministic::optimal_residual;

    fn uniform(m: usize, n: usize, seed: u64) -> Dense {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Dense::from_fn(m, n, |_, _| rng.next_uniform())
    }

    #[test]
    fn near_optimal_on_centered_target() {
        let x = uniform(50, 300, 0);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let cfg = SvdConfig { k: 8, oversample: 8, power_iters: 2, ..Default::default() };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
        let err = fro_diff(&f.reconstruct(), &xbar);
        let opt = optimal_residual(&xbar, 8);
        assert!(err <= 1.15 * opt, "err {err} vs opt {opt}");
    }

    #[test]
    fn zero_mu_is_plain_rsvd() {
        let x = uniform(40, 120, 2);
        let cfg = SvdConfig { k: 6, oversample: 6, power_iters: 2, ..Default::default() };
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let f = ShiftedRsvd::new(cfg)
            .factorize(&x, &vec![0.0; 40], &mut rng)
            .unwrap();
        let err = fro_diff(&f.reconstruct(), &x);
        let opt = optimal_residual(&x, 6);
        assert!(err <= 1.15 * opt, "err {err} vs opt {opt}");
    }

    #[test]
    fn all_basis_methods_are_accurate() {
        let x = uniform(40, 150, 4);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let opt = optimal_residual(&xbar, 6);
        for basis in [
            BasisMethod::Direct,
            BasisMethod::QrUpdatePaper,
            BasisMethod::QrUpdateExact,
        ] {
            let cfg = SvdConfig {
                k: 6,
                oversample: 6,
                power_iters: 2,
                basis,
                ..Default::default()
            };
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let err = fro_diff(&f.reconstruct(), &xbar);
            assert!(err <= 1.2 * opt, "{basis:?}: err {err} vs opt {opt}");
        }
    }

    #[test]
    fn gram_eig_matches_jacobi_backend() {
        let x = uniform(30, 200, 6);
        let mu = x.row_means();
        for method in [SmallSvdMethod::Jacobi, SmallSvdMethod::GramEig] {
            let cfg = SvdConfig {
                k: 5,
                oversample: 5,
                power_iters: 1,
                small_svd: method,
                ..Default::default()
            };
            // Same seed → same Ω → same basis: the two backends must agree
            // on singular values tightly.
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let mut rng2 = Xoshiro256pp::seed_from_u64(7);
            let f2 = ShiftedRsvd::new(SvdConfig {
                small_svd: SmallSvdMethod::Jacobi,
                ..cfg
            })
            .factorize(&x, &mu, &mut rng2)
            .unwrap();
            for (a, b) in f.s.iter().zip(&f2.s) {
                assert!((a - b).abs() < 1e-6 * f2.s[0], "{method:?}");
            }
        }
    }

    #[test]
    fn sparse_path_matches_dense_path_exactly() {
        // Same Ω (same seed) ⇒ bitwise-comparable results modulo float
        // associativity; they must agree to ~1e-10.
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let sp = Csr::random(40, 200, 0.05, &mut rng, |r| r.next_uniform() + 0.5);
        let de = sp.to_dense();
        let mu = MatVecOps::row_means(&sp);
        let cfg = SvdConfig { k: 5, oversample: 5, power_iters: 1, ..Default::default() };
        let f_sp = ShiftedRsvd::new(cfg)
            .factorize(&sp, &mu, &mut Xoshiro256pp::seed_from_u64(9))
            .unwrap();
        let f_de = ShiftedRsvd::new(cfg)
            .factorize(&de, &mu, &mut Xoshiro256pp::seed_from_u64(9))
            .unwrap();
        for (a, b) in f_sp.s.iter().zip(&f_de.s) {
            assert!((a - b).abs() < 1e-8, "sv {a} vs {b}");
        }
        assert!(fro_diff(&f_sp.reconstruct(), &f_de.reconstruct()) < 1e-7);
    }

    #[test]
    fn implicit_equals_explicit_centering() {
        // Fig. 1d: S-RSVD(X, μ) ≈ RSVD(X̄ explicit) with the same Ω.
        let x = uniform(30, 100, 10);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let cfg = SvdConfig { k: 5, oversample: 5, power_iters: 1, ..Default::default() };
        let f_implicit = ShiftedRsvd::new(cfg)
            .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(11))
            .unwrap();
        let f_explicit = ShiftedRsvd::new(cfg)
            .factorize(&xbar, &vec![0.0; 30], &mut Xoshiro256pp::seed_from_u64(11))
            .unwrap();
        for (a, b) in f_implicit.s.iter().zip(&f_explicit.s) {
            assert!((a - b).abs() < 1e-9 * f_explicit.s[0].max(1.0));
        }
        assert!(
            fro_diff(&f_implicit.reconstruct(), &f_explicit.reconstruct()) < 1e-8
        );
    }

    #[test]
    fn fused_pass_policy_is_accurate() {
        let x = uniform(50, 300, 14);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let opt = optimal_residual(&xbar, 8);
        for q in [1usize, 2] {
            let cfg = SvdConfig {
                k: 8,
                oversample: 8,
                power_iters: q,
                pass_policy: PassPolicy::Fused,
                ..Default::default()
            };
            let mut rng = Xoshiro256pp::seed_from_u64(15);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let err = fro_diff(&f.reconstruct(), &xbar);
            assert!(err <= 1.15 * opt, "q={q}: err {err} vs opt {opt}");
        }
    }

    #[test]
    fn fused_with_zero_power_iters_equals_exact_direct_bitwise() {
        // With q = 0 the fused schedule degenerates to exactly the
        // Exact/Direct operation sequence: capture pass + projection.
        let x = uniform(40, 120, 16);
        let mu = x.row_means();
        let run = |pass_policy| {
            let cfg = SvdConfig {
                k: 5,
                oversample: 5,
                power_iters: 0,
                pass_policy,
                ..Default::default()
            };
            ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(17))
                .unwrap()
        };
        let e = run(PassPolicy::Exact);
        let f = run(PassPolicy::Fused);
        let bits = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&e.u), bits(&f.u));
        assert_eq!(bits(&e.v), bits(&f.v));
        assert_eq!(
            e.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            f.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn invalid_configs_error() {
        let x = uniform(10, 20, 12);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        // mu wrong length.
        assert!(ShiftedRsvd::new(SvdConfig::paper(2))
            .factorize(&x, &[0.0; 3], &mut rng)
            .is_err());
        // k = 0.
        let bad = SvdConfig { k: 0, ..Default::default() };
        assert!(ShiftedRsvd::new(bad)
            .factorize(&x, &vec![0.0; 10], &mut rng)
            .is_err());
    }

    #[test]
    fn rank_capped_by_matrix_size() {
        // K = k + oversample > min(m, n) must clamp, not panic.
        let x = uniform(8, 12, 13);
        let cfg = SvdConfig { k: 6, oversample: 20, ..Default::default() };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let f = ShiftedRsvd::new(cfg)
            .factorize_mean_centered(&x, &mut rng)
            .unwrap();
        assert_eq!(f.rank(), 6);
    }
}
