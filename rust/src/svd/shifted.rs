//! S-RSVD — the paper's Algorithm 1.
//!
//! Rank-k SVD of `X̄ = X − μ·1ᵀ` without materializing `X̄`:
//!
//! ```text
//! 1. Ω ~ N(0,1)^{n×K}
//! 2. basis Q of X̄Ω            (L2-7: sample + QR, shift via rank-1)
//! 3. q power iterations        (L8-11: Q ← qr(X̄ qr(X̄ᵀQ)))
//! 4. Y = QᵀX̄                  (L12: projection, shift via rank-1)
//! 5. Y = U₁ΣVᵀ, U = QU₁       (L13-14: small SVD + back-projection)
//! ```
//!
//! Every product against `X̄` is a product against `X` plus a rank-1
//! downdate (Eqs. 7/8/10), dispatched through [`MatVecOps`] so sparse
//! inputs stay sparse — the complexity drops from O(mnk) to
//! O(nnz·k + (m+n)k²) (paper Eq. 15).
//!
//! All those products — the sampling pass, each power-iteration leg
//! (L8-11) and the projection (L12) — execute on the shared
//! [`crate::parallel`] pool via the pool-aware [`MatVecOps`] kernels.
//! The parallel kernels partition output rows, so a factorization is
//! bit-identical for every pool size: seeded runs replay exactly.
//!
//! ## Sweep stages and the pass schedule
//!
//! The engine is organized as explicit sweep stages, each of which
//! touches the data matrix a known number of times — the currency that
//! matters for out-of-core ([`crate::linalg::Streamed`]) inputs, where
//! every product is a full disk sweep:
//!
//! | Stage | [`PassPolicy::Exact`] | [`PassPolicy::Fused`] | adaptive ([`StopCriterion::Tolerance`]) |
//! |-------|-----------------------|------------------------|------------------------|
//! | `‖X̄‖²_F` ([`MatVecOps::sq_fro_shifted`]) | — | — | 1 |
//! | sampling basis (L2-7)    | 1 | — (folded into range capture) | — (Ω orthonormalized, no data pass) |
//! | power iteration (L8-11) | 2 per iteration ×q | 1 per iteration ×q ([`MatVecOps::gram_sweep`]) | 1 per sweep, count decided at run time |
//! | range capture            | — | 1 (`H = X̄W`, then QR) | 1 |
//! | projection (L12)         | 1 | 1 | 1 |
//! | **total source passes**  | **2 + 2q** | **q + 2** | **sweeps + 3** |
//!
//! `Exact` runs the paper's literal chain (`Q ← qr(X̄·qr(X̄ᵀQ))`) and is
//! byte-identical to the in-memory path for streamed sources. `Fused`
//! runs the Gram-chain variant of Halko et al. (arXiv:1007.5510 §4.5 /
//! Li et al. arXiv:1412.3510): each iteration computes `X̄ᵀ(X̄·W)` in
//! one pass and renormalizes with an n×K Householder QR — which needs
//! no data pass at all — so the subspace is mathematically the same
//! (`range((X̄X̄ᵀ)^q X̄Ω)` either way) but the factors are not
//! bit-identical to `Exact`.
//!
//! ## Dynamic shifts + accuracy control (dashSVD, arXiv:2404.09276)
//!
//! Under [`StopCriterion::Tolerance`] the engine runs *shifted* power
//! iteration on `X̄ᵀX̄ − αI`: each sweep computes
//! `Z = gram_sweep(W) − α·W` (the dynamic shift is a rank-K epilogue
//! composing with the same fused Gram sweep, one source pass), takes a
//! small deterministic SVD of the n×K `Z` to obtain Ritz estimates
//! `λ̂_j = s_j(Z) + α` of the eigenvalues of `X̄ᵀX̄`, then updates the
//! shift to `α ← (α + λ̂_K)/2` — half-way toward the smallest retained
//! estimate, which damps the unwanted tail of the spectrum and
//! accelerates convergence of the leading subspace. The loop stops as
//! soon as `max_{j<k} |λ̂_j − λ̂_j'| ≤ pve_tol · ‖X̄‖²_F` between
//! consecutive sweeps (the PVE accuracy criterion), or at `max_sweeps`.
//! Ω is orthonormalized before the first sweep (an n×K Householder QR,
//! no data pass) so the Ritz bound `λ̂_j ≤ λ_j` holds from sweep one
//! and the shift can never overshoot the spectrum.
//!
//! Every stage is deterministic and accumulates in a fixed order, so
//! the adaptive path inherits the crate-wide contract: factors are
//! bit-identical across thread-pool sizes and streamed block sizes.
//! [`ShiftedRsvd::factorize_with_report`] surfaces the sweeps actually
//! used and the achieved PVE.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::linalg::{
    gemm, householder_qr, jacobi_svd, qr_rank1_update, sym_jacobi_eig, Dense, JacobiOpts,
};
use crate::rng::Rng;
use crate::util::{faults, Error, Result};

use super::checkpoint::{Checkpointer, Stage, SweepState};
use super::ops::colsums;
use super::{Factorization, MatVecOps, StopCriterion, SvdConfig};

/// How the basis of the shifted sample matrix is computed (Alg. 1 L4-6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BasisMethod {
    /// Fuse the shift into the sampling product and QR once:
    /// `Q = qr(XΩ − μ(1ᵀΩ))`. Mathematically the exact shifted sample;
    /// O(mK²). This is the default.
    Direct,
    /// The paper's literal Line 4-6: `Q₁R₁ = qr(XΩ)` then rank-1
    /// QR-update with `u = −μ, v = 1` (K ones). Note `XΩ − μ1ᵀ` is not
    /// exactly `X̄Ω`; both bases contain span{μ} so accuracy matches —
    /// quantified by the `ablation_qr_update` bench.
    QrUpdatePaper,
    /// QR-update with the exact right factor `v = Ωᵀ1` (column sums),
    /// making the updated factorization exactly `qr(X̄Ω)`.
    QrUpdateExact,
}

/// Source-pass schedule of the sweep stages: how many passes over the
/// data matrix one factorization performs. The dominant wall-clock
/// lever for out-of-core inputs, where every pass is a disk sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PassPolicy {
    /// One sweep per product — sampling, two per power iteration,
    /// projection: `2 + 2q` passes. Streamed factorizations stay
    /// **byte-identical** to the in-memory [`Dense`] path (the
    /// `rust/tests/stream.rs` contract). The default.
    Exact,
    /// Fused Gram-chain power passes: each iteration computes
    /// `X̄ᵀ(X̄·W)` in one sweep ([`MatVecOps::gram_sweep`]) with an n×K
    /// Householder QR renormalization between passes (no data pass),
    /// for `q + 2` passes total. Same subspace in exact arithmetic and
    /// the same accuracy bound in tests, but *not* bit-identical to
    /// `Exact`. [`BasisMethod`] is not consulted — the fused schedule
    /// has no separate sampling QR to rank-1-update (its capture pass
    /// is always the exact shifted product).
    Fused,
}

impl PassPolicy {
    /// Canonical lowercase name (`"exact"` / `"fused"`) — the inverse
    /// of [`crate::config::parse_pass_policy`], shared by the wire
    /// protocol and the bench JSON schema so they cannot desynchronize.
    pub fn name(&self) -> &'static str {
        match self {
            PassPolicy::Exact => "exact",
            PassPolicy::Fused => "fused",
        }
    }
}

/// Backend for the small K×n SVD (Alg. 1 L13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SmallSvdMethod {
    /// One-sided Jacobi on Yᵀ (n×K): accurate, O(nK²·sweeps).
    Jacobi,
    /// Eigendecomposition of the K×K Gram matrix YYᵀ: faster for large
    /// n, squares the condition number (fine for top-k factors).
    GramEig,
}

/// What the power-sweep loop of one factorization actually did —
/// returned by [`ShiftedRsvd::factorize_with_report`] and surfaced
/// through the coordinator's job results and `GET /metrics`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepReport {
    /// Power sweeps executed. Equals `q` under
    /// [`StopCriterion::FixedPower`]; decided at run time by the PVE
    /// rule under [`StopCriterion::Tolerance`].
    pub sweeps_used: usize,
    /// Proportion of the shifted matrix's variance explained by the
    /// retained k factors, `Σ_{j<k} s_j² / ‖X̄‖²_F`. Only computed by
    /// the adaptive mode (it already paid the `‖X̄‖²_F` pass); `None`
    /// under [`StopCriterion::FixedPower`], which keeps the legacy
    /// pass budget untouched.
    pub achieved_pve: Option<f64>,
}

/// Cooperative-cancellation checkpoint: the coordinator's shared flag
/// is polled between power sweeps (and between streamed blocks inside
/// [`crate::linalg::Streamed`]); a set flag abandons the factorization.
fn check_cancel(cancel: &AtomicBool) -> Result<()> {
    if cancel.load(Ordering::Relaxed) {
        Err(Error::Cancelled("factorization cancelled".into()))
    } else {
        Ok(())
    }
}

/// The shifted randomized SVD engine.
#[derive(Debug, Clone)]
pub struct ShiftedRsvd {
    /// Rank / oversampling / power-iteration configuration.
    pub config: SvdConfig,
    /// Sweep-granular crash-safe checkpointing
    /// ([`crate::svd::checkpoint`]); `None` — the default — runs
    /// exactly as before checkpointing existed.
    checkpoint: Option<Checkpointer>,
}

impl ShiftedRsvd {
    /// Build an engine with the given configuration.
    pub fn new(config: SvdConfig) -> Self {
        ShiftedRsvd { config, checkpoint: None }
    }

    /// Enable sweep-granular checkpointing: after every completed
    /// power/adaptive sweep the engine spills its state through `ckpt`,
    /// and on the next run of the same spec it resumes from the latest
    /// valid checkpoint — producing factors byte-identical to an
    /// uninterrupted run. Checkpoints are cleared on success.
    pub fn with_checkpoint(mut self, ckpt: Checkpointer) -> Self {
        self.checkpoint = Some(ckpt);
        self
    }

    fn load_checkpoint(&self, stage: Stage, shape: (usize, usize)) -> Option<SweepState> {
        self.checkpoint.as_ref()?.load(stage, shape)
    }

    fn save_checkpoint(&self, state: &SweepState) {
        if let Some(c) = &self.checkpoint {
            c.save(state);
        }
    }

    /// Factorize `X − μ·1ᵀ`. `mu` may be any m-vector; zeros reduce the
    /// algorithm to plain RSVD on `X` (Halko et al. 2011).
    pub fn factorize(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        Ok(self.factorize_with_report(x, mu, rng)?.0)
    }

    /// Like [`ShiftedRsvd::factorize`], additionally reporting the
    /// sweeps actually executed and (in adaptive mode) the achieved
    /// PVE. [`StopCriterion::FixedPower`] runs are unchanged by the
    /// report — same operation sequence, byte-identical factors.
    pub fn factorize_with_report(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        rng: &mut dyn Rng,
    ) -> Result<(Factorization, SweepReport)> {
        self.factorize_with_report_cancellable(x, mu, rng, &AtomicBool::new(false))
    }

    /// Like [`ShiftedRsvd::factorize_with_report`], polling a shared
    /// cancel flag between power sweeps: when the coordinator sets it
    /// (job cancellation / eviction), the factorization abandons its
    /// remaining work and fails with [`Error::Cancelled`]. A never-set
    /// flag leaves the operation sequence — and the factors —
    /// byte-identical to the plain entry points.
    pub fn factorize_with_report_cancellable(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        rng: &mut dyn Rng,
        cancel: &AtomicBool,
    ) -> Result<(Factorization, SweepReport)> {
        // Scope the job's kernel tier onto this thread: every product
        // below (and in the helpers it calls) dispatches on the
        // configured precision without threading it through each call.
        // The gemm layer resolves the kernel once per product on the
        // calling thread, so pool workers inherit the decision.
        crate::linalg::gemm::kernels::with_precision(self.config.precision, || {
            self.factorize_stages(x, mu, rng, cancel)
        })
    }

    /// The factorization pipeline proper, running under the precision
    /// scope installed by the public entry point.
    fn factorize_stages(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        rng: &mut dyn Rng,
        cancel: &AtomicBool,
    ) -> Result<(Factorization, SweepReport)> {
        let (m, n) = x.shape();
        crate::ensure!(mu.len() == m, "mu length {} != m {}", mu.len(), m);
        let k = self.config.k;
        let kk = self.config.sample_width().min(m).min(n);
        crate::ensure!(k >= 1, "rank k must be >= 1");
        crate::ensure!(k <= kk, "k {} exceeds sample width {}", k, kk);

        let shifted = mu.iter().any(|&v| v != 0.0);
        let ones_n = vec![1.0; n];

        // ---- Stage 1+2: range finding (L2-11) -----------------------------
        // Sampling + power schedule, dispatched on the stop criterion
        // and pass policy. The FixedPower stages replay the original
        // operation sequence verbatim, so streamed byte-identity and
        // the pre-redesign fixed-q factors are preserved.
        check_cancel(cancel)?;
        let omega = Dense::gaussian(n, kk, rng);
        let (q, sweeps_used, fro2) = match self.config.stop {
            StopCriterion::FixedPower { q: iters } => {
                let basis = match self.config.pass_policy {
                    PassPolicy::Exact => {
                        // A valid checkpoint replaces the sampling
                        // basis (and its source pass) with the panel as
                        // of the last completed sweep; Ω was already
                        // drawn above, so the RNG stream is unperturbed.
                        let (q0, start) = match self.load_checkpoint(Stage::ExactPower, (m, kk)) {
                            Some(st) => (st.panel, st.sweep),
                            None => (self.exact_basis(x, mu, &omega, shifted, kk), 0),
                        };
                        self.exact_power(x, mu, q0, &ones_n, start, iters, cancel)?
                    }
                    PassPolicy::Fused => {
                        self.fused_range(x, mu, omega, shifted, iters, cancel)?
                    }
                };
                (basis, iters, None)
            }
            StopCriterion::Tolerance { pve_tol, max_sweeps } => {
                let (basis, sweeps, fro2) =
                    self.adaptive_range(x, mu, omega, shifted, pve_tol, max_sweeps, cancel)?;
                (basis, sweeps, Some(fro2))
            }
        };
        check_cancel(cancel)?;

        // ---- Stage 3: project (L12) ---------------------------------------
        // Yᵀ = X̄ᵀQ (n×K) — computed transposed so the sparse path streams
        // CSR rows once; Y itself is never formed.
        let mtq = q.tmatvec(mu);
        let yt = x.tmm_rank1(&q, &ones_n, &mtq);
        // A cancel raised mid-projection leaves `yt` truncated on the
        // streamed path; re-check before treating it as a result.
        check_cancel(cancel)?;

        // ---- Stage 4: small SVD + back-projection (L13-14) ----------------
        let (u1, s, v) = match self.config.small_svd {
            SmallSvdMethod::Jacobi => {
                // Yᵀ = U_t Σ V_tᵀ → Y = V_t Σ U_tᵀ: left factors V_t (K×K),
                // right factors U_t (n×K).
                let (ut, s, vt) = jacobi_svd(&yt, JacobiOpts::default());
                (vt, s, ut)
            }
            SmallSvdMethod::GramEig => {
                // G = YYᵀ = YtᵀYt (K×K) = U₁ Σ² U₁ᵀ; V = Yt U₁ Σ⁻¹.
                let g = gemm::tmatmul(&yt, &yt);
                let (evecs, evals) = sym_jacobi_eig(&g, JacobiOpts::default());
                let s: Vec<f64> = evals.iter().map(|&l| l.max(0.0).sqrt()).collect();
                let inv: Vec<f64> = s
                    .iter()
                    .map(|&x| if x > 1e-300 { 1.0 / x } else { 0.0 })
                    .collect();
                let v = gemm::matmul(&yt, &evecs).scale_cols(&inv);
                (evecs, s, v)
            }
        };

        let u = gemm::matmul(&q, &u1); // m×K

        // Achieved PVE from the final singular values of X̄: since
        // s_j² are the eigenvalues of X̄ᵀX̄, Σ_{j<k} s_j² / ‖X̄‖²_F is
        // exactly the proportion of variance the retained factors
        // explain. Only the adaptive mode paid the fro² pass.
        let achieved_pve = fro2.map(|f2| {
            if f2 > 0.0 {
                s[..k].iter().map(|v| v * v).sum::<f64>() / f2
            } else {
                0.0
            }
        });
        // The factorization completed: its checkpoint is now stale
        // state that must not shadow a future identical job.
        if let Some(c) = &self.checkpoint {
            c.clear();
        }
        let report = SweepReport { sweeps_used, achieved_pve };
        Ok((
            Factorization {
                u: u.truncate_cols(k),
                s: s[..k].to_vec(),
                v: v.truncate_cols(k),
            },
            report,
        ))
    }

    /// Exact sampling stage (L2-7): basis of `X̄Ω`, one source pass.
    /// Replays the pre-stage-refactor operation sequence verbatim (the
    /// streamed byte-identity contract pins this).
    fn exact_basis(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        omega: &Dense,
        shifted: bool,
        kk: usize,
    ) -> Dense {
        match (self.config.basis, shifted) {
            (_, false) => {
                // mu = 0: plain RSVD sampling.
                householder_qr(&x.mm(omega)).0
            }
            (BasisMethod::Direct, true) => {
                let colsum: Vec<f64> = colsums(omega);
                householder_qr(&x.mm_rank1(omega, mu, &colsum)).0
            }
            (BasisMethod::QrUpdatePaper, true) => {
                let (q1, r1) = householder_qr(&x.mm(omega));
                let neg_mu: Vec<f64> = mu.iter().map(|v| -v).collect();
                let v1 = vec![1.0; kk]; // the paper's v = 1
                qr_rank1_update(&q1, &r1, &neg_mu, &v1).q
            }
            (BasisMethod::QrUpdateExact, true) => {
                let (q1, r1) = householder_qr(&x.mm(omega));
                let neg_mu: Vec<f64> = mu.iter().map(|v| -v).collect();
                let v1 = colsums(omega); // exact: v = Ωᵀ1
                qr_rank1_update(&q1, &r1, &neg_mu, &v1).q
            }
        }
    }

    /// Exact power stage (L8-11): `Q ← qr(X̄·qr(X̄ᵀQ))`, two source
    /// passes per iteration. `start` is the number of sweeps the
    /// incoming `q` has already absorbed (0 cold, >0 when resumed from
    /// a checkpoint).
    #[allow(clippy::too_many_arguments)]
    fn exact_power(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        mut q: Dense,
        ones_n: &[f64],
        start: usize,
        iters: usize,
        cancel: &AtomicBool,
    ) -> Result<Dense> {
        for sweep in start..iters {
            check_cancel(cancel)?;
            faults::check("svd.sweep")?;
            // Q' = qr(X̄ᵀQ) = qr(XᵀQ − 1(μᵀQ))
            let mtq = q.tmatvec(mu); // μᵀQ, length K
            let qp = householder_qr(&x.tmm_rank1(&q, ones_n, &mtq)).0;
            // Q = qr(X̄Q') = qr(XQ' − μ(1ᵀQ'))
            let colsum_qp = colsums(&qp);
            q = householder_qr(&x.mm_rank1(&qp, mu, &colsum_qp)).0;
            if self.checkpoint.is_some() {
                self.save_checkpoint(&SweepState::fixed(Stage::ExactPower, sweep + 1, q.clone()));
            }
        }
        Ok(q)
    }

    /// Fused range finding: `q` Gram sweeps (`W ← qr(X̄ᵀ(X̄·W))`, one
    /// source pass each — the between-pass QR is an n×K Householder
    /// factorization that touches no data), then one capture pass
    /// `Q = qr(X̄·W)`. Total `q + 1` source passes; with the projection
    /// stage the whole factorization does `q + 2` (vs `2 + 2q` Exact).
    fn fused_range(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        omega: Dense,
        shifted: bool,
        iters: usize,
        cancel: &AtomicBool,
    ) -> Result<Dense> {
        let shape = (omega.rows(), omega.cols());
        // Resume replaces Ω with the panel as of the last completed
        // sweep; the remaining sweeps replay the uninterrupted sequence.
        let (mut w, start) = match self.load_checkpoint(Stage::FusedRange, shape) {
            Some(st) => (st.panel, st.sweep),
            None => (omega, 0),
        };
        for sweep in start..iters {
            check_cancel(cancel)?;
            faults::check("svd.sweep")?;
            let z = x.gram_sweep(&w, mu);
            w = householder_qr(&z).0; // renormalize: no data pass
            if self.checkpoint.is_some() {
                self.save_checkpoint(&SweepState::fixed(Stage::FusedRange, sweep + 1, w.clone()));
            }
        }
        check_cancel(cancel)?;
        Ok(self.capture(x, mu, &w, shifted))
    }

    /// Range capture shared by the fused and adaptive schedules:
    /// `Q = qr(X̄·W)`, one source pass.
    fn capture(&self, x: &dyn MatVecOps, mu: &[f64], w: &Dense, shifted: bool) -> Dense {
        let h = if shifted {
            let colsum = colsums(w);
            x.mm_rank1(w, mu, &colsum) // H = X̄·W, one pass
        } else {
            x.mm(w)
        };
        householder_qr(&h).0
    }

    /// dashSVD dynamic-shift range finding (arXiv:2404.09276) under
    /// [`StopCriterion::Tolerance`]: shifted Gram sweeps
    /// `Z = X̄ᵀ(X̄·W) − α·W` with the shift updated each sweep from the
    /// current Ritz estimates, stopping when the per-eigenvalue
    /// movement drops below `pve_tol·‖X̄‖²_F` or at `max_sweeps`.
    /// Returns the captured basis, the sweeps executed, and `‖X̄‖²_F`.
    ///
    /// Pass budget: 1 (`sq_fro_shifted`) + sweeps (`gram_sweep`) +
    /// 1 (capture) = sweeps + 2 before the projection stage.
    #[allow(clippy::too_many_arguments)]
    fn adaptive_range(
        &self,
        x: &dyn MatVecOps,
        mu: &[f64],
        omega: Dense,
        shifted: bool,
        pve_tol: f64,
        max_sweeps: usize,
        cancel: &AtomicBool,
    ) -> Result<(Dense, usize, f64)> {
        let k = self.config.k;
        let shape = (omega.rows(), omega.cols());
        // A resumed run restores the full between-sweep state — panel,
        // dynamic shift, previous Ritz estimates, ‖X̄‖²_F (skipping its
        // source pass) and whether the loop had already converged.
        let resumed = self.load_checkpoint(Stage::AdaptiveRange, shape);
        let (mut w, mut alpha, mut prev, mut sweeps, fro2, mut finished) = match resumed {
            Some(st) => (st.panel, st.alpha, st.prev, st.sweep, st.fro2, st.done),
            None => {
                let fro2 = x.sq_fro_shifted(mu); // one source pass
                // Orthonormalize Ω before the first sweep (n×K
                // Householder QR, no data pass) so the Ritz values are
                // bounded by the true spectrum and the shift can never
                // overshoot it.
                (householder_qr(&omega).0, 0.0_f64, None, 0usize, fro2, false)
            }
        };
        while !finished && sweeps < max_sweeps {
            check_cancel(cancel)?;
            faults::check("svd.sweep")?;
            let mut z = x.gram_sweep(&w, mu); // one source pass
            if alpha != 0.0 {
                // Dynamic shift: Z ← Z − α·W. A rank-K epilogue over
                // resident n×K buffers — composes with the fused Gram
                // sweep without touching the source again.
                for (zv, wv) in z.data_mut().iter_mut().zip(w.data()) {
                    *zv -= alpha * wv;
                }
            }
            // Ritz step: the SVD of the n×K Z yields s_j(Z) and an
            // orthonormal range basis in one deterministic kernel; the
            // eigenvalue estimates of X̄ᵀX̄ are λ̂_j = s_j(Z) + α.
            let (u, s, _) = jacobi_svd(&z, JacobiOpts::default());
            sweeps += 1;
            w = u; // already orthonormal — replaces the QR renorm
            let lam: Vec<f64> = s.iter().take(k).map(|&v| v + alpha).collect();
            let converged = fro2 <= 0.0
                || prev.as_ref().is_some_and(|p| {
                    lam.iter()
                        .zip(p)
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0, f64::max)
                        <= pve_tol * fro2
                });
            prev = Some(lam);
            if converged {
                // Converged: record `done` so a crash *after* this
                // point resumes straight into range capture instead of
                // running one extra sweep (which would break
                // byte-identity with the uninterrupted run).
                finished = true;
            } else {
                // α ← (α + λ̂_K)/2 = α + s_K(Z)/2: half-way toward the
                // smallest retained estimate (the dashSVD update).
                if let Some(&tail) = s.last() {
                    alpha += tail / 2.0;
                }
            }
            if self.checkpoint.is_some() {
                self.save_checkpoint(&SweepState {
                    stage: Stage::AdaptiveRange,
                    sweep: sweeps,
                    done: finished,
                    panel: w.clone(),
                    alpha,
                    fro2,
                    prev: prev.clone(),
                });
            }
        }
        check_cancel(cancel)?;
        Ok((self.capture(x, mu, &w, shifted), sweeps, fro2))
    }

    /// Convenience: factorize the mean-centered matrix (μ = row means) —
    /// the PCA use case of §2.
    pub fn factorize_mean_centered(
        &self,
        x: &dyn MatVecOps,
        rng: &mut dyn Rng,
    ) -> Result<Factorization> {
        let mu = x.row_means();
        self.factorize(x, &mu, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_diff, Csr};
    use crate::rng::Xoshiro256pp;
    use crate::svd::deterministic::optimal_residual;

    fn uniform(m: usize, n: usize, seed: u64) -> Dense {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Dense::from_fn(m, n, |_, _| rng.next_uniform())
    }

    #[test]
    fn near_optimal_on_centered_target() {
        let x = uniform(50, 300, 0);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let cfg = SvdConfig::paper(8).with_fixed_power(2);
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
        let err = fro_diff(&f.reconstruct(), &xbar);
        let opt = optimal_residual(&xbar, 8);
        assert!(err <= 1.15 * opt, "err {err} vs opt {opt}");
    }

    #[test]
    fn zero_mu_is_plain_rsvd() {
        let x = uniform(40, 120, 2);
        let cfg = SvdConfig::paper(6).with_fixed_power(2);
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let f = ShiftedRsvd::new(cfg)
            .factorize(&x, &vec![0.0; 40], &mut rng)
            .unwrap();
        let err = fro_diff(&f.reconstruct(), &x);
        let opt = optimal_residual(&x, 6);
        assert!(err <= 1.15 * opt, "err {err} vs opt {opt}");
    }

    #[test]
    fn all_basis_methods_are_accurate() {
        let x = uniform(40, 150, 4);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let opt = optimal_residual(&xbar, 6);
        for basis in [
            BasisMethod::Direct,
            BasisMethod::QrUpdatePaper,
            BasisMethod::QrUpdateExact,
        ] {
            let cfg = SvdConfig {
                k: 6,
                oversample: 6,
                stop: StopCriterion::FixedPower { q: 2 },
                basis,
                ..Default::default()
            };
            let mut rng = Xoshiro256pp::seed_from_u64(5);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let err = fro_diff(&f.reconstruct(), &xbar);
            assert!(err <= 1.2 * opt, "{basis:?}: err {err} vs opt {opt}");
        }
    }

    #[test]
    fn gram_eig_matches_jacobi_backend() {
        let x = uniform(30, 200, 6);
        let mu = x.row_means();
        for method in [SmallSvdMethod::Jacobi, SmallSvdMethod::GramEig] {
            let cfg = SvdConfig {
                k: 5,
                oversample: 5,
                stop: StopCriterion::FixedPower { q: 1 },
                small_svd: method,
                ..Default::default()
            };
            // Same seed → same Ω → same basis: the two backends must agree
            // on singular values tightly.
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let mut rng2 = Xoshiro256pp::seed_from_u64(7);
            let f2 = ShiftedRsvd::new(SvdConfig {
                small_svd: SmallSvdMethod::Jacobi,
                ..cfg
            })
            .factorize(&x, &mu, &mut rng2)
            .unwrap();
            for (a, b) in f.s.iter().zip(&f2.s) {
                assert!((a - b).abs() < 1e-6 * f2.s[0], "{method:?}");
            }
        }
    }

    #[test]
    fn sparse_path_matches_dense_path_exactly() {
        // Same Ω (same seed) ⇒ bitwise-comparable results modulo float
        // associativity; they must agree to ~1e-10.
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let sp = Csr::random(40, 200, 0.05, &mut rng, |r| r.next_uniform() + 0.5);
        let de = sp.to_dense();
        let mu = MatVecOps::row_means(&sp);
        let cfg = SvdConfig::paper(5).with_fixed_power(1);
        let f_sp = ShiftedRsvd::new(cfg)
            .factorize(&sp, &mu, &mut Xoshiro256pp::seed_from_u64(9))
            .unwrap();
        let f_de = ShiftedRsvd::new(cfg)
            .factorize(&de, &mu, &mut Xoshiro256pp::seed_from_u64(9))
            .unwrap();
        for (a, b) in f_sp.s.iter().zip(&f_de.s) {
            assert!((a - b).abs() < 1e-8, "sv {a} vs {b}");
        }
        assert!(fro_diff(&f_sp.reconstruct(), &f_de.reconstruct()) < 1e-7);
    }

    #[test]
    fn implicit_equals_explicit_centering() {
        // Fig. 1d: S-RSVD(X, μ) ≈ RSVD(X̄ explicit) with the same Ω.
        let x = uniform(30, 100, 10);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let cfg = SvdConfig::paper(5).with_fixed_power(1);
        let f_implicit = ShiftedRsvd::new(cfg)
            .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(11))
            .unwrap();
        let f_explicit = ShiftedRsvd::new(cfg)
            .factorize(&xbar, &vec![0.0; 30], &mut Xoshiro256pp::seed_from_u64(11))
            .unwrap();
        for (a, b) in f_implicit.s.iter().zip(&f_explicit.s) {
            assert!((a - b).abs() < 1e-9 * f_explicit.s[0].max(1.0));
        }
        assert!(
            fro_diff(&f_implicit.reconstruct(), &f_explicit.reconstruct()) < 1e-8
        );
    }

    #[test]
    fn fused_pass_policy_is_accurate() {
        let x = uniform(50, 300, 14);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let opt = optimal_residual(&xbar, 8);
        for q in [1usize, 2] {
            let cfg = SvdConfig::paper(8)
                .with_fixed_power(q)
                .with_pass_policy(PassPolicy::Fused);
            let mut rng = Xoshiro256pp::seed_from_u64(15);
            let f = ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap();
            let err = fro_diff(&f.reconstruct(), &xbar);
            assert!(err <= 1.15 * opt, "q={q}: err {err} vs opt {opt}");
        }
    }

    #[test]
    fn fused_with_zero_power_iters_equals_exact_direct_bitwise() {
        // With q = 0 the fused schedule degenerates to exactly the
        // Exact/Direct operation sequence: capture pass + projection.
        let x = uniform(40, 120, 16);
        let mu = x.row_means();
        let run = |pass_policy| {
            let cfg = SvdConfig::paper(5).with_pass_policy(pass_policy);
            ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(17))
                .unwrap()
        };
        let e = run(PassPolicy::Exact);
        let f = run(PassPolicy::Fused);
        let bits = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&e.u), bits(&f.u));
        assert_eq!(bits(&e.v), bits(&f.v));
        assert_eq!(
            e.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            f.s.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fixed_power_report_is_static() {
        let x = uniform(30, 100, 20);
        let cfg = SvdConfig::paper(5).with_fixed_power(2);
        let (_, rep) = ShiftedRsvd::new(cfg)
            .factorize_with_report(&x, &x.row_means(), &mut Xoshiro256pp::seed_from_u64(21))
            .unwrap();
        assert_eq!(rep, SweepReport { sweeps_used: 2, achieved_pve: None });
    }

    #[test]
    fn adaptive_tolerance_is_accurate_and_reports() {
        let x = uniform(50, 300, 22);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        let cfg = SvdConfig::paper(8).with_tolerance(1e-4, 16);
        let (f, rep) = ShiftedRsvd::new(cfg)
            .factorize_with_report(&x, &mu, &mut Xoshiro256pp::seed_from_u64(23))
            .unwrap();
        let err = fro_diff(&f.reconstruct(), &xbar);
        let opt = optimal_residual(&xbar, 8);
        assert!(err <= 1.15 * opt, "err {err} vs opt {opt}");
        assert!(rep.sweeps_used >= 1 && rep.sweeps_used <= 16, "{rep:?}");
        let pve = rep.achieved_pve.expect("adaptive mode reports PVE");
        assert!(pve > 0.0 && pve <= 1.0 + 1e-12, "pve {pve}");
    }

    #[test]
    fn adaptive_converges_before_the_sweep_ceiling() {
        // A uniform random matrix has a rapidly flattening tail, so a
        // coarse tolerance must stop well before the cap — the whole
        // point of accuracy control over a fixed q.
        let x = uniform(60, 400, 24);
        let mu = x.row_means();
        let cfg = SvdConfig::paper(6).with_tolerance(1e-2, 32);
        let (_, rep) = ShiftedRsvd::new(cfg)
            .factorize_with_report(&x, &mu, &mut Xoshiro256pp::seed_from_u64(25))
            .unwrap();
        assert!(rep.sweeps_used < 32, "never converged: {rep:?}");
    }

    #[test]
    fn adaptive_respects_max_sweeps_ceiling() {
        let x = uniform(30, 90, 26);
        let mu = x.row_means();
        let cfg = SvdConfig::paper(4).with_tolerance(0.0, 3);
        // pve_tol = 0 can only stop on an exact Ritz repeat; the cap
        // must bound the loop regardless.
        let (_, rep) = ShiftedRsvd::new(cfg)
            .factorize_with_report(&x, &mu, &mut Xoshiro256pp::seed_from_u64(27))
            .unwrap();
        assert!(rep.sweeps_used <= 3, "{rep:?}");
    }

    #[test]
    fn adaptive_ignores_pass_policy() {
        // Tolerance mode always runs the fused Gram-sweep schedule;
        // the Exact/Fused knob must not change the factors.
        let x = uniform(40, 150, 28);
        let mu = x.row_means();
        let run = |policy| {
            let cfg = SvdConfig::paper(5)
                .with_tolerance(1e-3, 8)
                .with_pass_policy(policy);
            ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(29))
                .unwrap()
        };
        let a = run(PassPolicy::Exact);
        let b = run(PassPolicy::Fused);
        let bits = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.u), bits(&b.u));
        assert_eq!(bits(&a.v), bits(&b.v));
    }

    #[test]
    fn preset_cancel_flag_aborts_factorization() {
        let x = uniform(30, 100, 30);
        let mu = x.row_means();
        for cfg in [
            SvdConfig::paper(4).with_fixed_power(2),
            SvdConfig::paper(4).with_fixed_power(2).with_pass_policy(PassPolicy::Fused),
            SvdConfig::paper(4).with_tolerance(1e-3, 8),
        ] {
            let flag = AtomicBool::new(true);
            let err = ShiftedRsvd::new(cfg)
                .factorize_with_report_cancellable(
                    &x,
                    &mu,
                    &mut Xoshiro256pp::seed_from_u64(31),
                    &flag,
                )
                .unwrap_err();
            assert!(matches!(err, Error::Cancelled(_)), "{err}");
        }
    }

    #[test]
    fn unset_cancel_flag_is_byte_identical_to_plain_entry_point() {
        let x = uniform(30, 100, 32);
        let mu = x.row_means();
        let cfg = SvdConfig::paper(4).with_fixed_power(1);
        let (a, _) = ShiftedRsvd::new(cfg)
            .factorize_with_report(&x, &mu, &mut Xoshiro256pp::seed_from_u64(33))
            .unwrap();
        let flag = AtomicBool::new(false);
        let (b, _) = ShiftedRsvd::new(cfg)
            .factorize_with_report_cancellable(
                &x,
                &mu,
                &mut Xoshiro256pp::seed_from_u64(33),
                &flag,
            )
            .unwrap();
        let bits = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&a.u), bits(&b.u));
        assert_eq!(bits(&a.v), bits(&b.v));
    }

    #[test]
    fn invalid_configs_error() {
        let x = uniform(10, 20, 12);
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        // mu wrong length.
        assert!(ShiftedRsvd::new(SvdConfig::paper(2))
            .factorize(&x, &[0.0; 3], &mut rng)
            .is_err());
        // k = 0.
        let bad = SvdConfig { k: 0, ..Default::default() };
        assert!(ShiftedRsvd::new(bad)
            .factorize(&x, &vec![0.0; 10], &mut rng)
            .is_err());
    }

    #[test]
    fn rank_capped_by_matrix_size() {
        // K = k + oversample > min(m, n) must clamp, not panic.
        let x = uniform(8, 12, 13);
        let cfg = SvdConfig { k: 6, oversample: 20, ..Default::default() };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let f = ShiftedRsvd::new(cfg)
            .factorize_mean_centered(&x, &mut rng)
            .unwrap();
        assert_eq!(f.rank(), 6);
    }

    // ---- checkpoint/resume ------------------------------------------------

    fn ckpt_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("srsvd_shifted_ckpt_{name}"));
        let _ = std::fs::create_dir_all(&d);
        d
    }

    fn factor_bits(f: &Factorization) -> (Vec<u64>, Vec<u64>, Vec<u64>) {
        let b = |d: &Dense| d.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        (b(&f.u), f.s.iter().map(|v| v.to_bits()).collect(), b(&f.v))
    }

    fn stage_configs() -> [SvdConfig; 3] {
        [
            SvdConfig::paper(4).with_fixed_power(3),
            SvdConfig::paper(4)
                .with_fixed_power(3)
                .with_pass_policy(PassPolicy::Fused),
            SvdConfig::paper(4).with_tolerance(0.0, 3),
        ]
    }

    #[test]
    fn checkpointed_clean_run_is_byte_identical_and_cleans_up() {
        let x = uniform(25, 80, 40);
        let mu = x.row_means();
        for (i, cfg) in stage_configs().into_iter().enumerate() {
            let plain = ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(41))
                .unwrap();
            let dir = ckpt_dir(&format!("clean_{i}"));
            let ckpt = Checkpointer::new(&dir, 100 + i as u64);
            let checked = ShiftedRsvd::new(cfg)
                .with_checkpoint(ckpt.clone())
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(41))
                .unwrap();
            assert_eq!(
                factor_bits(&plain),
                factor_bits(&checked),
                "cfg {i}: checkpointing must not perturb the factors"
            );
            // Success cleared the checkpoint pair.
            let leftover = std::fs::read_dir(&dir).map(|it| it.count()).unwrap_or(0);
            assert_eq!(leftover, 0, "cfg {i}: stale checkpoint files");
            drop(ckpt);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn crash_mid_sweep_resumes_byte_identical() {
        let _g = faults::test_lock();
        let x = uniform(25, 80, 42);
        let mu = x.row_means();
        for (i, cfg) in stage_configs().into_iter().enumerate() {
            let reference = ShiftedRsvd::new(cfg)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(43))
                .unwrap();
            let dir = ckpt_dir(&format!("crash_{i}"));
            let ckpt = Checkpointer::new(&dir, 200 + i as u64);
            // Crash at the top of the second sweep: the first sweep's
            // checkpoint is on disk, the job dies mid-flight.
            faults::arm("svd.sweep=die_after:2").unwrap();
            let engine = ShiftedRsvd::new(cfg).with_checkpoint(ckpt.clone());
            let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                engine.factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(43))
            }));
            faults::disarm();
            let payload = crashed.expect_err("die_after must panic");
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("");
            assert!(msg.contains(faults::CRASH_MARKER), "cfg {i}: panic payload {msg:?}");
            // Restart: same spec, same seed — resumes from sweep 1 and
            // must reproduce the uninterrupted factors bit for bit.
            let resumed_before = crate::svd::checkpoint::checkpoints_resumed();
            let resumed = ShiftedRsvd::new(cfg)
                .with_checkpoint(ckpt)
                .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(43))
                .unwrap();
            assert!(
                crate::svd::checkpoint::checkpoints_resumed() > resumed_before,
                "cfg {i}: run did not take the resume path"
            );
            assert_eq!(
                factor_bits(&reference),
                factor_bits(&resumed),
                "cfg {i}: resumed factors differ from uninterrupted run"
            );
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn foreign_checkpoint_tag_starts_cold() {
        // A checkpoint written under one tag must never be picked up by
        // a job with a different tag (different spec hash).
        let _g = faults::test_lock();
        let x = uniform(20, 60, 44);
        let mu = x.row_means();
        let cfg = SvdConfig::paper(3).with_fixed_power(2);
        let dir = ckpt_dir("foreign");
        faults::arm("svd.sweep=die_after:2").unwrap();
        let engine = ShiftedRsvd::new(cfg).with_checkpoint(Checkpointer::new(&dir, 300));
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(45))
        }));
        faults::disarm();
        let reference = ShiftedRsvd::new(cfg)
            .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(45))
            .unwrap();
        let other = ShiftedRsvd::new(cfg)
            .with_checkpoint(Checkpointer::new(&dir, 301))
            .factorize(&x, &mu, &mut Xoshiro256pp::seed_from_u64(45))
            .unwrap();
        assert_eq!(factor_bits(&reference), factor_bits(&other));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
