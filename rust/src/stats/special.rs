//! Special functions needed for the t-distribution CDF: log-gamma
//! (Lanczos) and the regularized incomplete beta function (continued
//! fraction, Lentz's algorithm) — the standard route to Student-t
//! p-values without a stats library.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
///
/// Absolute error < 1e-13 for x > 0.
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut acc = COEF[0];
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        acc += c / (x + i as f64);
    }
    let t = x + 7.5;
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + acc.ln()
}

/// Regularized incomplete beta function I_x(a, b).
pub fn betainc(a: f64, b: f64, x: f64) -> f64 {
    assert!(a > 0.0 && b > 0.0, "betainc needs a,b > 0");
    if x <= 0.0 {
        return 0.0;
    }
    if x >= 1.0 {
        return 1.0;
    }
    let ln_front = ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln();
    // Use the continued fraction in its rapidly-converging region.
    if x < (a + 1.0) / (a + b + 2.0) {
        (ln_front.exp() * beta_cf(a, b, x)) / a
    } else {
        1.0 - (ln_front.exp() * beta_cf(b, a, 1.0 - x)) / b
    }
}

/// Continued fraction for betainc (modified Lentz).
fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_ITER: usize = 300;
    const EPS: f64 = 3e-16;
    const FPMIN: f64 = 1e-300;

    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_ITER {
        let m_f = m as f64;
        let m2 = 2.0 * m_f;
        // Even step.
        let aa = m_f * (b - m_f) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        // Odd step.
        let aa = -(a + m_f) * (qab + m_f) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// CDF of Student's t distribution with `df` degrees of freedom.
pub fn student_t_cdf(t: f64, df: f64) -> f64 {
    assert!(df > 0.0);
    let x = df / (df + t * t);
    let p = 0.5 * betainc(0.5 * df, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Two-sided p-value for a t statistic.
pub fn t_two_sided_p(t: f64, df: f64) -> f64 {
    let x = df / (df + t * t);
    betainc(0.5 * df, 0.5, x).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(5)=24, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-11);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-11);
    }

    #[test]
    fn betainc_symmetry_and_bounds() {
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.4), (0.5, 0.5, 0.7), (10.0, 2.0, 0.9)] {
            let lhs = betainc(a, b, x);
            let rhs = 1.0 - betainc(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-12, "({a},{b},{x})");
            assert!((0.0..=1.0).contains(&lhs));
        }
        assert_eq!(betainc(1.0, 1.0, 0.0), 0.0);
        assert_eq!(betainc(1.0, 1.0, 1.0), 1.0);
        // I_x(1,1) = x (uniform CDF).
        assert!((betainc(1.0, 1.0, 0.3) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn t_cdf_reference_values() {
        // Standard references: t=0 -> 0.5; large df -> normal; known quantiles.
        assert!((student_t_cdf(0.0, 5.0) - 0.5).abs() < 1e-12);
        // t_{0.975, df=10} = 2.228139: CDF(2.228139, 10) ≈ 0.975
        assert!((student_t_cdf(2.228139, 10.0) - 0.975).abs() < 1e-5);
        // t_{0.95, df=1} = 6.313752 (Cauchy-ish heavy tail)
        assert!((student_t_cdf(6.313752, 1.0) - 0.95).abs() < 1e-5);
        // df=29, t=2.045 -> ~0.975 (the paper's 30-run tests have df=29)
        assert!((student_t_cdf(2.045230, 29.0) - 0.975).abs() < 1e-5);
    }

    #[test]
    fn two_sided_p_consistency() {
        let p = t_two_sided_p(2.228139, 10.0);
        assert!((p - 0.05).abs() < 2e-5, "p {p}");
        assert!(t_two_sided_p(0.0, 7.0) > 0.999);
        assert!(t_two_sided_p(50.0, 29.0) < 1e-10);
    }
}
