//! Statistics substrate: descriptive stats, special functions, and the
//! paired t-tests the paper's Table 1 reports (p₁ on MSE pairs, p₂ on
//! per-column reconstruction errors), plus win-rates.

pub mod special;
pub mod ttest;

pub use ttest::{paired_t_test, win_rate, TTestResult};

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance (n−1 denominator).
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation (square root of [`variance`]).
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (copies + sorts; fine at experiment scale).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// p-quantile by linear interpolation, p in [0, 1].
pub fn quantile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = p.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = idx.floor() as usize;
    let hi = idx.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (idx - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptive_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-14);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }
}
