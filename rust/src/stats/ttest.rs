//! Paired t-tests and win-rates — the machinery behind Table 1.
//!
//! The paper runs each factorization 30 times and tests
//!   H₀¹: no difference between the MSE of S-RSVD and RSVD
//!   H₀²: no difference between individual column reconstruction errors
//! and additionally reports the win-rate (fraction of columns/images one
//! algorithm reconstructs better).

use super::special::t_two_sided_p;
use super::{mean, variance};

/// Outcome of a paired two-sided t-test on differences `a[i] - b[i]`.
#[derive(Debug, Clone, Copy)]
pub struct TTestResult {
    /// t statistic (mean(d) / (sd(d)/√n)).
    pub t: f64,
    /// Degrees of freedom (n − 1).
    pub df: f64,
    /// Two-sided p-value.
    pub p: f64,
    /// Mean difference (negative ⇒ `a` smaller, i.e. `a` more accurate
    /// when the measurements are errors).
    pub mean_diff: f64,
    /// Number of pairs.
    pub n: usize,
}

/// Paired two-sided t-test of `a` vs `b` (equal lengths, n ≥ 2).
pub fn paired_t_test(a: &[f64], b: &[f64]) -> TTestResult {
    assert_eq!(a.len(), b.len(), "paired test needs equal lengths");
    assert!(a.len() >= 2, "paired test needs n >= 2");
    let d: Vec<f64> = a.iter().zip(b).map(|(x, y)| x - y).collect();
    let n = d.len();
    let md = mean(&d);
    let sd = variance(&d).sqrt();
    let df = (n - 1) as f64;
    if sd == 0.0 {
        // All differences identical: p = 1 if exactly zero, else ~0.
        let p = if md == 0.0 { 1.0 } else { 0.0 };
        let t = if md == 0.0 { 0.0 } else { f64::INFINITY };
        return TTestResult { t, df, p, mean_diff: md, n };
    }
    let t = md / (sd / (n as f64).sqrt());
    TTestResult { t, df, p: t_two_sided_p(t, df), mean_diff: md, n }
}

/// Fraction of indices where `a[i] < b[i]` (ties split evenly) — the
/// paper's WR row: how often algorithm A reconstructs a column/image
/// more accurately than algorithm B.
pub fn win_rate(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.5;
    }
    let mut wins = 0.0;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            wins += 1.0;
        } else if x == y {
            wins += 0.5;
        }
    }
    wins / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};

    #[test]
    fn detects_systematic_difference() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let a: Vec<f64> = (0..30).map(|_| 1.0 + 0.05 * rng.next_gaussian()).collect();
        let b: Vec<f64> = a.iter().map(|x| x + 0.2).collect(); // b clearly larger
        let r = paired_t_test(&a, &b);
        assert!(r.p < 1e-10, "p {}", r.p);
        assert!(r.mean_diff < 0.0);
    }

    #[test]
    fn no_difference_high_p() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let b: Vec<f64> = (0..100).map(|_| rng.next_gaussian()).collect();
        let r = paired_t_test(&a, &b);
        assert!(r.p > 0.01, "p {}", r.p); // independent same-dist samples
    }

    #[test]
    fn identical_inputs_p_one() {
        let a = [1.0, 2.0, 3.0];
        let r = paired_t_test(&a, &a);
        assert_eq!(r.p, 1.0);
        assert_eq!(r.t, 0.0);
    }

    #[test]
    fn constant_offset_zero_variance() {
        let a = [1.0, 2.0, 3.0];
        let b = [2.0, 3.0, 4.0];
        let r = paired_t_test(&a, &b);
        assert_eq!(r.p, 0.0);
        assert_eq!(r.mean_diff, -1.0);
    }

    #[test]
    fn matches_reference_scipy_example() {
        // scipy.stats.ttest_rel([1,2,3,4,5],[1.1,2.4,2.9,4.3,5.4])
        // -> statistic=-2.2691267, pvalue=0.0858104 (df=4)
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [1.1, 2.4, 2.9, 4.3, 5.4];
        let r = paired_t_test(&a, &b);
        assert!((r.t - (-2.2691267)).abs() < 1e-6, "t {}", r.t);
        assert!((r.p - 0.0858104).abs() < 1e-6, "p {}", r.p);
    }

    #[test]
    fn win_rate_basics() {
        assert_eq!(win_rate(&[1.0, 1.0], &[2.0, 2.0]), 1.0);
        assert_eq!(win_rate(&[2.0, 2.0], &[1.0, 1.0]), 0.0);
        assert_eq!(win_rate(&[1.0, 2.0], &[2.0, 1.0]), 0.5);
        assert_eq!(win_rate(&[1.0], &[1.0]), 0.5);
    }
}
