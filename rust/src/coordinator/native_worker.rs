//! Native-engine execution: what a worker thread actually does with a
//! job routed to [`crate::svd::ShiftedRsvd`].
//!
//! Worker threads install the coordinator's shared [`crate::parallel`]
//! pool before entering their loop (see `native_loop` in the parent
//! module), so the GEMM / CSR kernels inside a job run panel-parallel
//! on one process-wide pool rather than each job being serial.

use std::path::Path;
use std::sync::atomic::AtomicBool;

use crate::linalg::Dense;
use crate::rng::Xoshiro256pp;
use crate::svd::{Checkpointer, ShiftedRsvd};
use crate::util::Result;

use super::job::{JobOutput, JobSpec, MatrixInput};

/// Run one job on the native engine (synchronously, on this thread).
pub fn execute_native(spec: &JobSpec) -> Result<JobOutput> {
    execute_native_cancellable(spec, &AtomicBool::new(false))
}

/// [`execute_native`] with a cooperative cancel flag: a set flag makes
/// the factorization abandon work at its next between-sweep checkpoint
/// and the job fail with [`crate::util::Error::Cancelled`].
pub fn execute_native_cancellable(spec: &JobSpec, cancel: &AtomicBool) -> Result<JobOutput> {
    execute_native_job(spec, cancel, None)
}

/// The full worker entry point: cancellation plus optional sweep-
/// granular checkpointing. With `checkpoint_dir` set and the spec
/// having a stable identity ([`crate::server::cache::checkpoint_spec_hash`]),
/// the engine spills its state after each completed sweep and resumes a
/// previously interrupted run of the same spec byte-identically.
pub fn execute_native_job(
    spec: &JobSpec,
    cancel: &AtomicBool,
    checkpoint_dir: Option<&Path>,
) -> Result<JobOutput> {
    let mu = spec.shift.resolve(&spec.input)?;
    let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
    let mut engine = ShiftedRsvd::new(spec.config);
    if let Some(dir) = checkpoint_dir {
        if let Some(tag) = crate::server::cache::checkpoint_spec_hash(spec) {
            engine = engine.with_checkpoint(Checkpointer::new(dir, tag));
        }
    }
    let (fact, report) =
        engine.factorize_with_report_cancellable(spec.input.as_ops(), &mu, &mut rng, cancel)?;
    let mse = if spec.score {
        Some(score(spec, &mu, &fact))
    } else {
        None
    };
    // The MSE pass sweeps the source too; a cancel raised during it
    // leaves a truncated score that must not surface as success.
    if cancel.load(std::sync::atomic::Ordering::Relaxed) {
        return Err(crate::util::Error::Cancelled(
            "job cancelled during scoring".into(),
        ));
    }
    Ok(JobOutput {
        factorization: fact,
        mse,
        sweeps_used: report.sweeps_used,
        achieved_pve: report.achieved_pve,
    })
}

/// The paper's MSE metric, dispatched by input kind: dense computes the
/// residual directly; sparse uses the O(nnz·k) expansion that never
/// densifies; streamed uses the generic [`crate::svd::shifted_low_rank_mse`]
/// expansion, which touches the source in two block sweeps and never
/// materializes it.
fn score(spec: &JobSpec, mu: &[f64], fact: &crate::svd::Factorization) -> f64 {
    match &spec.input {
        MatrixInput::Dense(x) => {
            let xbar = x.subtract_column(mu);
            fact.mse_against(&xbar)
        }
        MatrixInput::Sparse(x) => x.shifted_mse(mu, &fact.u, &fact.s, &fact.v),
        MatrixInput::Streamed(x) => {
            crate::svd::shifted_low_rank_mse(x, mu, &fact.u, &fact.s, &fact.v)
        }
    }
}

/// Scoring helper shared with benches: MSE of a factorization against a
/// dense matrix's implicit centering.
pub fn dense_mse(x: &Dense, mu: &[f64], fact: &crate::svd::Factorization) -> f64 {
    fact.mse_against(&x.subtract_column(mu))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{EnginePreference, ShiftSpec};
    use crate::linalg::Csr;
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::svd::SvdConfig;

    #[test]
    fn dense_job_executes_and_scores() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::from_fn(30, 100, |_, _| rng.next_uniform());
        let spec = JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(5),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 1,
            score: true,
        };
        let out = execute_native(&spec).unwrap();
        assert_eq!(out.factorization.rank(), 5);
        assert!(out.mse.unwrap() > 0.0);
        // Fixed-q jobs report the static sweep count and no PVE.
        assert_eq!(out.sweeps_used, 0);
        assert_eq!(out.achieved_pve, None);
    }

    #[test]
    fn adaptive_job_reports_sweeps_and_pve() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let x = Dense::from_fn(30, 100, |_, _| rng.next_uniform());
        let spec = JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(5).with_tolerance(1e-3, 16),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 5,
            score: true,
        };
        let out = execute_native(&spec).unwrap();
        assert!(out.sweeps_used >= 1 && out.sweeps_used <= 16);
        let pve = out.achieved_pve.expect("adaptive mode reports PVE");
        assert!(pve > 0.0 && pve <= 1.0 + 1e-12, "pve {pve}");
    }

    #[test]
    fn sparse_and_dense_scores_agree() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let sp = Csr::random(25, 80, 0.1, &mut rng, |r| r.next_uniform() + 0.2);
        let de = sp.to_dense();
        let mk = |input| JobSpec {
            input,
            config: SvdConfig::paper(4),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 3,
            score: true,
        };
        let a = execute_native(&mk(MatrixInput::Sparse(sp))).unwrap();
        let b = execute_native(&mk(MatrixInput::Dense(de))).unwrap();
        let (ma, mb) = (a.mse.unwrap(), b.mse.unwrap());
        assert!((ma - mb).abs() < 1e-8 * mb.max(1.0), "{ma} vs {mb}");
    }

    #[test]
    fn same_seed_is_deterministic() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = Dense::from_fn(20, 60, |_, _| rng.next_uniform());
        let spec = JobSpec {
            input: MatrixInput::Dense(x),
            config: SvdConfig::paper(3),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed: 42,
            score: true,
        };
        let a = execute_native(&spec).unwrap();
        let b = execute_native(&spec).unwrap();
        assert_eq!(a.mse, b.mse);
        assert_eq!(a.factorization.s, b.factorization.s);
    }
}
