//! Routing: decide which engine executes a job.
//!
//! A job can run on a compiled artifact only if (a) the input is a
//! resident dense matrix (artifacts take a dense f32 operand), (b) the
//! manifest has an `srsvd_scored` entry whose static shape/rank/power
//! match the job config exactly, and (c) the job uses the default
//! Direct basis — the AOT pipeline implements the fused (exact) shift.
//! Everything else — arbitrary shapes, sparse inputs, ablation
//! variants, and streamed (out-of-core) inputs, whose matrices never
//! exist as a single operand — runs on the native engine.

use crate::runtime::Manifest;
use crate::svd::{BasisMethod, PassPolicy, Precision, SvdEngine};
use crate::util::{Error, Result};

use super::job::{EnginePreference, JobSpec, MatrixInput};

/// Route decision with the artifact name when applicable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Run on the native rust engine.
    Native,
    /// Run the named compiled artifact on the PJRT runtime.
    Artifact {
        /// Artifact name in the manifest.
        name: String,
    },
}

impl Route {
    /// The engine this route executes on.
    pub fn engine(&self) -> SvdEngine {
        match self {
            Route::Native => SvdEngine::Native,
            Route::Artifact { .. } => SvdEngine::Artifact,
        }
    }
}

/// Compute the route for `spec` under `manifest` (None = no runtime).
///
/// When `engine = ArtifactOnly` and nothing matches, the error carries
/// the router's *specific* refusal reason (streamed input, fused pass
/// policy, adaptive stop criterion, shape miss, …) as
/// [`Error::Invalid`] — the HTTP layer maps that to a 400 whose body
/// tells the client exactly which knob to change.
pub fn route(spec: &JobSpec, manifest: Option<&Manifest>) -> Result<Route> {
    let artifact = find_artifact(spec, manifest);
    match (spec.engine, artifact) {
        (EnginePreference::Native, _) => Ok(Route::Native),
        (EnginePreference::Auto, Ok(name)) => Ok(Route::Artifact { name }),
        (EnginePreference::Auto, Err(_)) => Ok(Route::Native),
        (EnginePreference::ArtifactOnly, Ok(name)) => Ok(Route::Artifact { name }),
        (EnginePreference::ArtifactOnly, Err(reason)) => Err(Error::Invalid(format!(
            "engine=artifact was requested but the job cannot run on a \
             compiled artifact: {reason}"
        ))),
    }
}

/// The artifact name matching `spec`, or the specific reason no
/// artifact can run it.
fn find_artifact(spec: &JobSpec, manifest: Option<&Manifest>) -> std::result::Result<String, String> {
    // Job-intrinsic refusals come first so the reason names the
    // offending knob even on a service running without artifacts.
    match spec.input {
        MatrixInput::Dense(_) => {}
        MatrixInput::Sparse(_) => {
            // Sparse inputs always run native (that's the point).
            return Err("sparse inputs run native only (artifacts take a dense operand)".into());
        }
        MatrixInput::Streamed(_) => {
            return Err(
                "streamed (out-of-core) inputs run native only: the matrix never \
                 exists as a single dense operand"
                    .into(),
            );
        }
    }
    if spec.config.basis != BasisMethod::Direct {
        return Err(format!(
            "basis {:?} is native-only (artifacts compile the Direct basis)",
            spec.config.basis
        ));
    }
    if spec.config.pass_policy != PassPolicy::Exact {
        return Err(format!(
            "pass_policy={} is native-only: the AOT pipeline compiles the exact \
             pass schedule",
            spec.config.pass_policy.name()
        ));
    }
    if spec.config.precision != Precision::Exact {
        return Err(format!(
            "precision={} is native-only: artifacts are compiled against the \
             exact kernel tier",
            spec.config.precision.name()
        ));
    }
    // Artifacts are compiled for a fixed q; the adaptive tolerance mode
    // decides its sweep count at run time.
    let Some(q) = spec.config.stop.fixed_q() else {
        return Err(
            "the adaptive stop criterion (pve_tol) is native-only: artifacts are \
             compiled for a fixed power_iters"
                .into(),
        );
    };
    let Some(manifest) = manifest else {
        return Err("no artifact manifest is loaded (artifact_dir off or missing)".into());
    };
    let (m, n) = spec.input.shape();
    let Some(a) = manifest.find_srsvd(m, n, spec.config.k, q) else {
        return Err(format!(
            "no compiled artifact matches shape {m}x{n} k={} q={q}",
            spec.config.k
        ));
    };
    // The artifact's sampling width must match the job's.
    if a.kk != spec.config.sample_width() {
        return Err(format!(
            "artifact {} was compiled for sampling width K={} but the job asks K={}",
            a.name,
            a.kk,
            spec.config.sample_width()
        ));
    }
    Ok(a.name.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobSpec, MatrixInput, ShiftSpec};
    use crate::linalg::{Csr, Dense};
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::svd::SvdConfig;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    fn dense_job(m: usize, n: usize, k: usize, pref: EnginePreference) -> JobSpec {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        JobSpec {
            input: MatrixInput::Dense(Dense::from_fn(m, n, |_, _| rng.next_uniform())),
            config: SvdConfig::paper(k),
            shift: ShiftSpec::MeanCenter,
            engine: pref,
            seed: 0,
            score: true,
        }
    }

    #[test]
    fn native_preference_always_native() {
        let m = manifest();
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Native), m.as_ref()).unwrap();
        assert_eq!(r, Route::Native);
    }

    #[test]
    fn auto_picks_artifact_for_grid_shape() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Auto), Some(&m)).unwrap();
        assert!(matches!(r, Route::Artifact { .. }), "{r:?}");
    }

    #[test]
    fn auto_falls_back_for_off_grid_shape() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(33, 77, 4, EnginePreference::Auto), Some(&m)).unwrap();
        assert_eq!(r, Route::Native);
    }

    #[test]
    fn artifact_only_errors_when_unmatched() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(33, 77, 4, EnginePreference::ArtifactOnly), Some(&m));
        let msg = format!("{}", r.unwrap_err());
        assert!(msg.contains("33x77"), "reason should name the shape: {msg}");
    }

    #[test]
    fn artifact_only_refusals_carry_specific_reasons() {
        // Each refusal path names the offending knob so a 400 response
        // tells the client what to change. No manifest at all is its own
        // reason.
        let r = route(&dense_job(100, 1000, 10, EnginePreference::ArtifactOnly), None);
        assert!(format!("{}", r.unwrap_err()).contains("manifest"));

        // Job-intrinsic refusals name the offending knob even when the
        // service runs without artifacts at all.
        let mut fused = dense_job(100, 1000, 10, EnginePreference::ArtifactOnly);
        fused.config = fused.config.with_pass_policy(PassPolicy::Fused);
        let msg = format!("{}", route(&fused, None).unwrap_err());
        assert!(msg.contains("pass_policy=fused"), "{msg}");

        let mut adaptive = dense_job(100, 1000, 10, EnginePreference::ArtifactOnly);
        adaptive.config = adaptive.config.with_tolerance(1e-3, 8);
        let msg = format!("{}", route(&adaptive, None).unwrap_err());
        assert!(msg.contains("pve_tol"), "{msg}");

        let mut fast = dense_job(100, 1000, 10, EnginePreference::ArtifactOnly);
        fast.config = fast.config.with_precision(Precision::Fast);
        let msg = format!("{}", route(&fast, None).unwrap_err());
        assert!(msg.contains("precision=fast"), "{msg}");

        // Auto still silently falls back native for the same specs.
        let m = manifest();
        fused.engine = EnginePreference::Auto;
        adaptive.engine = EnginePreference::Auto;
        fast.engine = EnginePreference::Auto;
        assert_eq!(route(&fused, m.as_ref()).unwrap(), Route::Native);
        assert_eq!(route(&adaptive, m.as_ref()).unwrap(), Route::Native);
        assert_eq!(route(&fast, m.as_ref()).unwrap(), Route::Native);
    }

    #[test]
    fn sparse_inputs_never_route_to_artifacts() {
        let Some(m) = manifest() else { return };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let spec = JobSpec {
            input: MatrixInput::Sparse(Csr::random(100, 1000, 0.01, &mut rng, |r| {
                r.next_uniform()
            })),
            config: SvdConfig::paper(10),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 0,
            score: false,
        };
        assert_eq!(route(&spec, Some(&m)).unwrap(), Route::Native);
    }

    #[test]
    fn streamed_inputs_never_route_to_artifacts() {
        // Even an artifact-grid shape routes native when streamed — the
        // matrix never exists as a single dense operand.
        let src = crate::linalg::GeneratorSource::new(
            100,
            1000,
            crate::data::Distribution::Uniform,
            3,
        )
        .unwrap();
        let spec = JobSpec {
            input: MatrixInput::streamed(src, &crate::linalg::StreamConfig::default()),
            config: SvdConfig::paper(10),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 0,
            score: false,
        };
        let m = manifest();
        assert_eq!(route(&spec, m.as_ref()).unwrap(), Route::Native);
        // ArtifactOnly must error, not silently fall back.
        let mut only = spec;
        only.engine = EnginePreference::ArtifactOnly;
        assert!(route(&only, m.as_ref()).is_err());
    }

    #[test]
    fn no_manifest_means_native() {
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Auto), None).unwrap();
        assert_eq!(r, Route::Native);
    }
}
