//! Routing: decide which engine executes a job.
//!
//! A job can run on a compiled artifact only if (a) the input is a
//! resident dense matrix (artifacts take a dense f32 operand), (b) the
//! manifest has an `srsvd_scored` entry whose static shape/rank/power
//! match the job config exactly, and (c) the job uses the default
//! Direct basis — the AOT pipeline implements the fused (exact) shift.
//! Everything else — arbitrary shapes, sparse inputs, ablation
//! variants, and streamed (out-of-core) inputs, whose matrices never
//! exist as a single operand — runs on the native engine.

use crate::runtime::Manifest;
use crate::svd::{BasisMethod, PassPolicy, SvdEngine};
use crate::util::{Error, Result};

use super::job::{EnginePreference, JobSpec, MatrixInput};

/// Route decision with the artifact name when applicable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Route {
    /// Run on the native rust engine.
    Native,
    /// Run the named compiled artifact on the PJRT runtime.
    Artifact {
        /// Artifact name in the manifest.
        name: String,
    },
}

impl Route {
    /// The engine this route executes on.
    pub fn engine(&self) -> SvdEngine {
        match self {
            Route::Native => SvdEngine::Native,
            Route::Artifact { .. } => SvdEngine::Artifact,
        }
    }
}

/// Compute the route for `spec` under `manifest` (None = no runtime).
pub fn route(spec: &JobSpec, manifest: Option<&Manifest>) -> Result<Route> {
    let artifact = find_artifact(spec, manifest);
    match (spec.engine, artifact) {
        (EnginePreference::Native, _) => Ok(Route::Native),
        (EnginePreference::Auto, Some(name)) => Ok(Route::Artifact { name }),
        (EnginePreference::Auto, None) => Ok(Route::Native),
        (EnginePreference::ArtifactOnly, Some(name)) => Ok(Route::Artifact { name }),
        (EnginePreference::ArtifactOnly, None) => Err(Error::Service(format!(
            "no compiled artifact matches job (shape {:?}, k={}, q={}) and \
             engine=ArtifactOnly was requested",
            spec.input.shape(),
            spec.config.k,
            spec.config.power_iters,
        ))),
    }
}

fn find_artifact(spec: &JobSpec, manifest: Option<&Manifest>) -> Option<String> {
    let manifest = manifest?;
    if !matches!(spec.input, MatrixInput::Dense(_)) {
        return None; // sparse inputs always run native (that's the point)
    }
    if spec.config.basis != BasisMethod::Direct {
        return None; // ablation variants are native-only
    }
    if spec.config.pass_policy != PassPolicy::Exact {
        return None; // the AOT pipeline compiles the exact pass schedule
    }
    let (m, n) = spec.input.shape();
    let a = manifest.find_srsvd(m, n, spec.config.k, spec.config.power_iters)?;
    // The artifact's sampling width must match the job's.
    if a.kk != spec.config.sample_width() {
        return None;
    }
    Some(a.name.clone())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{JobSpec, MatrixInput, ShiftSpec};
    use crate::linalg::{Csr, Dense};
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::svd::SvdConfig;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    fn dense_job(m: usize, n: usize, k: usize, pref: EnginePreference) -> JobSpec {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        JobSpec {
            input: MatrixInput::Dense(Dense::from_fn(m, n, |_, _| rng.next_uniform())),
            config: SvdConfig::paper(k),
            shift: ShiftSpec::MeanCenter,
            engine: pref,
            seed: 0,
            score: true,
        }
    }

    #[test]
    fn native_preference_always_native() {
        let m = manifest();
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Native), m.as_ref()).unwrap();
        assert_eq!(r, Route::Native);
    }

    #[test]
    fn auto_picks_artifact_for_grid_shape() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Auto), Some(&m)).unwrap();
        assert!(matches!(r, Route::Artifact { .. }), "{r:?}");
    }

    #[test]
    fn auto_falls_back_for_off_grid_shape() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(33, 77, 4, EnginePreference::Auto), Some(&m)).unwrap();
        assert_eq!(r, Route::Native);
    }

    #[test]
    fn artifact_only_errors_when_unmatched() {
        let Some(m) = manifest() else { return };
        let r = route(&dense_job(33, 77, 4, EnginePreference::ArtifactOnly), Some(&m));
        assert!(r.is_err());
    }

    #[test]
    fn sparse_inputs_never_route_to_artifacts() {
        let Some(m) = manifest() else { return };
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let spec = JobSpec {
            input: MatrixInput::Sparse(Csr::random(100, 1000, 0.01, &mut rng, |r| {
                r.next_uniform()
            })),
            config: SvdConfig::paper(10),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 0,
            score: false,
        };
        assert_eq!(route(&spec, Some(&m)).unwrap(), Route::Native);
    }

    #[test]
    fn streamed_inputs_never_route_to_artifacts() {
        // Even an artifact-grid shape routes native when streamed — the
        // matrix never exists as a single dense operand.
        let src = crate::linalg::GeneratorSource::new(
            100,
            1000,
            crate::data::Distribution::Uniform,
            3,
        )
        .unwrap();
        let spec = JobSpec {
            input: MatrixInput::streamed(src, &crate::linalg::StreamConfig::default()),
            config: SvdConfig::paper(10),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 0,
            score: false,
        };
        let m = manifest();
        assert_eq!(route(&spec, m.as_ref()).unwrap(), Route::Native);
        // ArtifactOnly must error, not silently fall back.
        let mut only = spec;
        only.engine = EnginePreference::ArtifactOnly;
        assert!(route(&only, m.as_ref()).is_err());
    }

    #[test]
    fn no_manifest_means_native() {
        let r = route(&dense_job(100, 1000, 10, EnginePreference::Auto), None).unwrap();
        assert_eq!(r, Route::Native);
    }
}
