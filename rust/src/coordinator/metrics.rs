//! Lock-free service metrics (atomics only — read on the hot path).

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters, updated by workers and the submitter.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Jobs accepted by `submit`/`try_submit`.
    pub submitted: AtomicU64,
    /// Jobs that finished executing (ok or failed).
    pub completed: AtomicU64,
    /// Jobs whose outcome was an error.
    pub failed: AtomicU64,
    /// Jobs routed to the native engine.
    pub native_jobs: AtomicU64,
    /// Jobs routed to the artifact engine.
    pub artifact_jobs: AtomicU64,
    /// Jobs currently queued (submitted − picked up).
    pub queue_depth: AtomicU64,
    /// Jobs currently executing on a worker (picked up − completed).
    pub in_flight: AtomicU64,
    /// HTTP jobs accepted by the network service layer.
    pub http_accepted: AtomicU64,
    /// HTTP jobs rejected with 503 (queue full — backpressure).
    pub http_rejected: AtomicU64,
    /// Request bytes read by the network service layer.
    pub http_bytes_in: AtomicU64,
    /// Response bytes written by the network service layer.
    pub http_bytes_out: AtomicU64,
    /// Source passes (full sweeps) performed by streamed jobs.
    pub stream_passes: AtomicU64,
    /// Payload bytes read from streamed sources.
    pub stream_bytes_read: AtomicU64,
    /// Transient streamed-source read failures retried inside sweeps
    /// (each successful retry is a job that did NOT fail).
    pub stream_retries: AtomicU64,
    /// Journaled job specs re-run through the resume path after a
    /// service restart.
    pub journal_replayed: AtomicU64,
    /// Power sweeps executed across completed jobs (fixed `q` or the
    /// adaptive count — the accuracy-control savings signal).
    pub sweeps_used: AtomicU64,
    /// Jobs that reported an achieved PVE (adaptive tolerance mode).
    pub pve_jobs: AtomicU64,
    /// Sum of achieved PVE over those jobs, in micro-units (PVE ∈
    /// [0, 1] scaled by 1e6 so a lock-free integer can carry it).
    pub pve_sum_micro: AtomicU64,
    /// Total execution time, nanoseconds.
    pub exec_ns: AtomicU64,
    /// Total queueing time, nanoseconds.
    pub queue_ns: AtomicU64,
    /// Max single-job execution time, nanoseconds.
    pub max_exec_ns: AtomicU64,
    /// Jobs cancelled via `DELETE /v1/jobs/{id}` (or evicted while
    /// still running).
    pub cancelled: AtomicU64,
    /// Pending-map entries evicted by the server's result TTL sweep.
    pub evicted: AtomicU64,
    /// Submits served from the content-addressed result cache (the
    /// coordinator never sees these).
    pub cache_hits: AtomicU64,
    /// Cacheable submits that missed the result cache.
    pub cache_misses: AtomicU64,
    /// Rendered result bytes currently resident in the result cache.
    pub cache_bytes: AtomicU64,
}

impl Metrics {
    /// Record a completed job's sweep report (see
    /// [`crate::coordinator::JobOutput`]).
    pub fn record_sweeps(&self, sweeps_used: usize, achieved_pve: Option<f64>) {
        self.sweeps_used
            .fetch_add(sweeps_used as u64, Ordering::Relaxed);
        if let Some(pve) = achieved_pve {
            self.pve_jobs.fetch_add(1, Ordering::Relaxed);
            self.pve_sum_micro
                .fetch_add((pve.clamp(0.0, 1.0) * 1e6) as u64, Ordering::Relaxed);
        }
    }

    /// Record one executed job's timings and outcome.
    pub fn record_exec(&self, exec_s: f64, queue_s: f64, ok: bool) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if !ok {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let ns = (exec_s * 1e9) as u64;
        self.exec_ns.fetch_add(ns, Ordering::Relaxed);
        self.queue_ns
            .fetch_add((queue_s * 1e9) as u64, Ordering::Relaxed);
        self.max_exec_ns.fetch_max(ns, Ordering::Relaxed);
    }

    /// Snapshot the job counters. The pool fields are zero here; the
    /// coordinator overlays its shared pool's stats (it owns the pool,
    /// the raw `Metrics` struct deliberately does not).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let completed = self.completed.load(Ordering::Relaxed);
        let exec_ns = self.exec_ns.load(Ordering::Relaxed);
        let queue_ns = self.queue_ns.load(Ordering::Relaxed);
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed,
            failed: self.failed.load(Ordering::Relaxed),
            native_jobs: self.native_jobs.load(Ordering::Relaxed),
            artifact_jobs: self.artifact_jobs.load(Ordering::Relaxed),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            http_accepted: self.http_accepted.load(Ordering::Relaxed),
            http_rejected: self.http_rejected.load(Ordering::Relaxed),
            http_bytes_in: self.http_bytes_in.load(Ordering::Relaxed),
            http_bytes_out: self.http_bytes_out.load(Ordering::Relaxed),
            stream_passes: self.stream_passes.load(Ordering::Relaxed),
            stream_bytes_read: self.stream_bytes_read.load(Ordering::Relaxed),
            stream_retries: self.stream_retries.load(Ordering::Relaxed),
            journal_replayed: self.journal_replayed.load(Ordering::Relaxed),
            // Process-global resilience counters: the fault registry and
            // the checkpoint layer are statics (armed/written once per
            // process), so the snapshot reads them directly rather than
            // duplicating them per coordinator.
            faults_injected: crate::util::faults::injected_count(),
            checkpoints_written: crate::svd::checkpoint::checkpoints_written(),
            checkpoints_resumed: crate::svd::checkpoint::checkpoints_resumed(),
            sweeps_used: self.sweeps_used.load(Ordering::Relaxed),
            mean_achieved_pve: {
                let jobs = self.pve_jobs.load(Ordering::Relaxed);
                if jobs > 0 {
                    self.pve_sum_micro.load(Ordering::Relaxed) as f64 / 1e6 / jobs as f64
                } else {
                    0.0
                }
            },
            mean_exec_s: if completed > 0 {
                exec_ns as f64 / completed as f64 / 1e9
            } else {
                0.0
            },
            mean_queue_s: if completed > 0 {
                queue_ns as f64 / completed as f64 / 1e9
            } else {
                0.0
            },
            max_exec_s: self.max_exec_ns.load(Ordering::Relaxed) as f64 / 1e9,
            cancelled: self.cancelled.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_bytes: self.cache_bytes.load(Ordering::Relaxed),
            pool_threads: 0,
            pool_parallel_ops: 0,
            pool_serial_ops: 0,
            pool_chunks: 0,
            pool_spawned: 0,
            io_threads: 0,
            io_parallel_ops: 0,
            io_serial_ops: 0,
            io_chunks: 0,
            io_spawned: 0,
        }
    }
}

/// Point-in-time view of the service counters, including the shared
/// linalg pool (filled in by [`crate::coordinator::Coordinator::metrics`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MetricsSnapshot {
    /// Jobs accepted by `submit`/`try_submit`.
    pub submitted: u64,
    /// Jobs that finished executing (ok or failed).
    pub completed: u64,
    /// Jobs whose outcome was an error.
    pub failed: u64,
    /// Jobs routed to the native engine.
    pub native_jobs: u64,
    /// Jobs routed to the artifact engine.
    pub artifact_jobs: u64,
    /// Jobs currently queued.
    pub queue_depth: u64,
    /// Jobs currently executing on a worker.
    pub in_flight: u64,
    /// HTTP jobs accepted by the network service layer.
    pub http_accepted: u64,
    /// HTTP jobs rejected with 503 (queue full — backpressure).
    pub http_rejected: u64,
    /// Request bytes read by the network service layer.
    pub http_bytes_in: u64,
    /// Response bytes written by the network service layer.
    pub http_bytes_out: u64,
    /// Source passes (full sweeps) performed by streamed jobs — the
    /// pass-efficiency signal (`PassPolicy::Fused` cuts it roughly in
    /// half on power-iterated workloads).
    pub stream_passes: u64,
    /// Payload bytes read from streamed sources.
    pub stream_bytes_read: u64,
    /// Transient streamed-source read failures retried inside sweeps.
    pub stream_retries: u64,
    /// Journaled job specs re-run after a service restart.
    pub journal_replayed: u64,
    /// Faults injected by the armed fail-point registry (0 in
    /// production — a nonzero value means `SRSVD_FAULTS` is live).
    pub faults_injected: u64,
    /// Sweep checkpoints written by the engine (process-wide).
    pub checkpoints_written: u64,
    /// Factorizations resumed from a checkpoint (process-wide).
    pub checkpoints_resumed: u64,
    /// Power sweeps executed across completed jobs.
    pub sweeps_used: u64,
    /// Mean achieved PVE over jobs that reported one (adaptive
    /// tolerance mode); 0 when no job has.
    pub mean_achieved_pve: f64,
    /// Mean seconds spent executing, over completed jobs.
    pub mean_exec_s: f64,
    /// Mean seconds spent queued, over completed jobs.
    pub mean_queue_s: f64,
    /// Longest single-job execution, seconds.
    pub max_exec_s: f64,
    /// Jobs cancelled via `DELETE /v1/jobs/{id}` (or evicted running).
    pub cancelled: u64,
    /// Pending-map entries evicted by the server's result TTL sweep.
    pub evicted: u64,
    /// Submits served straight from the content-addressed result cache.
    pub cache_hits: u64,
    /// Cacheable submits that missed the result cache.
    pub cache_misses: u64,
    /// Rendered result bytes resident in the result cache.
    pub cache_bytes: u64,
    /// Size of the shared linalg (cpu) thread pool.
    pub pool_threads: usize,
    /// Linalg operations the cpu pool dispatched across threads.
    pub pool_parallel_ops: u64,
    /// Linalg operations the cpu pool ran inline (small inputs / size-1 pool).
    pub pool_serial_ops: u64,
    /// Total chunks executed by the cpu pool's parallel operations.
    pub pool_chunks: u64,
    /// Fire-and-forget jobs handed to the cpu pool via `spawn`.
    pub pool_spawned: u64,
    /// Size of the io thread pool (prefetch readers, connection workers).
    pub io_threads: usize,
    /// Operations the io pool dispatched across threads.
    pub io_parallel_ops: u64,
    /// Operations the io pool ran inline.
    pub io_serial_ops: u64,
    /// Total chunks executed by the io pool's parallel operations.
    pub io_chunks: u64,
    /// Fire-and-forget jobs handed to the io pool via `spawn` —
    /// connection drain loops and scoped prefetch readers land here.
    pub io_spawned: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "submitted={} completed={} failed={} native={} artifact={} \
             depth={} inflight={} mean_exec={:.3}ms mean_queue={:.3}ms max_exec={:.3}ms \
             pool[threads={} par_ops={} serial_ops={} chunks={} spawned={}] \
             io[threads={} par_ops={} serial_ops={} chunks={} spawned={}] \
             stream[passes={} read={}B retries={}] \
             http[accepted={} rejected={} in={}B out={}B] \
             sweeps[used={} mean_pve={:.4}] \
             cache[hits={} misses={} bytes={}B] \
             lifecycle[cancelled={} evicted={}] \
             resilience[faults={} ckpt_written={} ckpt_resumed={} replayed={}]",
            self.submitted,
            self.completed,
            self.failed,
            self.native_jobs,
            self.artifact_jobs,
            self.queue_depth,
            self.in_flight,
            self.mean_exec_s * 1e3,
            self.mean_queue_s * 1e3,
            self.max_exec_s * 1e3,
            self.pool_threads,
            self.pool_parallel_ops,
            self.pool_serial_ops,
            self.pool_chunks,
            self.pool_spawned,
            self.io_threads,
            self.io_parallel_ops,
            self.io_serial_ops,
            self.io_chunks,
            self.io_spawned,
            self.stream_passes,
            self.stream_bytes_read,
            self.stream_retries,
            self.http_accepted,
            self.http_rejected,
            self.http_bytes_in,
            self.http_bytes_out,
            self.sweeps_used,
            self.mean_achieved_pve,
            self.cache_hits,
            self.cache_misses,
            self.cache_bytes,
            self.cancelled,
            self.evicted,
            self.faults_injected,
            self.checkpoints_written,
            self.checkpoints_resumed,
            self.journal_replayed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_snapshot() {
        let m = Metrics::default();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.record_exec(0.010, 0.001, true);
        m.record_exec(0.030, 0.002, false);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert_eq!(s.failed, 1);
        assert!((s.mean_exec_s - 0.020).abs() < 1e-6);
        assert!((s.max_exec_s - 0.030).abs() < 1e-6);
        assert!(format!("{s}").contains("completed=2"));
    }

    #[test]
    fn gauges_and_http_counters_snapshot() {
        let m = Metrics::default();
        m.queue_depth.fetch_add(2, Ordering::Relaxed);
        m.in_flight.fetch_add(1, Ordering::Relaxed);
        m.http_accepted.fetch_add(5, Ordering::Relaxed);
        m.http_rejected.fetch_add(1, Ordering::Relaxed);
        m.http_bytes_in.fetch_add(100, Ordering::Relaxed);
        m.http_bytes_out.fetch_add(300, Ordering::Relaxed);
        m.stream_passes.fetch_add(4, Ordering::Relaxed);
        m.stream_bytes_read.fetch_add(4096, Ordering::Relaxed);
        m.stream_retries.fetch_add(3, Ordering::Relaxed);
        m.record_sweeps(2, None);
        m.record_sweeps(3, Some(0.75));
        m.record_sweeps(5, Some(0.25));
        m.cancelled.fetch_add(2, Ordering::Relaxed);
        m.evicted.fetch_add(1, Ordering::Relaxed);
        m.cache_hits.fetch_add(7, Ordering::Relaxed);
        m.cache_misses.fetch_add(3, Ordering::Relaxed);
        m.cache_bytes.fetch_add(512, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.in_flight, 1);
        assert_eq!(s.http_accepted, 5);
        assert_eq!(s.http_rejected, 1);
        assert_eq!(s.stream_passes, 4);
        assert_eq!(s.stream_bytes_read, 4096);
        assert_eq!(s.sweeps_used, 10);
        assert!((s.mean_achieved_pve - 0.5).abs() < 1e-9);
        let text = format!("{s}");
        assert!(text.contains("inflight=1"), "{text}");
        assert!(text.contains("stream[passes=4 read=4096B retries=3]"), "{text}");
        assert!(text.contains("resilience["), "{text}");
        assert!(text.contains("http[accepted=5 rejected=1 in=100B out=300B]"), "{text}");
        assert!(text.contains("sweeps[used=10 mean_pve=0.5000]"), "{text}");
        assert_eq!(s.cancelled, 2);
        assert_eq!(s.evicted, 1);
        assert_eq!(s.cache_hits, 7);
        assert_eq!(s.cache_misses, 3);
        assert_eq!(s.cache_bytes, 512);
        assert!(text.contains("cache[hits=7 misses=3 bytes=512B]"), "{text}");
        assert!(text.contains("lifecycle[cancelled=2 evicted=1]"), "{text}");
        // The raw snapshot carries zeroed pool segments; the coordinator
        // overlays both pools' live stats.
        assert!(text.contains("pool[threads=0 par_ops=0 serial_ops=0 chunks=0 spawned=0]"), "{text}");
        assert!(text.contains("io[threads=0 par_ops=0 serial_ops=0 chunks=0 spawned=0]"), "{text}");
    }
}
