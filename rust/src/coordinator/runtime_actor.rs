//! The runtime actor: a single thread owning the PJRT [`Executor`]
//! (whose wrappers are not `Send`), consuming artifact-routed jobs from
//! a bounded channel.
//!
//! The actor compiles executables lazily on first use and keeps them
//! cached for the life of the service, so steady-state jobs pay only
//! the execute cost.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use crate::linalg::Dense;
use crate::rng::Xoshiro256pp;
use crate::runtime::Executor;
use crate::svd::SvdEngine;
use crate::util::{Error, Result};

use super::job::{JobOutput, JobResult, JobSpec, MatrixInput};
use super::metrics::Metrics;

pub(super) fn actor_loop(dir: PathBuf, rx: Receiver<super::WorkItem>, metrics: Arc<Metrics>) {
    let mut executor = match Executor::new(&dir) {
        Ok(e) => e,
        Err(e) => {
            // Fail every queued job with a clear error, then exit.
            crate::log_error!("runtime actor failed to start: {e}");
            for item in rx.iter() {
                metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                let _ = item.reply.send(JobResult {
                    id: item.id,
                    outcome: Err(Error::Runtime(format!("executor unavailable: {e}"))),
                    engine: SvdEngine::Artifact,
                    exec_s: 0.0,
                    queue_s: item.enqueued.elapsed().as_secs_f64(),
                });
                metrics.record_exec(0.0, 0.0, false);
            }
            return;
        }
    };

    for item in rx.iter() {
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        let queue_s = item.enqueued.elapsed().as_secs_f64();
        let t = Instant::now();
        let outcome = execute_artifact(&mut executor, &item.spec);
        let exec_s = t.elapsed().as_secs_f64();
        metrics.record_exec(exec_s, queue_s, outcome.is_ok());
        if let Ok(out) = &outcome {
            metrics.record_sweeps(out.sweeps_used, out.achieved_pve);
        }
        metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
        let _ = item.reply.send(JobResult {
            id: item.id,
            outcome,
            engine: SvdEngine::Artifact,
            exec_s,
            queue_s,
        });
    }
}

fn execute_artifact(executor: &mut Executor, spec: &JobSpec) -> Result<JobOutput> {
    let MatrixInput::Dense(x) = &spec.input else {
        return Err(Error::Service(
            "artifact engine requires a dense input (router bug)".into(),
        ));
    };
    let (m, n) = x.shape();
    // The router only sends fixed-q jobs here (artifacts are compiled
    // for a static sweep count).
    let q = spec.config.stop.fixed_q().ok_or_else(|| {
        Error::Service("artifact engine requires a fixed power_iters (router bug)".into())
    })?;
    let art = executor
        .manifest()
        .find_srsvd(m, n, spec.config.k, q)
        .ok_or_else(|| {
            Error::Service(format!(
                "no artifact for shape {m}x{n} k={} q={q} (router bug)",
                spec.config.k
            ))
        })?
        .clone();
    let mu = spec.shift.resolve(&spec.input)?;
    // Ω generated rust-side: deterministic replay across engines.
    let mut rng = Xoshiro256pp::seed_from_u64(spec.seed);
    let omega = Dense::gaussian(n, art.kk, &mut rng);
    let out = executor.run_srsvd(&art, x, &mu, &omega)?;
    Ok(JobOutput {
        factorization: out.factorization,
        mse: spec.score.then_some(out.mse),
        sweeps_used: q,
        achieved_pve: None,
    })
}

// Integration tests for the actor live in rust/tests/service.rs (they
// need built artifacts); unit coverage of the routing/queueing logic is
// in coordinator::tests.
