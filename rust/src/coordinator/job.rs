//! Job types for the factorization service.

use crate::linalg::stream::{MatrixSource, SharedSource, StreamConfig, Streamed};
use crate::linalg::{Csr, Dense};
use crate::svd::{Factorization, SvdConfig, SvdEngine};
use crate::util::Result;

/// Monotonic job identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// The data matrix of a job.
#[derive(Debug, Clone)]
pub enum MatrixInput {
    /// A resident dense matrix.
    Dense(Dense),
    /// A resident CSR sparse matrix.
    Sparse(Csr),
    /// An out-of-core source swept block-at-a-time under a memory
    /// budget (see [`crate::linalg::stream`]); always runs native.
    Streamed(Streamed<SharedSource>),
}

impl MatrixInput {
    /// Wrap any [`MatrixSource`] as a streamed, type-erased job input
    /// under the given memory policy.
    pub fn streamed<S: MatrixSource + 'static>(source: S, config: &StreamConfig) -> MatrixInput {
        let shared: SharedSource = std::sync::Arc::new(source);
        MatrixInput::Streamed(Streamed::new(shared, config))
    }

    /// Matrix dimensions `(m, n)`.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            MatrixInput::Dense(x) => x.shape(),
            MatrixInput::Sparse(x) => x.shape(),
            MatrixInput::Streamed(x) => crate::svd::MatVecOps::shape(x),
        }
    }

    /// Stored entry count: m·n for dense and streamed (logical size —
    /// a streamed input keeps only one block resident), nnz for sparse.
    pub fn stored_entries(&self) -> usize {
        match self {
            MatrixInput::Dense(x) => x.rows() * x.cols(),
            MatrixInput::Sparse(x) => x.nnz(),
            MatrixInput::Streamed(x) => crate::svd::MatVecOps::stored_entries(x),
        }
    }

    /// The operator view every engine consumes.
    pub fn as_ops(&self) -> &dyn crate::svd::MatVecOps {
        match self {
            MatrixInput::Dense(x) => x,
            MatrixInput::Sparse(x) => x,
            MatrixInput::Streamed(x) => x,
        }
    }
}

/// What to shift by (Alg. 1's μ).
#[derive(Debug, Clone)]
pub enum ShiftSpec {
    /// μ = 0: plain RSVD of X.
    None,
    /// μ = row means of X: the PCA use case.
    MeanCenter,
    /// An explicit shifting vector.
    Vector(Vec<f64>),
}

impl ShiftSpec {
    /// Concrete μ for `input`: zeros, its row means (one streaming pass
    /// for [`MatrixInput::Streamed`]), or the supplied vector.
    pub fn resolve(&self, input: &MatrixInput) -> Result<Vec<f64>> {
        let (m, _) = input.shape();
        match self {
            ShiftSpec::None => Ok(vec![0.0; m]),
            ShiftSpec::MeanCenter => Ok(input.as_ops().row_means()),
            ShiftSpec::Vector(v) => {
                crate::ensure!(v.len() == m, "shift vector length {} != m {}", v.len(), m);
                Ok(v.clone())
            }
        }
    }
}

/// Where the job may run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePreference {
    /// Prefer a compiled artifact when one matches, else native.
    Auto,
    /// Native rust engine only.
    Native,
    /// Compiled artifact only (error if no shape match).
    ArtifactOnly,
}

/// A factorization request.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// The data matrix (dense, sparse, or streamed).
    pub input: MatrixInput,
    /// Rank / oversampling / power-iteration configuration.
    pub config: SvdConfig,
    /// What to shift by (Alg. 1's μ).
    pub shift: ShiftSpec,
    /// Engine routing preference.
    pub engine: EnginePreference,
    /// Seed for Ω (deterministic replay).
    pub seed: u64,
    /// Also compute the paper's MSE metric.
    pub score: bool,
}

impl JobSpec {
    /// Mean-centered PCA job with paper parameters (K = 2k, q = 0).
    pub fn pca(input: MatrixInput, k: usize, seed: u64) -> JobSpec {
        JobSpec {
            input,
            config: SvdConfig::paper(k),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed,
            score: true,
        }
    }
}

/// Successful job output.
#[derive(Debug, Clone)]
pub struct JobOutput {
    /// The rank-k factors.
    pub factorization: Factorization,
    /// The paper's MSE (present when `score` was requested).
    pub mse: Option<f64>,
    /// Power sweeps the engine executed: the fixed `q` under
    /// [`crate::svd::StopCriterion::FixedPower`], the run-time count
    /// under the adaptive tolerance mode.
    pub sweeps_used: usize,
    /// Achieved proportion of variance explained — only reported by
    /// the adaptive tolerance mode (see
    /// [`crate::svd::SweepReport::achieved_pve`]).
    pub achieved_pve: Option<f64>,
}

/// Completed job envelope.
#[derive(Debug)]
pub struct JobResult {
    /// The identifier handed out at submit time.
    pub id: JobId,
    /// The factors (or the error that stopped them).
    pub outcome: Result<JobOutput>,
    /// Engine that actually ran the job.
    pub engine: SvdEngine,
    /// Seconds spent executing.
    pub exec_s: f64,
    /// Seconds spent queued before a worker picked the job up.
    pub queue_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn shift_spec_resolution() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::gaussian(5, 8, &mut rng);
        let input = MatrixInput::Dense(x.clone());
        assert_eq!(ShiftSpec::None.resolve(&input).unwrap(), vec![0.0; 5]);
        assert_eq!(
            ShiftSpec::MeanCenter.resolve(&input).unwrap(),
            x.row_means()
        );
        assert!(ShiftSpec::Vector(vec![1.0; 3]).resolve(&input).is_err());
        assert_eq!(
            ShiftSpec::Vector(vec![1.0; 5]).resolve(&input).unwrap(),
            vec![1.0; 5]
        );
    }

    #[test]
    fn pca_spec_defaults() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let spec = JobSpec::pca(
            MatrixInput::Dense(Dense::gaussian(4, 6, &mut rng)),
            2,
            7,
        );
        assert_eq!(spec.config.sample_width(), 4);
        assert!(spec.score);
        assert_eq!(spec.input.shape(), (4, 6));
    }
}
