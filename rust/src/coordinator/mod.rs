//! The factorization service — L3 of the stack.
//!
//! A [`Coordinator`] owns a pool of native worker threads plus (when
//! artifacts are available) one *runtime actor* thread that hosts the
//! PJRT [`crate::runtime::Executor`] (PJRT wrappers are not `Send`, so
//! the executor is confined to its actor). Jobs are routed at submit
//! time ([`router`]): dense, grid-shaped jobs go to the compiled
//! artifact; everything else — arbitrary shapes, sparse inputs,
//! streamed (out-of-core) sources, ablation variants — runs natively.
//!
//! Backpressure: both queues are bounded (`queue_capacity`); `submit`
//! blocks when full, `try_submit` returns [`crate::util::Error::Busy`]
//! instead (the network layer's 503 signal).
//!
//! ```no_run
//! use srsvd::coordinator::{Coordinator, CoordinatorConfig};
//! use srsvd::coordinator::job::{JobSpec, MatrixInput};
//! use srsvd::linalg::Dense;
//! # use srsvd::rng::{Rng, Xoshiro256pp};
//! let coord = Coordinator::start(CoordinatorConfig::default()).unwrap();
//! # let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let x = Dense::from_fn(100, 1000, |_, _| rng.next_uniform());
//! let handle = coord.submit(JobSpec::pca(MatrixInput::Dense(x), 10, 7)).unwrap();
//! let result = handle.wait().unwrap();
//! println!("mse = {:?}", result.outcome.unwrap().mse);
//! ```

pub mod job;
pub mod metrics;
pub mod native_worker;
pub mod router;
mod runtime_actor;

pub use job::{EnginePreference, JobId, JobOutput, JobResult, JobSpec, MatrixInput, ShiftSpec};
pub use metrics::{Metrics, MetricsSnapshot};
pub use router::Route;

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::parallel::{self, ThreadPool};
use crate::runtime::Manifest;
use crate::svd::SvdEngine;
use crate::util::{retry::RetryPolicy, Error, Result};

/// Service configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Native worker threads.
    pub native_workers: usize,
    /// Bounded queue capacity (per engine).
    pub queue_capacity: usize,
    /// Artifact directory; `None` disables the artifact engine,
    /// `Some(dir)` requires a valid manifest there.
    pub artifact_dir: Option<PathBuf>,
    /// Size of the shared linalg thread pool the native workers execute
    /// on (`[parallel] threads` in srsvd.conf). `None` = the process
    /// global pool (`SRSVD_THREADS` / all cores).
    pub pool_threads: Option<usize>,
    /// Size of the io pool (`[parallel] io_threads`) that carries
    /// streamed prefetch readers and server connection workers, kept
    /// separate from the cpu pool so blocking reads cannot starve
    /// GEMM/SVD compute. `None` = the process global io pool
    /// (`SRSVD_IO_THREADS` / a small core-count-derived default).
    pub io_threads: Option<usize>,
    /// Sweep-granular checkpoint/resume directory (`[svd]
    /// checkpoint_dir` / `--checkpoint-dir`): native jobs spill their
    /// state after every completed sweep and a restarted service
    /// resumes interrupted jobs byte-identically. `None` (default) = no
    /// checkpointing.
    pub checkpoint_dir: Option<PathBuf>,
    /// Retry policy for transient streamed-source read failures inside
    /// a sweep (`[retry]` / `--retry-*`). The default allows a couple
    /// of backed-off retries; [`RetryPolicy::none`] restores fail-fast.
    pub retry: RetryPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            native_workers: worker_default(),
            queue_capacity: 256,
            artifact_dir: default_artifact_dir(),
            pool_threads: None,
            io_threads: None,
            checkpoint_dir: None,
            retry: RetryPolicy::default(),
        }
    }
}

fn worker_default() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

fn default_artifact_dir() -> Option<PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

struct WorkItem {
    id: JobId,
    spec: JobSpec,
    enqueued: Instant,
    reply: std::sync::mpsc::Sender<JobResult>,
    /// Shared with the [`JobHandle`]: a set flag asks the executing
    /// worker to abandon the job at its next cooperative checkpoint.
    cancel: Arc<AtomicBool>,
}

/// Handle to an in-flight job.
pub struct JobHandle {
    /// The identifier assigned at submit time.
    pub id: JobId,
    rx: Receiver<JobResult>,
    cancel: Arc<AtomicBool>,
}

impl JobHandle {
    /// Request cooperative cancellation: the flag is checked before
    /// execution starts and between power sweeps / streamed blocks, so
    /// a cancelled job resolves (via [`Self::wait`]) with
    /// [`Error::Cancelled`] as its outcome shortly after. Idempotent;
    /// a job that already finished is unaffected.
    pub fn cancel(&self) {
        self.cancel.store(true, std::sync::atomic::Ordering::Relaxed);
    }

    /// Block until the job completes.
    pub fn wait(self) -> Result<JobResult> {
        self.rx
            .recv()
            .map_err(|_| Error::Service("worker dropped without reply".into()))
    }

    /// Block with a timeout. Expiry is the typed [`Error::Timeout`]
    /// (the job keeps running; wait again), distinct from a dead
    /// worker's [`Error::Service`].
    pub fn wait_timeout(&self, dur: Duration) -> Result<JobResult> {
        self.rx.recv_timeout(dur).map_err(|e| match e {
            RecvTimeoutError::Timeout => Error::Timeout("job still running".into()),
            RecvTimeoutError::Disconnected => {
                Error::Service("worker dropped without reply".into())
            }
        })
    }
}

/// The factorization service.
pub struct Coordinator {
    native_tx: Option<SyncSender<WorkItem>>,
    artifact_tx: Option<SyncSender<WorkItem>>,
    manifest: Option<Manifest>,
    metrics: Arc<Metrics>,
    /// Shared linalg (cpu) pool the native workers execute on.
    pool: Arc<ThreadPool>,
    /// Shared io pool: streamed prefetch readers and (when the network
    /// layer is attached) connection workers run here.
    io: Arc<ThreadPool>,
    next_id: AtomicU64,
    /// Bounded queue capacity (per engine), kept for readiness probes:
    /// `GET /readyz` compares the live `queue_depth` gauge against it.
    queue_capacity: usize,
    /// Retry policy stamped onto every streamed input at submit time.
    retry: RetryPolicy,
    native_handles: Vec<std::thread::JoinHandle<()>>,
    actor_handle: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start workers (and the runtime actor when artifacts are present).
    pub fn start(config: CoordinatorConfig) -> Result<Coordinator> {
        crate::util::logging::init();
        crate::ensure!(config.native_workers >= 1, "need at least one worker");
        let metrics = Arc::new(Metrics::default());

        // The shared linalg pool: every native worker installs it as its
        // thread pool, so jobs run panel-parallel GEMM / row-parallel
        // CSR kernels on one pool instead of each job being serial.
        let pool = match config.pool_threads {
            Some(t) => Arc::new(ThreadPool::new(t)),
            None => parallel::global(),
        };
        // The io pool is always `named` (dedicated workers): a size-1 io
        // pool still runs its jobs off-thread, which is what keeps a
        // blocking read from pinning a compute worker.
        let io = match config.io_threads {
            Some(t) => Arc::new(ThreadPool::named(t, "io")),
            None => parallel::global_io(),
        };

        // Native workers: shared bounded queue behind a mutexed receiver.
        let (native_tx, native_rx) = sync_channel::<WorkItem>(config.queue_capacity);
        let native_rx = Arc::new(Mutex::new(native_rx));
        let mut native_handles = Vec::new();
        for w in 0..config.native_workers {
            let rx = Arc::clone(&native_rx);
            let mx = Arc::clone(&metrics);
            let pl = Arc::clone(&pool);
            let iop = Arc::clone(&io);
            let ckpt = config.checkpoint_dir.clone();
            native_handles.push(
                std::thread::Builder::new()
                    .name(format!("srsvd-native-{w}"))
                    .spawn(move || native_loop(rx, mx, pl, iop, ckpt))
                    .map_err(|e| Error::Service(format!("spawn worker: {e}")))?,
            );
        }

        // Artifact actor (optional).
        let (artifact_tx, actor_handle, manifest) = match &config.artifact_dir {
            Some(dir) => {
                let manifest = Manifest::load(dir)?;
                let (tx, rx) = sync_channel::<WorkItem>(config.queue_capacity);
                let mx = Arc::clone(&metrics);
                let dir = dir.clone();
                let handle = std::thread::Builder::new()
                    .name("srsvd-runtime-actor".into())
                    .spawn(move || runtime_actor::actor_loop(dir, rx, mx))
                    .map_err(|e| Error::Service(format!("spawn actor: {e}")))?;
                (Some(tx), Some(handle), Some(manifest))
            }
            None => (None, None, None),
        };

        crate::log_info!(
            "coordinator: {} native workers on a {}-thread cpu pool + {}-thread io pool, \
             artifact engine: {}",
            config.native_workers,
            pool.threads(),
            io.threads(),
            if artifact_tx.is_some() { "on" } else { "off" }
        );
        Ok(Coordinator {
            native_tx: Some(native_tx),
            artifact_tx,
            manifest,
            metrics,
            pool,
            io,
            next_id: AtomicU64::new(1),
            queue_capacity: config.queue_capacity,
            retry: config.retry,
            native_handles,
            actor_handle,
        })
    }

    /// Start with the native engine only (no artifacts required).
    pub fn start_native_only(workers: usize) -> Result<Coordinator> {
        Coordinator::start(CoordinatorConfig {
            native_workers: workers,
            queue_capacity: 256,
            artifact_dir: None,
            pool_threads: None,
            io_threads: None,
            checkpoint_dir: None,
            retry: RetryPolicy::default(),
        })
    }

    /// Service counters plus both pools' stats.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut s = self.metrics.snapshot();
        let ps = self.pool.stats();
        s.pool_threads = ps.threads;
        s.pool_parallel_ops = ps.parallel_ops;
        s.pool_serial_ops = ps.serial_ops;
        s.pool_chunks = ps.chunks;
        s.pool_spawned = ps.spawned;
        let is = self.io.stats();
        s.io_threads = is.threads;
        s.io_parallel_ops = is.parallel_ops;
        s.io_serial_ops = is.serial_ops;
        s.io_chunks = is.chunks;
        s.io_spawned = is.spawned;
        s
    }

    /// The io pool this coordinator routes blocking work onto — the
    /// network layer runs its connection workers here so request
    /// plumbing shares capacity with prefetch readers, not with GEMM.
    pub fn io_pool(&self) -> Arc<ThreadPool> {
        Arc::clone(&self.io)
    }

    /// The loaded artifact manifest, when the artifact engine is on.
    pub fn manifest(&self) -> Option<&Manifest> {
        self.manifest.as_ref()
    }

    /// The bounded queue capacity each engine was started with. The
    /// network layer's `GET /readyz` answers 503 once `queue_depth`
    /// reaches this, so a router can shed load to a sibling replica
    /// *before* a submit eats the 503.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// The shared raw counters — the network service layer
    /// ([`crate::server`]) records its accepted/rejected/byte counts
    /// here so `/metrics` is one coherent snapshot.
    pub(crate) fn metrics_shared(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Submit a job; blocks when the target queue is full (backpressure).
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        self.submit_inner(spec, true)
    }

    /// Submit without blocking; `Error::Service` when the queue is full.
    pub fn try_submit(&self, spec: JobSpec) -> Result<JobHandle> {
        self.submit_inner(spec, false)
    }

    fn submit_inner(&self, mut spec: JobSpec, block: bool) -> Result<JobHandle> {
        // Streamed inputs get private I/O counters per submission:
        // `SourceStats` handles are shared across clones of a spec, and
        // two such jobs running concurrently would interleave their
        // per-job metric deltas otherwise.
        if let MatrixInput::Streamed(s) = &mut spec.input {
            *s = s.fresh_stats();
            // Transient read failures inside a sweep retry under the
            // service's policy instead of failing the job outright.
            s.set_retry(self.retry);
        }
        let route = router::route(&spec, self.manifest.as_ref())?;
        let id = JobId(self.next_id.fetch_add(1, Ordering::Relaxed));
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let item = WorkItem {
            id,
            spec,
            enqueued: Instant::now(),
            reply: reply_tx,
            cancel: Arc::clone(&cancel),
        };
        let tx = match route {
            Route::Native => self.native_tx.as_ref().unwrap(),
            Route::Artifact { .. } => self.artifact_tx.as_ref().ok_or_else(|| {
                Error::Service("artifact route chosen but engine is off".into())
            })?,
        };
        // queue_depth must be visible before the item can be dequeued
        // (a worker decrements it), so bump it first and roll back on a
        // failed send. The cumulative counters are only ever read, so
        // they count *accepted* submissions after the send succeeds —
        // a 503-rejected try_submit must not inflate them.
        self.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
        let send_result = if block {
            tx.send(item).map_err(|_| Error::Service("queue closed".into()))
        } else {
            tx.try_send(item).map_err(|e| match e {
                std::sync::mpsc::TrySendError::Full(_) => {
                    Error::Busy("queue full".into())
                }
                std::sync::mpsc::TrySendError::Disconnected(_) => {
                    Error::Service("queue closed".into())
                }
            })
        };
        if let Err(e) = send_result {
            self.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            return Err(e);
        }
        match route {
            Route::Native => self.metrics.native_jobs.fetch_add(1, Ordering::Relaxed),
            Route::Artifact { .. } => {
                self.metrics.artifact_jobs.fetch_add(1, Ordering::Relaxed)
            }
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(JobHandle { id, rx: reply_rx, cancel })
    }

    /// Convenience: submit and wait.
    pub fn submit_blocking(&self, spec: JobSpec) -> Result<JobResult> {
        self.submit(spec)?.wait()
    }

    /// Drain queues and join all threads.
    pub fn shutdown(mut self) {
        self.native_tx.take();
        self.artifact_tx.take();
        for h in self.native_handles.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.actor_handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Close queues so worker threads exit even without shutdown().
        self.native_tx.take();
        self.artifact_tx.take();
    }
}

fn native_loop(
    rx: Arc<Mutex<Receiver<WorkItem>>>,
    metrics: Arc<Metrics>,
    pool: Arc<ThreadPool>,
    io: Arc<ThreadPool>,
    checkpoint_dir: Option<PathBuf>,
) {
    // Every linalg hot path this worker executes dispatches onto the
    // coordinator's shared cpu pool instead of running serial; streamed
    // prefetch readers dispatch onto the io pool.
    parallel::set_thread_pool(Some(pool));
    parallel::set_io_pool(Some(io));
    loop {
        let item = {
            let guard = rx.lock().expect("queue mutex poisoned");
            guard.recv()
        };
        let Ok(mut item) = item else { return };
        metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
        metrics.in_flight.fetch_add(1, Ordering::Relaxed);
        let queue_s = item.enqueued.elapsed().as_secs_f64();
        let t = Instant::now();
        // Streamed sweeps check the flag between blocks; dense/sparse
        // jobs check it between power sweeps inside `ShiftedRsvd`.
        if let MatrixInput::Streamed(s) = &mut item.spec.input {
            s.set_cancel(Arc::clone(&item.cancel));
        }
        // Panic isolation: a panicking job (e.g. a streamed source whose
        // backing file fails mid-sweep) must fail *that job*, not kill
        // the worker and strand everything queued behind it.
        let outcome = if item.cancel.load(Ordering::Relaxed) {
            // Cancelled while queued: never execute at all.
            Err(Error::Cancelled("job cancelled before execution".into()))
        } else {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                native_worker::execute_native_job(
                    &item.spec,
                    &item.cancel,
                    checkpoint_dir.as_deref(),
                )
            }))
            .unwrap_or_else(|payload| {
                let msg = panic_message(payload.as_ref());
                crate::log_error!("{}: job panicked: {msg}", item.id);
                if msg.contains(crate::linalg::stream::SOURCE_IO_PANIC) {
                    // A streamed source that exhausted its retry budget:
                    // surface the typed IO error (with the attempt
                    // count already in the message), not a bare panic.
                    Err(Error::Io(std::io::Error::new(
                        std::io::ErrorKind::Other,
                        format!("{}: {msg}", item.id),
                    )))
                } else {
                    Err(Error::Service(format!("{}: job panicked: {msg}", item.id)))
                }
            })
        };
        let exec_s = t.elapsed().as_secs_f64();
        metrics.record_exec(exec_s, queue_s, outcome.is_ok());
        if let Ok(out) = &outcome {
            metrics.record_sweeps(out.sweeps_used, out.achieved_pve);
        }
        // Streamed jobs carry private per-submission I/O counters
        // (zeroed in `submit_inner`), so the totals ARE this job's
        // delta — including partial sweeps of a panicked job.
        if let MatrixInput::Streamed(s) = &item.spec.input {
            let io = s.stats();
            metrics.stream_passes.fetch_add(io.passes, Ordering::Relaxed);
            metrics
                .stream_bytes_read
                .fetch_add(io.bytes_read, Ordering::Relaxed);
            metrics
                .stream_retries
                .fetch_add(io.retries, Ordering::Relaxed);
        }
        metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
        let _ = item.reply.send(JobResult {
            id: item.id,
            outcome,
            engine: SvdEngine::Native,
            exec_s,
            queue_s,
        });
    }
}

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Csr, Dense};
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::svd::SvdConfig;

    fn dense_spec(seed: u64) -> JobSpec {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        JobSpec {
            input: MatrixInput::Dense(Dense::from_fn(30, 80, |_, _| rng.next_uniform())),
            config: SvdConfig::paper(4),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Native,
            seed,
            score: true,
        }
    }

    #[test]
    fn native_only_roundtrip() {
        let coord = Coordinator::start_native_only(2).unwrap();
        let r = coord.submit_blocking(dense_spec(1)).unwrap();
        assert!(r.outcome.is_ok());
        assert_eq!(r.engine, SvdEngine::Native);
        let m = coord.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.failed, 0);
        coord.shutdown();
    }

    #[test]
    fn many_jobs_all_complete() {
        let coord = Coordinator::start_native_only(3).unwrap();
        let handles: Vec<_> = (0..20)
            .map(|s| coord.submit(dense_spec(s)).unwrap())
            .collect();
        let mut ids = std::collections::HashSet::new();
        for h in handles {
            let r = h.wait().unwrap();
            assert!(r.outcome.is_ok());
            ids.insert(r.id);
        }
        assert_eq!(ids.len(), 20);
        assert_eq!(coord.metrics().completed, 20);
        assert_eq!(coord.metrics().queue_depth, 0);
        coord.shutdown();
    }

    #[test]
    fn sparse_jobs_run_native() {
        let coord = Coordinator::start_native_only(1).unwrap();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let spec = JobSpec {
            input: MatrixInput::Sparse(Csr::random(40, 200, 0.05, &mut rng, |r| {
                r.next_uniform() + 0.1
            })),
            config: SvdConfig::paper(5),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 6,
            score: true,
        };
        let r = coord.submit_blocking(spec).unwrap();
        assert_eq!(r.engine, SvdEngine::Native);
        assert!(r.outcome.unwrap().mse.unwrap() >= 0.0);
        coord.shutdown();
    }

    #[test]
    fn bad_job_reports_error_not_hang() {
        let coord = Coordinator::start_native_only(1).unwrap();
        let mut spec = dense_spec(7);
        spec.shift = ShiftSpec::Vector(vec![0.0; 3]); // wrong length
        let r = coord.submit_blocking(spec).unwrap();
        assert!(r.outcome.is_err());
        assert_eq!(coord.metrics().failed, 1);
        coord.shutdown();
    }

    #[test]
    fn try_submit_backpressure() {
        // 1 worker, capacity 1: a burst must eventually hit "queue full".
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 1,
            queue_capacity: 1,
            artifact_dir: None,
            ..Default::default()
        })
        .unwrap();
        let mut handles = Vec::new();
        let mut saw_full = false;
        for s in 0..50 {
            match coord.try_submit(dense_spec(s)) {
                Ok(h) => handles.push(h),
                Err(e) => {
                    saw_full = true;
                    assert!(format!("{e}").contains("backpressure"), "{e}");
                    break;
                }
            }
        }
        assert!(saw_full, "expected backpressure with capacity 1");
        let accepted = handles.len() as u64;
        for h in handles {
            let _ = h.wait();
        }
        // Rejected submissions must not inflate the cumulative counters.
        let m = coord.metrics();
        assert_eq!(m.submitted, accepted);
        assert_eq!(m.native_jobs, accepted);
        coord.shutdown();
    }

    #[test]
    fn cancelled_queued_job_reports_cancelled_without_executing() {
        // One worker pinned by a slow job; a queued job cancelled
        // behind it must resolve as Error::Cancelled without running.
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 1,
            queue_capacity: 8,
            artifact_dir: None,
            pool_threads: Some(1),
            ..Default::default()
        })
        .unwrap();
        let mut slow = dense_spec(1);
        slow.input = MatrixInput::Dense(Dense::from_fn(200, 800, |i, j| {
            ((i * 31 + j) % 97) as f64 / 97.0
        }));
        slow.config = SvdConfig::paper(16).with_fixed_power(4);
        let slow_handle = coord.submit(slow).unwrap();
        let victim = coord.submit(dense_spec(2)).unwrap();
        victim.cancel();
        let r = victim.wait().unwrap();
        assert!(
            matches!(r.outcome, Err(Error::Cancelled(_))),
            "expected cancelled outcome, got {:?}",
            r.outcome.map(|_| ())
        );
        assert!(slow_handle.wait().unwrap().outcome.is_ok());
        coord.shutdown();
    }

    #[test]
    fn deterministic_results_across_pool_sizes() {
        let r1 = {
            let c = Coordinator::start_native_only(1).unwrap();
            let r = c.submit_blocking(dense_spec(9)).unwrap();
            c.shutdown();
            r.outcome.unwrap().mse.unwrap()
        };
        let r4 = {
            let c = Coordinator::start_native_only(4).unwrap();
            let r = c.submit_blocking(dense_spec(9)).unwrap();
            c.shutdown();
            r.outcome.unwrap().mse.unwrap()
        };
        assert_eq!(r1, r4);
    }

    #[test]
    fn pool_threads_knob_sizes_the_shared_pool() {
        let coord = Coordinator::start(CoordinatorConfig {
            native_workers: 2,
            queue_capacity: 16,
            artifact_dir: None,
            pool_threads: Some(3),
            io_threads: Some(2),
            ..Default::default()
        })
        .unwrap();
        let r = coord.submit_blocking(dense_spec(11)).unwrap();
        assert!(r.outcome.is_ok());
        let m = coord.metrics();
        assert_eq!(m.pool_threads, 3);
        assert_eq!(m.io_threads, 2);
        let text = format!("{m}");
        assert!(text.contains("pool[threads=3"), "{text}");
        assert!(text.contains("io[threads=2"), "{text}");
        coord.shutdown();
    }
}
