//! The parallel execution subsystem: a small chunked thread pool built
//! on std threads + channels (the offline environment has no rayon),
//! shared process-wide and threaded through every linalg hot path.
//!
//! ## Design
//!
//! * **Chunked self-scheduling.** A parallel operation is split into
//!   contiguous output chunks; workers (plus the calling thread) claim
//!   chunk indices from a shared atomic counter, so fast threads steal
//!   the chunks slow threads never reach. Dynamic load balance without
//!   per-task queues.
//! * **Deterministic by construction.** Chunks always partition the
//!   *output*: each output row is produced entirely by one task running
//!   the exact serial inner-loop order. No cross-thread reductions, so
//!   results are bit-identical for every pool size (including 1) — a
//!   hard requirement, since every experiment is seeded.
//! * **Split cpu/io pools.** The process runs *two* pools (the
//!   [`Pools`] pair, symbolicator-style): the **cpu** pool fans out
//!   GEMM panels and SVD stages, while the **io** pool parks blocking
//!   work — `Streamed` prefetch readers, `FileSource` handle reads,
//!   HTTP connection draining — so a slow disk or a pile of idle
//!   keep-alive sockets can never steal compute threads from the hot
//!   path. Io-style work is submitted with [`ThreadPool::spawn`]
//!   (fire-and-forget) or [`ThreadPool::spawn_scoped`] (borrowing,
//!   joinable); compute fan-out keeps using [`ThreadPool::run_chunks`].
//! * **Process-wide handles.** [`global()`] lazily builds the cpu pool
//!   sized from `SRSVD_THREADS` (else the machine's available
//!   parallelism); [`global_io()`] builds the io pool from
//!   `SRSVD_IO_THREADS` (else a small bounded default). The coordinator
//!   can size its own pair from the `[parallel] threads` / `[parallel]
//!   io_threads` config knobs; worker threads install them with
//!   [`set_thread_pool`] / [`set_io_pool`] so every job shares one pair
//!   instead of each job running serial.
//! * **No nested parallelism.** A parallel op issued from inside a pool
//!   worker runs inline — the pool can never deadlock on itself.
//!   Likewise [`ThreadPool::spawn_scoped`] refuses (returns `None`)
//!   when every worker is already occupied, so callers fall back to a
//!   plain scoped thread instead of queueing behind long-running jobs.
//!
//! The only `unsafe` lives here: lifetime erasures for the scoped
//! closures (sound because `run_chunks` blocks until every helper has
//! finished, and a [`ScopedTask`] blocks on drop/join until its job
//! has finished) and the disjoint row-slice split in
//! [`par_row_chunks`].

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Counters a pool keeps about its own usage (read via
/// [`ThreadPool::stats`] and surfaced in the coordinator metrics).
#[derive(Debug, Default)]
pub struct PoolStats {
    /// Parallel operations dispatched across threads.
    parallel_ops: AtomicU64,
    /// Operations executed inline (pool size 1, single chunk, or issued
    /// from inside a worker).
    serial_ops: AtomicU64,
    /// Total chunks executed by parallel operations.
    chunks: AtomicU64,
    /// Jobs submitted via [`ThreadPool::spawn`] / [`ThreadPool::spawn_scoped`]
    /// (the io-pool submission path).
    spawned: AtomicU64,
}

/// Point-in-time view of a pool's counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStatsSnapshot {
    /// Total participants (workers + caller) of a parallel operation.
    pub threads: usize,
    /// Operations dispatched across threads.
    pub parallel_ops: u64,
    /// Operations executed inline (small input / size-1 pool / nested).
    pub serial_ops: u64,
    /// Total chunks executed by parallel operations.
    pub chunks: u64,
    /// Jobs submitted via `spawn` / `spawn_scoped`.
    pub spawned: u64,
}

impl std::fmt::Display for PoolStatsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "threads={} par_ops={} serial_ops={} chunks={} spawned={}",
            self.threads, self.parallel_ops, self.serial_ops, self.chunks, self.spawned
        )
    }
}

/// A fixed-size pool of `threads - 1` worker threads; the caller of a
/// parallel operation is the remaining participant.
pub struct ThreadPool {
    threads: usize,
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    stats: PoolStats,
    /// Workers currently held by `spawn` / `spawn_scoped` jobs; gates
    /// `spawn_scoped` saturation (shared with the job wrappers, which
    /// outlive `&self`).
    in_use: Arc<AtomicUsize>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool")
            .field("threads", &self.threads)
            .finish()
    }
}

thread_local! {
    /// Per-thread pool override (set on coordinator worker threads and
    /// inside [`with_pool`] scopes); `None` means use the global pool.
    static CURRENT: RefCell<Option<Arc<ThreadPool>>> = const { RefCell::new(None) };
    /// Per-thread io-pool override, mirroring `CURRENT`.
    static CURRENT_IO: RefCell<Option<Arc<ThreadPool>>> = const { RefCell::new(None) };
    /// True on pool worker threads: parallel ops issued there run inline.
    static IN_WORKER: Cell<bool> = const { Cell::new(false) };
}

static GLOBAL: OnceLock<Arc<ThreadPool>> = OnceLock::new();
static GLOBAL_IO: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// Pool size from the environment: `SRSVD_THREADS` if set to a positive
/// integer, else the machine's available parallelism, else 1.
pub fn default_threads() -> usize {
    if let Ok(s) = std::env::var("SRSVD_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// The process-wide pool, built on first use with [`default_threads`].
pub fn global() -> Arc<ThreadPool> {
    GLOBAL
        .get_or_init(|| Arc::new(ThreadPool::new(default_threads())))
        .clone()
}

/// Size the global pool explicitly (e.g. from a config file) before its
/// first use. Returns `false` if the global pool already exists, in
/// which case the existing pool is kept.
pub fn init_global(threads: usize) -> bool {
    GLOBAL.set(Arc::new(ThreadPool::new(threads))).is_ok()
}

/// Io-pool size from the environment: `SRSVD_IO_THREADS` if set to a
/// positive integer, else a small bounded default — enough workers to
/// overlap prefetch reads and connection draining, but never sized like
/// the compute pool (io jobs block, they don't burn cores).
pub fn default_io_threads() -> usize {
    if let Ok(s) = std::env::var("SRSVD_IO_THREADS") {
        if let Ok(n) = s.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    hw.clamp(2, 8)
}

/// The process-wide io pool, built on first use with
/// [`default_io_threads`].
pub fn global_io() -> Arc<ThreadPool> {
    GLOBAL_IO
        .get_or_init(|| Arc::new(ThreadPool::named(default_io_threads(), "io")))
        .clone()
}

/// Size the global io pool explicitly (e.g. from a config file) before
/// its first use. Returns `false` if it already exists, in which case
/// the existing pool is kept.
pub fn init_global_io(threads: usize) -> bool {
    GLOBAL_IO
        .set(Arc::new(ThreadPool::named(threads, "io")))
        .is_ok()
}

/// Install (or clear) this thread's pool override. Coordinator worker
/// threads call this once at startup so jobs share the service pool.
pub fn set_thread_pool(pool: Option<Arc<ThreadPool>>) {
    CURRENT.with(|c| *c.borrow_mut() = pool);
}

/// Install (or clear) this thread's *io*-pool override, mirroring
/// [`set_thread_pool`].
pub fn set_io_pool(pool: Option<Arc<ThreadPool>>) {
    CURRENT_IO.with(|c| *c.borrow_mut() = pool);
}

/// Run `f` against the calling thread's effective pool: the thread-local
/// override when one is installed, else the global pool.
pub fn with_current<R>(f: impl FnOnce(&ThreadPool) -> R) -> R {
    let cur = CURRENT.with(|c| c.borrow().clone());
    match cur {
        Some(p) => f(&p),
        None => f(&global()),
    }
}

/// Run `f` against the calling thread's effective *io* pool: the
/// thread-local override when installed, else the global io pool.
pub fn with_current_io<R>(f: impl FnOnce(&ThreadPool) -> R) -> R {
    let cur = CURRENT_IO.with(|c| c.borrow().clone());
    match cur {
        Some(p) => f(&p),
        None => f(&global_io()),
    }
}

/// The split executor pair: compute fan-out on `cpu`, blocking work
/// (prefetch readers, file-handle reads, connection draining) parked on
/// `io` so neither load can starve the other. The coordinator owns one
/// pair per process; benches and tests may build ad-hoc pairs.
#[derive(Debug, Clone)]
pub struct Pools {
    /// Compute pool — GEMM panels and SVD stages (`run_chunks` path).
    pub cpu: Arc<ThreadPool>,
    /// Blocking pool — io jobs (`spawn` / `spawn_scoped` path).
    pub io: Arc<ThreadPool>,
}

impl Pools {
    /// Build from explicit sizes; `None` falls back to the process-wide
    /// pool of that kind ([`global`] / [`global_io`]).
    pub fn from_sizes(cpu: Option<usize>, io: Option<usize>) -> Pools {
        Pools {
            cpu: match cpu {
                Some(t) => Arc::new(ThreadPool::new(t)),
                None => global(),
            },
            io: match io {
                Some(t) => Arc::new(ThreadPool::named(t, "io")),
                None => global_io(),
            },
        }
    }

    /// Install both pools as the calling thread's overrides.
    pub fn install(&self) {
        set_thread_pool(Some(Arc::clone(&self.cpu)));
        set_io_pool(Some(Arc::clone(&self.io)));
    }
}

/// Run `f` with `pool` installed as this thread's pool override,
/// restoring the previous override afterwards (even on panic). Used by
/// benches and the determinism tests to pin an exact pool size.
pub fn with_pool<R>(pool: &Arc<ThreadPool>, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<ThreadPool>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let old = self.0.take();
            CURRENT.with(|c| *c.borrow_mut() = old);
        }
    }
    let old = CURRENT.with(|c| c.replace(Some(Arc::clone(pool))));
    let _restore = Restore(old);
    f()
}

impl ThreadPool {
    /// Build a pool with `threads` total participants (`threads - 1`
    /// spawned workers; the caller of each operation is the last one).
    /// `threads = 1` is a valid, fully inline pool.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        if threads == 1 {
            return ThreadPool {
                threads,
                tx: None,
                handles: Vec::new(),
                stats: PoolStats::default(),
                in_use: Arc::new(AtomicUsize::new(0)),
            };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads - 1);
        for w in 0..threads - 1 {
            let rx = Arc::clone(&rx);
            let h = std::thread::Builder::new()
                .name(format!("srsvd-pool-{w}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn pool worker");
            handles.push(h);
        }
        ThreadPool {
            threads,
            tx: Some(tx),
            handles,
            stats: PoolStats::default(),
            in_use: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Build a *named* pool that always dedicates `threads.max(1)`
    /// spawned workers (`srsvd-{name}-{w}`), even for size 1 — unlike
    /// [`ThreadPool::new`], whose size-1 pool is fully inline. This is
    /// the io-pool constructor: `spawn`ed jobs must actually run off
    /// the caller's thread for a size-1 io pool to be useful.
    pub fn named(threads: usize, name: &str) -> ThreadPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let rx = Arc::clone(&rx);
            let h = std::thread::Builder::new()
                .name(format!("srsvd-{name}-{w}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn pool worker");
            handles.push(h);
        }
        ThreadPool {
            threads,
            tx: Some(tx),
            handles,
            stats: PoolStats::default(),
            in_use: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// Total participants (workers + caller) of a parallel operation.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Snapshot this pool's usage counters.
    pub fn stats(&self) -> PoolStatsSnapshot {
        PoolStatsSnapshot {
            threads: self.threads,
            parallel_ops: self.stats.parallel_ops.load(Ordering::Relaxed),
            serial_ops: self.stats.serial_ops.load(Ordering::Relaxed),
            chunks: self.stats.chunks.load(Ordering::Relaxed),
            spawned: self.stats.spawned.load(Ordering::Relaxed),
        }
    }

    /// Spawned worker threads (differs from [`ThreadPool::threads`] for
    /// `new` pools, where the caller is a participant).
    fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `job` asynchronously on a pool worker (fire-and-forget; the
    /// io-pool path for long-lived work like HTTP connection draining).
    /// On a pool with no workers (a size-1 [`ThreadPool::new`] pool) the
    /// job runs inline on the caller. A panicking job is caught and
    /// logged so the worker thread survives.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.stats.spawned.fetch_add(1, Ordering::Relaxed);
        if let Some(tx) = &self.tx {
            let in_use = Arc::clone(&self.in_use);
            in_use.fetch_add(1, Ordering::SeqCst);
            let wrapped: Job = Box::new(move || {
                if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
                    eprintln!("srsvd: spawned pool job panicked (worker survives)");
                }
                in_use.fetch_sub(1, Ordering::SeqCst);
            });
            tx.send(wrapped).expect("pool queue closed");
            return;
        }
        job();
    }

    /// Run a *borrowing* job on a pool worker, returning a handle that
    /// must finish (join or drop) before the borrow ends — the prefetch
    /// path: readers borrow `&source` for one sweep.
    ///
    /// Returns `None` (without running the job) when the pool has no
    /// workers or every worker is already held by a spawned job: the
    /// caller falls back to a plain scoped thread instead of queueing
    /// behind long-running io jobs — degradation, never deadlock.
    pub fn spawn_scoped<'a>(
        &self,
        job: Box<dyn FnOnce() + Send + 'a>,
    ) -> Option<ScopedTask<'a>> {
        let tx = self.tx.as_ref()?;
        let workers = self.workers();
        if self
            .in_use
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| {
                if n >= workers {
                    None
                } else {
                    Some(n + 1)
                }
            })
            .is_err()
        {
            return None;
        }
        self.stats.spawned.fetch_add(1, Ordering::Relaxed);
        // SAFETY: the returned ScopedTask blocks (join or Drop) until
        // the worker has sent the job's result, so the erased borrow
        // never outlives 'a. Same precedent as run_chunks above.
        let job_static: Box<dyn FnOnce() + Send + 'static> =
            unsafe { std::mem::transmute(job) };
        let in_use = Arc::clone(&self.in_use);
        let (done_tx, done_rx) = channel::<std::thread::Result<()>>();
        let wrapped: Job = Box::new(move || {
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job_static));
            in_use.fetch_sub(1, Ordering::SeqCst);
            let _ = done_tx.send(result);
        });
        tx.send(wrapped).expect("pool queue closed");
        Some(ScopedTask {
            rx: done_rx,
            joined: false,
            _scope: std::marker::PhantomData,
        })
    }

    /// Execute `f(0), f(1), ..., f(chunks - 1)`, distributing chunk
    /// indices over the pool. Blocks until every chunk has run. Chunks
    /// must touch disjoint data (the callers in `linalg` partition
    /// output rows). Panics in `f` are propagated to the caller after
    /// all tasks have finished, so the pool stays usable.
    pub fn run_chunks(&self, chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        if chunks == 0 {
            return;
        }
        let nested = IN_WORKER.with(|w| w.get());
        if self.threads == 1 || chunks == 1 || nested || self.tx.is_none() {
            self.stats.serial_ops.fetch_add(1, Ordering::Relaxed);
            for i in 0..chunks {
                f(i);
            }
            return;
        }
        self.stats.parallel_ops.fetch_add(1, Ordering::Relaxed);
        self.stats.chunks.fetch_add(chunks as u64, Ordering::Relaxed);

        let next = Arc::new(AtomicUsize::new(0));
        let panicked = Arc::new(AtomicBool::new(false));
        let (done_tx, done_rx) = channel::<()>();
        // SAFETY: the helpers only call `f` before sending on `done_tx`,
        // and we receive exactly `helpers` messages below before
        // returning — so the erased borrow never outlives this call.
        let f_static = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let helpers = (self.threads - 1).min(chunks - 1);
        let tx = self.tx.as_ref().expect("pool queue");
        for _ in 0..helpers {
            let next = Arc::clone(&next);
            let panicked = Arc::clone(&panicked);
            let done = done_tx.clone();
            let job: Job = Box::new(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= chunks {
                        break;
                    }
                    f_static(i);
                }));
                if result.is_err() {
                    panicked.store(true, Ordering::SeqCst);
                }
                let _ = done.send(());
            });
            tx.send(job).expect("pool queue closed");
        }
        drop(done_tx);

        // The caller is a full participant.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= chunks {
                break;
            }
            f(i);
        }));
        // Wait for every helper before the borrow of `f` can end.
        for _ in 0..helpers {
            let _ = done_rx.recv();
        }
        if let Err(p) = caller {
            std::panic::resume_unwind(p);
        }
        if panicked.load(Ordering::SeqCst) {
            panic!("srsvd parallel task panicked (see stderr for the worker backtrace)");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Closing the channel makes every worker's recv() fail -> exit.
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle to a borrowing job submitted with [`ThreadPool::spawn_scoped`].
/// The job is guaranteed finished once this is joined *or dropped* —
/// that blocking is what makes the lifetime erasure inside
/// `spawn_scoped` sound, exactly like a `std::thread::scope` guard.
pub struct ScopedTask<'scope> {
    rx: Receiver<std::thread::Result<()>>,
    joined: bool,
    _scope: std::marker::PhantomData<&'scope ()>,
}

impl ScopedTask<'_> {
    /// Block until the job finishes, propagating its panic (mirrors
    /// `JoinHandle::join` + `resume_unwind`, like the prefetch reader's
    /// previous scoped-thread join did).
    pub fn join(mut self) {
        self.joined = true;
        match self.rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(p)) => std::panic::resume_unwind(p),
            Err(_) => panic!("scoped pool job dropped without reporting"),
        }
    }
}

impl Drop for ScopedTask<'_> {
    fn drop(&mut self) {
        if !self.joined {
            // Must block even on the unwind path: the job may still be
            // using the borrow this task is scoped to. Panics are
            // swallowed here (can't double-panic); `join` propagates.
            let _ = self.rx.recv();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    IN_WORKER.with(|w| w.set(true));
    loop {
        // Jobs catch panics internally, so the lock is never poisoned by
        // a task; recv() itself cannot panic.
        let job = {
            let guard = rx.lock().expect("pool queue mutex");
            guard.recv()
        };
        match job {
            Ok(job) => job(),
            Err(_) => return, // pool dropped
        }
    }
}

/// Raw pointer wrapper so disjoint sub-slices can be formed inside
/// `Sync` closures. Soundness is the caller's obligation (disjoint
/// ranges only) — both uses below partition by non-overlapping rows.
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Partition the `rows` rows (each `stride` elements, row-major) of
/// `data` into contiguous chunks and run `f(first_row, n_rows,
/// chunk_slice)` on each, in parallel on `pool`.
///
/// Each output row belongs to exactly one chunk, so as long as `f`
/// computes rows independently (every caller in `linalg` does), the
/// result is bit-identical for every pool size.
pub fn par_row_chunks(
    pool: &ThreadPool,
    data: &mut [f64],
    rows: usize,
    stride: usize,
    f: impl Fn(usize, usize, &mut [f64]) + Sync,
) {
    assert_eq!(data.len(), rows * stride, "par_row_chunks shape");
    if rows == 0 {
        return;
    }
    // ~4 chunks per thread: enough slack for dynamic balance, few
    // enough that per-chunk overhead stays negligible.
    let target = pool.threads().max(1) * 4;
    let chunk_rows = ((rows + target - 1) / target).max(1);
    let chunks = (rows + chunk_rows - 1) / chunk_rows;
    let base = SendPtr(data.as_mut_ptr());
    pool.run_chunks(chunks, &|ci| {
        let r0 = ci * chunk_rows;
        let r1 = (r0 + chunk_rows).min(rows);
        // SAFETY: chunk `ci` covers rows [r0, r1) and chunks are
        // disjoint; `data` outlives `run_chunks`, which blocks until
        // every chunk has run.
        let slice = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(r0 * stride), (r1 - r0) * stride)
        };
        f(r0, r1 - r0, slice);
    });
}

/// The standard dispatch for a row-partitioned kernel: run `f` once
/// over the whole range when the pool is size one or the operation is
/// too small (`work < min_work`) to amortize dispatch; otherwise fan
/// out via [`par_row_chunks`]. Serial and parallel paths invoke the
/// *same* `f`, so this changes scheduling only, never results.
pub fn par_row_chunks_min(
    pool: &ThreadPool,
    work: usize,
    min_work: usize,
    data: &mut [f64],
    rows: usize,
    stride: usize,
    f: impl Fn(usize, usize, &mut [f64]) + Sync,
) {
    assert_eq!(data.len(), rows * stride, "par_row_chunks_min shape");
    if pool.threads() <= 1 || rows < 2 || work < min_work {
        pool.stats.serial_ops.fetch_add(1, Ordering::Relaxed);
        f(0, rows, data);
        return;
    }
    par_row_chunks(pool, data, rows, stride, f);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_chunks_covers_every_index_once() {
        for threads in [1, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicUsize> = (0..37).map(|_| AtomicUsize::new(0)).collect();
            pool.run_chunks(37, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "chunk {i} (threads {threads})");
            }
        }
    }

    #[test]
    fn par_row_chunks_matches_serial_bitwise() {
        let rows = 53;
        let stride = 17;
        let fill = |r0: usize, _nrows: usize, chunk: &mut [f64]| {
            for (local, row) in chunk.chunks_mut(stride).enumerate() {
                let i = r0 + local;
                for (j, x) in row.iter_mut().enumerate() {
                    // Non-trivial float math so bit-equality means something.
                    *x = ((i * 31 + j) as f64).sin() * 1e3 + (j as f64).sqrt();
                }
            }
        };
        let mut want = vec![0.0; rows * stride];
        par_row_chunks(&ThreadPool::new(1), &mut want, rows, stride, fill);
        for threads in [2, 3, 8] {
            let mut got = vec![0.0; rows * stride];
            par_row_chunks(&ThreadPool::new(threads), &mut got, rows, stride, fill);
            let same = want
                .iter()
                .zip(&got)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "threads {threads}");
        }
    }

    #[test]
    fn par_row_chunks_min_serial_and_parallel_agree() {
        let rows = 40;
        let stride = 8;
        let fill = |r0: usize, _n: usize, chunk: &mut [f64]| {
            for (local, row) in chunk.chunks_mut(stride).enumerate() {
                for (j, x) in row.iter_mut().enumerate() {
                    *x = ((r0 + local) * stride + j) as f64 * 0.5;
                }
            }
        };
        let pool = ThreadPool::new(4);
        let mut small = vec![0.0; rows * stride];
        // work below min_work -> serial path.
        par_row_chunks_min(&pool, 0, 1, &mut small, rows, stride, fill);
        let mut big = vec![0.0; rows * stride];
        // work above min_work -> parallel path.
        par_row_chunks_min(&pool, usize::MAX, 1, &mut big, rows, stride, fill);
        assert_eq!(small, big);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_chunks(16, &|i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate");
        // Pool still works afterwards.
        let count = AtomicUsize::new(0);
        pool.run_chunks(8, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn with_pool_overrides_and_restores() {
        let two = Arc::new(ThreadPool::new(2));
        let seen = with_pool(&two, || with_current(|p| p.threads()));
        assert_eq!(seen, 2);
        // Outside the scope the override is gone (global or None again).
        let after = CURRENT.with(|c| c.borrow().clone());
        assert!(after.is_none());
    }

    #[test]
    fn stats_count_parallel_and_serial_ops() {
        let pool = ThreadPool::new(2);
        pool.run_chunks(1, &|_| {}); // single chunk -> inline
        pool.run_chunks(6, &|_| {});
        let s = pool.stats();
        assert_eq!(s.threads, 2);
        assert_eq!(s.serial_ops, 1);
        assert_eq!(s.parallel_ops, 1);
        assert_eq!(s.chunks, 6);
        assert!(format!("{s}").contains("threads=2"));
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = ThreadPool::new(1);
        let mut touched = vec![false; 9];
        // Closure needs Sync access; use the slice through a RefCell-free
        // trick: run_chunks with threads=1 executes inline on this
        // thread, so a Mutex is enough and uncontended.
        let cells = Mutex::new(&mut touched);
        pool.run_chunks(9, &|i| {
            cells.lock().unwrap()[i] = true;
        });
        assert!(touched.iter().all(|&t| t));
        assert_eq!(pool.stats().parallel_ops, 0);
    }

    #[test]
    fn named_pool_spawn_runs_off_thread() {
        // Even a size-1 named pool has a dedicated worker: the spawned
        // job runs on a different thread than the caller.
        let pool = ThreadPool::named(1, "spawntest");
        let (tx, rx) = channel();
        let caller = std::thread::current().id();
        pool.spawn(move || {
            let _ = tx.send(std::thread::current().id());
        });
        let worker = rx.recv().expect("spawned job must run");
        assert_ne!(worker, caller, "named-pool spawn must not run inline");
        assert_eq!(pool.stats().spawned, 1);
    }

    #[test]
    fn inline_pool_spawn_runs_on_caller() {
        let pool = ThreadPool::new(1); // no workers: inline fallback
        let (tx, rx) = channel();
        let caller = std::thread::current().id();
        pool.spawn(move || {
            let _ = tx.send(std::thread::current().id());
        });
        assert_eq!(rx.recv().unwrap(), caller);
    }

    #[test]
    fn spawn_panic_does_not_kill_worker() {
        let pool = ThreadPool::named(1, "panictest");
        pool.spawn(|| panic!("spawned boom"));
        // The single worker must survive to run the next job.
        let (tx, rx) = channel();
        pool.spawn(move || {
            let _ = tx.send(42);
        });
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn spawn_scoped_borrows_and_joins() {
        let pool = ThreadPool::named(2, "scopedtest");
        let data = vec![1u64, 2, 3, 4];
        let sum = Mutex::new(0u64);
        let task = pool
            .spawn_scoped(Box::new(|| {
                // Borrows both `data` and `sum` non-'static.
                *sum.lock().unwrap() = data.iter().sum();
            }))
            .expect("idle named pool must accept a scoped job");
        task.join();
        assert_eq!(*sum.lock().unwrap(), 10);
    }

    #[test]
    fn spawn_scoped_refuses_when_saturated() {
        let pool = ThreadPool::named(1, "sattest");
        let (release_tx, release_rx) = channel::<()>();
        let blocker = pool
            .spawn_scoped(Box::new(move || {
                let _ = release_rx.recv();
            }))
            .expect("first scoped job fits");
        // The only worker is held: a second scoped job must be refused
        // (the caller falls back to std::thread::scope), not queued.
        assert!(pool.spawn_scoped(Box::new(|| {})).is_none());
        release_tx.send(()).unwrap();
        blocker.join();
        // After release the worker frees up again.
        let again = pool.spawn_scoped(Box::new(|| {}));
        assert!(again.is_some());
        again.unwrap().join();
    }

    #[test]
    fn spawn_scoped_propagates_panic_on_join() {
        let pool = ThreadPool::named(1, "scopanic");
        let task = pool.spawn_scoped(Box::new(|| panic!("scoped boom"))).unwrap();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task.join()));
        assert!(result.is_err(), "scoped panic must propagate at join");
        // Worker survives for the next job.
        let ok = pool.spawn_scoped(Box::new(|| {})).expect("worker survived");
        ok.join();
    }

    #[test]
    fn pools_pair_installs_and_restores() {
        let pools = Pools::from_sizes(Some(2), Some(1));
        assert_eq!(pools.cpu.threads(), 2);
        assert_eq!(pools.io.threads(), 1);
        pools.install();
        assert_eq!(with_current(|p| p.threads()), 2);
        assert_eq!(with_current_io(|p| p.threads()), 1);
        set_thread_pool(None);
        set_io_pool(None);
    }

    #[test]
    fn default_io_threads_is_bounded() {
        // Regardless of host size the default stays in [2, 8] (unless
        // SRSVD_IO_THREADS overrides, which tests don't set).
        if std::env::var("SRSVD_IO_THREADS").is_err() {
            let n = default_io_threads();
            assert!((2..=8).contains(&n), "default io threads {n} out of bounds");
        }
    }
}
