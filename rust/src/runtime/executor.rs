//! The PJRT executor: compile HLO-text artifacts once, execute many
//! times. Thread-confined (PJRT wrappers are not `Send`); the
//! coordinator hosts one executor inside a dedicated actor thread.
//!
//! The real implementation needs the external `xla` PJRT wrapper crate,
//! which the zero-dependency offline build does not have — so it lives
//! behind the off-by-default `pjrt` cargo feature. The default build
//! compiles the stub at the bottom of this file: same API, but
//! [`Executor::new`] reports the runtime as unavailable, and the
//! coordinator degrades gracefully to native-only execution
//! (`coordinator::runtime_actor` fails artifact jobs with a clear
//! error; the router only picks artifacts when a manifest exists).

use crate::linalg::Dense;
use crate::runtime::manifest::ArtifactSpec;
use crate::svd::Factorization;

/// Outputs of one `srsvd_scored` artifact execution.
#[derive(Debug, Clone)]
pub struct SrsvdOutput {
    /// The rank-k factors (f32 artifact outputs widened to f64).
    pub factorization: Factorization,
    /// The paper's MSE metric, computed in-graph by the fused Pallas
    /// scorer (f32).
    pub mse: f64,
}

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use std::collections::HashMap;

    use super::SrsvdOutput;
    use crate::linalg::Dense;
    use crate::runtime::manifest::{ArtifactSpec, Manifest};
    use crate::svd::Factorization;
    use crate::util::{Error, Result};

    /// Compiles and runs AOT artifacts on the PJRT CPU client.
    pub struct Executor {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    fn xerr(context: &str, e: xla::Error) -> Error {
        Error::Runtime(format!("{context}: {e}"))
    }

    impl Executor {
        /// Create a CPU PJRT client and parse the manifest in `dir`.
        pub fn new(dir: &std::path::Path) -> Result<Executor> {
            let manifest = Manifest::load(dir)?;
            manifest.validate_files()?;
            let client = xla::PjRtClient::cpu().map_err(|e| xerr("PjRtClient::cpu", e))?;
            crate::log_info!(
                "runtime: platform={} devices={} artifacts={}",
                client.platform_name(),
                client.device_count(),
                manifest.artifacts.len()
            );
            Ok(Executor { client, manifest, cache: HashMap::new() })
        }

        /// The manifest parsed at construction.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile (and cache) the named artifact. Returns compile seconds.
        pub fn ensure_compiled(&mut self, name: &str) -> Result<f64> {
            if self.cache.contains_key(name) {
                return Ok(0.0);
            }
            let spec = self
                .manifest
                .find(name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))?
                .clone();
            let path = self.manifest.path_of(&spec);
            let t = crate::util::timer::Timer::start();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )
            .map_err(|e| xerr("HloModuleProto::from_text_file", e))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| xerr(&format!("compile {name}"), e))?;
            let secs = t.elapsed_secs();
            crate::log_debug!("compiled artifact {name} in {:.2}s", secs);
            self.cache.insert(name.to_string(), exe);
            Ok(secs)
        }

        /// Execute an artifact with row-major f32 inputs; returns the output
        /// tuple elements as flat f32 vectors (in manifest output order).
        pub fn run_raw(
            &mut self,
            name: &str,
            inputs: &[(Vec<f32>, Vec<usize>)],
        ) -> Result<Vec<Vec<f32>>> {
            self.ensure_compiled(name)?;
            let spec = self.manifest.find(name).unwrap().clone();
            if inputs.len() != spec.inputs.len() {
                return Err(Error::Invalid(format!(
                    "artifact {name}: expected {} inputs, got {}",
                    spec.inputs.len(),
                    inputs.len()
                )));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for ((data, shape), ispec) in inputs.iter().zip(&spec.inputs) {
                if *shape != ispec.shape {
                    return Err(Error::Shape(format!(
                        "artifact {name} input {}: expected {:?}, got {:?}",
                        ispec.name, ispec.shape, shape
                    )));
                }
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = if dims.is_empty() {
                    lit.reshape(&[]).map_err(|e| xerr("reshape scalar", e))?
                } else {
                    lit.reshape(&dims).map_err(|e| xerr("reshape input", e))?
                };
                literals.push(lit);
            }
            let exe = self.cache.get(name).unwrap();
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| xerr(&format!("execute {name}"), e))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| xerr("to_literal_sync", e))?;
            // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
            let parts = tuple.to_tuple().map_err(|e| xerr("to_tuple", e))?;
            if parts.len() != spec.outputs.len() {
                return Err(Error::Runtime(format!(
                    "artifact {name}: expected {} outputs, got {}",
                    spec.outputs.len(),
                    parts.len()
                )));
            }
            parts
                .into_iter()
                .map(|p| p.to_vec::<f32>().map_err(|e| xerr("to_vec", e)))
                .collect()
        }

        /// Execute an `srsvd_scored` artifact: factorize `X − μ1ᵀ` with the
        /// supplied Gaussian test matrix Ω (generated rust-side for seed
        /// control).
        pub fn run_srsvd(
            &mut self,
            spec: &ArtifactSpec,
            x: &Dense,
            mu: &[f64],
            omega: &Dense,
        ) -> Result<SrsvdOutput> {
            let (m, n, k, kk) = (spec.m, spec.n, spec.k, spec.kk);
            crate::ensure_shape!(x.shape() == (m, n), "x must be {m}x{n}");
            crate::ensure_shape!(mu.len() == m, "mu must have length {m}");
            crate::ensure_shape!(omega.shape() == (n, kk), "omega must be {n}x{kk}");

            let mu32: Vec<f32> = mu.iter().map(|&v| v as f32).collect();
            let outs = self.run_raw(
                &spec.name,
                &[
                    (x.to_f32(), vec![m, n]),
                    (mu32, vec![m]),
                    (omega.to_f32(), vec![n, kk]),
                ],
            )?;
            let u = Dense::from_f32(m, k, &outs[0]);
            let s: Vec<f64> = outs[1].iter().map(|&v| v as f64).collect();
            let v = Dense::from_f32(n, k, &outs[2]);
            let mse = outs[3][0] as f64;
            Ok(SrsvdOutput { factorization: Factorization { u, s, v }, mse })
        }

        /// Execute a `row_mean` artifact.
        pub fn run_row_mean(&mut self, spec: &ArtifactSpec, x: &Dense) -> Result<Vec<f64>> {
            let (m, n) = (spec.m, spec.n);
            crate::ensure_shape!(x.shape() == (m, n), "x must be {m}x{n}");
            let outs = self.run_raw(&spec.name, &[(x.to_f32(), vec![m, n])])?;
            Ok(outs[0].iter().map(|&v| v as f64).collect())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_impl::Executor;

#[cfg(not(feature = "pjrt"))]
mod stub_impl {
    use super::{ArtifactSpec, Dense, SrsvdOutput};
    use crate::runtime::manifest::Manifest;
    use crate::util::{Error, Result};

    /// Uninhabited: a stub `Executor` can never be constructed, which
    /// lets every method body type-check as `match self.void {}`.
    enum Void {}

    /// Stub executor for the default (no-`pjrt`) build: construction
    /// always fails with a clear error and the coordinator runs
    /// native-only.
    pub struct Executor {
        void: Void,
    }

    impl Executor {
        /// Always fails: this build has no PJRT runtime.
        pub fn new(dir: &std::path::Path) -> Result<Executor> {
            Err(Error::Runtime(format!(
                "PJRT runtime unavailable: srsvd was built without the `pjrt` \
                 feature (artifact dir {}); artifact jobs run native-only",
                dir.display()
            )))
        }

        /// Unreachable on the stub (no instance can exist).
        pub fn manifest(&self) -> &Manifest {
            match self.void {}
        }

        /// Unreachable on the stub (no instance can exist).
        pub fn ensure_compiled(&mut self, _name: &str) -> Result<f64> {
            match self.void {}
        }

        /// Unreachable on the stub (no instance can exist).
        pub fn run_raw(
            &mut self,
            _name: &str,
            _inputs: &[(Vec<f32>, Vec<usize>)],
        ) -> Result<Vec<Vec<f32>>> {
            match self.void {}
        }

        /// Unreachable on the stub (no instance can exist).
        pub fn run_srsvd(
            &mut self,
            _spec: &ArtifactSpec,
            _x: &Dense,
            _mu: &[f64],
            _omega: &Dense,
        ) -> Result<SrsvdOutput> {
            match self.void {}
        }

        /// Unreachable on the stub (no instance can exist).
        pub fn run_row_mean(&mut self, _spec: &ArtifactSpec, _x: &Dense) -> Result<Vec<f64>> {
            match self.void {}
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub_impl::Executor;

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_executor_reports_unavailable() {
        let err = Executor::new(std::path::Path::new("artifacts")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "{msg}");
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use std::path::Path;

    fn executor() -> Option<Executor> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping executor tests: artifacts not built");
            return None;
        }
        Some(Executor::new(&dir).expect("executor"))
    }

    #[test]
    fn smoke_matmul_rank1_numerics() {
        let Some(mut ex) = executor() else { return };
        // a (8x16) = all 0.5, b (16x4) = all 0.25, u = 1s, v = [0,1,2,3]:
        // (a@b)[i,j] = 16*0.5*0.25 = 2.0; out[i,j] = 2.0 - v[j].
        let a = vec![0.5f32; 8 * 16];
        let b = vec![0.25f32; 16 * 4];
        let u = vec![1.0f32; 8];
        let v = vec![0.0f32, 1.0, 2.0, 3.0];
        let outs = ex
            .run_raw(
                "smoke_matmul_rank1",
                &[
                    (a, vec![8, 16]),
                    (b, vec![16, 4]),
                    (u, vec![8]),
                    (v, vec![4]),
                ],
            )
            .unwrap();
        let c = &outs[0];
        assert_eq!(c.len(), 32);
        for i in 0..8 {
            for j in 0..4 {
                let want = 2.0 - j as f32;
                assert!((c[i * 4 + j] - want).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(mut ex) = executor() else { return };
        let bad = ex.run_raw("smoke_matmul_rank1", &[(vec![0.0; 4], vec![2, 2])]);
        assert!(bad.is_err());
        let bad2 = ex.run_raw(
            "smoke_matmul_rank1",
            &[
                (vec![0.0; 64], vec![8, 8]), // wrong shape
                (vec![0.0; 64], vec![16, 4]),
                (vec![0.0; 8], vec![8]),
                (vec![0.0; 4], vec![4]),
            ],
        );
        assert!(bad2.is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(mut ex) = executor() else { return };
        assert!(ex.ensure_compiled("no_such_artifact").is_err());
    }
}
