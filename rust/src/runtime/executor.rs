//! The PJRT executor: compile HLO-text artifacts once, execute many
//! times. Thread-confined (PJRT wrappers are not `Send`); the
//! coordinator hosts one executor inside a dedicated actor thread.

use std::collections::HashMap;

use crate::linalg::Dense;
use crate::runtime::manifest::{ArtifactSpec, Manifest};
use crate::svd::Factorization;
use crate::util::{Error, Result};

/// Outputs of one `srsvd_scored` artifact execution.
#[derive(Debug, Clone)]
pub struct SrsvdOutput {
    pub factorization: Factorization,
    /// The paper's MSE metric, computed in-graph by the fused Pallas
    /// scorer (f32).
    pub mse: f64,
}

/// Compiles and runs AOT artifacts on the PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

fn xerr(context: &str, e: xla::Error) -> Error {
    Error::Runtime(format!("{context}: {e}"))
}

impl Executor {
    /// Create a CPU PJRT client and parse the manifest in `dir`.
    pub fn new(dir: &std::path::Path) -> Result<Executor> {
        let manifest = Manifest::load(dir)?;
        manifest.validate_files()?;
        let client = xla::PjRtClient::cpu().map_err(|e| xerr("PjRtClient::cpu", e))?;
        log::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Executor { client, manifest, cache: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) the named artifact. Returns compile seconds.
    pub fn ensure_compiled(&mut self, name: &str) -> Result<f64> {
        if self.cache.contains_key(name) {
            return Ok(0.0);
        }
        let spec = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))?
            .clone();
        let path = self.manifest.path_of(&spec);
        let t = crate::util::timer::Timer::start();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
        )
        .map_err(|e| xerr("HloModuleProto::from_text_file", e))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| xerr(&format!("compile {name}"), e))?;
        let secs = t.elapsed_secs();
        log::debug!("compiled artifact {name} in {:.2}s", secs);
        self.cache.insert(name.to_string(), exe);
        Ok(secs)
    }

    /// Execute an artifact with row-major f32 inputs; returns the output
    /// tuple elements as flat f32 vectors (in manifest output order).
    pub fn run_raw(&mut self, name: &str, inputs: &[(Vec<f32>, Vec<usize>)]) -> Result<Vec<Vec<f32>>> {
        self.ensure_compiled(name)?;
        let spec = self.manifest.find(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Invalid(format!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for ((data, shape), ispec) in inputs.iter().zip(&spec.inputs) {
            if *shape != ispec.shape {
                return Err(Error::Shape(format!(
                    "artifact {name} input {}: expected {:?}, got {:?}",
                    ispec.name, ispec.shape, shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = if dims.is_empty() {
                lit.reshape(&[]).map_err(|e| xerr("reshape scalar", e))?
            } else {
                lit.reshape(&dims).map_err(|e| xerr("reshape input", e))?
            };
            literals.push(lit);
        }
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| xerr(&format!("execute {name}"), e))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| xerr("to_literal_sync", e))?;
        // aot.py lowers with return_tuple=True: always a tuple, even 1-ary.
        let parts = tuple.to_tuple().map_err(|e| xerr("to_tuple", e))?;
        if parts.len() != spec.outputs.len() {
            return Err(Error::Runtime(format!(
                "artifact {name}: expected {} outputs, got {}",
                spec.outputs.len(),
                parts.len()
            )));
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| xerr("to_vec", e)))
            .collect()
    }

    /// Execute an `srsvd_scored` artifact: factorize `X − μ1ᵀ` with the
    /// supplied Gaussian test matrix Ω (generated rust-side for seed
    /// control).
    pub fn run_srsvd(
        &mut self,
        spec: &ArtifactSpec,
        x: &Dense,
        mu: &[f64],
        omega: &Dense,
    ) -> Result<SrsvdOutput> {
        let (m, n, k, kk) = (spec.m, spec.n, spec.k, spec.kk);
        crate::ensure_shape!(x.shape() == (m, n), "x must be {m}x{n}");
        crate::ensure_shape!(mu.len() == m, "mu must have length {m}");
        crate::ensure_shape!(omega.shape() == (n, kk), "omega must be {n}x{kk}");

        let mu32: Vec<f32> = mu.iter().map(|&v| v as f32).collect();
        let outs = self.run_raw(
            &spec.name,
            &[
                (x.to_f32(), vec![m, n]),
                (mu32, vec![m]),
                (omega.to_f32(), vec![n, kk]),
            ],
        )?;
        let u = Dense::from_f32(m, k, &outs[0]);
        let s: Vec<f64> = outs[1].iter().map(|&v| v as f64).collect();
        let v = Dense::from_f32(n, k, &outs[2]);
        let mse = outs[3][0] as f64;
        Ok(SrsvdOutput { factorization: Factorization { u, s, v }, mse })
    }

    /// Execute a `row_mean` artifact.
    pub fn run_row_mean(&mut self, spec: &ArtifactSpec, x: &Dense) -> Result<Vec<f64>> {
        let (m, n) = (spec.m, spec.n);
        crate::ensure_shape!(x.shape() == (m, n), "x must be {m}x{n}");
        let outs = self.run_raw(&spec.name, &[(x.to_f32(), vec![m, n])])?;
        Ok(outs[0].iter().map(|&v| v as f64).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn executor() -> Option<Executor> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping executor tests: artifacts not built");
            return None;
        }
        Some(Executor::new(&dir).expect("executor"))
    }

    #[test]
    fn smoke_matmul_rank1_numerics() {
        let Some(mut ex) = executor() else { return };
        // a (8x16) = all 0.5, b (16x4) = all 0.25, u = 1s, v = [0,1,2,3]:
        // (a@b)[i,j] = 16*0.5*0.25 = 2.0; out[i,j] = 2.0 - v[j].
        let a = vec![0.5f32; 8 * 16];
        let b = vec![0.25f32; 16 * 4];
        let u = vec![1.0f32; 8];
        let v = vec![0.0f32, 1.0, 2.0, 3.0];
        let outs = ex
            .run_raw(
                "smoke_matmul_rank1",
                &[
                    (a, vec![8, 16]),
                    (b, vec![16, 4]),
                    (u, vec![8]),
                    (v, vec![4]),
                ],
            )
            .unwrap();
        let c = &outs[0];
        assert_eq!(c.len(), 32);
        for i in 0..8 {
            for j in 0..4 {
                let want = 2.0 - j as f32;
                assert!((c[i * 4 + j] - want).abs() < 1e-5, "({i},{j})");
            }
        }
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(mut ex) = executor() else { return };
        let bad = ex.run_raw("smoke_matmul_rank1", &[(vec![0.0; 4], vec![2, 2])]);
        assert!(bad.is_err());
        let bad2 = ex.run_raw(
            "smoke_matmul_rank1",
            &[
                (vec![0.0; 64], vec![8, 8]), // wrong shape
                (vec![0.0; 64], vec![16, 4]),
                (vec![0.0; 8], vec![8]),
                (vec![0.0; 4], vec![4]),
            ],
        );
        assert!(bad2.is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(mut ex) = executor() else { return };
        assert!(ex.ensure_compiled("no_such_artifact").is_err());
    }
}
