//! PJRT runtime: load the AOT HLO artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU client, execute
//! them from the rust hot path.
//!
//! Interchange is HLO **text** (see aot.py for why), parsed by
//! `HloModuleProto::from_text_file`. The PJRT wrapper types are not
//! `Send`, so [`Executor`] is confined to whichever thread created it;
//! the coordinator wraps it in a dedicated actor thread
//! (`coordinator::runtime_actor`).

pub mod executor;
pub mod manifest;

pub use executor::{Executor, SrsvdOutput};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
