//! `artifacts/manifest.json` — the contract between the python compile
//! path and the rust runtime.

use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::util::{Error, Result};

/// Shape/name of one input or output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Tensor name in the HLO signature.
    pub name: String,
    /// Static shape, outermost dimension first.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total element count (1 for scalars).
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled pipeline configuration.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Unique artifact name (manifest key).
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Operation kind: `srsvd_scored`, `row_mean`, `matmul_rank1`, ...
    pub op: String,
    /// Static row count of the data operand.
    pub m: usize,
    /// Static column count of the data operand.
    pub n: usize,
    /// Target rank k.
    pub k: usize,
    /// Sampling width K.
    pub kk: usize,
    /// Power-iteration count baked into the pipeline.
    pub q: usize,
    /// Jacobi sweep count baked into the small SVD.
    pub sweeps: usize,
    /// Compilation method tag (from the python AOT pipeline).
    pub method: String,
    /// Input tensor signatures, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor signatures, in result order.
    pub outputs: Vec<TensorSpec>,
    /// SHA-256 of the HLO text (integrity check).
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Manifest schema version.
    pub version: usize,
    /// Directory the manifest (and artifact files) live in.
    pub dir: PathBuf,
    /// Every compiled artifact, in manifest order.
    pub artifacts: Vec<ArtifactSpec>,
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t.get("name")?.as_str()?.to_string(),
                shape: t
                    .get("shape")?
                    .as_arr()?
                    .iter()
                    .map(|d| d.as_usize())
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let artifacts = v
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a.get("name")?.as_str()?.to_string(),
                    file: a.get("file")?.as_str()?.to_string(),
                    op: a.get("op")?.as_str()?.to_string(),
                    m: a.get("m")?.as_usize()?,
                    n: a.get("n")?.as_usize()?,
                    k: a.get("k")?.as_usize()?,
                    kk: a.get("K")?.as_usize()?,
                    q: a.get("q")?.as_usize()?,
                    sweeps: a.get("sweeps")?.as_usize()?,
                    method: a.get("method")?.as_str()?.to_string(),
                    inputs: tensor_specs(a.get("inputs")?)?,
                    outputs: tensor_specs(a.get("outputs")?)?,
                    sha256: a.get("sha256")?.as_str()?.to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            version: v.get("version")?.as_usize()?,
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// The default artifact directory: `$SRSVD_ARTIFACTS` or
    /// `<repo>/artifacts` relative to the current dir.
    pub fn default_dir() -> PathBuf {
        std::env::var("SRSVD_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Look an artifact up by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find a compiled S-RSVD pipeline matching a job configuration.
    pub fn find_srsvd(&self, m: usize, n: usize, k: usize, q: usize) -> Option<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.op == "srsvd_scored" && a.m == m && a.n == n && a.k == k && a.q == q)
    }

    /// Path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// Validate that every listed file exists (not content hashes — the
    /// python side owns those; see python/tests/test_aot.py).
    pub fn validate_files(&self) -> Result<()> {
        for a in &self.artifacts {
            let p = self.path_of(a);
            if !p.exists() {
                return Err(Error::Artifact(format!(
                    "artifact file missing: {}",
                    p.display()
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(m) = repo_artifacts() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(m.version, 1);
        assert!(!m.artifacts.is_empty());
        m.validate_files().unwrap();
        // The smoke artifact is always in the grid.
        let smoke = m.find("smoke_matmul_rank1").expect("smoke artifact");
        assert_eq!(smoke.inputs.len(), 4);
        assert_eq!(smoke.outputs[0].shape, vec![8, 4]);
    }

    #[test]
    fn find_srsvd_matches_grid_config() {
        let Some(m) = repo_artifacts() else {
            return;
        };
        let a = m.find_srsvd(100, 1000, 10, 0).expect("grid config");
        assert_eq!(a.kk, 20);
        assert!(m.find_srsvd(123, 456, 7, 0).is_none());
    }

    #[test]
    fn parse_error_messages_are_useful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
