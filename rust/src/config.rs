//! Configuration system: a small key=value format (INI-like sections)
//! parsed into typed service/experiment configs, with env-var and CLI
//! overrides layered on top.
//!
//! The offline environment has no serde/toml; this covers the subset a
//! deployment needs:
//!
//! ```text
//! # srsvd.conf
//! [service]
//! native_workers = 4
//! queue_capacity = 256
//! artifact_dir   = artifacts
//!
//! [parallel]
//! threads = 8              # cpu (linalg) pool; 0/unset = auto
//!                          # (SRSVD_THREADS env overrides auto-sizing)
//! io_threads = 2           # io pool: prefetch readers + connection
//!                          # workers; 0/unset = auto (SRSVD_IO_THREADS)
//! simd = on                # runtime SIMD kernel dispatch (on|off);
//!                          # SRSVD_SIMD=off wins over the config
//!
//! [stream]
//! block_rows = 0           # rows per resident block; 0 = derive from budget
//! budget_mb  = 64          # resident-block budget (MiB) when block_rows = 0
//! prefetch   = on          # double-buffered background block reads (on|off)
//! pass_policy = exact      # source-pass schedule: exact (2+2q passes,
//!                          # byte-identical to dense) | fused (<= q+2 passes)
//!
//! [server]
//! addr              = 127.0.0.1:7878   # listen address for `serve --listen`
//! max_body_mb       = 64               # request body cap (413 beyond)
//! workers           = 4                # HTTP connection workers
//! request_timeout_s = 30               # per-request / blocking-GET timeout
//! result_ttl_s      = 600              # unclaimed parked-result lifetime
//! cache_dir         = off              # persist the result cache here (off|none = memory-only)
//! cache_entries     = 256              # result-cache capacity (0 disables caching)
//! journal_dir       = off              # journal accepted-but-unfinished job specs here
//!                                      # (off|none = no crash recovery of queued jobs)
//! connect_timeout_ms = 1000            # bound on outbound TCP connects made against
//!                                      # this deployment (router fallback; see [router])
//!
//! [router]
//! listen            = 127.0.0.1:7979   # front-end address for `route --listen`
//! replicas          = 127.0.0.1:7878, 127.0.0.1:7879   # the `srsvd serve` backends
//! workers           = 4                # front-end connection workers
//! max_body_mb       = 64               # request body cap (413 beyond)
//! request_timeout_s = 30               # front-end request timeout; keep >= the replicas'
//! connect_timeout_ms = 1000            # back-end connect bound (falls back to
//!                                      # [server] connect_timeout_ms when unset)
//! probe_interval_ms = 1000             # health-loop period
//! probe_timeout_ms  = 500              # per-probe IO bound
//! unhealthy_after   = 3                # consecutive probe failures before mark-down
//!
//! [retry]
//! max_attempts    = 3     # total tries per idempotent operation (1 = fail-fast)
//! backoff_base_ms = 10    # first backoff; doubles per attempt
//! backoff_max_ms  = 1000  # backoff ceiling (also caps honored Retry-After)
//! jitter          = on    # deterministic ±25% spread (seeded, reproducible)
//!
//! [faults]
//! # spec = stream.read=err:2@0.5;svd.sweep=die_after:3   # fail-point plan
//! #                                  (same grammar as SRSVD_FAULTS / --faults)
//!
//! [svd]
//! k           = 10
//! oversample  = 10
//! power_iters = 0             # fixed sweep count (StopCriterion::FixedPower)
//! checkpoint_dir = off        # spill per-sweep panel checkpoints here for
//!                             # crash-safe resume (off|none = cold starts only)
//! # pve_tol    = 1e-3         # adaptive dashSVD accuracy control instead:
//! # max_sweeps = 32           #   mutually exclusive with power_iters
//! basis       = direct        # direct | qr-update-paper | qr-update-exact
//! small_svd   = jacobi        # jacobi | gram
//! precision   = exact         # kernel tier: exact (byte-identical) | fast
//!                             #   (packed AVX2/FMA, last-ulp differences)
//! ```
//!
//! All stopping-criterion spellings — `[svd] power_iters`/`pve_tol`/
//! `max_sweeps`, the `--q`/`--pve-tol`/`--max-sweeps` CLI flags, and
//! the wire protocol's submit fields — funnel through one conversion
//! point, [`stop_criterion`], so the validation rules cannot drift.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::coordinator::CoordinatorConfig;
use crate::linalg::stream::StreamConfig;
use crate::svd::{BasisMethod, PassPolicy, Precision, SmallSvdMethod, StopCriterion, SvdConfig};
use crate::util::{Error, Result};

/// Raw parsed file: section -> key -> value.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse the key=value format. `#` and `;` start comments; keys
    /// outside a section go into the "" section.
    pub fn parse(text: &str) -> Result<RawConfig> {
        let mut out = RawConfig::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = match raw.find(['#', ';']) {
                Some(i) => &raw[..i],
                None => raw,
            }
            .trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                out.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return Err(Error::Invalid(format!(
                    "config line {}: expected key = value, got {raw:?}",
                    lineno + 1
                )));
            };
            out.sections
                .entry(section.clone())
                .or_default()
                .insert(key.trim().to_string(), value.trim().to_string());
        }
        Ok(out)
    }

    /// Parse the file at `path`.
    pub fn load(path: &std::path::Path) -> Result<RawConfig> {
        RawConfig::parse(&std::fs::read_to_string(path)?)
    }

    /// Raw string value of `section.key`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| Error::Invalid(format!("{section}.{key}: not an integer: {v:?}"))),
        }
    }

    fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| Error::Invalid(format!("{section}.{key}: not a number: {v:?}"))),
        }
    }

    /// Build the service config (defaults where unset).
    pub fn coordinator(&self) -> Result<CoordinatorConfig> {
        let mut cfg = CoordinatorConfig::default();
        if let Some(w) = self.get_usize("service", "native_workers")? {
            cfg.native_workers = w.max(1);
        }
        if let Some(c) = self.get_usize("service", "queue_capacity")? {
            cfg.queue_capacity = c.max(1);
        }
        match self.get("service", "artifact_dir") {
            Some("off") | Some("none") => cfg.artifact_dir = None,
            Some(dir) => cfg.artifact_dir = Some(PathBuf::from(dir)),
            None => {}
        }
        // [parallel] threads / io_threads: 0 (or unset) keeps auto-sizing.
        if let Some(t) = self.get_usize("parallel", "threads")? {
            cfg.pool_threads = if t == 0 { None } else { Some(t) };
        }
        if let Some(t) = self.get_usize("parallel", "io_threads")? {
            cfg.io_threads = if t == 0 { None } else { Some(t) };
        }
        // Sweep-granular crash recovery lives in the [svd] section (it
        // is a property of the factorization), but lands on the
        // coordinator, which owns job execution.
        match self.get("svd", "checkpoint_dir") {
            Some("off") | Some("none") => cfg.checkpoint_dir = None,
            Some(dir) => cfg.checkpoint_dir = Some(PathBuf::from(dir)),
            None => {}
        }
        cfg.retry = self.retry()?;
        Ok(cfg)
    }

    /// Build the typed retry/backoff policy (defaults where unset):
    /// `[retry] max_attempts` / `backoff_base_ms` / `backoff_max_ms` /
    /// `jitter`. One section feeds every layer that retries — streamed
    /// source reads, the blocking client, and the router's proxied
    /// `GET`s — so budgets can't drift apart per layer.
    pub fn retry(&self) -> Result<crate::util::retry::RetryPolicy> {
        let mut p = crate::util::retry::RetryPolicy::default();
        if let Some(n) = self.get_usize("retry", "max_attempts")? {
            p.max_attempts = (n as u32).max(1);
        }
        if let Some(ms) = self.get_usize("retry", "backoff_base_ms")? {
            p.backoff_base_ms = ms as u64;
        }
        if let Some(ms) = self.get_usize("retry", "backoff_max_ms")? {
            p.backoff_max_ms = ms as u64;
        }
        if let Some(j) = self.get("retry", "jitter") {
            p.jitter = parse_switch(j)
                .ok_or_else(|| Error::Invalid(format!("retry.jitter: not a boolean: {j:?}")))?;
        }
        Ok(p)
    }

    /// The `[faults] spec` fail-point plan, if set — same grammar as
    /// the `SRSVD_FAULTS` env var and the `--faults` CLI flag (the env
    /// var wins when both are set, so a chaos run can override a
    /// config file without editing it).
    pub fn faults_spec(&self) -> Option<&str> {
        self.get("faults", "spec").filter(|s| !s.is_empty())
    }

    /// The `[parallel] simd` switch, if set: `Some(false)` forces the
    /// portable scalar kernels process-wide (applied by the binary via
    /// [`crate::linalg::gemm::kernels::set_simd_enabled`]). The
    /// `SRSVD_SIMD=off` env override wins regardless.
    pub fn parallel_simd(&self) -> Result<Option<bool>> {
        match self.get("parallel", "simd") {
            None => Ok(None),
            Some(v) => parse_switch(v)
                .map(Some)
                .ok_or_else(|| Error::Invalid(format!("parallel.simd: not a boolean: {v:?}"))),
        }
    }

    /// Build the out-of-core streaming config (defaults where unset):
    /// `[stream] block_rows` / `budget_mb` / `prefetch`.
    pub fn stream(&self) -> Result<StreamConfig> {
        let mut cfg = StreamConfig::default();
        if let Some(b) = self.get_usize("stream", "block_rows")? {
            cfg.block_rows = b;
        }
        if let Some(mb) = self.get_usize("stream", "budget_mb")? {
            cfg.budget_mb = mb.max(1);
        }
        if let Some(p) = self.get("stream", "prefetch") {
            cfg.prefetch = parse_switch(p).ok_or_else(|| {
                Error::Invalid(format!("stream.prefetch: not a boolean: {p:?}"))
            })?;
        }
        Ok(cfg)
    }

    /// Build the network service config (defaults where unset):
    /// `[server] addr` / `max_body_mb` / `workers` / `request_timeout_s`
    /// / `result_ttl_s` / `cache_dir` / `cache_entries`.
    pub fn server(&self) -> Result<crate::server::ServerConfig> {
        let mut cfg = crate::server::ServerConfig::default();
        if let Some(addr) = self.get("server", "addr") {
            cfg.addr = addr.to_string();
        }
        if let Some(mb) = self.get_usize("server", "max_body_mb")? {
            cfg.max_body_bytes = mb.max(1) << 20;
        }
        if let Some(w) = self.get_usize("server", "workers")? {
            cfg.workers = w.max(1);
        }
        if let Some(t) = self.get_usize("server", "request_timeout_s")? {
            cfg.request_timeout_s = (t as u64).max(1);
        }
        if let Some(t) = self.get_usize("server", "result_ttl_s")? {
            cfg.result_ttl_s = (t as u64).max(1);
        }
        match self.get("server", "cache_dir") {
            Some("off") | Some("none") => cfg.cache_dir = None,
            Some(dir) => cfg.cache_dir = Some(PathBuf::from(dir)),
            None => {}
        }
        if let Some(c) = self.get_usize("server", "cache_entries")? {
            cfg.cache_entries = c;
        }
        match self.get("server", "journal_dir") {
            Some("off") | Some("none") => cfg.journal_dir = None,
            Some(dir) => cfg.journal_dir = Some(PathBuf::from(dir)),
            None => {}
        }
        Ok(cfg)
    }

    /// Build the routing-tier config (defaults where unset): `[router]
    /// listen` / `replicas` (comma-separated) / `workers` /
    /// `max_body_mb` / `request_timeout_s` / `connect_timeout_ms` /
    /// `probe_interval_ms` / `probe_timeout_ms` / `unhealthy_after`.
    ///
    /// `connect_timeout_ms` falls back to `[server] connect_timeout_ms`
    /// when the `[router]` section leaves it unset, so one shared
    /// srsvd.conf can bound outbound connects for the whole deployment
    /// in one place.
    pub fn router(&self) -> Result<crate::router::RouterConfig> {
        let mut cfg = crate::router::RouterConfig::default();
        if let Some(addr) = self.get("router", "listen") {
            cfg.listen = addr.to_string();
        }
        if let Some(list) = self.get("router", "replicas") {
            cfg.replicas = split_addr_list(list);
        }
        if let Some(w) = self.get_usize("router", "workers")? {
            cfg.workers = w.max(1);
        }
        if let Some(mb) = self.get_usize("router", "max_body_mb")? {
            cfg.max_body_bytes = mb.max(1) << 20;
        }
        if let Some(t) = self.get_usize("router", "request_timeout_s")? {
            cfg.request_timeout_s = (t as u64).max(1);
        }
        match self.get_usize("router", "connect_timeout_ms")? {
            Some(t) => cfg.connect_timeout_ms = (t as u64).max(1),
            None => {
                if let Some(t) = self.get_usize("server", "connect_timeout_ms")? {
                    cfg.connect_timeout_ms = (t as u64).max(1);
                }
            }
        }
        if let Some(t) = self.get_usize("router", "probe_interval_ms")? {
            cfg.probe_interval_ms = (t as u64).max(1);
        }
        if let Some(t) = self.get_usize("router", "probe_timeout_ms")? {
            cfg.probe_timeout_ms = (t as u64).max(1);
        }
        if let Some(n) = self.get_usize("router", "unhealthy_after")? {
            cfg.unhealthy_after = (n as u32).max(1);
        }
        cfg.retry = self.retry()?;
        Ok(cfg)
    }

    /// Build the SVD config (defaults where unset).
    pub fn svd(&self) -> Result<SvdConfig> {
        let mut cfg = SvdConfig::default();
        if let Some(k) = self.get_usize("svd", "k")? {
            cfg.k = k;
        }
        if let Some(o) = self.get_usize("svd", "oversample")? {
            cfg.oversample = o;
        }
        cfg.stop = stop_criterion(
            self.get_usize("svd", "power_iters")?,
            self.get_f64("svd", "pve_tol")?,
            self.get_usize("svd", "max_sweeps")?,
        )?;
        if let Some(b) = self.get("svd", "basis") {
            cfg.basis = parse_basis(b)?;
        }
        if let Some(s) = self.get("svd", "small_svd") {
            cfg.small_svd = parse_small_svd(s)?;
        }
        if let Some(p) = self.get("svd", "precision") {
            cfg.precision = parse_precision(p)?;
        }
        // The pass schedule lives in the [stream] section — it is the
        // out-of-core wall-clock knob — but lands on SvdConfig, which
        // is what the sweep stages read.
        if let Some(p) = self.get("stream", "pass_policy") {
            cfg.pass_policy = parse_pass_policy(p)?;
        }
        Ok(cfg)
    }
}

/// The single conversion point from the scattered stopping-criterion
/// spellings (config keys, CLI flags, wire fields) to the typed
/// [`StopCriterion`]. `power_iters` and `pve_tol` are mutually
/// exclusive; `max_sweeps` only makes sense with `pve_tol` (defaulting
/// to [`StopCriterion::DEFAULT_MAX_SWEEPS`] when omitted); nothing set
/// means the back-compat fixed `q = 0`.
pub fn stop_criterion(
    power_iters: Option<usize>,
    pve_tol: Option<f64>,
    max_sweeps: Option<usize>,
) -> Result<StopCriterion> {
    match (power_iters, pve_tol) {
        (Some(_), Some(_)) => Err(Error::Invalid(
            "power_iters and pve_tol are mutually exclusive: pick a fixed sweep \
             count or dashSVD accuracy control, not both"
                .into(),
        )),
        (_, Some(tol)) => {
            if !(tol.is_finite() && tol > 0.0) {
                return Err(Error::Invalid(format!(
                    "pve_tol must be a finite positive number, got {tol}"
                )));
            }
            let max_sweeps = max_sweeps.unwrap_or(StopCriterion::DEFAULT_MAX_SWEEPS);
            if max_sweeps == 0 {
                return Err(Error::Invalid("max_sweeps must be >= 1".into()));
            }
            Ok(StopCriterion::Tolerance { pve_tol: tol, max_sweeps })
        }
        (q, None) => {
            if max_sweeps.is_some() {
                return Err(Error::Invalid(
                    "max_sweeps requires pve_tol (it caps the adaptive loop)".into(),
                ));
            }
            Ok(StopCriterion::FixedPower { q: q.unwrap_or(0) })
        }
    }
}

/// Split a comma-separated address list (`a:1, b:2`), dropping empty
/// entries — shared by `[router] replicas` and the repeatable
/// `--replicas` CLI flag.
pub fn split_addr_list(list: &str) -> Vec<String> {
    list.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect()
}

/// Parse an on/off switch (`1|true|on|yes` / `0|false|off|no`).
fn parse_switch(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

/// Parse a basis-method name (`direct | qr-update-paper | qr-update-exact`).
pub fn parse_basis(s: &str) -> Result<BasisMethod> {
    match s {
        "direct" => Ok(BasisMethod::Direct),
        "qr-update-paper" => Ok(BasisMethod::QrUpdatePaper),
        "qr-update-exact" => Ok(BasisMethod::QrUpdateExact),
        _ => Err(Error::Invalid(format!(
            "unknown basis {s:?} (direct | qr-update-paper | qr-update-exact)"
        ))),
    }
}

/// Parse a small-SVD backend name (`jacobi | gram`).
pub fn parse_small_svd(s: &str) -> Result<SmallSvdMethod> {
    match s {
        "jacobi" => Ok(SmallSvdMethod::Jacobi),
        "gram" => Ok(SmallSvdMethod::GramEig),
        _ => Err(Error::Invalid(format!("unknown small_svd {s:?} (jacobi | gram)"))),
    }
}

/// Parse a source-pass schedule name (`exact | fused`) — the
/// `[stream] pass_policy` knob, the `--pass-policy` CLI flag, and the
/// wire protocol's `pass_policy` field.
pub fn parse_pass_policy(s: &str) -> Result<PassPolicy> {
    match s {
        "exact" => Ok(PassPolicy::Exact),
        "fused" => Ok(PassPolicy::Fused),
        _ => Err(Error::Invalid(format!(
            "unknown pass_policy {s:?} (exact | fused)"
        ))),
    }
}

/// Parse a kernel arithmetic tier name (`exact | fast`) — the
/// `[svd] precision` knob, the `--precision` CLI flag, and the wire
/// protocol's `precision` field.
pub fn parse_precision(s: &str) -> Result<Precision> {
    match s {
        "exact" => Ok(Precision::Exact),
        "fast" => Ok(Precision::Fast),
        _ => Err(Error::Invalid(format!("unknown precision {s:?} (exact | fast)"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
# demo
[service]
native_workers = 3
queue_capacity = 8
artifact_dir = artifacts   ; inline comment

[svd]
k = 25
oversample = 25
power_iters = 2
basis = qr-update-exact
small_svd = gram
";

    #[test]
    fn full_roundtrip() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let svc = raw.coordinator().unwrap();
        assert_eq!(svc.native_workers, 3);
        assert_eq!(svc.queue_capacity, 8);
        assert_eq!(svc.artifact_dir, Some(PathBuf::from("artifacts")));
        let svd = raw.svd().unwrap();
        assert_eq!(svd.k, 25);
        assert_eq!(svd.sample_width(), 50);
        assert_eq!(svd.stop, StopCriterion::FixedPower { q: 2 });
        assert_eq!(svd.basis, BasisMethod::QrUpdateExact);
        assert_eq!(svd.small_svd, SmallSvdMethod::GramEig);
    }

    #[test]
    fn defaults_when_missing() {
        let raw = RawConfig::parse("").unwrap();
        let svd = raw.svd().unwrap();
        assert_eq!(svd.k, SvdConfig::default().k);
        // Back-compat: nothing set means the fixed q = 0 of every
        // pre-redesign deployment.
        assert_eq!(svd.stop, StopCriterion::FixedPower { q: 0 });
    }

    #[test]
    fn svd_tolerance_keys() {
        let raw = RawConfig::parse("[svd]\npve_tol = 1e-3\nmax_sweeps = 12\n").unwrap();
        assert_eq!(
            raw.svd().unwrap().stop,
            StopCriterion::Tolerance { pve_tol: 1e-3, max_sweeps: 12 }
        );
        // max_sweeps defaults when only the tolerance is given.
        let raw = RawConfig::parse("[svd]\npve_tol = 1e-2\n").unwrap();
        assert_eq!(
            raw.svd().unwrap().stop,
            StopCriterion::Tolerance {
                pve_tol: 1e-2,
                max_sweeps: StopCriterion::DEFAULT_MAX_SWEEPS
            }
        );
    }

    #[test]
    fn stop_criterion_conversion_rules() {
        // Mutually exclusive spellings.
        assert!(stop_criterion(Some(2), Some(1e-3), None).is_err());
        // max_sweeps without a tolerance is meaningless.
        assert!(stop_criterion(Some(2), None, Some(8)).is_err());
        assert!(stop_criterion(None, None, Some(8)).is_err());
        // Tolerance must be a positive finite number; the cap >= 1.
        assert!(stop_criterion(None, Some(0.0), None).is_err());
        assert!(stop_criterion(None, Some(-1.0), None).is_err());
        assert!(stop_criterion(None, Some(f64::NAN), None).is_err());
        assert!(stop_criterion(None, Some(1e-3), Some(0)).is_err());
        // The happy paths.
        assert_eq!(
            stop_criterion(Some(3), None, None).unwrap(),
            StopCriterion::FixedPower { q: 3 }
        );
        assert_eq!(
            stop_criterion(None, None, None).unwrap(),
            StopCriterion::FixedPower { q: 0 }
        );
        // Config-level errors surface through svd().
        let raw = RawConfig::parse("[svd]\npower_iters = 1\npve_tol = 1e-3\n").unwrap();
        assert!(raw.svd().is_err());
        let raw = RawConfig::parse("[svd]\npve_tol = soon\n").unwrap();
        assert!(raw.svd().is_err());
    }

    #[test]
    fn artifact_dir_off() {
        let raw = RawConfig::parse("[service]\nartifact_dir = off\n").unwrap();
        assert_eq!(raw.coordinator().unwrap().artifact_dir, None);
    }

    #[test]
    fn parallel_threads_knob() {
        let raw = RawConfig::parse("[parallel]\nthreads = 6\n").unwrap();
        assert_eq!(raw.coordinator().unwrap().pool_threads, Some(6));
        // 0 and unset both mean auto.
        let raw = RawConfig::parse("[parallel]\nthreads = 0\n").unwrap();
        assert_eq!(raw.coordinator().unwrap().pool_threads, None);
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.coordinator().unwrap().pool_threads, None);
        // Non-integer errors.
        let raw = RawConfig::parse("[parallel]\nthreads = many\n").unwrap();
        assert!(raw.coordinator().is_err());
    }

    #[test]
    fn parallel_io_threads_knob() {
        let raw = RawConfig::parse("[parallel]\nio_threads = 3\n").unwrap();
        assert_eq!(raw.coordinator().unwrap().io_threads, Some(3));
        // 0 and unset both mean auto (the process-wide io pool).
        let raw = RawConfig::parse("[parallel]\nio_threads = 0\n").unwrap();
        assert_eq!(raw.coordinator().unwrap().io_threads, None);
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.coordinator().unwrap().io_threads, None);
        let raw = RawConfig::parse("[parallel]\nio_threads = lots\n").unwrap();
        assert!(raw.coordinator().is_err());
    }

    #[test]
    fn parallel_simd_switch() {
        let raw = RawConfig::parse("[parallel]\nsimd = off\n").unwrap();
        assert_eq!(raw.parallel_simd().unwrap(), Some(false));
        let raw = RawConfig::parse("[parallel]\nsimd = on\n").unwrap();
        assert_eq!(raw.parallel_simd().unwrap(), Some(true));
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.parallel_simd().unwrap(), None);
        let raw = RawConfig::parse("[parallel]\nsimd = turbo\n").unwrap();
        assert!(raw.parallel_simd().is_err());
    }

    #[test]
    fn svd_precision_knob() {
        let raw = RawConfig::parse("[svd]\nprecision = fast\n").unwrap();
        assert_eq!(raw.svd().unwrap().precision, Precision::Fast);
        let raw = RawConfig::parse("[svd]\nprecision = exact\n").unwrap();
        assert_eq!(raw.svd().unwrap().precision, Precision::Exact);
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.svd().unwrap().precision, Precision::Exact);
        let raw = RawConfig::parse("[svd]\nprecision = warp\n").unwrap();
        assert!(raw.svd().is_err());
        assert!(parse_precision("bogus").is_err());
        assert_eq!(parse_precision("fast").unwrap(), Precision::Fast);
    }

    #[test]
    fn stream_section_knobs() {
        let raw = RawConfig::parse(
            "[stream]\nblock_rows = 512\nbudget_mb = 16\nprefetch = off\n",
        )
        .unwrap();
        let s = raw.stream().unwrap();
        assert_eq!(s.block_rows, 512);
        assert_eq!(s.budget_mb, 16);
        assert!(!s.prefetch);
        // Defaults when missing (prefetch on).
        let s = RawConfig::parse("").unwrap().stream().unwrap();
        assert_eq!(s, StreamConfig::default());
        assert!(s.prefetch);
        // Non-integer / non-boolean errors.
        let raw = RawConfig::parse("[stream]\nblock_rows = lots\n").unwrap();
        assert!(raw.stream().is_err());
        let raw = RawConfig::parse("[stream]\nprefetch = sometimes\n").unwrap();
        assert!(raw.stream().is_err());
    }

    #[test]
    fn stream_pass_policy_feeds_svd_config() {
        let raw = RawConfig::parse("[stream]\npass_policy = fused\n").unwrap();
        assert_eq!(raw.svd().unwrap().pass_policy, PassPolicy::Fused);
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.svd().unwrap().pass_policy, PassPolicy::Exact);
        let raw = RawConfig::parse("[stream]\npass_policy = warp\n").unwrap();
        assert!(raw.svd().is_err());
        assert!(parse_pass_policy("bogus").is_err());
        assert_eq!(parse_pass_policy("exact").unwrap(), PassPolicy::Exact);
    }

    #[test]
    fn server_section_knobs() {
        let raw = RawConfig::parse(
            "[server]\naddr = 0.0.0.0:9000\nmax_body_mb = 8\nworkers = 2\nrequest_timeout_s = 5\n",
        )
        .unwrap();
        let s = raw.server().unwrap();
        assert_eq!(s.addr, "0.0.0.0:9000");
        assert_eq!(s.max_body_bytes, 8 << 20);
        assert_eq!(s.workers, 2);
        assert_eq!(s.request_timeout_s, 5);
        // Defaults when missing.
        let d = RawConfig::parse("").unwrap().server().unwrap();
        assert_eq!(d.addr, crate::server::ServerConfig::default().addr);
        // Floors: zero workers / timeout are clamped, not accepted.
        let raw = RawConfig::parse("[server]\nworkers = 0\nrequest_timeout_s = 0\n").unwrap();
        let s = raw.server().unwrap();
        assert_eq!(s.workers, 1);
        assert_eq!(s.request_timeout_s, 1);
        // Non-integer errors.
        let raw = RawConfig::parse("[server]\nworkers = many\n").unwrap();
        assert!(raw.server().is_err());
        // Lifecycle/cache knobs (mirrors [service] artifact_dir: off|none
        // disables persistence; cache_entries = 0 disables caching).
        let raw = RawConfig::parse(
            "[server]\nresult_ttl_s = 45\ncache_dir = /tmp/srsvd-cache\ncache_entries = 0\n",
        )
        .unwrap();
        let s = raw.server().unwrap();
        assert_eq!(s.result_ttl_s, 45);
        assert_eq!(s.cache_dir, Some(PathBuf::from("/tmp/srsvd-cache")));
        assert_eq!(s.cache_entries, 0);
        let raw = RawConfig::parse("[server]\ncache_dir = off\n").unwrap();
        assert_eq!(raw.server().unwrap().cache_dir, None);
        let raw = RawConfig::parse("[server]\nresult_ttl_s = 0\n").unwrap();
        assert_eq!(raw.server().unwrap().result_ttl_s, 1);
    }

    #[test]
    fn router_section_knobs() {
        let raw = RawConfig::parse(
            "[router]\nlisten = 0.0.0.0:7979\nreplicas = 127.0.0.1:7878, 127.0.0.1:7879,\n\
             workers = 2\nmax_body_mb = 8\nrequest_timeout_s = 5\nconnect_timeout_ms = 250\n\
             probe_interval_ms = 100\nprobe_timeout_ms = 50\nunhealthy_after = 2\n",
        )
        .unwrap();
        let r = raw.router().unwrap();
        assert_eq!(r.listen, "0.0.0.0:7979");
        assert_eq!(r.replicas, vec!["127.0.0.1:7878", "127.0.0.1:7879"]);
        assert_eq!(r.workers, 2);
        assert_eq!(r.max_body_bytes, 8 << 20);
        assert_eq!(r.request_timeout_s, 5);
        assert_eq!(r.connect_timeout_ms, 250);
        assert_eq!(r.probe_interval_ms, 100);
        assert_eq!(r.probe_timeout_ms, 50);
        assert_eq!(r.unhealthy_after, 2);
        // Defaults when missing (no replicas: Router::bind refuses).
        let d = RawConfig::parse("").unwrap().router().unwrap();
        assert_eq!(d.listen, crate::router::RouterConfig::default().listen);
        assert!(d.replicas.is_empty());
        // Floors: zeros are clamped, not accepted.
        let raw = RawConfig::parse(
            "[router]\nworkers = 0\nconnect_timeout_ms = 0\nunhealthy_after = 0\n",
        )
        .unwrap();
        let r = raw.router().unwrap();
        assert_eq!(r.workers, 1);
        assert_eq!(r.connect_timeout_ms, 1);
        assert_eq!(r.unhealthy_after, 1);
        // Non-integer errors.
        let raw = RawConfig::parse("[router]\nprobe_interval_ms = often\n").unwrap();
        assert!(raw.router().is_err());
    }

    #[test]
    fn router_connect_timeout_falls_back_to_server_section() {
        // One shared srsvd.conf: [server] sets the deployment-wide
        // connect bound, [router] inherits it...
        let raw = RawConfig::parse("[server]\nconnect_timeout_ms = 300\n").unwrap();
        assert_eq!(raw.router().unwrap().connect_timeout_ms, 300);
        // ...unless the [router] section pins its own.
        let raw = RawConfig::parse(
            "[server]\nconnect_timeout_ms = 300\n[router]\nconnect_timeout_ms = 700\n",
        )
        .unwrap();
        assert_eq!(raw.router().unwrap().connect_timeout_ms, 700);
        // Neither set: the typed default.
        let d = RawConfig::parse("").unwrap().router().unwrap();
        assert_eq!(
            d.connect_timeout_ms,
            crate::router::RouterConfig::default().connect_timeout_ms
        );
    }

    #[test]
    fn retry_section_knobs() {
        let raw = RawConfig::parse(
            "[retry]\nmax_attempts = 5\nbackoff_base_ms = 20\nbackoff_max_ms = 400\njitter = off\n",
        )
        .unwrap();
        let p = raw.retry().unwrap();
        assert_eq!(p.max_attempts, 5);
        assert_eq!(p.backoff_base_ms, 20);
        assert_eq!(p.backoff_max_ms, 400);
        assert!(!p.jitter);
        // One [retry] section feeds both the coordinator and the router.
        assert_eq!(raw.coordinator().unwrap().retry.max_attempts, 5);
        assert_eq!(raw.router().unwrap().retry.max_attempts, 5);
        // Defaults when missing; max_attempts floors at 1 (fail-fast).
        let d = RawConfig::parse("").unwrap().retry().unwrap();
        assert_eq!(d, crate::util::retry::RetryPolicy::default());
        let raw = RawConfig::parse("[retry]\nmax_attempts = 0\n").unwrap();
        assert_eq!(raw.retry().unwrap().max_attempts, 1);
        // Non-integer / non-boolean errors.
        let raw = RawConfig::parse("[retry]\nmax_attempts = lots\n").unwrap();
        assert!(raw.retry().is_err());
        let raw = RawConfig::parse("[retry]\njitter = maybe\n").unwrap();
        assert!(raw.retry().is_err());
    }

    #[test]
    fn faults_spec_passthrough() {
        let raw = RawConfig::parse("[faults]\nspec = stream.read=err:2@0.5\n").unwrap();
        assert_eq!(raw.faults_spec(), Some("stream.read=err:2@0.5"));
        assert_eq!(RawConfig::parse("").unwrap().faults_spec(), None);
        let raw = RawConfig::parse("[faults]\nspec =\n").unwrap();
        assert_eq!(raw.faults_spec(), None, "empty spec means disarmed");
    }

    #[test]
    fn checkpoint_and_journal_dirs() {
        let raw = RawConfig::parse(
            "[svd]\ncheckpoint_dir = /tmp/ckpt\n[server]\njournal_dir = /tmp/journal\n",
        )
        .unwrap();
        assert_eq!(raw.coordinator().unwrap().checkpoint_dir, Some(PathBuf::from("/tmp/ckpt")));
        assert_eq!(raw.server().unwrap().journal_dir, Some(PathBuf::from("/tmp/journal")));
        // off|none and unset all mean disabled (cold starts only).
        let raw = RawConfig::parse("[svd]\ncheckpoint_dir = off\n[server]\njournal_dir = none\n")
            .unwrap();
        assert_eq!(raw.coordinator().unwrap().checkpoint_dir, None);
        assert_eq!(raw.server().unwrap().journal_dir, None);
        let raw = RawConfig::parse("").unwrap();
        assert_eq!(raw.coordinator().unwrap().checkpoint_dir, None);
        assert_eq!(raw.server().unwrap().journal_dir, None);
    }

    #[test]
    fn addr_list_splitting() {
        assert_eq!(split_addr_list("a:1,b:2"), vec!["a:1", "b:2"]);
        assert_eq!(split_addr_list(" a:1 , b:2 , "), vec!["a:1", "b:2"]);
        assert!(split_addr_list("").is_empty());
        assert!(split_addr_list(" , ").is_empty());
    }

    #[test]
    fn errors_are_located() {
        let err = RawConfig::parse("[svd]\nk 10\n").unwrap_err();
        assert!(format!("{err}").contains("line 2"));
        let raw = RawConfig::parse("[svd]\nk = ten\n").unwrap();
        assert!(raw.svd().is_err());
        assert!(parse_basis("bogus").is_err());
        assert!(parse_small_svd("bogus").is_err());
    }
}
