//! Synthetic image matrices standing in for the paper's §5.2 datasets.
//!
//! * **Digits** (UCI handwritten digits substitute): a procedural 8×8
//!   glyph renderer. Ten digit stencils (hand-authored on a 8×8 grid,
//!   mirroring the 0–16 ink scale of the UCI set) are jittered per
//!   sample: sub-pixel translation, stroke-weight scaling, and additive
//!   noise. Vectorized to a 64×n matrix. Preserves: strongly non-zero
//!   mean (ink mass), low intrinsic rank with 10-class structure.
//! * **Faces** (LFW substitute): an eigenface-style generator — a smooth
//!   base face (composition of 2-D Gaussian blobs for head, eyes, nose,
//!   mouth) shared by every sample plus a low-rank identity subspace and
//!   pixel noise, at configurable resolution. Preserves: a huge common
//!   mean component and a slowly decaying spectrum — the regime where
//!   the paper reports S-RSVD's biggest win-rate (82%).

use crate::linalg::Dense;
use crate::rng::Rng;

/// 8×8 digit stencils, rows top-to-bottom, `#` = full ink. Deliberately
/// blocky — the UCI set is 8×8 downsampled handwriting.
#[rustfmt::skip]
const STENCILS: [[&str; 8]; 10] = [
    [" ####   ", "##  ##  ", "##  ##  ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    ["  ##    ", " ###    ", "  ##    ", "  ##    ", "  ##    ", "  ##    ", " ####   ", "        "],
    [" ####   ", "##  ##  ", "    ##  ", "   ##   ", "  ##    ", " ##     ", "######  ", "        "],
    [" ####   ", "##  ##  ", "    ##  ", "  ###   ", "    ##  ", "##  ##  ", " ####   ", "        "],
    ["   ###  ", "  ####  ", " ## ##  ", "##  ##  ", "######  ", "    ##  ", "    ##  ", "        "],
    ["######  ", "##      ", "#####   ", "    ##  ", "    ##  ", "##  ##  ", " ####   ", "        "],
    [" ####   ", "##      ", "#####   ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    ["######  ", "    ##  ", "   ##   ", "  ##    ", " ##     ", " ##     ", " ##     ", "        "],
    [" ####   ", "##  ##  ", " ####   ", "##  ##  ", "##  ##  ", "##  ##  ", " ####   ", "        "],
    [" ####   ", "##  ##  ", "##  ##  ", " #####  ", "    ##  ", "    ##  ", " ####   ", "        "],
];

/// Digits dataset parameters.
#[derive(Debug, Clone, Copy)]
pub struct DigitsSpec {
    /// Number of images (the paper's copy has 1979).
    pub count: usize,
    /// Ink scale (UCI pixels are 0..16).
    pub ink: f64,
    /// Additive noise std-dev.
    pub noise: f64,
}

impl Default for DigitsSpec {
    fn default() -> Self {
        DigitsSpec { count: 1979, ink: 16.0, noise: 1.0 }
    }
}

fn stencil_pixel(digit: usize, r: f64, c: f64) -> f64 {
    // Bilinear sample of the stencil with clamped coordinates.
    let clamp = |x: f64| x.clamp(0.0, 7.0);
    let (r, c) = (clamp(r), clamp(c));
    let (r0, c0) = (r.floor() as usize, c.floor() as usize);
    let (r1, c1) = ((r0 + 1).min(7), (c0 + 1).min(7));
    let (fr, fc) = (r - r0 as f64, c - c0 as f64);
    let at = |rr: usize, cc: usize| -> f64 {
        if STENCILS[digit][rr].as_bytes()[cc] == b'#' {
            1.0
        } else {
            0.0
        }
    };
    at(r0, c0) * (1.0 - fr) * (1.0 - fc)
        + at(r1, c0) * fr * (1.0 - fc)
        + at(r0, c1) * (1.0 - fr) * fc
        + at(r1, c1) * fr * fc
}

/// Render the digits matrix: 64 × `count`, one vectorized image per
/// column, classes cycling 0–9.
pub fn digits_matrix(spec: DigitsSpec, rng: &mut dyn Rng) -> Dense {
    let mut x = Dense::zeros(64, spec.count);
    for j in 0..spec.count {
        let digit = j % 10;
        let dr = rng.next_range(-0.7, 0.7); // sub-pixel translation
        let dc = rng.next_range(-0.7, 0.7);
        let weight = rng.next_range(0.75, 1.15); // stroke weight
        for r in 0..8 {
            for c in 0..8 {
                let ink = stencil_pixel(digit, r as f64 + dr, c as f64 + dc);
                let val = (ink * weight * spec.ink + spec.noise * rng.next_gaussian())
                    .clamp(0.0, spec.ink);
                x[(r * 8 + c, j)] = val;
            }
        }
    }
    x
}

/// Faces dataset parameters.
#[derive(Debug, Clone, Copy)]
pub struct FacesSpec {
    /// Image side (LFW is 250; default 32 keeps benches quick while
    /// preserving the spectral regime — the full size also works).
    pub side: usize,
    /// Number of images.
    pub count: usize,
    /// Number of latent identity components (the "eigenfaces").
    pub rank: usize,
    /// Pixel noise std-dev relative to the 0..255 scale.
    pub noise: f64,
}

impl Default for FacesSpec {
    fn default() -> Self {
        FacesSpec { side: 32, count: 400, rank: 24, noise: 6.0 }
    }
}

/// An isotropic 2-D Gaussian blob evaluated at (r, c).
fn blob(r: f64, c: f64, cr: f64, cc: f64, sr: f64, sc: f64) -> f64 {
    let dr = (r - cr) / sr;
    let dc = (c - cc) / sc;
    (-0.5 * (dr * dr + dc * dc)).exp()
}

/// The shared base face on an s×s grid, 0..255 scale: a bright oval
/// head with darker eye/nose/mouth features. This is the large common
/// mean component that makes centering matter for face PCA.
fn base_face(side: usize) -> Vec<f64> {
    let s = side as f64;
    let mut img = vec![0.0; side * side];
    for r in 0..side {
        for c in 0..side {
            let (rf, cf) = (r as f64, c as f64);
            // Head oval.
            let mut v = 210.0 * blob(rf, cf, 0.52 * s, 0.5 * s, 0.38 * s, 0.30 * s);
            // Eyes (dark).
            v -= 90.0 * blob(rf, cf, 0.40 * s, 0.35 * s, 0.045 * s, 0.06 * s);
            v -= 90.0 * blob(rf, cf, 0.40 * s, 0.65 * s, 0.045 * s, 0.06 * s);
            // Nose ridge.
            v -= 30.0 * blob(rf, cf, 0.55 * s, 0.5 * s, 0.10 * s, 0.035 * s);
            // Mouth.
            v -= 70.0 * blob(rf, cf, 0.72 * s, 0.5 * s, 0.035 * s, 0.12 * s);
            img[r * side + c] = v.clamp(0.0, 255.0);
        }
    }
    img
}

/// Smooth random identity component: a handful of localized blobs with
/// random sign/position/scale — low spatial frequency like real
/// illumination/identity modes.
fn identity_component(side: usize, rng: &mut dyn Rng) -> Vec<f64> {
    let s = side as f64;
    let mut img = vec![0.0; side * side];
    let blobs = 6;
    for _ in 0..blobs {
        let cr = rng.next_range(0.15 * s, 0.85 * s);
        let cc = rng.next_range(0.15 * s, 0.85 * s);
        let sr = rng.next_range(0.06 * s, 0.22 * s);
        let sc = rng.next_range(0.06 * s, 0.22 * s);
        let amp = rng.next_range(-1.0, 1.0);
        for r in 0..side {
            for c in 0..side {
                img[r * side + c] += amp * blob(r as f64, c as f64, cr, cc, sr, sc);
            }
        }
    }
    // Normalize to unit L2.
    let nrm = img.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    for x in &mut img {
        *x /= nrm;
    }
    img
}

/// Render the faces matrix: side² × count, one vectorized face per
/// column: base + Σ w_l · component_l + noise, clamped to 0..255.
pub fn faces_matrix(spec: FacesSpec, rng: &mut dyn Rng) -> Dense {
    let dim = spec.side * spec.side;
    let base = base_face(spec.side);
    let comps: Vec<Vec<f64>> = (0..spec.rank)
        .map(|_| identity_component(spec.side, rng))
        .collect();
    // Component weights decay like 1/(1+l): a slowly decaying spectrum.
    let mut x = Dense::zeros(dim, spec.count);
    for j in 0..spec.count {
        let weights: Vec<f64> = (0..spec.rank)
            .map(|l| 60.0 / (1.0 + l as f64 * 0.35) * rng.next_gaussian())
            .collect();
        for p in 0..dim {
            let mut v = base[p];
            for (l, comp) in comps.iter().enumerate() {
                v += weights[l] * comp[p];
            }
            v += spec.noise * rng.next_gaussian();
            x[(p, j)] = v.clamp(0.0, 255.0);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn digits_shape_and_ink_range() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = digits_matrix(DigitsSpec { count: 50, ..Default::default() }, &mut rng);
        assert_eq!(x.shape(), (64, 50));
        assert!(x.data().iter().all(|&v| (0.0..=16.0).contains(&v)));
        // Ink mass: strongly non-zero mean.
        let grand: f64 = x.row_means().iter().sum::<f64>() / 64.0;
        assert!(grand > 2.0, "grand mean {grand}");
    }

    #[test]
    fn digits_same_class_more_similar_than_cross_class() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let x = digits_matrix(DigitsSpec { count: 40, noise: 0.5, ..Default::default() }, &mut rng);
        // Average same-class distance (digit 0 pairs) must be smaller
        // than average cross-class distance (digit 0 vs digit 1).
        let dist = |a: usize, b: usize| -> f64 {
            (0..64).map(|i| (x[(i, a)] - x[(i, b)]).powi(2)).sum::<f64>()
        };
        let same = (dist(0, 10) + dist(0, 20) + dist(10, 30)) / 3.0;
        let cross = (dist(0, 11) + dist(0, 21) + dist(10, 31)) / 3.0;
        assert!(same < cross, "same {same} cross {cross}");
    }

    #[test]
    fn faces_shape_and_common_component() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let spec = FacesSpec { side: 16, count: 30, rank: 8, noise: 4.0 };
        let x = faces_matrix(spec, &mut rng);
        assert_eq!(x.shape(), (256, 30));
        // The mean face carries most of the energy (off-center regime).
        let mu = x.row_means();
        let mu_energy: f64 = mu.iter().map(|v| v * v).sum::<f64>() * 30.0;
        let total: f64 = x.data().iter().map(|v| v * v).sum();
        assert!(mu_energy / total > 0.5, "mean fraction {}", mu_energy / total);
    }

    #[test]
    fn faces_deterministic_per_seed() {
        let spec = FacesSpec { side: 8, count: 4, rank: 3, noise: 1.0 };
        let a = faces_matrix(spec, &mut Xoshiro256pp::seed_from_u64(7));
        let b = faces_matrix(spec, &mut Xoshiro256pp::seed_from_u64(7));
        assert_eq!(a, b);
    }

    #[test]
    fn stencils_are_8x8() {
        for s in &STENCILS {
            for row in s {
                assert_eq!(row.len(), 8);
            }
        }
    }
}
