//! Random data matrices for the Figure 1 experiments.
//!
//! §5.1 samples an m-dimensional random vector n times and stacks the
//! samples column-wise. The distributions (uniform in [0,1], normal,
//! exponential, Zipfian) are all *off-center* — non-zero mean — which is
//! what makes mean-centering matter.

use crate::linalg::Dense;
use crate::rng::{Rng, ZipfSampler};

/// Data distribution for a random matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Distribution {
    /// Uniform in [0, 1) — mean 0.5 (the paper's default).
    Uniform,
    /// Normal(1, 1) — shifted so the mean is non-zero, matching the
    /// "off-center" regime of §5.1.
    Normal,
    /// Exponential(1) — mean 1, skewed.
    Exponential,
    /// Zipfian: coordinate i of each sample is a Zipf-distributed count
    /// share, producing the heavy-tailed rows of a word-frequency-like
    /// matrix (the distribution where the paper sees the largest and
    /// most persistent S-RSVD advantage; Fig. 1f).
    Zipf,
}

impl Distribution {
    /// Every supported distribution, in display order.
    pub const ALL: [Distribution; 4] = [
        Distribution::Uniform,
        Distribution::Normal,
        Distribution::Exponential,
        Distribution::Zipf,
    ];

    /// Stable lowercase name (CLI/config token).
    pub fn name(&self) -> &'static str {
        match self {
            Distribution::Uniform => "uniform",
            Distribution::Normal => "normal",
            Distribution::Exponential => "exponential",
            Distribution::Zipf => "zipf",
        }
    }

    /// Inverse of [`Distribution::name`].
    pub fn parse(s: &str) -> Option<Distribution> {
        Self::ALL.iter().copied().find(|d| d.name() == s)
    }
}

/// Specification of a random data matrix (m rows = features, n cols =
/// samples).
#[derive(Debug, Clone, Copy)]
pub struct DataSpec {
    /// Rows (features).
    pub m: usize,
    /// Columns (samples).
    pub n: usize,
    /// Entry distribution.
    pub dist: Distribution,
}

/// Generate the matrix described by `spec`.
pub fn random_matrix(spec: DataSpec, rng: &mut dyn Rng) -> Dense {
    let DataSpec { m, n, dist } = spec;
    match dist {
        Distribution::Uniform => Dense::from_fn(m, n, |_, _| rng.next_uniform()),
        Distribution::Normal => Dense::from_fn(m, n, |_, _| 1.0 + rng.next_gaussian()),
        Distribution::Exponential => Dense::from_fn(m, n, |_, _| rng.next_exponential()),
        Distribution::Zipf => {
            // Each sample (column): draw `draws` Zipf ranks over the m
            // coordinates and histogram them — a unigram count vector,
            // normalized to relative frequencies. Rows then carry
            // Zipf-decaying means with sampling noise.
            let z = ZipfSampler::new(m as u64, 1.2);
            let draws = (4 * m).max(64);
            let mut x = Dense::zeros(m, n);
            for j in 0..n {
                for _ in 0..draws {
                    let rank = z.sample(rng) as usize - 1;
                    x[(rank, j)] += 1.0;
                }
            }
            let inv = 1.0 / draws as f64;
            for v in x.data_mut() {
                *v *= inv;
            }
            x
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn uniform_off_center() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = random_matrix(
            DataSpec { m: 20, n: 500, dist: Distribution::Uniform },
            &mut rng,
        );
        let mu = x.row_means();
        // Every row mean near 0.5.
        assert!(mu.iter().all(|&m| (m - 0.5).abs() < 0.1), "{mu:?}");
    }

    #[test]
    fn normal_mean_one() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let x = random_matrix(
            DataSpec { m: 10, n: 2000, dist: Distribution::Normal },
            &mut rng,
        );
        let grand: f64 = x.row_means().iter().sum::<f64>() / 10.0;
        assert!((grand - 1.0).abs() < 0.1, "{grand}");
    }

    #[test]
    fn exponential_positive() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = random_matrix(
            DataSpec { m: 5, n: 100, dist: Distribution::Exponential },
            &mut rng,
        );
        assert!(x.data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn zipf_columns_sum_to_one_and_head_heavy() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x = random_matrix(
            DataSpec { m: 50, n: 20, dist: Distribution::Zipf },
            &mut rng,
        );
        for j in 0..20 {
            let s: f64 = x.col(j).iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "col {j} sums to {s}");
        }
        // Rank-1 row mean far above rank-50 row mean.
        let mu = x.row_means();
        assert!(mu[0] > 5.0 * mu[49], "head {} tail {}", mu[0], mu[49]);
    }

    #[test]
    fn distribution_parse_roundtrip() {
        for d in Distribution::ALL {
            assert_eq!(Distribution::parse(d.name()), Some(d));
        }
        assert_eq!(Distribution::parse("cauchy"), None);
    }
}
