//! Synthetic Zipfian corpus → sparse word co-occurrence matrix (§5.3
//! substitute for the Wikipedia/CoNLL-2017 counts).
//!
//! The paper builds p(wᵢ | wⱼ) ≈ n(wⱼ, wᵢ)/n(wⱼ) over the m most
//! frequent context words and n most frequent target words. What the
//! experiment needs from the data is: Zipfian unigram margins, extreme
//! sparsity at large n, non-negative entries, non-zero row means. We
//! generate exactly that: a Zipfian unigram language with topic-like
//! bigram affinity, sampled into a count matrix and normalized per
//! context word.

use crate::linalg::{Csr, Triplets};
use crate::rng::{Rng, ZipfSampler};

/// Corpus / co-occurrence matrix parameters.
#[derive(Debug, Clone, Copy)]
pub struct CorpusSpec {
    /// Context vocabulary (matrix rows; the paper fixes m = 1000).
    pub contexts: usize,
    /// Target vocabulary (matrix columns; the paper sweeps n up to 3e5).
    pub targets: usize,
    /// Number of sampled co-occurrence pairs ("corpus size"). Drives the
    /// density: pairs / (contexts · targets).
    pub pairs: usize,
    /// Zipf exponent of the unigram distribution (≈1 for natural text).
    pub zipf_s: f64,
    /// Number of latent topics coupling context and target choice; more
    /// topics → lower-rank structure in the conditional matrix.
    pub topics: usize,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec {
            contexts: 1000,
            targets: 10_000,
            pairs: 2_000_000,
            zipf_s: 1.05,
            topics: 32,
        }
    }
}

/// Build the m×n conditional-probability co-occurrence matrix
/// p(target | context).
///
/// Sampling model: a pair is drawn by (1) sampling a topic t, (2)
/// sampling the context word from a Zipf distribution re-ranked by a
/// topic-dependent permutation offset, (3) likewise for the target.
/// This produces Zipfian margins *and* correlated structure (the
/// low-rank signal PCA is after), at O(pairs) cost.
pub fn cooccurrence_matrix(spec: CorpusSpec, rng: &mut dyn Rng) -> Csr {
    let m = spec.contexts;
    let n = spec.targets;
    let zc = ZipfSampler::new(m as u64, spec.zipf_s);
    let zt = ZipfSampler::new(n as u64, spec.zipf_s);

    // Topic offsets: each topic re-ranks the vocabulary by a fixed
    // rotation, so words cluster by topic without changing the margins.
    let ctx_off: Vec<usize> = (0..spec.topics)
        .map(|_| rng.next_below(m as u64) as usize)
        .collect();
    let tgt_off: Vec<usize> = (0..spec.topics)
        .map(|_| rng.next_below(n as u64) as usize)
        .collect();

    let mut counts = Triplets::new(m, n);
    let mut ctx_totals = vec![0u32; m];
    for _ in 0..spec.pairs {
        let t = rng.next_below(spec.topics as u64) as usize;
        let c = (zc.sample(rng) as usize - 1 + ctx_off[t]) % m;
        let w = (zt.sample(rng) as usize - 1 + tgt_off[t]) % n;
        counts.push(c, w, 1.0);
        ctx_totals[c] += 1;
    }
    let counts = counts.to_csr();

    // Normalize each row by the context total: p(w | c).
    let mut probs = Triplets::new(m, n);
    for i in 0..m {
        let tot = ctx_totals[i].max(1) as f64;
        for (j, v) in counts.row_iter(i) {
            probs.push(i, j, v / tot);
        }
    }
    probs.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn small_spec() -> CorpusSpec {
        CorpusSpec {
            contexts: 50,
            targets: 300,
            pairs: 30_000,
            zipf_s: 1.05,
            topics: 4,
        }
    }

    #[test]
    fn rows_are_conditional_distributions() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = cooccurrence_matrix(small_spec(), &mut rng);
        assert_eq!(x.shape(), (50, 300));
        for i in 0..50 {
            let s: f64 = x.row_iter(i).map(|(_, v)| v).sum();
            if x.row_iter(i).count() > 0 {
                assert!((s - 1.0).abs() < 1e-9, "row {i} sums to {s}");
            }
        }
        assert!(x.to_dense().data().iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn sparse_at_scale() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let spec = CorpusSpec {
            contexts: 200,
            targets: 5000,
            pairs: 100_000,
            zipf_s: 1.05,
            topics: 8,
        };
        let x = cooccurrence_matrix(spec, &mut rng);
        // Density bounded by pairs/(m·n) and Zipf collisions push it lower.
        assert!(x.density() < 0.1, "density {}", x.density());
        assert!(x.nnz() > 10_000);
    }

    #[test]
    fn zipfian_margins_head_heavy() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = cooccurrence_matrix(small_spec(), &mut rng);
        // Column mass concentrates on a small head (after topic
        // rotation the *sorted* mass profile must still be Zipf-like).
        let mut col_mass = vec![0.0; 300];
        for i in 0..50 {
            for (j, v) in x.row_iter(i) {
                col_mass[j] += v;
            }
        }
        col_mass.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let head: f64 = col_mass[..30].iter().sum();
        let total: f64 = col_mass.iter().sum();
        assert!(head / total > 0.3, "head share {}", head / total);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = cooccurrence_matrix(small_spec(), &mut Xoshiro256pp::seed_from_u64(9));
        let b = cooccurrence_matrix(small_spec(), &mut Xoshiro256pp::seed_from_u64(9));
        assert_eq!(a.nnz(), b.nnz());
        assert!(crate::linalg::fro_diff(&a.to_dense(), &b.to_dense()) == 0.0);
    }
}
