//! Synthetic workload generators substituting the paper's datasets.
//!
//! The paper evaluates on (1) random matrices of several distributions,
//! (2) UCI handwritten digits and LFW faces, (3) word co-occurrence
//! probabilities from English Wikipedia. (2) and (3) are not available
//! in this offline environment, so each is replaced by a generator that
//! preserves the property the experiment exercises — see DESIGN.md
//! §Substitutions for the full argument. All generators are seeded and
//! deterministic.

pub mod corpus;
pub mod images;
pub mod random;

pub use corpus::{CorpusSpec, cooccurrence_matrix};
pub use images::{digits_matrix, faces_matrix, DigitsSpec, FacesSpec};
pub use random::{random_matrix, DataSpec, Distribution};
