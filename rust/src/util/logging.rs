//! Minimal leveled stderr logger — self-contained (the crate builds
//! with zero external dependencies, so there is no `log` facade).
//!
//! Call sites use the crate-level macros:
//!
//! ```no_run
//! srsvd::util::logging::init();
//! srsvd::log_info!("coordinator: {} workers", 4);
//! ```
//!
//! The level comes from `SRSVD_LOG` (`trace|debug|info|warn|error|off`,
//! default `info`), parsed once by [`init`].

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or user-visible failures.
    Error = 0,
    /// Degraded-but-continuing conditions.
    Warn = 1,
    /// Lifecycle events (default level).
    Info = 2,
    /// Per-operation detail.
    Debug = 3,
    /// Inner-loop detail.
    Trace = 4,
}

impl Level {
    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Numeric max level (`Level as usize`); records at or below it are
/// emitted. `OFF` disables everything. Pre-`init` default is Info.
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(Level::Info as usize);
const OFF: usize = usize::MAX;
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// Parse `SRSVD_LOG` once; idempotent.
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("SRSVD_LOG").as_deref() {
        Ok("trace") => Level::Trace as usize,
        Ok("debug") => Level::Debug as usize,
        Ok("warn") => Level::Warn as usize,
        Ok("error") => Level::Error as usize,
        Ok("off") => OFF,
        _ => Level::Info as usize,
    };
    MAX_LEVEL.store(level, Ordering::SeqCst);
}

/// Whether a message at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    max != OFF && (level as usize) <= max
}

/// Emit one record (used via the `log_*!` macros).
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}: {}", level.tag(), target, args);
    }
}

/// `log_error!("...")` — formatted record at Error level.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_warn!("...")` — formatted record at Warn level.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_info!("...")` — formatted record at Info level.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_debug!("...")` — formatted record at Debug level.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// `log_trace!("...")` — formatted record at Trace level.
#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Trace,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent_and_macros_format() {
        init();
        init();
        crate::log_info!("logging smoke {}", 42);
        crate::log_debug!("hidden at default level");
        assert!(enabled(Level::Error));
        assert!(Level::Error < Level::Trace);
    }
}
