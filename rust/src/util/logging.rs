//! Minimal `log` backend writing to stderr with a level filter.
//!
//! The offline crate cache has `log` but no `env_logger`; this is the
//! ~60-line subset we need: `SRSVD_LOG=debug cargo run ...`.

use std::sync::atomic::{AtomicBool, Ordering};

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

static LOGGER: StderrLogger = StderrLogger;
static INSTALLED: AtomicBool = AtomicBool::new(false);

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{lvl}] {}: {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the stderr logger once; level from `SRSVD_LOG` (default `info`).
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("SRSVD_LOG").as_deref() {
        Ok("trace") => LevelFilter::Trace,
        Ok("debug") => LevelFilter::Debug,
        Ok("warn") => LevelFilter::Warn,
        Ok("error") => LevelFilter::Error,
        Ok("off") => LevelFilter::Off,
        _ => LevelFilter::Info,
    };
    let _ = log::set_logger(&LOGGER);
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }
}
