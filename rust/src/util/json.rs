//! Minimal JSON value, parser and writer.
//!
//! The offline crate cache has no `serde`/`serde_json`; this module is
//! the subset we need: parsing `artifacts/manifest.json` (written by
//! `python/compile/aot.py`), the network wire protocol of
//! [`crate::server`], and emitting experiment reports. It is a complete
//! RFC 8259 parser for the constructs we produce (objects, arrays,
//! strings with escapes including surrogate pairs, numbers, booleans,
//! null) with precise error offsets.
//!
//! Since the parser reads bytes straight off a socket it is hardened as
//! an attack surface: trailing garbage after the top-level value is an
//! error, nesting depth is capped ([`MAX_DEPTH`] — a flood of `[`s
//! cannot overflow the parse stack), `\u` escapes must be exactly four
//! hex digits, and rendering a parsed value round-trips bit-exactly for
//! finite numbers (Rust's shortest-repr `Display` for `f64`), which the
//! property tests in `rust/tests/props.rs` pin.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::{Error, Result};

/// Maximum container nesting depth the parser accepts. Deeper documents
/// error instead of recursing toward a stack overflow — the parser
/// reads untrusted network bytes (see [`crate::server`]).
pub const MAX_DEPTH: usize = 128;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (all JSON numbers are f64 here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with ordered keys.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ----- typed accessors ------------------------------------------------

    /// Borrow as an object, or a typed error.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    /// Borrow as an array, or a typed error.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    /// Borrow as a string, or a typed error.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    /// Read as a number, or a typed error.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    /// Read as a boolean, or a typed error.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json(format!("expected boolean, got {self:?}"))),
        }
    }

    /// Read as a non-negative integer `u64`, or a typed error. JSON
    /// numbers are `f64`, so values above 2⁵³ cannot be represented
    /// exactly and are rejected.
    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 || x >= 9.007_199_254_740_992e15 {
            return Err(Error::Json(format!(
                "expected non-negative integer below 2^53, got {x}"
            )));
        }
        Ok(x as u64)
    }

    /// Read as a non-negative integer, or a typed error.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {x}")));
        }
        Ok(x as usize)
    }

    /// Object field lookup with a useful error message.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    // ----- construction helpers -------------------------------------------

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from any iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a number.
    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    /// Build a string.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ----- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if !x.is_finite() {
                    // JSON has no NaN/±inf spelling; `null` is the only
                    // valid rendering (the typed accessors then surface
                    // a clean error instead of invalid JSON).
                    out.push_str("null");
                } else if x.fract() == 0.0 && x.abs() < 9e15 && (*x != 0.0 || x.is_sign_positive())
                {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    // Shortest-repr Display: round-trips every finite
                    // f64 (including -0.0, which renders as "-0") to
                    // the exact same bits.
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            c @ (b'{' | b'[') => {
                if self.depth >= MAX_DEPTH {
                    return Err(self.err("nesting too deep"));
                }
                self.depth += 1;
                let v = if c == b'{' { self.object() } else { self.array() };
                self.depth -= 1;
                v
            }
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected character {:?}", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad utf8 in number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let cp = self.u_escape_digits()?;
                            let c = if (0xD800..=0xDBFF).contains(&cp) {
                                // High surrogate: a following low
                                // surrogate escape forms one
                                // supplementary code point (RFC 8259
                                // §7); a lone surrogate is U+FFFD.
                                let paired = self.b.get(self.i + 1) == Some(&b'\\')
                                    && self.b.get(self.i + 2) == Some(&b'u');
                                if paired {
                                    let save = self.i;
                                    self.i += 2;
                                    let lo = self.u_escape_digits()?;
                                    if (0xDC00..=0xDFFF).contains(&lo) {
                                        let sup =
                                            0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                        char::from_u32(sup).unwrap_or('\u{fffd}')
                                    } else {
                                        // Not a low surrogate: leave it
                                        // to be parsed as its own escape.
                                        self.i = save;
                                        '\u{fffd}'
                                    }
                                } else {
                                    '\u{fffd}'
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{fffd}')
                            };
                            out.push(c);
                        }
                        c => return Err(self.err(&format!("bad escape \\{}", c as char))),
                    }
                    self.i += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Parse the four hex digits of a `\uXXXX` escape: enters with
    /// `self.i` on the `u`, leaves it on the last digit. Exactly four
    /// ASCII hex digits are required (no signs, no shortfall).
    fn u_escape_digits(&mut self) -> Result<u32> {
        if self.i + 4 >= self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let digits = &self.b[self.i + 1..self.i + 5];
        if !digits.iter().all(|d| d.is_ascii_hexdigit()) {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(digits).map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(cp)
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn decodes_surrogate_pairs() {
        // \ud83d\ude00 is the surrogate pair for U+1F600.
        let v = Json::parse(r#""\ud83d\ude00!""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{1f600}!");
        // Lone high surrogate -> replacement character.
        let v = Json::parse(r#""\ud83dx""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{fffd}x");
        // High surrogate followed by a non-surrogate escape: the second
        // escape survives as its own character.
        let v = Json::parse(r#""\ud83d\n""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "\u{fffd}\n");
        // Malformed second escape is still an error.
        assert!(Json::parse(r#""\ud83d\uzzzz""#).is_err());
        assert!(Json::parse(r#""\u+123""#).is_err());
    }

    #[test]
    fn escape_sequences_round_trip() {
        for s in [
            "plain",
            "tab\there\nnewline\rcr",
            "quote\" backslash\\ slash/",
            "control\u{1}\u{1f}",
            "unicode é 漢 😀 \u{fffd}",
            "",
        ] {
            let v = Json::Str(s.to_string());
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v, "{s:?}");
            assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v, "{s:?}");
        }
    }

    #[test]
    fn depth_is_capped() {
        // Within the cap parses fine…
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // …a flood of opening brackets errors instead of overflowing.
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(format!("{err}").contains("nesting too deep"), "{err}");
        let deep_obj = "{\"a\":".repeat(100_000);
        assert!(Json::parse(&deep_obj).is_err());
    }

    #[test]
    fn bool_and_u64_accessors() {
        assert!(Json::parse("true").unwrap().as_bool().unwrap());
        assert!(Json::parse("1").unwrap().as_bool().is_err());
        assert_eq!(Json::parse("7").unwrap().as_u64().unwrap(), 7);
        assert!(Json::parse("-1").unwrap().as_u64().is_err());
        assert!(Json::parse("1.5").unwrap().as_u64().is_err());
        assert!(Json::parse("1e300").unwrap().as_u64().is_err());
    }

    #[test]
    fn usize_accessor_validates() {
        assert_eq!(Json::parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(Json::parse("4.5").unwrap().as_usize().is_err());
        assert!(Json::parse("-1").unwrap().as_usize().is_err());
    }

    #[test]
    fn integer_formatting_stays_integral() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn non_finite_renders_null_and_neg_zero_round_trips() {
        // Never emit invalid JSON, whatever the computation produced.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        // -0.0 must not take the integer fast path ("0" would lose the
        // sign bit and break the bit-exact wire contract).
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert_eq!(back.to_bits(), (-0.0f64).to_bits());
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"version":1,"artifacts":[{"name":"a","file":"a.hlo.txt",
          "m":100,"n":1000,"inputs":[{"name":"x","shape":[100,1000]}]}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("m").unwrap().as_usize().unwrap(), 100);
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize().unwrap(), 1000);
    }
}
