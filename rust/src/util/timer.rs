//! Wall-clock timing helpers used by benches and the coordinator metrics.

use std::time::{Duration, Instant};

/// A simple scope timer.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since [`Timer::start`].
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Human-readable duration (`1.234s`, `56.7ms`, `890µs`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_secs() >= 0.002);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.500s");
        assert_eq!(fmt_duration(0.0123), "12.30ms");
        assert_eq!(fmt_duration(12.3e-6), "12.3µs");
    }
}
