//! Typed retry/backoff policy, shared by every layer that retries.
//!
//! One [`RetryPolicy`] shape flows from config (`[retry]`), the CLI
//! (`--retry-*` flags) and the routing tier down to the call sites that
//! are allowed to retry: transient streamed-source read errors inside a
//! sweep, the client's connect/GET paths, and the router's
//! pre-acceptance failover chain. Sites where a retry could duplicate
//! work (POST resubmission) never consult a policy — at-most-once is a
//! property of the call site, not of the knobs.
//!
//! Backoff is exponential with an optional deterministic jitter:
//! `delay(attempt) = min(base · 2^(attempt−1), max)`, the jitter drawn
//! from a [`SplitMix64`] stream keyed by the caller's seed so chaos
//! runs replay the exact same schedule.

use crate::rng::{Rng, SplitMix64};

/// How many times to try, and how long to wait between tries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first (1 = no retries).
    pub max_attempts: u32,
    /// First backoff delay, milliseconds.
    pub backoff_base_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub backoff_max_ms: u64,
    /// Scale each delay by a deterministic factor in [0.5, 1.0] to
    /// de-synchronize retrying peers.
    pub jitter: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            backoff_base_ms: 10,
            backoff_max_ms: 1_000,
            jitter: true,
        }
    }
}

impl RetryPolicy {
    /// The no-retry policy: one attempt, fail fast. This is the
    /// behavior every call site had before policies existed.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            backoff_base_ms: 0,
            backoff_max_ms: 0,
            jitter: false,
        }
    }

    /// Whether another attempt is allowed after `attempt` tries have
    /// already failed.
    pub fn allows(&self, attempts_so_far: u32) -> bool {
        attempts_so_far < self.max_attempts.max(1)
    }

    /// Backoff before attempt `attempt + 1`, given `attempt` failures
    /// so far (`attempt >= 1`). Deterministic in `(self, attempt, seed)`.
    pub fn backoff_ms(&self, attempt: u32, seed: u64) -> u64 {
        if self.backoff_base_ms == 0 {
            return 0;
        }
        let exp = attempt.saturating_sub(1).min(32);
        let raw = self
            .backoff_base_ms
            .saturating_mul(1u64 << exp)
            .min(self.backoff_max_ms.max(self.backoff_base_ms));
        if !self.jitter {
            return raw;
        }
        // Deterministic jitter in [0.5, 1.0]: keyed by caller seed and
        // attempt so concurrent retriers spread out but replays agree.
        let mut rng = SplitMix64::new(seed ^ ((attempt as u64) << 32));
        let f = 0.5 + ((rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64) * 0.5;
        ((raw as f64) * f) as u64
    }

    /// Sleep for the backoff before attempt `attempt + 1` (no-op when
    /// the computed delay is zero, so zero-base chaos tests never
    /// sleep).
    pub fn sleep_backoff(&self, attempt: u32, seed: u64) {
        let ms = self.backoff_ms(attempt, seed);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let p = RetryPolicy::default();
        assert!(p.max_attempts >= 2);
        assert!(p.backoff_max_ms >= p.backoff_base_ms);
        assert!(p.allows(0));
        assert!(p.allows(p.max_attempts - 1));
        assert!(!p.allows(p.max_attempts));
    }

    #[test]
    fn none_means_one_attempt() {
        let p = RetryPolicy::none();
        assert!(p.allows(0));
        assert!(!p.allows(1));
        assert_eq!(p.backoff_ms(1, 42), 0);
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let p = RetryPolicy {
            max_attempts: 10,
            backoff_base_ms: 10,
            backoff_max_ms: 50,
            jitter: false,
        };
        assert_eq!(p.backoff_ms(1, 0), 10);
        assert_eq!(p.backoff_ms(2, 0), 20);
        assert_eq!(p.backoff_ms(3, 0), 40);
        assert_eq!(p.backoff_ms(4, 0), 50); // capped
        assert_eq!(p.backoff_ms(9, 0), 50);
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        let p = RetryPolicy { jitter: true, ..RetryPolicy::default() };
        let a = p.backoff_ms(2, 7);
        let b = p.backoff_ms(2, 7);
        assert_eq!(a, b, "same (attempt, seed) must replay");
        let raw = RetryPolicy { jitter: false, ..p }.backoff_ms(2, 7);
        assert!(a >= raw / 2 && a <= raw, "jittered {a} outside [{}..{raw}]", raw / 2);
        // Different seeds spread.
        let c = p.backoff_ms(2, 8);
        let d = p.backoff_ms(2, 9);
        assert!(a != c || a != d, "jitter should vary by seed");
    }

    #[test]
    fn zero_base_never_sleeps() {
        let p = RetryPolicy {
            max_attempts: 5,
            backoff_base_ms: 0,
            backoff_max_ms: 0,
            jitter: true,
        };
        assert_eq!(p.backoff_ms(3, 1), 0);
        let t = std::time::Instant::now();
        p.sleep_backoff(3, 1);
        assert!(t.elapsed().as_millis() < 50);
    }
}
