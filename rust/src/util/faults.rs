//! Deterministic fail-point injection — the chaos-testing substrate.
//!
//! Production code marks the I/O boundaries that can fail in the real
//! world with named *fail-points* (`faults::check("stream.read")?`).
//! Disarmed — the default — a fail-point is one relaxed atomic load and
//! a branch, cheap enough for per-block hot paths (the `perf_micro`
//! bench smoke asserts the overhead stays under 1%). Armed, each site
//! consults its policy and may inject an error, a delay, a truncated
//! write, or a crash.
//!
//! ## Arming
//!
//! A *fault spec* is a `;`-separated list of `site=policy` entries plus
//! an optional `seed=N`:
//!
//! ```text
//! SRSVD_FAULTS='seed=7;stream.read=err:2@1.0;cache.body=partial_write:1'
//! ```
//!
//! Policies:
//!
//! * `err[:K][@p]` — fail with an injected `std::io::Error` with
//!   probability `p` (default 1.0), at most `K` times (default
//!   unlimited). The bounded count is what lets chaos tests arm
//!   `p=1.0` on a transient class and still converge: the first `K`
//!   attempts fail, the retry loop's next attempt succeeds.
//! * `delay:Nms[:K][@p]` — sleep `N` milliseconds.
//! * `partial_write[:K][@p]` — the instrumented write path truncates
//!   its buffer (roughly in half), modelling a torn write.
//! * `die_after:N` — the `N`-th evaluation of the site panics with the
//!   marker [`CRASH_MARKER`], modelling a worker crash mid-job. The
//!   coordinator's `catch_unwind` maps it to a failed job; a restarted
//!   run then exercises checkpoint resume.
//!
//! The spec can come from the `SRSVD_FAULTS` env var
//! ([`init_from_env`]), the `[faults] spec` config key, or the
//! `--faults` CLI flag (both via [`arm`]). Randomized policies draw
//! from per-site [`SplitMix64`] streams derived from the spec's seed,
//! so a chaos run is reproducible by seed regardless of thread
//! interleaving.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::rng::{Rng, SplitMix64};
use crate::util::{Error, Result};

/// Panic-message prefix of an injected `die_after` crash. The
/// coordinator's panic isolation recognizes it (and test harnesses
/// assert on it) to tell an injected crash from a genuine bug.
pub const CRASH_MARKER: &str = "srsvd-fault: injected crash";

/// Message prefix of every injected `err` fault, so logs and tests can
/// tell injected failures from real ones.
pub const ERR_MARKER: &str = "srsvd-fault: injected error";

/// The zero-cost fast path: false until [`arm`] installs a policy.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Total faults injected (errors + delays + partial writes + crashes)
/// since process start — surfaced as the `faults_injected` metric.
static INJECTED: AtomicU64 = AtomicU64::new(0);

static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

/// What a policy does when it fires.
#[derive(Debug, Clone, PartialEq)]
enum Action {
    Err,
    DelayMs(u64),
    PartialWrite,
    DieAfter(u64),
}

#[derive(Debug)]
struct SitePolicy {
    action: Action,
    /// Firing probability (1.0 = every eligible evaluation).
    p: f64,
    /// Remaining firings; `None` = unlimited. `die_after` counts
    /// *evaluations* in `evals` instead.
    budget: Option<u64>,
    /// Evaluations seen (drives `die_after:N`).
    evals: u64,
    /// Per-site deterministic stream for the probability draw.
    rng: SplitMix64,
}

#[derive(Debug, Default)]
struct Registry {
    sites: HashMap<String, SitePolicy>,
}

/// What an armed fail-point decided (see [`check`] / [`write_len`]).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Decision {
    Clean,
    Err,
    Delay(u64),
    PartialWrite,
    Die,
}

/// Whether any fault policy is armed — the inlineable fast-path guard.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Total faults injected since process start.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

/// Arm the registry from a fault spec (see the module docs for the
/// grammar). Replaces any previously armed spec. An empty spec
/// disarms.
pub fn arm(spec: &str) -> Result<()> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "off" || spec == "none" {
        disarm();
        return Ok(());
    }
    let mut seed = 0u64;
    let mut entries: Vec<(String, String)> = Vec::new();
    for part in spec.split(';').map(str::trim).filter(|s| !s.is_empty()) {
        let Some((key, value)) = part.split_once('=') else {
            return Err(Error::Invalid(format!(
                "fault spec entry {part:?}: expected site=policy"
            )));
        };
        let (key, value) = (key.trim(), value.trim());
        if key == "seed" {
            seed = value
                .parse()
                .map_err(|_| Error::Invalid(format!("fault spec seed: not a u64: {value:?}")))?;
        } else {
            entries.push((key.to_string(), value.to_string()));
        }
    }
    let mut registry = Registry::default();
    for (site, policy) in entries {
        let parsed = parse_policy(&policy, seed, &site)?;
        registry.sites.insert(site, parsed);
    }
    let any = !registry.sites.is_empty();
    *REGISTRY.lock().expect("fault registry mutex") = any.then_some(registry);
    ARMED.store(any, Ordering::SeqCst);
    if any {
        crate::log_info!("faults: armed ({spec})");
    }
    Ok(())
}

/// Disarm every fail-point (back to the zero-cost path).
pub fn disarm() {
    ARMED.store(false, Ordering::SeqCst);
    *REGISTRY.lock().expect("fault registry mutex") = None;
}

/// Arm from the `SRSVD_FAULTS` env var if it is set. Called by the
/// service entry points; an invalid spec is a hard error there (a chaos
/// run with a typo'd spec silently testing nothing is worse than a
/// refusal to start).
pub fn init_from_env() -> Result<()> {
    match std::env::var("SRSVD_FAULTS") {
        Ok(spec) => arm(&spec),
        Err(_) => Ok(()),
    }
}

/// `policy[:K][@p]` → [`SitePolicy`]. The per-site RNG stream is
/// derived from the spec seed and the site name so two sites armed
/// with the same `p` do not fire in lockstep.
fn parse_policy(text: &str, seed: u64, site: &str) -> Result<SitePolicy> {
    let bad = |why: &str| Error::Invalid(format!("fault policy {text:?} for {site:?}: {why}"));
    let (body, p) = match text.rsplit_once('@') {
        Some((body, p)) => {
            let p: f64 = p.parse().map_err(|_| bad("bad probability"))?;
            if !(0.0..=1.0).contains(&p) {
                return Err(bad("probability must be in [0, 1]"));
            }
            (body, p)
        }
        None => (text, 1.0),
    };
    let mut parts = body.split(':');
    let name = parts.next().unwrap_or("");
    let (action, budget) = match name {
        "err" => {
            let budget = match parts.next() {
                None => None,
                Some(k) => Some(k.parse::<u64>().map_err(|_| bad("bad count"))?),
            };
            (Action::Err, budget)
        }
        "delay" => {
            let ms = parts
                .next()
                .and_then(|s| s.strip_suffix("ms"))
                .ok_or_else(|| bad("expected delay:Nms"))?
                .parse::<u64>()
                .map_err(|_| bad("bad delay"))?;
            let budget = match parts.next() {
                None => None,
                Some(k) => Some(k.parse::<u64>().map_err(|_| bad("bad count"))?),
            };
            (Action::DelayMs(ms), budget)
        }
        "partial_write" => {
            let budget = match parts.next() {
                None => None,
                Some(k) => Some(k.parse::<u64>().map_err(|_| bad("bad count"))?),
            };
            (Action::PartialWrite, budget)
        }
        "die_after" => {
            let n = parts
                .next()
                .ok_or_else(|| bad("expected die_after:N"))?
                .parse::<u64>()
                .map_err(|_| bad("bad count"))?;
            if n == 0 {
                return Err(bad("die_after count must be >= 1"));
            }
            (Action::DieAfter(n), None)
        }
        other => return Err(bad(&format!("unknown action {other:?}"))),
    };
    if parts.next().is_some() {
        return Err(bad("trailing policy fields"));
    }
    // Site-keyed substream: fold the site bytes into the seed.
    let mut h = seed ^ 0x5EED_FA17;
    for &b in site.as_bytes() {
        h = h.wrapping_mul(0x100_0000_01B3).wrapping_add(b as u64);
    }
    Ok(SitePolicy { action, p, budget, evals: 0, rng: SplitMix64::new(h) })
}

/// Evaluate `site` against the armed registry.
fn decide(site: &str) -> Decision {
    let mut guard = REGISTRY.lock().expect("fault registry mutex");
    let Some(registry) = guard.as_mut() else {
        return Decision::Clean;
    };
    let Some(policy) = registry.sites.get_mut(site) else {
        return Decision::Clean;
    };
    policy.evals += 1;
    if let Action::DieAfter(n) = policy.action {
        if policy.evals == n {
            INJECTED.fetch_add(1, Ordering::Relaxed);
            return Decision::Die;
        }
        return Decision::Clean;
    }
    if policy.budget == Some(0) {
        return Decision::Clean;
    }
    if policy.p < 1.0 {
        // Uniform in [0, 1) from the site's deterministic stream.
        let draw = (policy.rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        if draw >= policy.p {
            return Decision::Clean;
        }
    }
    if let Some(b) = policy.budget.as_mut() {
        *b -= 1;
    }
    INJECTED.fetch_add(1, Ordering::Relaxed);
    match policy.action {
        Action::Err => Decision::Err,
        Action::DelayMs(ms) => Decision::Delay(ms),
        Action::PartialWrite => Decision::PartialWrite,
        Action::DieAfter(_) => unreachable!("handled above"),
    }
}

/// The standard fail-point: no-op when disarmed; armed, it may inject
/// a delay, an `std::io::Error` (kind `Other`, message prefixed with
/// [`ERR_MARKER`]), or a [`CRASH_MARKER`] panic.
#[inline]
pub fn check(site: &str) -> std::io::Result<()> {
    if !armed() {
        return Ok(());
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &str) -> std::io::Result<()> {
    match decide(site) {
        Decision::Clean | Decision::PartialWrite => Ok(()),
        Decision::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(())
        }
        Decision::Err => Err(std::io::Error::new(std::io::ErrorKind::Other, format!("{ERR_MARKER} at {site}"))),
        Decision::Die => panic!("{CRASH_MARKER} at {site}"),
    }
}

/// Fail-point for write paths that support torn writes: returns how
/// many of `len` bytes the caller should actually write. Disarmed (or
/// clean) that is `len`; a `partial_write` firing truncates to half;
/// `err`/`delay`/`die_after` behave as in [`check`].
#[inline]
pub fn write_len(site: &str, len: usize) -> std::io::Result<usize> {
    if !armed() {
        return Ok(len);
    }
    match decide(site) {
        Decision::Clean => Ok(len),
        Decision::PartialWrite => Ok(len / 2),
        Decision::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            Ok(len)
        }
        Decision::Err => Err(std::io::Error::new(std::io::ErrorKind::Other, format!("{ERR_MARKER} at {site}"))),
        Decision::Die => panic!("{CRASH_MARKER} at {site}"),
    }
}

/// Whether an I/O error is an injected fault (useful for transient
/// classification: injected errors model transient faults).
pub fn is_injected(e: &std::io::Error) -> bool {
    e.to_string().contains(ERR_MARKER)
}

/// Serializes in-crate tests that arm the process-global registry (lib
/// tests share one process and run on parallel threads). Every test
/// that calls [`arm`] must hold this guard for its whole body.
#[cfg(test)]
pub(crate) fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global, so tests that arm it must not
    /// interleave — the crate-wide [`test_lock`] serializes them.
    fn locked() -> std::sync::MutexGuard<'static, ()> {
        test_lock()
    }

    #[test]
    fn disarmed_is_clean_and_cheap() {
        let _g = locked();
        disarm();
        assert!(!armed());
        assert!(check("stream.read").is_ok());
        assert_eq!(write_len("stream.write", 100).unwrap(), 100);
    }

    #[test]
    fn bounded_err_budget_fires_then_clears() {
        let _g = locked();
        arm("seed=1;x.read=err:2@1.0").unwrap();
        assert!(check("x.read").is_err());
        assert!(check("x.read").is_err());
        assert!(check("x.read").is_ok()); // budget exhausted
        assert!(check("unrelated.site").is_ok());
        disarm();
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let _g = locked();
        let run = |seed: u64| -> Vec<bool> {
            arm(&format!("seed={seed};y.read=err@0.5")).unwrap();
            (0..32).map(|_| check("y.read").is_err()).collect()
        };
        let a = run(9);
        let b = run(9);
        let c = run(10);
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert_ne!(a, c, "different seeds should differ (vanishingly unlikely otherwise)");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f), "p=0.5 mixes outcomes");
        disarm();
    }

    #[test]
    fn partial_write_truncates() {
        let _g = locked();
        arm("w.out=partial_write:1@1.0").unwrap();
        assert_eq!(write_len("w.out", 100).unwrap(), 50);
        assert_eq!(write_len("w.out", 100).unwrap(), 100);
        disarm();
    }

    #[test]
    fn die_after_panics_on_the_nth_evaluation() {
        let _g = locked();
        arm("z.sweep=die_after:3").unwrap();
        assert!(check("z.sweep").is_ok());
        assert!(check("z.sweep").is_ok());
        let crash = std::panic::catch_unwind(|| check("z.sweep"));
        let payload = crash.expect_err("third evaluation must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains(CRASH_MARKER), "{msg}");
        assert!(check("z.sweep").is_ok(), "after the crash the site is clean");
        disarm();
    }

    #[test]
    fn injected_errors_are_recognizable() {
        let _g = locked();
        arm("q.read=err:1").unwrap();
        let e = check("q.read").unwrap_err();
        assert!(is_injected(&e), "{e}");
        assert!(!is_injected(&std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")));
        disarm();
    }

    #[test]
    fn bad_specs_are_rejected() {
        let _g = locked();
        assert!(arm("nonsense").is_err());
        assert!(arm("a.b=explode").is_err());
        assert!(arm("a.b=err@2.0").is_err());
        assert!(arm("a.b=die_after:0").is_err());
        assert!(arm("a.b=delay:5").is_err());
        assert!(arm("seed=x;a.b=err").is_err());
        assert!(!armed(), "a rejected spec must not leave faults armed");
        // And the disarm spellings.
        arm("a.b=err:1").unwrap();
        assert!(armed());
        arm("off").unwrap();
        assert!(!armed());
    }
}
