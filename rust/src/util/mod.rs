//! Small shared utilities: errors, timing, logging, JSON, fault
//! injection, retry policies.

pub mod faults;
pub mod json;
pub mod logging;
pub mod retry;
pub mod timer;

/// Library-wide error type.
///
/// Display/Error are hand-written: the crate builds with zero external
/// dependencies (no `thiserror` in the offline environment).
#[derive(Debug)]
pub enum Error {
    /// Matrix/vector dimensions do not line up.
    Shape(String),
    /// Invalid argument or configuration.
    Invalid(String),
    /// A numerical procedure failed (non-convergence, singularity).
    Numerical(String),
    /// A compiled AOT artifact is missing or malformed.
    Artifact(String),
    /// The PJRT runtime failed (or is unavailable in this build).
    Runtime(String),
    /// Coordinator/service failure (queues, workers).
    Service(String),
    /// The service's bounded queue is full (`try_submit` admission
    /// control); retry later. The network layer maps this to HTTP 503.
    Busy(String),
    /// A bounded wait expired before the job completed (the job keeps
    /// running). The network layer maps this to HTTP 202 "running".
    Timeout(String),
    /// The job was cancelled (`DELETE /v1/jobs/{id}` or eviction)
    /// before or while executing; cooperative checkpoints between
    /// sweeps/blocks abandon the work. Surfaces as the job's failed
    /// outcome.
    Cancelled(String),
    /// The addressed resource does not exist. The network layer maps
    /// HTTP 404 here so callers (the routing tier in particular) can
    /// tell "unknown id" apart from a transport failure.
    NotFound(String),
    /// An underlying IO failure.
    Io(std::io::Error),
    /// JSON parsing or schema mismatch.
    Json(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Invalid(m) => write!(f, "invalid argument: {m}"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Busy(m) => write!(f, "service busy (backpressure): {m}"),
            Error::Timeout(m) => write!(f, "timed out: {m}"),
            Error::Cancelled(m) => write!(f, "cancelled: {m}"),
            Error::NotFound(m) => write!(f, "not found: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

/// Library-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// `assert!`-style helper returning [`Error::Shape`].
#[macro_export]
macro_rules! ensure_shape {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err($crate::util::Error::Shape(format!($($fmt)*)));
        }
    };
}

/// `assert!`-style helper returning [`Error::Invalid`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err($crate::util::Error::Invalid(format!($($fmt)*)));
        }
    };
}
