//! Small shared utilities: errors, timing, logging, JSON.

pub mod json;
pub mod logging;
pub mod timer;

use thiserror::Error;

/// Library-wide error type.
#[derive(Debug, Error)]
pub enum Error {
    #[error("shape mismatch: {0}")]
    Shape(String),
    #[error("invalid argument: {0}")]
    Invalid(String),
    #[error("numerical failure: {0}")]
    Numerical(String),
    #[error("artifact error: {0}")]
    Artifact(String),
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("service error: {0}")]
    Service(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
}

pub type Result<T> = std::result::Result<T, Error>;

/// `assert!`-style helper returning [`Error::Shape`].
#[macro_export]
macro_rules! ensure_shape {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err($crate::util::Error::Shape(format!($($fmt)*)));
        }
    };
}

/// `assert!`-style helper returning [`Error::Invalid`].
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err($crate::util::Error::Invalid(format!($($fmt)*)));
        }
    };
}
