//! `srsvd` — the command-line front end.
//!
//! ```text
//! srsvd factorize --dist uniform --m 100 --n 1000 --k 10 ...   one-shot PCA
//!                 [--stream --stream-budget-mb 16]              out-of-core input
//! srsvd serve     --listen 127.0.0.1:7878 ...                  run the HTTP service
//! srsvd serve     --jobs 32 --workers 2 ...                    synthetic in-process demo
//! srsvd route     --listen 127.0.0.1:7979 --replicas a,b ...   shard over serve replicas
//! srsvd experiment --id fig1a ...                              regenerate a paper artifact
//! srsvd artifacts [--dir artifacts]                            inspect the AOT manifest
//! ```

use srsvd::cli::ArgSpec;
use srsvd::config::{
    parse_basis, parse_pass_policy, parse_precision, parse_small_svd, stop_criterion, RawConfig,
};
use srsvd::coordinator::{
    Coordinator, CoordinatorConfig, EnginePreference, JobSpec, MatrixInput, ShiftSpec,
};
use srsvd::data::{random_matrix, DataSpec, Distribution};
use srsvd::experiments::{fig1, k_grid, table1};
use srsvd::linalg::{Dense, GeneratorSource, StreamConfig};
use srsvd::rng::Xoshiro256pp;
use srsvd::router::Router;
use srsvd::runtime::Manifest;
use srsvd::server::Server;
use srsvd::svd::SvdConfig;
use srsvd::util::Result;

fn main() {
    srsvd::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_root_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "factorize" => cmd_factorize(rest),
        "serve" => cmd_serve(rest),
        "route" => cmd_route(rest),
        "experiment" => cmd_experiment(rest),
        "artifacts" => cmd_artifacts(rest),
        "--help" | "-h" | "help" => {
            print_root_help();
            Ok(())
        }
        other => {
            print_root_help();
            Err(srsvd::util::Error::Invalid(format!("unknown command {other:?}")))
        }
    }
}

fn print_root_help() {
    println!(
        "srsvd — Shifted Randomized SVD (Basirat 2019) reproduction\n\n\
         COMMANDS:\n\
         \x20 factorize   one-shot PCA of a generated matrix\n\
         \x20 serve       run the factorization service: --listen ADDR for the\n\
         \x20             HTTP server, or a synthetic in-process job stream\n\
         \x20 route       run the routing tier: shard jobs over several serve\n\
         \x20             replicas with health checks and failover\n\
         \x20 experiment  regenerate a paper figure/table\n\
         \x20             (fig1a..fig1f, table1-images, table1-words)\n\
         \x20 artifacts   list the compiled AOT artifacts\n\n\
         Run `srsvd <command> --help` for options."
    );
}

/// Declare the resilience options shared by `factorize`, `serve`, and
/// `route`: the fail-point plan and the typed retry/backoff overrides.
fn resilience_opts(spec: ArgSpec) -> ArgSpec {
    spec.opt(
        "faults",
        "",
        "fail-point plan, e.g. stream.read=err:2@0.5;svd.sweep=die_after:3 \
         (SRSVD_FAULTS env wins; off|none disarms)",
    )
    .opt("retry-max-attempts", "0", "total tries per idempotent op (0 = config/default)")
    .opt("retry-backoff-base-ms", "0", "first retry backoff, ms (0 = config/default)")
    .opt("retry-backoff-max-ms", "0", "retry backoff ceiling, ms (0 = config/default)")
}

/// Arm fail-points with the documented precedence: the `--faults` flag
/// beats `[faults] spec`, and `SRSVD_FAULTS` (applied last, also
/// re-applied at service bind) beats both — a chaos run can override
/// any deployment without editing it.
fn arm_faults(a: &srsvd::cli::Args, raw: &RawConfig) -> Result<()> {
    match (a.get("faults"), raw.faults_spec()) {
        ("", None) => {}
        ("", Some(spec)) => srsvd::util::faults::arm(spec)?,
        (flag, _) => srsvd::util::faults::arm(flag)?,
    }
    srsvd::util::faults::init_from_env()
}

/// Layer the `--retry-*` CLI overrides onto a config-derived policy.
fn apply_retry_flags(
    a: &srsvd::cli::Args,
    p: &mut srsvd::util::retry::RetryPolicy,
) -> Result<()> {
    if a.get_usize("retry-max-attempts")? > 0 {
        p.max_attempts = a.get_usize("retry-max-attempts")? as u32;
    }
    if a.get_u64("retry-backoff-base-ms")? > 0 {
        p.backoff_base_ms = a.get_u64("retry-backoff-base-ms")?;
    }
    if a.get_u64("retry-backoff-max-ms")? > 0 {
        p.backoff_max_ms = a.get_u64("retry-backoff-max-ms")?;
    }
    Ok(())
}

fn svd_config_from(a: &srsvd::cli::Args) -> Result<SvdConfig> {
    // All three stopping flags funnel through the shared conversion
    // point: empty/zero flags mean "unset" so the defaults and the
    // mutual-exclusion rules live in `stop_criterion`, not here.
    let q = a.get_usize("q")?;
    let pve_tol = match a.get("pve-tol") {
        "" => None,
        s => Some(s.parse::<f64>().map_err(|_| {
            srsvd::util::Error::Invalid(format!("--pve-tol: not a number: {s:?}"))
        })?),
    };
    let max_sweeps = a.get_usize("max-sweeps")?;
    let stop = stop_criterion(
        (q > 0).then_some(q),
        pve_tol,
        (max_sweeps > 0).then_some(max_sweeps),
    )?;
    Ok(SvdConfig {
        k: a.get_usize("k")?,
        oversample: a.get_usize("oversample")?,
        stop,
        basis: parse_basis(a.get("basis"))?,
        small_svd: parse_small_svd(a.get("small-svd"))?,
        pass_policy: parse_pass_policy(a.get("pass-policy"))?,
        precision: parse_precision(a.get("precision"))?,
    })
}

fn cmd_factorize(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("One-shot PCA of a generated random matrix")
        .opt("dist", "uniform", "uniform | normal | exponential | zipf")
        .opt("m", "100", "rows (features)")
        .opt("n", "1000", "columns (samples)")
        .opt("k", "10", "target rank")
        .opt("oversample", "10", "K = k + oversample (paper: oversample = k)")
        .opt("q", "0", "fixed power iterations (exclusive with --pve-tol)")
        .opt(
            "pve-tol",
            "",
            "dashSVD accuracy control: stop sweeping when the PVE estimates \
             move less than this (e.g. 1e-3); exclusive with --q",
        )
        .opt("max-sweeps", "0", "adaptive sweep ceiling (0 = default 32; needs --pve-tol)")
        .opt("basis", "direct", "direct | qr-update-paper | qr-update-exact")
        .opt("small-svd", "jacobi", "jacobi | gram")
        .opt(
            "pass-policy",
            "exact",
            "source-pass schedule: exact (2+2q passes, byte-identical to \
             dense) | fused (<= q+2 passes)",
        )
        .opt(
            "precision",
            "exact",
            "GEMM kernel tier: exact (byte-identical results everywhere) | \
             fast (packed AVX2/FMA, last-ulps differences)",
        )
        .opt("seed", "0", "rng seed")
        .opt("engine", "auto", "auto | native | artifact")
        .opt("threads", "0", "linalg pool threads (0 = auto / SRSVD_THREADS)")
        .flag("stream", "generate row blocks on demand (out-of-core; not zipf)")
        .opt("stream-block", "0", "streamed block rows (0 = derive from budget)")
        .opt("stream-budget-mb", "64", "streamed resident-block budget, MiB")
        .flag("no-prefetch", "disable the double-buffered streamed block prefetch")
        .opt("checkpoint-dir", "", "spill per-sweep checkpoints here for crash-safe resume");
    let spec = resilience_opts(spec);
    let a = spec.parse(args)?;
    if a.help {
        print!("{}", spec.usage("srsvd factorize"));
        return Ok(());
    }
    arm_faults(&a, &RawConfig::default())?;
    let dist = Distribution::parse(a.get("dist"))
        .ok_or_else(|| srsvd::util::Error::Invalid(format!("unknown dist {:?}", a.get("dist"))))?;
    let (m, n) = (a.get_usize("m")?, a.get_usize("n")?);
    let seed = a.get_u64("seed")?;
    let engine = match a.get("engine") {
        "auto" => EnginePreference::Auto,
        "native" => EnginePreference::Native,
        "artifact" => EnginePreference::ArtifactOnly,
        e => return Err(srsvd::util::Error::Invalid(format!("unknown engine {e:?}"))),
    };
    let input = if a.has_flag("stream") {
        // Out-of-core: the matrix is generated row-block-wise and never
        // resident. (A different deterministic matrix than the dense
        // path below — GeneratorSource draws per-row seeds.)
        let stream_cfg = StreamConfig {
            block_rows: a.get_usize("stream-block")?,
            budget_mb: a.get_usize("stream-budget-mb")?.max(1),
            prefetch: !a.has_flag("no-prefetch"),
        };
        let src = GeneratorSource::new(m, n, dist, seed)?;
        println!(
            "streaming {}x{} {} matrix: block_rows={} prefetch={} pass_policy={} \
             (dense would be {:.1} MiB)",
            m,
            n,
            dist.name(),
            stream_cfg.resolve_block_rows(m, n),
            stream_cfg.prefetch,
            a.get("pass-policy"),
            (m * n * 8) as f64 / (1 << 20) as f64
        );
        MatrixInput::streamed(src, &stream_cfg)
    } else {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        MatrixInput::Dense(random_matrix(DataSpec { m, n, dist }, &mut rng))
    };
    let job = JobSpec {
        input,
        config: svd_config_from(&a)?,
        shift: ShiftSpec::MeanCenter,
        engine,
        seed: seed ^ 0xFA,
        score: true,
    };
    let mut svc = CoordinatorConfig::default();
    if a.get_usize("threads")? > 0 {
        svc.pool_threads = Some(a.get_usize("threads")?);
    }
    if !a.get("checkpoint-dir").is_empty() {
        svc.checkpoint_dir = Some(std::path::PathBuf::from(a.get("checkpoint-dir")));
    }
    apply_retry_flags(&a, &mut svc.retry)?;
    let coord = Coordinator::start(svc)?;
    let r = coord.submit_blocking(job)?;
    let out = r.outcome?;
    println!(
        "engine={:?} exec={} queue={}",
        r.engine,
        srsvd::util::timer::fmt_duration(r.exec_s),
        srsvd::util::timer::fmt_duration(r.queue_s)
    );
    println!("mse = {:.6}", out.mse.unwrap_or(f64::NAN));
    println!("singular values: {:?}", &out.factorization.s);
    coord.shutdown();
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "Run the factorization service: an HTTP server (--listen) or a \
         synthetic in-process job stream (default)",
    )
    .opt("listen", "", "bind the HTTP server on host:port (empty = demo mode)")
    .opt("http-workers", "0", "HTTP connection workers (0 = config/default)")
    .opt("max-body-mb", "0", "request body cap, MiB (0 = config/default)")
    .opt("request-timeout-s", "0", "per-request timeout, seconds (0 = config/default)")
    .opt("result-ttl-s", "0", "unclaimed-result lifetime, seconds (0 = config/default)")
    .opt("cache-dir", "", "persist the result cache here (off|none = memory-only)")
    .opt("cache-entries", "0", "result-cache capacity (0 = config/default)")
    .opt("jobs", "32", "demo mode: number of jobs to submit")
    .opt("workers", "0", "native workers (0 = auto)")
    .opt("queue", "64", "queue capacity")
    .opt("threads", "0", "linalg pool threads (0 = auto / SRSVD_THREADS)")
    .opt("io-threads", "0", "blocking-io pool threads (0 = config / SRSVD_IO_THREADS)")
    .opt("config", "", "optional srsvd.conf path")
    .opt("seed", "0", "rng seed")
    .flag("native-only", "disable the artifact engine")
    .opt("checkpoint-dir", "", "spill per-sweep checkpoints here for crash-safe resume")
    .opt(
        "journal-dir",
        "",
        "journal accepted-but-unfinished job specs here (defaults to \
         <checkpoint-dir>/journal when a checkpoint dir is set; off|none disables)",
    );
    let spec = resilience_opts(spec);
    let a = spec.parse(args)?;
    if a.help {
        print!("{}", spec.usage("srsvd serve"));
        return Ok(());
    }
    let raw = if a.get("config").is_empty() {
        RawConfig::default()
    } else {
        RawConfig::load(std::path::Path::new(a.get("config")))?
    };
    arm_faults(&a, &raw)?;
    // `[parallel] simd` is a process-wide override (like SRSVD_SIMD):
    // apply it before any kernel dispatch happens.
    if let Some(on) = raw.parallel_simd()? {
        srsvd::linalg::gemm::kernels::set_simd_enabled(on);
    }
    let mut cfg = raw.coordinator()?;
    if a.get_usize("workers")? > 0 {
        cfg.native_workers = a.get_usize("workers")?;
    }
    if a.get_usize("threads")? > 0 {
        cfg.pool_threads = Some(a.get_usize("threads")?);
    }
    if a.get_usize("io-threads")? > 0 {
        cfg.io_threads = Some(a.get_usize("io-threads")?);
    }
    cfg.queue_capacity = a.get_usize("queue")?;
    if a.has_flag("native-only") {
        cfg.artifact_dir = None;
    }
    if !a.get("checkpoint-dir").is_empty() {
        cfg.checkpoint_dir = Some(std::path::PathBuf::from(a.get("checkpoint-dir")));
    }
    apply_retry_flags(&a, &mut cfg.retry)?;

    if !a.get("listen").is_empty() {
        return serve_http(&a, raw, cfg);
    }

    let jobs = a.get_usize("jobs")?;
    let seed = a.get_u64("seed")?;

    let coord = Coordinator::start(cfg)?;
    let t = srsvd::util::timer::Timer::start();
    let mut handles = Vec::new();
    for j in 0..jobs {
        // Alternate artifact-shaped and native-shaped jobs.
        let (m, n, k) = if j % 2 == 0 { (100, 1000, 10) } else { (64, 512, 8) };
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ j as u64);
        let x = random_matrix(DataSpec { m, n, dist: Distribution::Uniform }, &mut rng);
        handles.push(coord.submit(JobSpec::pca(MatrixInput::Dense(x), k, seed ^ j as u64))?);
    }
    for h in handles {
        let r = h.wait()?;
        r.outcome?;
    }
    let wall = t.elapsed_secs();
    let m = coord.metrics();
    println!("{m}");
    println!(
        "wall={:.2}s throughput={:.1} jobs/s",
        wall,
        jobs as f64 / wall
    );
    coord.shutdown();
    Ok(())
}

/// `serve --listen`: the real HTTP service in front of a coordinator.
/// Runs until the process is killed.
fn serve_http(a: &srsvd::cli::Args, raw: RawConfig, cfg: CoordinatorConfig) -> Result<()> {
    let mut scfg = raw.server()?;
    scfg.addr = a.get("listen").to_string();
    if a.get_usize("http-workers")? > 0 {
        scfg.workers = a.get_usize("http-workers")?;
    }
    if a.get_usize("max-body-mb")? > 0 {
        scfg.max_body_bytes = a.get_usize("max-body-mb")? << 20;
    }
    if a.get_usize("request-timeout-s")? > 0 {
        scfg.request_timeout_s = a.get_usize("request-timeout-s")? as u64;
    }
    if a.get_usize("result-ttl-s")? > 0 {
        scfg.result_ttl_s = a.get_usize("result-ttl-s")? as u64;
    }
    match a.get("cache-dir") {
        "" => {}
        "off" | "none" => scfg.cache_dir = None,
        dir => scfg.cache_dir = Some(std::path::PathBuf::from(dir)),
    }
    if a.get_usize("cache-entries")? > 0 {
        scfg.cache_entries = a.get_usize("cache-entries")?;
    }
    match a.get("journal-dir") {
        "" => {}
        "off" | "none" => scfg.journal_dir = None,
        dir => scfg.journal_dir = Some(std::path::PathBuf::from(dir)),
    }
    // A deployment that checkpoints sweeps almost certainly wants its
    // accepted-job journal too: default it next to the checkpoints.
    if scfg.journal_dir.is_none() && a.get("journal-dir").is_empty() {
        if let Some(ckpt) = &cfg.checkpoint_dir {
            scfg.journal_dir = Some(ckpt.join("journal"));
        }
    }
    let stream_defaults = raw.stream()?;
    let coord = std::sync::Arc::new(Coordinator::start(cfg)?);
    let server = Server::bind(coord, &scfg, stream_defaults)?;
    println!("srsvd service listening on http://{}", server.local_addr());
    println!("  POST /v1/jobs        submit a job spec (dense | csr | generator | file)");
    println!("  GET  /v1/jobs/{{id}}   block for a submitted job's result");
    println!("  DEL  /v1/jobs/{{id}}   cancel a pending or running job");
    println!("  GET  /metrics        service counters as JSON");
    println!("  GET  /healthz        liveness probe");
    println!("  GET  /readyz         readiness probe (503 while the queue is full)");
    server.join();
    Ok(())
}

/// `srsvd route`: the sharding reverse proxy in front of several
/// `srsvd serve --listen` replicas. Runs until the process is killed.
fn cmd_route(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new(
        "Run the routing tier: shard job submissions over several serve \
         replicas by spec hash, with health checks and failover",
    )
    .opt("listen", "", "bind the router on host:port (empty = config/default)")
    .opt(
        "replicas",
        "",
        "comma-separated replica addresses, e.g. 127.0.0.1:7878,127.0.0.1:7879 \
         (empty = config)",
    )
    .opt("workers", "0", "router connection workers (0 = config/default)")
    .opt("max-body-mb", "0", "request body cap, MiB (0 = config/default)")
    .opt("request-timeout-s", "0", "per-request timeout, seconds (0 = config/default)")
    .opt("connect-timeout-ms", "0", "back-end connect bound, ms (0 = config/default)")
    .opt("probe-interval-ms", "0", "health-probe period, ms (0 = config/default)")
    .opt("probe-timeout-ms", "0", "health-probe io bound, ms (0 = config/default)")
    .opt("unhealthy-after", "0", "consecutive probe failures before mark-down (0 = config)")
    .opt("config", "", "optional srsvd.conf path");
    let spec = resilience_opts(spec);
    let a = spec.parse(args)?;
    if a.help {
        print!("{}", spec.usage("srsvd route"));
        return Ok(());
    }
    let raw = if a.get("config").is_empty() {
        RawConfig::default()
    } else {
        RawConfig::load(std::path::Path::new(a.get("config")))?
    };
    arm_faults(&a, &raw)?;
    let mut cfg = raw.router()?;
    if !a.get("listen").is_empty() {
        cfg.listen = a.get("listen").to_string();
    }
    if !a.get("replicas").is_empty() {
        cfg.replicas = srsvd::config::split_addr_list(a.get("replicas"));
    }
    if a.get_usize("workers")? > 0 {
        cfg.workers = a.get_usize("workers")?;
    }
    if a.get_usize("max-body-mb")? > 0 {
        cfg.max_body_bytes = a.get_usize("max-body-mb")? << 20;
    }
    if a.get_usize("request-timeout-s")? > 0 {
        cfg.request_timeout_s = a.get_usize("request-timeout-s")? as u64;
    }
    if a.get_usize("connect-timeout-ms")? > 0 {
        cfg.connect_timeout_ms = a.get_usize("connect-timeout-ms")? as u64;
    }
    if a.get_usize("probe-interval-ms")? > 0 {
        cfg.probe_interval_ms = a.get_usize("probe-interval-ms")? as u64;
    }
    if a.get_usize("probe-timeout-ms")? > 0 {
        cfg.probe_timeout_ms = a.get_usize("probe-timeout-ms")? as u64;
    }
    if a.get_usize("unhealthy-after")? > 0 {
        cfg.unhealthy_after = a.get_usize("unhealthy-after")? as u32;
    }
    apply_retry_flags(&a, &mut cfg.retry)?;
    let router = Router::bind(&cfg, raw.stream()?)?;
    println!("srsvd router listening on http://{}", router.local_addr());
    println!("  replicas: {}", cfg.replicas.join(", "));
    println!("  POST /v1/jobs        submit — sharded by spec hash, failover on dead replicas");
    println!("  GET  /v1/jobs/{{id}}   block for a routed job's result");
    println!("  DEL  /v1/jobs/{{id}}   cancel a routed job");
    println!("  GET  /metrics        router counters + per-replica snapshots");
    println!("  GET  /healthz        router liveness probe");
    println!("  GET  /readyz         503 until at least one replica is healthy");
    router.join();
    Ok(())
}

fn cmd_experiment(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("Regenerate a paper figure/table")
        .req(
            "id",
            "fig1a | fig1b | fig1c | fig1d | fig1e | fig1f | table1-images | \
             table1-words | efficiency",
        )
        .opt("seed", "42", "rng seed")
        .opt("runs", "10", "repetitions for table1 statistics")
        .flag("quick", "thin the sweep grids (~8x faster)");
    let a = spec.parse(args)?;
    if a.help {
        print!("{}", spec.usage("srsvd experiment"));
        return Ok(());
    }
    let seed = a.get_u64("seed")?;
    let quick = a.has_flag("quick") || srsvd::experiments::quick_mode();
    let ks = k_grid(100, quick);
    let runs = a.get_usize("runs")?;
    match a.get("id") {
        "fig1a" => {
            let rows = fig1::fig1a(&ks, seed);
            print!("{}", fig1::render_k_table("Fig 1a: MSE vs #components", &rows));
        }
        "fig1b" => {
            let ns: &[usize] = if quick {
                &[200, 1000, 5000]
            } else {
                &[100, 200, 500, 1000, 2000, 5000, 10000]
            };
            let mut t = srsvd::bench::Table::new(&["n", "MSE-SUM S-RSVD", "MSE-SUM RSVD"]);
            for (n, s, r) in fig1::fig1b(ns, &ks, seed) {
                t.row(&[n.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
            }
            print!("{}", t.render());
        }
        "fig1c" => {
            let mut t =
                srsvd::bench::Table::new(&["distribution", "MSE-SUM S-RSVD", "MSE-SUM RSVD"]);
            for (d, s, r) in fig1::fig1c(&ks, seed) {
                t.row(&[d.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
            }
            print!("{}", t.render());
        }
        "fig1d" => {
            let rows = fig1::fig1d(&ks, seed);
            let mut t =
                srsvd::bench::Table::new(&["k", "implicit (S-RSVD)", "explicit (RSVD on Xbar)"]);
            for (k, i, e) in rows {
                t.row(&[k.to_string(), format!("{i:.5}"), format!("{e:.5}")]);
            }
            print!("{}", t.render());
        }
        "fig1e" => {
            let qs: &[usize] = if quick { &[0, 1, 2, 4] } else { &[0, 1, 2, 3, 4, 6, 8] };
            let mut t = srsvd::bench::Table::new(&["q", "MSE-SUM S-RSVD", "MSE-SUM RSVD"]);
            for (q, s, r) in fig1::fig1e(qs, &ks, seed) {
                t.row(&[q.to_string(), format!("{s:.3}"), format!("{r:.3}")]);
            }
            print!("{}", t.render());
        }
        "fig1f" => {
            let qs: &[usize] = if quick { &[0, 1, 2, 4] } else { &[0, 1, 2, 4, 8, 16] };
            for (dist, series) in fig1::fig1f(qs, &ks, seed) {
                println!("{dist}:");
                for (q, d) in series {
                    println!("  q={q:<3} MSE-SUM(S-RSVD) - MSE-SUM(RSVD) = {d:.4}");
                }
            }
        }
        "table1-images" => {
            let digits = table1::digits_stats(if quick { 400 } else { 1979 }, runs, seed);
            let faces = table1::faces_stats(
                if quick {
                    srsvd::data::FacesSpec { side: 16, count: 120, rank: 12, noise: 5.0 }
                } else {
                    srsvd::data::FacesSpec::default()
                },
                runs,
                seed,
            );
            print!("{}", table1::render(&[digits, faces]));
        }
        "table1-words" => {
            let ns: &[usize] =
                if quick { &[1000, 4000] } else { &[1000, 10_000, 100_000, 300_000] };
            let stats: Vec<_> = ns
                .iter()
                .map(|&n| {
                    table1::words_stats(n, (n * 50).min(4_000_000), 100.min(n / 4), runs, seed)
                })
                .collect();
            print!("{}", table1::render(&stats));
        }
        "efficiency" => {
            let points: &[(usize, f64)] = if quick {
                &[(2000, 0.01), (8000, 0.005)]
            } else {
                &[(2000, 0.01), (8000, 0.005), (20_000, 0.002), (50_000, 0.001)]
            };
            let rows = srsvd::experiments::efficiency::sweep(500, points, 10, seed);
            print!("{}", srsvd::experiments::efficiency::render(&rows));
        }
        other => {
            return Err(srsvd::util::Error::Invalid(format!("unknown experiment {other:?}")));
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &[String]) -> Result<()> {
    let spec = ArgSpec::new("List the compiled AOT artifacts")
        .opt("dir", "artifacts", "artifact directory");
    let a = spec.parse(args)?;
    if a.help {
        print!("{}", spec.usage("srsvd artifacts"));
        return Ok(());
    }
    let manifest = Manifest::load(std::path::Path::new(a.get("dir")))?;
    manifest.validate_files()?;
    let mut t = srsvd::bench::Table::new(&["name", "op", "shape", "k", "K", "q", "method"]);
    for art in &manifest.artifacts {
        t.row(&[
            art.name.clone(),
            art.op.clone(),
            format!("{}x{}", art.m, art.n),
            art.k.to_string(),
            art.kk.to_string(),
            art.q.to_string(),
            art.method.clone(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

// `Dense` is used by the doc examples above.
#[allow(unused_imports)]
use Dense as _DocAnchor;
