//! One-sided Jacobi SVD and the symmetric Jacobi eigensolver.
//!
//! One-sided Jacobi (Hestenes 1958) orthogonalizes the columns of `W`
//! by plane rotations: `W·J₁·J₂⋯ = B` with mutually orthogonal columns,
//! giving `W = U·Σ·Vᵀ` with `σⱼ = ‖bⱼ‖`, `uⱼ = bⱼ/σⱼ` and `V` the
//! accumulated rotations. It is slow for big matrices but simple,
//! accurate (computes small singular values to high relative accuracy)
//! and has no LAPACK dependency — exactly what the deterministic oracle
//! and the small `K×n` projected SVD (Alg. 1 Line 13) need.

use super::Dense;

/// Convergence controls for the Jacobi loops.
#[derive(Debug, Clone, Copy)]
pub struct JacobiOpts {
    /// Hard cap on cyclic sweeps.
    pub max_sweeps: usize,
    /// Stop when every off-diagonal |gram(p,q)| <= tol·‖wₚ‖‖w_q‖.
    pub tol: f64,
}

impl Default for JacobiOpts {
    fn default() -> Self {
        JacobiOpts { max_sweeps: 30, tol: 1e-12 }
    }
}

/// Full SVD of `w` (n×k, n ≥ k): returns `(u, s, v)` with
/// `w = u·diag(s)·vᵀ`, `s` descending, `u` n×k, `v` k×k.
pub fn jacobi_svd(w: &Dense, opts: JacobiOpts) -> (Dense, Vec<f64>, Dense) {
    let (n, k) = w.shape();
    assert!(n >= k, "jacobi_svd wants tall input, got {n}x{k}");
    // Column-major copies for cache-friendly column rotations.
    let mut b: Vec<Vec<f64>> = (0..k).map(|j| w.col(j)).collect();
    let mut v: Vec<Vec<f64>> = (0..k)
        .map(|j| {
            let mut e = vec![0.0; k];
            e[j] = 1.0;
            e
        })
        .collect();

    // Cached squared column norms: rotating (p, q) maps the Gram
    // entries exactly (app' = c²app − 2cs·apq + s²aqq, and symmetrically
    // for aqq'), so only the cross term apq needs an O(n) reduction per
    // pair — a ~3× cut in reduction work. Norms are refreshed from the
    // data once per sweep to stop drift. (Perf log: EXPERIMENTS.md §Perf.)
    let mut norms: Vec<f64> = b.iter().map(|col| col.iter().map(|x| x * x).sum()).collect();

    for _sweep in 0..opts.max_sweeps {
        let mut converged = true;
        for p in 0..k.saturating_sub(1) {
            for q in (p + 1)..k {
                let (bp, bq) = pair_mut(&mut b, p, q);
                let app = norms[p];
                let aqq = norms[q];
                let apq: f64 = bp.iter().zip(bq.iter()).map(|(x, y)| x * y).sum();
                if apq.abs() <= opts.tol * (app * aqq).sqrt() + f64::MIN_POSITIVE {
                    continue;
                }
                converged = false;
                // Rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                rotate(bp, bq, c, s);
                let (vp, vq) = pair_mut(&mut v, p, q);
                rotate(vp, vq, c, s);
                let (c2, s2, cs) = (c * c, s * s, c * s);
                norms[p] = c2 * app - 2.0 * cs * apq + s2 * aqq;
                norms[q] = s2 * app + 2.0 * cs * apq + c2 * aqq;
            }
        }
        if converged {
            break;
        }
        // Refresh cached norms from the data between sweeps.
        for (j, col) in b.iter().enumerate() {
            norms[j] = col.iter().map(|x| x * x).sum();
        }
    }

    // Extract factors, sorted by descending singular value.
    let mut sv: Vec<(f64, usize)> = b
        .iter()
        .enumerate()
        .map(|(j, col)| (col.iter().map(|x| x * x).sum::<f64>().sqrt(), j))
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Dense::zeros(n, k);
    let mut vout = Dense::zeros(k, k);
    let mut s = Vec::with_capacity(k);
    for (out_j, &(sigma, j)) in sv.iter().enumerate() {
        s.push(sigma);
        let inv = if sigma > 1e-300 { 1.0 / sigma } else { 0.0 };
        for i in 0..n {
            u[(i, out_j)] = b[j][i] * inv;
        }
        for i in 0..k {
            vout[(i, out_j)] = v[j][i];
        }
    }
    (u, s, vout)
}

#[inline]
fn rotate(x: &mut [f64], y: &mut [f64], c: f64, s: f64) {
    for (xi, yi) in x.iter_mut().zip(y.iter_mut()) {
        let a = *xi;
        let b = *yi;
        *xi = c * a - s * b;
        *yi = s * a + c * b;
    }
}

/// Two distinct mutable column borrows.
fn pair_mut<T>(cols: &mut [Vec<T>], p: usize, q: usize) -> (&mut Vec<T>, &mut Vec<T>) {
    debug_assert!(p < q);
    let (lo, hi) = cols.split_at_mut(q);
    (&mut lo[p], &mut hi[0])
}

/// Symmetric Jacobi eigendecomposition of a k×k symmetric matrix.
///
/// Returns `(evecs, evals)` with eigenvalues descending; used by the
/// Gram-route small SVD (`Y·Yᵀ = U₁Σ²U₁ᵀ`).
pub fn sym_jacobi_eig(a: &Dense, opts: JacobiOpts) -> (Dense, Vec<f64>) {
    let k = a.rows();
    assert_eq!(a.shape(), (k, k), "need square symmetric");
    let mut m = a.clone();
    let mut v = Dense::eye(k);

    for _sweep in 0..opts.max_sweeps {
        let mut off = 0.0f64;
        for p in 0..k {
            for q in (p + 1)..k {
                off = off.max(m[(p, q)].abs());
            }
        }
        if off <= opts.tol * m.max_abs().max(1e-300) {
            break;
        }
        for p in 0..k.saturating_sub(1) {
            for q in (p + 1)..k {
                let apq = m[(p, q)];
                if apq.abs() <= opts.tol * m.max_abs().max(1e-300) {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // Update rows/cols p and q of m (two-sided rotation).
                for i in 0..k {
                    let mip = m[(i, p)];
                    let miq = m[(i, q)];
                    m[(i, p)] = c * mip - s * miq;
                    m[(i, q)] = s * mip + c * miq;
                }
                for i in 0..k {
                    let mpi = m[(p, i)];
                    let mqi = m[(q, i)];
                    m[(p, i)] = c * mpi - s * mqi;
                    m[(q, i)] = s * mpi + c * mqi;
                }
                for i in 0..k {
                    let vip = v[(i, p)];
                    let viq = v[(i, q)];
                    v[(i, p)] = c * vip - s * viq;
                    v[(i, q)] = s * vip + c * viq;
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&i, &j| m[(j, j)].partial_cmp(&m[(i, i)]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| m[(i, i)]).collect();
    let evecs = Dense::from_fn(k, k, |i, j| v[(i, order[j])]);
    (evecs, evals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gemm;
    use crate::linalg::qr::orthonormality_residual;
    use crate::linalg::{fro_diff, matmul};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn svd_reconstructs_random_matrices() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for (n, k) in [(1, 1), (5, 5), (40, 8), (100, 15), (64, 64)] {
            let w = Dense::gaussian(n, k, &mut rng);
            let (u, s, v) = jacobi_svd(&w, JacobiOpts::default());
            let rec = matmul(&u.scale_cols(&s), &v.transpose());
            assert!(fro_diff(&rec, &w) < 1e-9 * (n as f64), "{n}x{k}");
            assert!(orthonormality_residual(&v) < 1e-10, "{n}x{k}");
            // Descending.
            assert!(s.windows(2).all(|p| p[0] >= p[1] - 1e-12), "{n}x{k}");
        }
    }

    #[test]
    fn svd_singular_values_match_known_matrix() {
        // diag(3, 2, 1) embedded in a rotation.
        let d = Dense::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, 1.0]);
        let (_, s, _) = jacobi_svd(&d, JacobiOpts::default());
        assert!((s[0] - 3.0).abs() < 1e-12);
        assert!((s[1] - 2.0).abs() < 1e-12);
        assert!((s[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn svd_rank_deficient() {
        // Two identical columns: one zero singular value.
        let mut w = Dense::zeros(10, 3);
        for i in 0..10 {
            w[(i, 0)] = (i + 1) as f64;
            w[(i, 1)] = (i + 1) as f64;
            w[(i, 2)] = if i == 0 { 1.0 } else { 0.0 };
        }
        let (u, s, v) = jacobi_svd(&w, JacobiOpts::default());
        assert!(s[2] < 1e-10, "smallest sv {}", s[2]);
        let rec = matmul(&u.scale_cols(&s), &v.transpose());
        assert!(fro_diff(&rec, &w) < 1e-9);
    }

    #[test]
    fn svd_high_relative_accuracy_small_values() {
        // sigma = [1, 1e-6]: Jacobi should nail both.
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let (q1, _) = crate::linalg::qr::householder_qr(&Dense::gaussian(30, 2, &mut rng));
        let (q2, _) = crate::linalg::qr::householder_qr(&Dense::gaussian(2, 2, &mut rng));
        let w = matmul(&q1.scale_cols(&[1.0, 1e-6]), &q2.transpose());
        let (_, s, _) = jacobi_svd(&w, JacobiOpts::default());
        assert!((s[0] - 1.0).abs() < 1e-10);
        assert!((s[1] - 1e-6).abs() < 1e-12, "tiny sv {}", s[1]);
    }

    #[test]
    fn eig_matches_svd_on_psd_gram() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let w = Dense::gaussian(30, 6, &mut rng);
        let g = gemm::tmatmul(&w, &w); // 6x6 PSD
        let (evecs, evals) = sym_jacobi_eig(&g, JacobiOpts::default());
        let (_, s, _) = jacobi_svd(&w, JacobiOpts::default());
        for j in 0..6 {
            assert!(
                (evals[j].max(0.0).sqrt() - s[j]).abs() < 1e-8 * s[0].max(1.0),
                "eval {j}"
            );
        }
        // Eigen relation G V = V Λ.
        let gv = matmul(&g, &evecs);
        let vl = evecs.scale_cols(&evals);
        assert!(fro_diff(&gv, &vl) < 1e-8 * g.fro_norm().max(1.0));
        assert!(orthonormality_residual(&evecs) < 1e-10);
    }

    #[test]
    fn eig_handles_diagonal_and_identity() {
        let (v, l) = sym_jacobi_eig(&Dense::eye(4), JacobiOpts::default());
        assert!(l.iter().all(|&x| (x - 1.0).abs() < 1e-14));
        assert!(orthonormality_residual(&v) < 1e-12);
    }
}
