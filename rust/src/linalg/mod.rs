//! From-scratch dense and sparse linear algebra (no BLAS/LAPACK).
//!
//! The offline build environment has no numeric crates, and the paper's
//! claims are about *how* the factorization touches memory — so the
//! substrate is explicit here: a row-major dense type with a blocked
//! GEMM, Householder/MGS QR, the rank-1 QR-update the paper leans on
//! (Golub & Van Loan §12.5.1), one-sided Jacobi SVD, CSR sparse
//! kernels whose shifted products never densify, and the out-of-core
//! [`stream`] layer that runs the same kernels block-at-a-time over
//! matrices that never fit in RAM.

pub mod dense;
pub mod gemm;
pub mod jacobi;
pub mod qr;
pub mod qr_update;
pub mod sparse;
pub mod stream;

pub use dense::Dense;
pub use gemm::{matmul, matmul_rank1, MatmulPlan};
pub use jacobi::{jacobi_svd, sym_jacobi_eig, JacobiOpts};
pub use qr::{householder_qr, mgs_qr};
pub use qr_update::qr_rank1_update;
pub use sparse::{Csr, Triplets};
pub use stream::{
    CsrRowSource, FileSource, FileWriter, GeneratorSource, InMemorySource, MatrixSource,
    SharedSource, SourceStats, SourceStatsSnapshot, StreamConfig, Streamed,
};

/// Frobenius norm of the difference of two equally-shaped matrices.
pub fn fro_diff(a: &Dense, b: &Dense) -> f64 {
    assert_eq!(a.shape(), b.shape());
    a.data()
        .iter()
        .zip(b.data())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fro_diff_zero_for_identical() {
        let a = Dense::from_fn(3, 4, |i, j| (i * 4 + j) as f64);
        assert_eq!(fro_diff(&a, &a), 0.0);
    }
}
