//! Rank-1 QR update (Golub & Van Loan, *Matrix Computations* §12.5.1).
//!
//! Given a thin QR factorization `A = Q·R` (`Q` m×k orthonormal, `R`
//! k×k upper triangular), compute the factorization of `A + u·vᵀ`
//! without refactorizing. This is the device the paper's Algorithm 1
//! (Line 6) uses to turn the basis of `XΩ` into a basis of
//! `XΩ − μ·1ᵀ` in one rank-1 step.
//!
//! Thin-QR subtlety the paper glosses over: `u` generally has a
//! component *outside* range(Q) (the mean vector is not in the sample
//! range), so the update must grow the basis by the normalized residual
//! `q⁺ = (u − QQᵀu)/ρ` before the classical Givens sweep:
//!
//! ```text
//! A + uvᵀ = [Q q⁺] · ( [R; 0] + [w; ρ]·vᵀ ),   w = Qᵀu
//! ```
//!
//! Two Givens passes restore triangularity of the (k+1)×k inner factor;
//! the same rotations applied to `[Q q⁺]` yield the updated basis. Cost
//! is O(mk) — *cheaper* than the O(m²) the paper quotes (they cite the
//! square-Q variant); see DESIGN.md "Paper erratum".

use super::Dense;

/// Result of [`qr_rank1_update`].
pub struct QrUpdate {
    /// Updated orthonormal basis, m×k (the leading k columns after the
    /// augmented sweep; the (k+1)-th direction has zero weight in R).
    pub q: Dense,
    /// Updated k×k upper-triangular factor.
    pub r: Dense,
}

/// Apply one Givens rotation G(c, s) to rows (i, i+1) of a matrix,
/// columns `lo..`.
fn apply_givens_rows(m: &mut Dense, i: usize, c: f64, s: f64, lo: usize) {
    let cols = m.cols();
    for j in lo..cols {
        let a = m[(i, j)];
        let b = m[(i + 1, j)];
        m[(i, j)] = c * a + s * b;
        m[(i + 1, j)] = -s * a + c * b;
    }
}

/// Apply one Givens rotation to columns (i, i+1) of a matrix (acting on
/// Q from the right with Gᵀ).
fn apply_givens_cols(m: &mut Dense, i: usize, c: f64, s: f64) {
    let rows = m.rows();
    for r in 0..rows {
        let a = m[(r, i)];
        let b = m[(r, i + 1)];
        m[(r, i)] = c * a + s * b;
        m[(r, i + 1)] = -s * a + c * b;
    }
}

fn givens(a: f64, b: f64) -> (f64, f64) {
    if b == 0.0 {
        (1.0, 0.0)
    } else {
        let h = a.hypot(b);
        (a / h, b / h)
    }
}

/// Compute the thin QR factorization of `Q·R + u·vᵀ`.
///
/// `q` must have orthonormal columns; `r` upper triangular (k×k).
pub fn qr_rank1_update(q: &Dense, r: &Dense, u: &[f64], v: &[f64]) -> QrUpdate {
    let (m, k) = q.shape();
    assert_eq!(r.shape(), (k, k), "R must be kxk");
    assert_eq!(u.len(), m, "u length");
    assert_eq!(v.len(), k, "v length");

    // w = Qᵀu and the residual direction.
    let w = q.tmatvec(u);
    let qw = q.matvec(&w);
    let mut resid: Vec<f64> = u.iter().zip(&qw).map(|(a, b)| a - b).collect();
    let rho = resid.iter().map(|x| x * x).sum::<f64>().sqrt();

    // Augmented basis [Q q+] (m x (k+1)) and factor [R; 0] (k+1 x k).
    let kk = k + 1;
    let mut qa = Dense::zeros(m, kk);
    for i in 0..m {
        for j in 0..k {
            qa[(i, j)] = q[(i, j)];
        }
    }
    if rho > 1e-300 {
        for x in &mut resid {
            *x /= rho;
        }
        for i in 0..m {
            qa[(i, k)] = resid[i];
        }
    }
    let mut ra = Dense::zeros(kk, k);
    for i in 0..k {
        for j in i..k {
            ra[(i, j)] = r[(i, j)];
        }
    }

    // wa = [w; rho].
    let mut wa = w;
    wa.push(if rho > 1e-300 { rho } else { 0.0 });

    // Pass 1 (bottom-up): rotate wa to alpha*e1. Each rotation acts on
    // rows (i, i+1) of ra — making it upper Hessenberg — and columns
    // (i, i+1) of qa.
    for i in (0..kk - 1).rev() {
        let (c, s) = givens(wa[i], wa[i + 1]);
        if s != 0.0 {
            wa[i] = c * wa[i] + s * wa[i + 1];
            wa[i + 1] = 0.0;
            apply_givens_rows(&mut ra, i, c, s, i.saturating_sub(1));
            apply_givens_cols(&mut qa, i, c, s);
        }
    }

    // Rank-1 term now only touches row 0.
    for j in 0..k {
        ra[(0, j)] += wa[0] * v[j];
    }

    // Pass 2 (top-down): re-triangularize the Hessenberg ra.
    for i in 0..k.min(kk - 1) {
        let (c, s) = givens(ra[(i, i)], ra[(i + 1, i)]);
        if s != 0.0 {
            apply_givens_rows(&mut ra, i, c, s, i);
            ra[(i + 1, i)] = 0.0; // exact zero by construction
            apply_givens_cols(&mut qa, i, c, s);
        }
    }

    // The (k+1)-th row of ra is now zero: drop the last basis column.
    let q_out = Dense::from_fn(m, k, |i, j| qa[(i, j)]);
    let r_out = Dense::from_fn(k, k, |i, j| if i <= j { ra[(i, j)] } else { 0.0 });
    QrUpdate { q: q_out, r: r_out }
}

/// Convenience: basis of `A − μ·1_cᵀ·S` for the paper's Line 6, where the
/// rank-1 right factor is chosen by `variant` (see DESIGN.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftVariant {
    /// v = Ωᵀ1 (column sums of Ω): the exact shifted sample matrix
    /// `XΩ − μ(1ᵀΩ)`.
    Exact,
    /// v = 1: the paper's literal Line 6, `XΩ − μ·1ᵀ`.
    PaperLiteral,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::qr::{householder_qr, orthonormality_residual};
    use crate::linalg::{fro_diff, matmul};
    use crate::rng::{Rng, Xoshiro256pp};

    fn explicit_update(a: &Dense, u: &[f64], v: &[f64]) -> Dense {
        let mut out = a.clone();
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                out[(i, j)] += u[i] * v[j];
            }
        }
        out
    }

    #[test]
    fn update_matches_refactorization() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for (m, k) in [(10, 3), (50, 8), (120, 20)] {
            let a = Dense::gaussian(m, k, &mut rng);
            let (q, r) = householder_qr(&a);
            let u: Vec<f64> = (0..m).map(|_| rng.next_gaussian()).collect();
            let v: Vec<f64> = (0..k).map(|_| rng.next_gaussian()).collect();
            let upd = qr_rank1_update(&q, &r, &u, &v);
            let want = explicit_update(&a, &u, &v);
            assert!(
                fro_diff(&matmul(&upd.q, &upd.r), &want) < 1e-9 * (m as f64),
                "{m}x{k}"
            );
            assert!(orthonormality_residual(&upd.q) < 1e-10, "{m}x{k}");
        }
    }

    #[test]
    fn update_with_u_in_range_of_q() {
        // u = Q y exactly: rho = 0 path.
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Dense::gaussian(30, 5, &mut rng);
        let (q, r) = householder_qr(&a);
        let y: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let u = q.matvec(&y);
        let v: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let upd = qr_rank1_update(&q, &r, &u, &v);
        let want = explicit_update(&a, &u, &v);
        assert!(fro_diff(&matmul(&upd.q, &upd.r), &want) < 1e-9);
        assert!(orthonormality_residual(&upd.q) < 1e-10);
    }

    #[test]
    fn update_with_zero_u_is_identity() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let a = Dense::gaussian(20, 4, &mut rng);
        let (q, r) = householder_qr(&a);
        let upd = qr_rank1_update(&q, &r, &vec![0.0; 20], &vec![1.0; 4]);
        assert!(fro_diff(&matmul(&upd.q, &upd.r), &a) < 1e-10);
    }

    /// The paper's use: turn QR(XΩ) into a basis of the shifted sample.
    #[test]
    fn shifted_basis_via_update_spans_centered_sample() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x = Dense::from_fn(40, 200, |_, _| rng.next_uniform());
        let om = Dense::gaussian(200, 10, &mut rng);
        let mu = x.row_means();
        let x1 = matmul(&x, &om);
        let (q, r) = householder_qr(&x1);
        // Exact variant: v = colsum(Omega).
        let v: Vec<f64> = (0..10).map(|j| om.col(j).iter().sum::<f64>()).collect();
        let neg_mu: Vec<f64> = mu.iter().map(|x| -x).collect();
        let upd = qr_rank1_update(&q, &r, &neg_mu, &v);
        // The updated basis must capture Xbar*Omega.
        let want = matmul(&x.subtract_column(&mu), &om);
        let proj = matmul(
            &upd.q,
            &crate::linalg::gemm::tmatmul(&upd.q, &want),
        );
        assert!(fro_diff(&proj, &want) < 1e-8 * want.fro_norm().max(1.0));
    }
}
