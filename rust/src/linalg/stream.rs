//! Out-of-core streaming: factorize matrices that never fit in RAM.
//!
//! The paper's algorithm only ever *multiplies against* `X` — it never
//! needs the matrix resident. This module makes that operational: a
//! [`MatrixSource`] yields row blocks on demand, and [`Streamed`]
//! implements the full [`MatVecOps`] contract by sweeping those blocks
//! through the pool-aware GEMM kernels one at a time, under a
//! configurable memory budget ([`StreamConfig`]). Following Halko,
//! Martinsson, Shkolnisky & Tygert (arXiv:1007.5510), every operation —
//! sampling, power iteration, projection, row means, norms — is a
//! single pass over the row blocks, and the fused Gram sweep
//! ([`MatVecOps::gram_sweep`]) services a whole power-iteration leg
//! (`X̄ᵀ(X̄·W)`) from **one** block read per block — the
//! `PassPolicy::Fused` schedule that drops a factorization from
//! `2 + 2q` source passes to `q + 2`.
//!
//! ## Bit-exactness
//!
//! Streamed results under the default `PassPolicy::Exact` schedule are
//! **byte-identical** to the in-memory [`Dense`] path for every block
//! size, every thread-pool size, and with prefetch on or off:
//!
//! * `X·B` partitions rows of the output: each output row is produced by
//!   the same serial kernel ([`gemm`]) on the same row data, so block
//!   boundaries cannot change it.
//! * `Xᵀ·B` accumulates row-block contributions in ascending row order
//!   via [`gemm::tmatmul_acc`]; every output element receives its
//!   `i`-terms in exactly the serial order of the one-shot kernel.
//! * `sq_fro` / `row_means` continue one accumulator across blocks in
//!   the same element order the dense reductions use.
//! * The prefetch pipeline (below) only moves the *reads* to a
//!   background thread; blocks are still consumed in ascending order on
//!   the calling thread, so accumulation order never changes.
//!
//! The contract is pinned by `rust/tests/stream.rs`, which compares
//! whole factorizations (u/s/v) bit-for-bit at pools 1/2/8 across block
//! sizes with prefetch on and off. (`PassPolicy::Fused` trades that
//! byte-identity for the pass budget; its accuracy bound is pinned by
//! the same suite.)
//!
//! ## Prefetch
//!
//! Each sweep can run **double-buffered** ([`StreamConfig::prefetch`],
//! default on): a reader fills block `i+1` while the caller runs the
//! pool-parallel GEMM on block `i`, with two recycled block buffers
//! circulating between them. The reader runs on the **io pool**
//! ([`crate::parallel::with_current_io`]) so a blocking read never
//! occupies a compute thread; when every io worker is busy the sweep
//! falls back to a plain scoped thread (degraded, never deadlocked —
//! and never a behavior change, since blocks are consumed in ascending
//! order on the calling thread either way). Disk latency and compute
//! overlap instead of alternating, and [`FileSource`] keeps a small
//! pool of positioned file handles so concurrent readers (the prefetch
//! reader, parallel jobs sharing one source) never serialize behind a
//! single locked seek+read.
//!
//! ## Observability
//!
//! Every [`Streamed`] wrapper counts its I/O in a shared
//! [`SourceStats`]: full passes over the source, blocks read, payload
//! bytes. The coordinator aggregates them per job into the service
//! metrics (`stream_passes` / `stream_bytes_read` in `GET /metrics`),
//! and `rust/tests/stream.rs` asserts the `Fused` ≤ `q + 2` pass budget
//! against them.
//!
//! ## Sources
//!
//! * [`FileSource`] / [`FileWriter`] — an on-disk binary format (24-byte
//!   header + row-major little-endian f64 payload) read block-wise.
//! * [`GeneratorSource`] — synthetic matrices ([`Distribution`])
//!   generated row-by-row from per-row seeds; nothing materializes.
//! * [`CsrRowSource`] — adapts a sparse [`Csr`] (e.g. the corpus
//!   generator's co-occurrence matrix), densifying one block at a time.
//! * [`InMemorySource`] — wraps a resident [`Dense`]; the parity-test
//!   adapter.
//!
//! IO failures *after* construction (a file truncated mid-sweep) panic
//! with context rather than silently corrupting a factorization — the
//! [`MatVecOps`] signatures are infallible by design. Sources validate
//! everything they can (magic, version, payload length) at `open` time.

use std::fmt;
use std::fs;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use super::{gemm, Csr, Dense};
use crate::data::Distribution;
use crate::parallel;
use crate::rng::{Rng, SplitMix64, Xoshiro256pp};
use crate::svd::MatVecOps;
use crate::util::{faults, retry::RetryPolicy, Error, Result};

/// Panic-message prefix of a sweep that exhausted its read-retry budget
/// (or hit a non-retryable source error). The [`MatVecOps`] signatures
/// are infallible by design, so the sweep panics with context; the
/// coordinator's panic isolation recognizes this prefix and maps it
/// back to a typed [`Error::Io`] carrying the attempt count.
pub(crate) const SOURCE_IO_PANIC: &str = "matrix source failed reading rows";

/// A matrix exposed as on-demand row blocks.
///
/// Implementors are cheap handles (a file descriptor, a seed, a borrow)
/// — the matrix itself stays wherever it lives. `Send + Sync` so a
/// source can be shared across coordinator workers; `Debug` so job
/// types containing sources stay debuggable.
pub trait MatrixSource: Send + Sync + fmt::Debug {
    /// Matrix dimensions `(rows, cols)`.
    fn shape(&self) -> (usize, usize);

    /// Fill `out` (row-major, exactly `nrows * cols` elements) with rows
    /// `row0 .. row0 + nrows`. Implementations must overwrite the whole
    /// slice and must be deterministic: the same rows yield the same
    /// bytes regardless of block boundaries.
    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()>;

    /// Materialize the whole matrix as a [`Dense`] (tests / small
    /// inputs — this is exactly the allocation streaming avoids).
    fn materialize(&self) -> Result<Dense> {
        let (m, n) = self.shape();
        let mut data = vec![0.0; m * n];
        if m > 0 {
            self.read_rows(0, m, &mut data)?;
        }
        Ok(Dense::from_vec(m, n, data))
    }

    /// Canonical bytes identifying the matrix *content* for the
    /// server's content-addressed result cache, or `None` when the
    /// content cannot be proven stable from the handle alone (the
    /// default — e.g. a file path, whose bytes may change between
    /// jobs). Two sources returning the same key must yield the same
    /// matrix bytes via [`MatrixSource::read_rows`].
    fn cache_key(&self) -> Option<Vec<u8>> {
        None
    }

    /// Canonical bytes identifying the matrix for *checkpoint/resume*
    /// tagging, or `None` when not even a claimed identity exists.
    ///
    /// Weaker contract than [`MatrixSource::cache_key`] (which must
    /// prove content stability): a checkpoint key only needs to tell
    /// *different jobs* apart, because a resumed factorization re-reads
    /// the source anyway — a wrong cache hit silently serves stale
    /// factors, while a checkpoint under a mutated source is operator
    /// error with a visible (failed/garbage) outcome. Defaults to the
    /// cache key; sources with a stable *claimed* identity but
    /// unprovable content (e.g. a file path) override this one.
    fn checkpoint_key(&self) -> Option<Vec<u8>> {
        self.cache_key()
    }
}

impl<'a, S: MatrixSource + ?Sized> MatrixSource for &'a S {
    fn shape(&self) -> (usize, usize) {
        (**self).shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        (**self).read_rows(row0, nrows, out)
    }

    fn cache_key(&self) -> Option<Vec<u8>> {
        (**self).cache_key()
    }

    fn checkpoint_key(&self) -> Option<Vec<u8>> {
        (**self).checkpoint_key()
    }
}

/// Shared, type-erased source handle — what [`crate::coordinator::job`]
/// stores so job specs stay cheaply cloneable.
pub type SharedSource = Arc<dyn MatrixSource>;

impl MatrixSource for SharedSource {
    fn shape(&self) -> (usize, usize) {
        (**self).shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        (**self).read_rows(row0, nrows, out)
    }

    fn cache_key(&self) -> Option<Vec<u8>> {
        (**self).cache_key()
    }

    fn checkpoint_key(&self) -> Option<Vec<u8>> {
        (**self).checkpoint_key()
    }
}

fn check_block_bounds(shape: (usize, usize), row0: usize, nrows: usize, out_len: usize) {
    let (m, n) = shape;
    assert!(
        row0 + nrows <= m,
        "block rows {row0}..{} out of bounds for {m} rows",
        row0 + nrows
    );
    assert_eq!(out_len, nrows * n, "block buffer length mismatch");
}

// ---------------------------------------------------------------------------
// In-memory adapter
// ---------------------------------------------------------------------------

/// A [`MatrixSource`] over a resident [`Dense`] — the adapter that lets
/// parity tests run the streaming code path against in-memory truth.
#[derive(Debug, Clone)]
pub struct InMemorySource {
    matrix: Dense,
}

impl InMemorySource {
    /// Wrap a resident matrix.
    pub fn new(matrix: Dense) -> InMemorySource {
        InMemorySource { matrix }
    }

    /// Borrow the wrapped matrix.
    pub fn matrix(&self) -> &Dense {
        &self.matrix
    }
}

impl MatrixSource for InMemorySource {
    fn shape(&self) -> (usize, usize) {
        self.matrix.shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        check_block_bounds(self.shape(), row0, nrows, out.len());
        let n = self.matrix.cols();
        out.copy_from_slice(&self.matrix.data()[row0 * n..(row0 + nrows) * n]);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Sparse adapter
// ---------------------------------------------------------------------------

/// A [`MatrixSource`] over a [`Csr`] matrix: densifies one row block at
/// a time (never the whole matrix), so e.g. the corpus generator's
/// co-occurrence matrix can feed dense-only consumers out-of-core.
///
/// Note that for *factorization* the native sparse [`MatVecOps`] path is
/// strictly better (O(nnz) products); this adapter exists for spilling
/// sparse data to the dense on-disk format and for mixed pipelines.
#[derive(Debug, Clone)]
pub struct CsrRowSource {
    matrix: Csr,
}

impl CsrRowSource {
    /// Wrap a sparse matrix.
    pub fn new(matrix: Csr) -> CsrRowSource {
        CsrRowSource { matrix }
    }
}

impl MatrixSource for CsrRowSource {
    fn shape(&self) -> (usize, usize) {
        self.matrix.shape()
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        check_block_bounds(self.shape(), row0, nrows, out.len());
        let n = self.matrix.cols();
        out.fill(0.0);
        for local in 0..nrows {
            let base = local * n;
            for (j, v) in self.matrix.row_iter(row0 + local) {
                out[base + j] = v;
            }
        }
        Ok(())
    }

    fn cache_key(&self) -> Option<Vec<u8>> {
        // The matrix is resident, so its content *is* provable from the
        // handle: serialize shape + per-row (index, bits) structure,
        // mirroring the cache layer's canonical sparse encoding. Makes
        // streamed-CSR jobs cacheable and checkpointable.
        let (m, n) = self.shape();
        let mut key = Vec::with_capacity(32);
        key.push(b'C');
        key.extend_from_slice(&(m as u64).to_le_bytes());
        key.extend_from_slice(&(n as u64).to_le_bytes());
        for i in 0..m {
            let mut len: u64 = 0;
            let start = key.len();
            key.extend_from_slice(&0u64.to_le_bytes()); // patched below
            for (j, v) in self.matrix.row_iter(i) {
                key.extend_from_slice(&(j as u64).to_le_bytes());
                key.extend_from_slice(&v.to_bits().to_le_bytes());
                len += 1;
            }
            key[start..start + 8].copy_from_slice(&len.to_le_bytes());
        }
        Some(key)
    }
}

// ---------------------------------------------------------------------------
// Generator source
// ---------------------------------------------------------------------------

/// A synthetic random matrix generated row-by-row: each row draws from a
/// per-row seed, so any block partition yields the same matrix and
/// nothing is ever materialized.
///
/// Supports the i.i.d. entry distributions of [`Distribution`]
/// (`Uniform`, `Normal`, `Exponential`). `Zipf` is column-coupled (each
/// column is a normalized histogram) and cannot be generated
/// row-streamed — [`GeneratorSource::new`] rejects it; spill a
/// [`crate::data::random_matrix`] through [`FileWriter`] instead.
///
/// The matrix *family* matches `data/random.rs` (same entry
/// distributions) but the RNG stream layout differs, so for a given seed
/// this is a different — equally deterministic — matrix than
/// [`crate::data::random_matrix`] produces.
#[derive(Debug, Clone, Copy)]
pub struct GeneratorSource {
    rows: usize,
    cols: usize,
    dist: Distribution,
    seed: u64,
}

impl GeneratorSource {
    /// Describe an m×n matrix of i.i.d. `dist` entries under `seed`.
    /// Errors for [`Distribution::Zipf`] (column-coupled; see type docs).
    pub fn new(rows: usize, cols: usize, dist: Distribution, seed: u64) -> Result<GeneratorSource> {
        crate::ensure!(
            dist != Distribution::Zipf,
            "GeneratorSource cannot stream the Zipf distribution (each column \
             is a normalized histogram over all rows); materialize via \
             data::random_matrix and spill through stream::FileWriter instead"
        );
        Ok(GeneratorSource { rows, cols, dist, seed })
    }

    /// The seed a given row's RNG starts from (SplitMix64-scrambled so
    /// neighboring rows get unrelated streams).
    fn row_seed(&self, row: usize) -> u64 {
        let mut sm = SplitMix64::new(
            self.seed ^ (row as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        sm.next_u64()
    }
}

impl MatrixSource for GeneratorSource {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        check_block_bounds(self.shape(), row0, nrows, out.len());
        let n = self.cols;
        for local in 0..nrows {
            let mut rng = Xoshiro256pp::seed_from_u64(self.row_seed(row0 + local));
            for x in &mut out[local * n..(local + 1) * n] {
                *x = match self.dist {
                    Distribution::Uniform => rng.next_uniform(),
                    Distribution::Normal => 1.0 + rng.next_gaussian(),
                    Distribution::Exponential => rng.next_exponential(),
                    // Rejected by the constructor.
                    Distribution::Zipf => unreachable!("Zipf is not row-streamable"),
                };
            }
        }
        Ok(())
    }

    fn cache_key(&self) -> Option<Vec<u8>> {
        // The generated matrix is a pure function of (shape, dist,
        // seed), so those bytes identify its content exactly.
        let mut key = Vec::with_capacity(26);
        key.push(b'G');
        key.extend_from_slice(&(self.rows as u64).to_le_bytes());
        key.extend_from_slice(&(self.cols as u64).to_le_bytes());
        key.push(match self.dist {
            Distribution::Uniform => 0,
            Distribution::Normal => 1,
            Distribution::Exponential => 2,
            Distribution::Zipf => 3,
        });
        key.extend_from_slice(&self.seed.to_le_bytes());
        Some(key)
    }
}

// ---------------------------------------------------------------------------
// On-disk binary format
// ---------------------------------------------------------------------------

/// File magic of the on-disk matrix format (`SRSV`).
const FILE_MAGIC: [u8; 4] = *b"SRSV";
/// Current format version.
const FILE_VERSION: u32 = 1;
/// Header length in bytes: magic (4) + version (4) + rows (8) + cols (8).
const HEADER_LEN: u64 = 24;

/// Incremental writer for the on-disk matrix format: declare the shape,
/// append row blocks in order, [`FileWriter::finish`]. Lets a matrix
/// larger than RAM be spilled block-by-block (see
/// `examples/out_of_core.rs`).
///
/// Format: `SRSV` magic, u32 LE version, u64 LE rows, u64 LE cols, then
/// `rows*cols` f64 LE values row-major.
#[derive(Debug)]
pub struct FileWriter {
    path: PathBuf,
    out: BufWriter<fs::File>,
    rows: usize,
    cols: usize,
    written_rows: usize,
}

impl FileWriter {
    /// Create (truncate) `path` and write the header for an m×n matrix.
    pub fn create(path: &Path, rows: usize, cols: usize) -> Result<FileWriter> {
        let mut out = BufWriter::new(fs::File::create(path)?);
        out.write_all(&FILE_MAGIC)?;
        out.write_all(&FILE_VERSION.to_le_bytes())?;
        out.write_all(&(rows as u64).to_le_bytes())?;
        out.write_all(&(cols as u64).to_le_bytes())?;
        Ok(FileWriter {
            path: path.to_path_buf(),
            out,
            rows,
            cols,
            written_rows: 0,
        })
    }

    /// Append whole rows (`data.len()` must be a multiple of the column
    /// count; rows are appended in order).
    pub fn append_rows(&mut self, data: &[f64]) -> Result<()> {
        crate::ensure!(
            self.cols > 0 && data.len() % self.cols == 0,
            "append_rows: {} values is not a whole number of {}-column rows",
            data.len(),
            self.cols
        );
        let nrows = data.len() / self.cols;
        crate::ensure!(
            self.written_rows + nrows <= self.rows,
            "append_rows: {} rows exceed the declared {} (already wrote {})",
            nrows,
            self.rows,
            self.written_rows
        );
        // Fail-point: may error, delay, or truncate (torn write). A
        // truncated append writes a prefix and then reports the short
        // write, leaving the file detectably incomplete — exactly what
        // the checkpoint layer's temp-then-rename protocol must survive.
        let take = faults::write_len("stream.write", data.len())?;
        for &x in &data[..take] {
            self.out.write_all(&x.to_le_bytes())?;
        }
        if take < data.len() {
            self.out.flush()?;
            return Err(Error::Io(std::io::Error::new(
                std::io::ErrorKind::WriteZero,
                format!(
                    "short write to {}: {take} of {} values",
                    self.path.display(),
                    data.len()
                ),
            )));
        }
        self.written_rows += nrows;
        Ok(())
    }

    /// Flush, verify every declared row was written, and reopen the file
    /// as a [`FileSource`].
    pub fn finish(mut self) -> Result<FileSource> {
        crate::ensure!(
            self.written_rows == self.rows,
            "finish: wrote {} of {} declared rows",
            self.written_rows,
            self.rows
        );
        faults::check("stream.write")?;
        self.out.flush()?;
        let path = self.path.clone();
        drop(self);
        FileSource::open(&path)
    }
}

/// Write a resident [`Dense`] to `path` in the on-disk format.
pub fn write_matrix(path: &Path, x: &Dense) -> Result<FileSource> {
    let mut w = FileWriter::create(path, x.rows(), x.cols())?;
    w.append_rows(x.data())?;
    w.finish()
}

/// Spill any [`MatrixSource`] to the on-disk format, `block_rows` rows
/// at a time (bounded memory even for sources larger than RAM).
pub fn spill_to_file<S: MatrixSource>(
    src: &S,
    path: &Path,
    block_rows: usize,
) -> Result<FileSource> {
    let (m, n) = src.shape();
    let bl = block_rows.clamp(1, m.max(1));
    let mut w = FileWriter::create(path, m, n)?;
    let mut buf = vec![0.0; bl * n];
    let mut row0 = 0;
    while row0 < m {
        let nr = bl.min(m - row0);
        src.read_rows(row0, nr, &mut buf[..nr * n])?;
        w.append_rows(&buf[..nr * n])?;
        row0 += nr;
    }
    w.finish()
}

/// Idle [`FileSource`] handles kept for reuse; beyond this, extra
/// concurrent readers open (and then drop) their own descriptor.
const MAX_IDLE_HANDLES: usize = 8;

/// A [`MatrixSource`] reading row blocks from the on-disk format written
/// by [`FileWriter`]. Header and payload length are validated at open
/// time. Block reads take a *private* positioned handle from a small
/// pool (opening a fresh one when the pool is empty) and seek + read
/// without holding any lock, so concurrent readers — the prefetch
/// pipeline, several coordinator jobs sharing one source — never
/// serialize behind a single `Mutex<File>` seek+read.
#[derive(Debug)]
pub struct FileSource {
    path: PathBuf,
    rows: usize,
    cols: usize,
    /// Idle handles; the lock is held only to pop/push, never during IO.
    handles: Mutex<Vec<fs::File>>,
}

impl FileSource {
    /// Open and validate an on-disk matrix.
    pub fn open(path: &Path) -> Result<FileSource> {
        let mut f = fs::File::open(path)?;
        let mut header = [0u8; HEADER_LEN as usize];
        f.read_exact(&mut header).map_err(|e| {
            Error::Invalid(format!("{}: not an srsvd matrix file: {e}", path.display()))
        })?;
        crate::ensure!(
            header[..4] == FILE_MAGIC,
            "{}: bad magic (not an srsvd matrix file)",
            path.display()
        );
        let version = u32::from_le_bytes(header[4..8].try_into().unwrap());
        crate::ensure!(
            version == FILE_VERSION,
            "{}: unsupported format version {version} (expected {FILE_VERSION})",
            path.display()
        );
        let rows = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let cols = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let expect = (rows as u64)
            .checked_mul(cols as u64)
            .and_then(|e| e.checked_mul(8))
            .and_then(|e| e.checked_add(HEADER_LEN))
            .ok_or_else(|| Error::Invalid(format!("{}: shape overflows", path.display())))?;
        let actual = f.metadata()?.len();
        crate::ensure!(
            actual == expect,
            "{}: payload is {actual} bytes, header {rows}x{cols} implies {expect}",
            path.display()
        );
        Ok(FileSource {
            path: path.to_path_buf(),
            rows,
            cols,
            handles: Mutex::new(vec![f]),
        })
    }

    /// The backing file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl MatrixSource for FileSource {
    fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    fn read_rows(&self, row0: usize, nrows: usize, out: &mut [f64]) -> Result<()> {
        check_block_bounds(self.shape(), row0, nrows, out.len());
        faults::check("stream.read")?;
        let nbytes = out.len() * 8;
        let mut bytes = vec![0u8; nbytes];
        // Pop an idle handle (or open a private one); IO happens with no
        // lock held, so concurrent block reads proceed in parallel.
        let pooled = self.handles.lock().ok().and_then(|mut g| g.pop());
        let mut f = match pooled {
            Some(f) => f,
            None => fs::File::open(&self.path)?,
        };
        f.seek(SeekFrom::Start(
            HEADER_LEN + (row0 as u64) * (self.cols as u64) * 8,
        ))?;
        f.read_exact(&mut bytes)?;
        if let Ok(mut g) = self.handles.lock() {
            if g.len() < MAX_IDLE_HANDLES {
                g.push(f);
            }
        }
        for (x, chunk) in out.iter_mut().zip(bytes.chunks_exact(8)) {
            *x = f64::from_le_bytes(chunk.try_into().unwrap());
        }
        Ok(())
    }

    fn checkpoint_key(&self) -> Option<Vec<u8>> {
        // No cache_key — the file's bytes can change between jobs, so
        // content can't be proven stable. But (path, shape) is a stable
        // *claimed* identity, exactly what checkpoint tagging needs:
        // files are the primary out-of-core input, and a crash-resumed
        // job re-reads the same path anyway.
        let mut key = Vec::with_capacity(32);
        key.push(b'F');
        key.extend_from_slice(self.path.to_string_lossy().as_bytes());
        key.extend_from_slice(&(self.rows as u64).to_le_bytes());
        key.extend_from_slice(&(self.cols as u64).to_le_bytes());
        Some(key)
    }
}

// ---------------------------------------------------------------------------
// Streaming configuration
// ---------------------------------------------------------------------------

/// Memory and pipelining policy for a streamed sweep — the `[stream]`
/// config section (`block_rows`, `budget_mb`, `prefetch`) and the
/// `--stream-block` / `--stream-budget-mb` / `--no-prefetch` CLI flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamConfig {
    /// Rows per resident block. `0` (the default) derives the block
    /// height from `budget_mb`.
    pub block_rows: usize,
    /// Approximate budget for the resident row block, in MiB (used when
    /// `block_rows` is 0). The budget governs the f64 block buffer; the
    /// sweep's small outputs (block × K products) are extra. With
    /// prefetch on, two block buffers circulate instead of one.
    pub budget_mb: usize,
    /// Double-buffered background reads: a reader thread fills block
    /// `i+1` while block `i` is in the GEMM (default on). Never changes
    /// results — blocks are consumed in the same ascending order.
    pub prefetch: bool,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig { block_rows: 0, budget_mb: 64, prefetch: true }
    }
}

impl StreamConfig {
    /// The block height this policy yields for an m×n matrix: explicit
    /// `block_rows` clamped to `[1, m]`, else `budget_mb` divided by the
    /// f64 row footprint.
    pub fn resolve_block_rows(&self, m: usize, n: usize) -> usize {
        let cap = m.max(1);
        if self.block_rows > 0 {
            self.block_rows.min(cap)
        } else {
            let bytes = self.budget_mb.max(1).saturating_mul(1 << 20);
            (bytes / (8 * n.max(1))).clamp(1, cap)
        }
    }
}

// ---------------------------------------------------------------------------
// I/O observability
// ---------------------------------------------------------------------------

/// Cumulative I/O counters of a [`Streamed`] wrapper: full passes
/// (sweeps) over the source, row blocks read, and payload bytes pulled.
/// Shared across clones of one wrapper (the handle is an `Arc`), read
/// with [`Streamed::stats`]; the coordinator aggregates them per job
/// into the service metrics (`stream_passes` / `stream_bytes_read`).
#[derive(Debug, Default)]
pub struct SourceStats {
    passes: AtomicU64,
    blocks: AtomicU64,
    bytes_read: AtomicU64,
    retries: AtomicU64,
}

impl SourceStats {
    /// Point-in-time snapshot of the counters.
    pub fn snapshot(&self) -> SourceStatsSnapshot {
        SourceStatsSnapshot {
            passes: self.passes.load(Ordering::Relaxed),
            blocks: self.blocks.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time view of [`SourceStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SourceStatsSnapshot {
    /// Full sweeps over the source (one per product/reduction; the
    /// pass-budget currency: `2 + 2q` per Exact factorization, `≤ q + 2`
    /// per Fused one).
    pub passes: u64,
    /// Row blocks read.
    pub blocks: u64,
    /// Payload bytes read (`rows × cols × 8` per block).
    pub bytes_read: u64,
    /// Transient read failures retried inside a sweep (under the
    /// wrapper's [`RetryPolicy`]); each counted attempt eventually
    /// succeeded or exhausted the budget.
    pub retries: u64,
}

// ---------------------------------------------------------------------------
// The MatVecOps wrapper
// ---------------------------------------------------------------------------

/// Out-of-core [`MatVecOps`]: computes every product and reduction the
/// SVD algorithms need in one block-at-a-time sweep over a
/// [`MatrixSource`], dispatching each resident block through the
/// pool-aware GEMM kernels. Sweeps run double-buffered by default — a
/// background reader fills the next block while the current one is in
/// the GEMM (see the module docs).
///
/// Results are byte-identical to the in-memory [`Dense`] path for every
/// `block_rows`, every pool size, and with prefetch on or off (see the
/// module docs for why), so a streamed factorization replays a seeded
/// in-memory run exactly.
///
/// IO errors during a sweep panic with context (see the module docs).
#[derive(Debug, Clone)]
pub struct Streamed<S> {
    source: S,
    block_rows: usize,
    prefetch: bool,
    retry: RetryPolicy,
    stats: Arc<SourceStats>,
    cancel: Option<Arc<AtomicBool>>,
}

impl<S: MatrixSource> Streamed<S> {
    /// Wrap `source` under the given memory/pipelining policy. Sweeps
    /// fail fast on read errors ([`RetryPolicy::none`]) until a policy
    /// is attached via [`Streamed::with_retry`] (the coordinator does
    /// so for every submitted job).
    pub fn new(source: S, config: &StreamConfig) -> Streamed<S> {
        let (m, n) = source.shape();
        let block_rows = config.resolve_block_rows(m, n);
        Streamed {
            source,
            block_rows,
            prefetch: config.prefetch,
            retry: RetryPolicy::none(),
            stats: Arc::new(SourceStats::default()),
            cancel: None,
        }
    }

    /// Wrap `source` with an explicit block height (clamped to `[1, m]`)
    /// and prefetch on.
    pub fn with_block_rows(source: S, block_rows: usize) -> Streamed<S> {
        let (m, _) = source.shape();
        Streamed {
            source,
            block_rows: block_rows.clamp(1, m.max(1)),
            prefetch: true,
            retry: RetryPolicy::none(),
            stats: Arc::new(SourceStats::default()),
            cancel: None,
        }
    }

    /// Builder-style prefetch override (e.g. `--no-prefetch`).
    pub fn with_prefetch(mut self, prefetch: bool) -> Streamed<S> {
        self.prefetch = prefetch;
        self
    }

    /// Builder-style retry policy for transient read errors: a failed
    /// `read_rows` classified as I/O (not a shape/config bug) is
    /// retried with backoff inside the sweep, up to the policy's
    /// budget. Retries never change results — a block is only consumed
    /// once a read fully succeeds, in the same ascending order.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Streamed<S> {
        self.retry = retry;
        self
    }

    /// Attach a retry policy in place (coordinator submission path).
    pub(crate) fn set_retry(&mut self, retry: RetryPolicy) {
        self.retry = retry;
    }

    /// This wrapper with a fresh, zeroed [`SourceStats`] handle (same
    /// source and policy). Clones of a wrapper share one counter
    /// handle; the coordinator re-wraps each submission so per-job
    /// metric deltas from concurrently running cloned specs cannot
    /// interleave.
    pub fn fresh_stats(&self) -> Streamed<S>
    where
        S: Clone,
    {
        Streamed {
            source: self.source.clone(),
            block_rows: self.block_rows,
            prefetch: self.prefetch,
            retry: self.retry,
            stats: Arc::new(SourceStats::default()),
            cancel: None,
        }
    }

    /// Attach a cooperative cancel flag (shared with the coordinator's
    /// job handle). Both sweep paths stop fetching blocks once the flag
    /// is set, leaving the consumer's accumulator truncated — callers
    /// must re-check the flag before trusting any sweep result (the
    /// factorization loop in `svd::shifted` does).
    pub(crate) fn set_cancel(&mut self, flag: Arc<AtomicBool>) {
        self.cancel = Some(flag);
    }

    /// Whether an attached cancel flag is set.
    fn is_cancelled(&self) -> bool {
        self.cancel
            .as_ref()
            .is_some_and(|c| c.load(Ordering::Relaxed))
    }

    /// Rows per resident block.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Whether sweeps run the double-buffered prefetch pipeline.
    pub fn prefetch(&self) -> bool {
        self.prefetch
    }

    /// Borrow the underlying source.
    pub fn source(&self) -> &S {
        &self.source
    }

    /// Snapshot of the cumulative I/O counters (shared across clones of
    /// this wrapper).
    pub fn stats(&self) -> SourceStatsSnapshot {
        self.stats.snapshot()
    }

    /// One pass over the matrix: `f(row0, block)` for consecutive row
    /// blocks in ascending order — prefetched on a background reader
    /// thread when enabled, serial otherwise. Either way `f` observes
    /// the same blocks in the same order on the calling thread, so
    /// accumulation order (the byte-identity contract) never changes.
    fn sweep(&self, mut f: impl FnMut(usize, &Dense)) {
        let (m, n) = self.source.shape();
        self.stats.passes.fetch_add(1, Ordering::Relaxed);
        if self.prefetch && self.block_rows < m {
            self.sweep_prefetched(m, n, &mut f);
            return;
        }
        // Serial sweep: one buffer recycled across blocks, so peak
        // residency is one `block_rows × n` block.
        let mut buf: Vec<f64> = Vec::new();
        let mut row0 = 0;
        while row0 < m {
            if self.is_cancelled() {
                return;
            }
            let nr = self.block_rows.min(m - row0);
            buf.resize(nr * n, 0.0);
            read_block_retrying(&self.source, false, row0, nr, m, &mut buf, self.retry, &self.stats);
            self.stats.blocks.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_read
                .fetch_add((nr * n * 8) as u64, Ordering::Relaxed);
            let block = Dense::from_vec(nr, n, std::mem::take(&mut buf));
            f(row0, &block);
            buf = block.into_vec();
            row0 += nr;
        }
    }

    /// Double-buffered sweep: a background reader fills block `i+1`
    /// while the caller consumes block `i`. Two buffers circulate — one
    /// in flight, one in the GEMM — so peak residency is two blocks. A
    /// reader-side IO failure panics with the same context as the
    /// serial path (re-raised on the calling thread).
    ///
    /// The reader prefers an io-pool worker
    /// ([`crate::parallel::ThreadPool::spawn_scoped`] on the effective
    /// io pool), keeping blocking reads off compute threads. A
    /// saturated io pool — every worker already held by a spawned job —
    /// refuses the task, and the sweep falls back to a plain scoped
    /// thread: degradation, never a deadlock. Both paths consume blocks
    /// in ascending order on the calling thread, so the byte-identity
    /// contract is unaffected by which one ran.
    fn sweep_prefetched(&self, m: usize, n: usize, f: &mut impl FnMut(usize, &Dense)) {
        let block_rows = self.block_rows;
        let source = &self.source;
        let retry = self.retry;
        {
            let stats = Arc::clone(&self.stats);
            let (full_tx, full_rx) = mpsc::sync_channel::<(usize, Dense)>(1);
            let (empty_tx, empty_rx) = mpsc::channel::<Vec<f64>>();
            for _ in 0..2 {
                let _ = empty_tx.send(Vec::new());
            }
            let task = parallel::with_current_io(|io| {
                io.spawn_scoped(Box::new(move || {
                    reader_loop(source, m, n, block_rows, retry, &stats, empty_rx, full_tx)
                }))
            });
            if let Some(task) = task {
                self.consume_blocks(m, n, f, &full_rx, &empty_tx);
                // Unblocks a reader mid-`send` after a cancel break (its
                // send fails and it exits); a no-op on the normal path.
                drop(full_rx);
                // Re-raises a reader panic (source + rows context).
                task.join();
                return;
            }
        }
        std::thread::scope(|scope| {
            let stats = Arc::clone(&self.stats);
            let (full_tx, full_rx) = mpsc::sync_channel::<(usize, Dense)>(1);
            let (empty_tx, empty_rx) = mpsc::channel::<Vec<f64>>();
            for _ in 0..2 {
                let _ = empty_tx.send(Vec::new());
            }
            let reader = scope.spawn(move || {
                reader_loop(source, m, n, block_rows, retry, &stats, empty_rx, full_tx)
            });
            self.consume_blocks(m, n, f, &full_rx, &empty_tx);
            drop(full_rx);
            if let Err(payload) = reader.join() {
                // Preserve the reader's panic message (source + rows).
                std::panic::resume_unwind(payload);
            }
        });
    }

    /// The consumer half of a prefetched sweep: drain blocks in
    /// ascending row order, feeding each to `f` and recycling its
    /// buffer. A closed `full_rx` means the reader panicked mid-sweep;
    /// the caller joins the reader afterwards to re-raise it.
    fn consume_blocks(
        &self,
        m: usize,
        n: usize,
        f: &mut impl FnMut(usize, &Dense),
        full_rx: &mpsc::Receiver<(usize, Dense)>,
        empty_tx: &mpsc::Sender<Vec<f64>>,
    ) {
        let mut next_row = 0;
        while next_row < m {
            if self.is_cancelled() {
                break;
            }
            let Ok((row0, block)) = full_rx.recv() else { break };
            self.stats.blocks.fetch_add(1, Ordering::Relaxed);
            self.stats
                .bytes_read
                .fetch_add((block.rows() * n * 8) as u64, Ordering::Relaxed);
            f(row0, &block);
            next_row = row0 + block.rows();
            let _ = empty_tx.send(block.into_vec());
        }
    }
}

/// The reader half of a prefetched sweep (shared by the io-pool and
/// scoped-thread paths): fill recycled buffers with consecutive row
/// blocks and hand them over in ascending order. Transient read
/// failures retry under `retry` before the loop gives up (panicking
/// with the [`SOURCE_IO_PANIC`] context, re-raised on the caller).
#[allow(clippy::too_many_arguments)]
fn reader_loop<S: MatrixSource>(
    source: &S,
    m: usize,
    n: usize,
    block_rows: usize,
    retry: RetryPolicy,
    stats: &SourceStats,
    empty_rx: mpsc::Receiver<Vec<f64>>,
    full_tx: mpsc::SyncSender<(usize, Dense)>,
) {
    let mut row0 = 0;
    while row0 < m {
        let nr = block_rows.min(m - row0);
        // A missing recycled buffer (consumer gone) just means a fresh
        // allocation for the final read.
        let mut buf = empty_rx.recv().unwrap_or_default();
        buf.resize(nr * n, 0.0);
        read_block_retrying(source, true, row0, nr, m, &mut buf, retry, stats);
        if full_tx.send((row0, Dense::from_vec(nr, n, buf))).is_err() {
            return; // consumer stopped; no one wants more blocks
        }
        row0 += nr;
    }
}

/// Read one row block, retrying transient (I/O-classified) failures
/// under `retry` with deterministic backoff. Shape/config failures are
/// not transient and fail on the first attempt. Exhausting the budget
/// panics with [`SOURCE_IO_PANIC`] context including the attempt count
/// — the [`MatVecOps`] signatures are infallible, and the coordinator
/// maps the marker back to a typed [`Error::Io`].
#[allow(clippy::too_many_arguments)]
fn read_block_retrying<S: MatrixSource>(
    source: &S,
    prefetched: bool,
    row0: usize,
    nr: usize,
    m: usize,
    buf: &mut [f64],
    retry: RetryPolicy,
    stats: &SourceStats,
) {
    let mut attempts: u32 = 0;
    loop {
        attempts += 1;
        // The prefetch pipeline has its own fail-point so chaos runs
        // can target the background reader specifically.
        let result = if prefetched {
            faults::check("stream.prefetch")
                .map_err(Error::Io)
                .and_then(|()| source.read_rows(row0, nr, buf))
        } else {
            source.read_rows(row0, nr, buf)
        };
        match result {
            Ok(()) => return,
            Err(e @ Error::Io(_)) if retry.allows(attempts) => {
                stats.retries.fetch_add(1, Ordering::Relaxed);
                crate::log_warn!(
                    "transient read failure on rows {row0}..{} (attempt {attempts}): {e}; retrying",
                    row0 + nr
                );
                // Keyed by block so concurrent sweeps spread out while
                // a seeded replay reproduces the exact schedule.
                retry.sleep_backoff(attempts, (row0 as u64) ^ 0x5743_7265_7472_7921);
            }
            Err(e) => panic!(
                "{SOURCE_IO_PANIC} {row0}..{} of {m} after {attempts} attempt(s): {e}",
                row0 + nr
            ),
        }
    }
}

impl<S: MatrixSource> MatVecOps for Streamed<S> {
    fn shape(&self) -> (usize, usize) {
        self.source.shape()
    }

    fn mm(&self, b: &Dense) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(n, b.rows(), "streamed mm shape mismatch");
        let k = b.cols();
        let mut c = Dense::zeros(m, k);
        self.sweep(|row0, block| {
            let cb = gemm::matmul(block, b);
            c.data_mut()[row0 * k..(row0 + block.rows()) * k].copy_from_slice(cb.data());
        });
        c
    }

    fn tmm(&self, b: &Dense) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(m, b.rows(), "streamed tmm shape mismatch");
        let k = b.cols();
        let mut c = Dense::zeros(n, k);
        self.sweep(|row0, block| {
            let nr = block.rows();
            let b_rows = Dense::from_vec(nr, k, b.data()[row0 * k..(row0 + nr) * k].to_vec());
            gemm::tmatmul_acc(block, &b_rows, &mut c);
        });
        c
    }

    fn mm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(n, b.rows(), "streamed mm_rank1 shape mismatch");
        let k = b.cols();
        assert_eq!(u.len(), m, "u length");
        assert_eq!(v.len(), k, "v length");
        let mut c = Dense::zeros(m, k);
        self.sweep(|row0, block| {
            let nr = block.rows();
            let cb = gemm::matmul_rank1(block, b, &u[row0..row0 + nr], v);
            c.data_mut()[row0 * k..(row0 + nr) * k].copy_from_slice(cb.data());
        });
        c
    }

    fn tmm_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(m, b.rows(), "streamed tmm_rank1 shape mismatch");
        let k = b.cols();
        assert_eq!(u.len(), n, "u length");
        assert_eq!(v.len(), k, "v length");
        let mut c = Dense::zeros(n, k);
        // Seed with the downdate via the one-shot kernel's own epilogue
        // (shared helper — the two paths cannot drift apart), then
        // accumulate block contributions on top.
        parallel::with_current(|pool| gemm::seed_downdate(&mut c, u, v, pool));
        self.sweep(|row0, block| {
            let nr = block.rows();
            let b_rows = Dense::from_vec(nr, k, b.data()[row0 * k..(row0 + nr) * k].to_vec());
            gemm::tmatmul_acc(block, &b_rows, &mut c);
        });
        c
    }

    /// The fused power-iteration leg: `Z = X̄ᵀ(X̄·W)` in **one** pass
    /// over the source. Per block `i` the resident rows service both
    /// products — `Yᵢ = X̄ᵢ·W` (rank-1 shift fused via the shared
    /// [`gemm::matmul_rank1`] epilogue) immediately feeds
    /// `Z += XᵢᵀYᵢ`, with the left shift term `1·(μᵀY)` accumulated
    /// alongside and subtracted once at the end. This halves the data
    /// passes of the default two-product implementation and is what
    /// makes the `PassPolicy::Fused` `q + 2` budget possible.
    fn gram_sweep(&self, w: &Dense, mu: &[f64]) -> Dense {
        let (m, n) = self.shape();
        assert_eq!(w.rows(), n, "streamed gram_sweep shape mismatch");
        assert_eq!(mu.len(), m, "streamed gram_sweep mu length");
        let l = w.cols();
        let shifted = mu.iter().any(|&v| v != 0.0);
        let colsum_w: Vec<f64> = if shifted {
            crate::svd::ops::colsums(w)
        } else {
            Vec::new()
        };
        let mut z = Dense::zeros(n, l);
        let mut muy = vec![0.0; l]; // running μᵀY
        self.sweep(|row0, block| {
            let nr = block.rows();
            let y = if shifted {
                gemm::matmul_rank1(block, w, &mu[row0..row0 + nr], &colsum_w)
            } else {
                gemm::matmul(block, w)
            };
            gemm::tmatmul_acc(block, &y, &mut z);
            if shifted {
                for (local, &mi) in mu[row0..row0 + nr].iter().enumerate() {
                    if mi != 0.0 {
                        for (acc, &yv) in muy.iter_mut().zip(y.row(local)) {
                            *acc += mi * yv;
                        }
                    }
                }
            }
        });
        if shifted {
            // Z = XᵀY − 1·(μᵀY): subtract the accumulated row vector
            // from every output row.
            for i in 0..n {
                for (zx, &s) in z.row_mut(i).iter_mut().zip(&muy) {
                    *zx -= s;
                }
            }
        }
        z
    }

    fn row_means(&self) -> Vec<f64> {
        let (m, _) = self.shape();
        let mut mu = Vec::with_capacity(m);
        self.sweep(|_, block| mu.extend(block.row_means()));
        mu
    }

    fn sq_fro(&self) -> f64 {
        // One accumulator carried across blocks: the exact element order
        // of the dense reduction, hence bit-identical.
        let mut s = 0.0;
        self.sweep(|_, block| {
            for &x in block.data() {
                s += x * x;
            }
        });
        s
    }

    fn sq_fro_shifted(&self, mu: &[f64]) -> f64 {
        // One fused source sweep (vs two for the trait default), with a
        // single accumulator carried across blocks in the dense
        // row-major element order — bit-identical to the in-memory
        // `Dense` override for every block size and prefetch setting.
        let (m, _) = self.shape();
        assert_eq!(mu.len(), m, "sq_fro_shifted mu length");
        let mut s = 0.0;
        self.sweep(|row0, block| {
            for local in 0..block.rows() {
                let mi = mu[row0 + local];
                for &x in block.row(local) {
                    let d = x - mi;
                    s += d * d;
                }
            }
        });
        s
    }

    fn stored_entries(&self) -> usize {
        // Logical dense size; the *resident* footprint is block_rows·n.
        let (m, n) = self.shape();
        m * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    fn bits(x: &Dense) -> Vec<u64> {
        x.data().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn in_memory_source_round_trips() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::gaussian(13, 7, &mut rng);
        let src = InMemorySource::new(x.clone());
        assert_eq!(src.shape(), (13, 7));
        let back = src.materialize().unwrap();
        assert_eq!(bits(&back), bits(&x));
        let mut two = vec![0.0; 2 * 7];
        src.read_rows(5, 2, &mut two).unwrap();
        assert_eq!(&two[..7], x.row(5));
        assert_eq!(&two[7..], x.row(6));
    }

    #[test]
    fn generator_source_is_block_invariant() {
        let src = GeneratorSource::new(23, 11, Distribution::Uniform, 42).unwrap();
        let whole = src.materialize().unwrap();
        // Any partition reproduces the same rows.
        for bl in [1usize, 4, 10, 23] {
            let streamed = Streamed::with_block_rows(src, bl);
            let mut rebuilt = Vec::new();
            streamed.sweep(|_, block| rebuilt.extend_from_slice(block.data()));
            let same = whole
                .data()
                .iter()
                .zip(&rebuilt)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "block size {bl} changed the generated matrix");
        }
    }

    #[test]
    fn generator_rejects_zipf() {
        assert!(GeneratorSource::new(4, 4, Distribution::Zipf, 0).is_err());
    }

    #[test]
    fn csr_source_matches_to_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let sp = Csr::random(19, 33, 0.2, &mut rng, |r| r.next_uniform() + 0.1);
        let src = CsrRowSource::new(sp.clone());
        assert_eq!(bits(&src.materialize().unwrap()), bits(&sp.to_dense()));
    }

    #[test]
    fn streamed_ops_match_dense_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = Dense::from_fn(37, 53, |_, _| rng.next_uniform());
        let b = Dense::gaussian(53, 6, &mut rng);
        let bt = Dense::gaussian(37, 6, &mut rng);
        let u_m: Vec<f64> = (0..37).map(|_| rng.next_gaussian()).collect();
        let u_n: Vec<f64> = (0..53).map(|_| rng.next_gaussian()).collect();
        let v6: Vec<f64> = (0..6).map(|_| rng.next_gaussian()).collect();
        for bl in [1usize, 5, 16, 37] {
            let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), bl);
            assert_eq!(bits(&s.mm(&b)), bits(&MatVecOps::mm(&x, &b)), "mm bl={bl}");
            assert_eq!(
                bits(&s.tmm(&bt)),
                bits(&MatVecOps::tmm(&x, &bt)),
                "tmm bl={bl}"
            );
            assert_eq!(
                bits(&s.mm_rank1(&b, &u_m, &v6)),
                bits(&x.mm_rank1(&b, &u_m, &v6)),
                "mm_rank1 bl={bl}"
            );
            assert_eq!(
                bits(&s.tmm_rank1(&bt, &u_n, &v6)),
                bits(&x.tmm_rank1(&bt, &u_n, &v6)),
                "tmm_rank1 bl={bl}"
            );
            assert_eq!(MatVecOps::row_means(&s), Dense::row_means(&x), "bl={bl}");
            assert_eq!(
                MatVecOps::sq_fro(&s).to_bits(),
                MatVecOps::sq_fro(&x).to_bits(),
                "sq_fro bl={bl}"
            );
            assert_eq!(s.stored_entries(), 37 * 53);
        }
    }

    #[test]
    fn stream_config_resolution() {
        let cfg = |block_rows, budget_mb| StreamConfig { block_rows, budget_mb, prefetch: true };
        // Explicit block_rows wins and clamps.
        assert_eq!(cfg(10, 1).resolve_block_rows(100, 50), 10);
        assert_eq!(cfg(500, 1).resolve_block_rows(100, 50), 100);
        // Budget-derived: 1 MiB / (8 B × 1024 cols) = 128 rows.
        assert_eq!(cfg(0, 1).resolve_block_rows(10_000, 1024), 128);
        // Never below 1 row, even for absurdly wide matrices.
        assert_eq!(cfg(0, 1).resolve_block_rows(10, 1 << 30), 1);
        // Prefetch defaults on and threads through the constructor.
        assert!(StreamConfig::default().prefetch);
        let s = Streamed::new(
            InMemorySource::new(Dense::zeros(4, 3)),
            &StreamConfig { block_rows: 2, budget_mb: 1, prefetch: false },
        );
        assert!(!s.prefetch());
        assert!(s.with_prefetch(true).prefetch());
    }

    #[test]
    fn prefetched_sweep_matches_serial_bitwise_and_counts_io() {
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let x = Dense::from_fn(41, 13, |_, _| rng.next_uniform());
        for bl in [1usize, 5, 40, 41] {
            let serial =
                Streamed::with_block_rows(InMemorySource::new(x.clone()), bl).with_prefetch(false);
            let pre = Streamed::with_block_rows(InMemorySource::new(x.clone()), bl);
            let mut got_serial = Vec::new();
            serial.sweep(|_, block| got_serial.extend_from_slice(block.data()));
            let mut got_pre = Vec::new();
            pre.sweep(|_, block| got_pre.extend_from_slice(block.data()));
            let same = got_serial
                .iter()
                .zip(&got_pre)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same && got_pre.len() == 41 * 13, "bl={bl}");
            // Both account identically: 1 pass, same blocks and bytes.
            let (s, p) = (serial.stats(), pre.stats());
            assert_eq!(s, p, "bl={bl}");
            assert_eq!(s.passes, 1);
            assert_eq!(s.blocks as usize, 41usize.div_ceil(bl));
            assert_eq!(s.bytes_read, (41 * 13 * 8) as u64);
        }
    }

    #[test]
    fn gram_sweep_override_matches_default_expansion() {
        let mut rng = Xoshiro256pp::seed_from_u64(10);
        let x = Dense::from_fn(37, 29, |_, _| rng.next_uniform());
        let w = Dense::gaussian(29, 5, &mut rng);
        let mu = x.row_means();
        // Reference: the trait's default two-product expansion on Dense.
        let want = MatVecOps::gram_sweep(&x, &w, &mu);
        for bl in [1usize, 7, 37] {
            for prefetch in [false, true] {
                let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), bl)
                    .with_prefetch(prefetch);
                let got = MatVecOps::gram_sweep(&s, &w, &mu);
                assert!(
                    crate::linalg::fro_diff(&got, &want) < 1e-9,
                    "bl={bl} prefetch={prefetch}"
                );
                // The whole point: one source pass, not two.
                assert_eq!(s.stats().passes, 1, "bl={bl} prefetch={prefetch}");
            }
        }
        // Unshifted gram sweep equals Xᵀ(XW).
        let zero = vec![0.0; 37];
        let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), 11);
        let got = MatVecOps::gram_sweep(&s, &w, &zero);
        let want = MatVecOps::tmm(&x, &MatVecOps::mm(&x, &w));
        assert!(crate::linalg::fro_diff(&got, &want) < 1e-10);
    }

    #[test]
    fn sq_fro_shifted_matches_dense_bitwise_in_one_pass() {
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let x = Dense::from_fn(31, 23, |_, _| rng.next_uniform());
        let mu = x.row_means();
        let want = MatVecOps::sq_fro_shifted(&x, &mu);
        for bl in [1usize, 6, 31] {
            for prefetch in [false, true] {
                let s = Streamed::with_block_rows(InMemorySource::new(x.clone()), bl)
                    .with_prefetch(prefetch);
                let got = s.sq_fro_shifted(&mu);
                // Same carried-accumulator element order → bit-identical.
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "bl={bl} prefetch={prefetch}"
                );
                // One fused pass, not the default's two.
                assert_eq!(s.stats().passes, 1, "bl={bl} prefetch={prefetch}");
            }
        }
    }

    #[test]
    fn file_round_trip_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x = Dense::gaussian(29, 17, &mut rng);
        let path = std::env::temp_dir().join("srsvd_stream_test_roundtrip.bin");
        let src = write_matrix(&path, &x).unwrap();
        assert_eq!(src.shape(), (29, 17));
        assert_eq!(bits(&src.materialize().unwrap()), bits(&x));
        // Partial block read.
        let mut rows = vec![0.0; 3 * 17];
        src.read_rows(11, 3, &mut rows).unwrap();
        assert_eq!(&rows[..17], x.row(11));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn file_writer_enforces_shape() {
        let path = std::env::temp_dir().join("srsvd_stream_test_shape.bin");
        let mut w = FileWriter::create(&path, 2, 3).unwrap();
        // Not a whole row.
        assert!(w.append_rows(&[1.0, 2.0]).is_err());
        w.append_rows(&[1.0, 2.0, 3.0]).unwrap();
        // Too many rows.
        assert!(w.append_rows(&[0.0; 6]).is_err());
        // finish() before all rows are written fails.
        assert!(w.finish().is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_rejects_garbage() {
        let path = std::env::temp_dir().join("srsvd_stream_test_garbage.bin");
        std::fs::write(&path, b"definitely not a matrix").unwrap();
        assert!(FileSource::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn transient_read_errors_retry_to_success() {
        let _g = faults::test_lock();
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let x = Dense::gaussian(17, 5, &mut rng);
        let path = std::env::temp_dir().join("srsvd_stream_test_retry.bin");
        let src = write_matrix(&path, &x).unwrap();
        let retry = RetryPolicy {
            max_attempts: 4,
            backoff_base_ms: 0,
            backoff_max_ms: 0,
            jitter: false,
        };
        for prefetch in [false, true] {
            // Two injected failures, then clean: the retry loop must
            // absorb both and still rebuild the matrix bit-exactly.
            faults::arm("stream.read=err:2@1.0").unwrap();
            let s = Streamed::with_block_rows(&src, 6)
                .with_prefetch(prefetch)
                .with_retry(retry);
            let mut rebuilt = Vec::new();
            s.sweep(|_, block| rebuilt.extend_from_slice(block.data()));
            faults::disarm();
            assert_eq!(rebuilt.len(), 17 * 5, "prefetch={prefetch}");
            let same = x
                .data()
                .iter()
                .zip(&rebuilt)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "prefetch={prefetch}");
            assert_eq!(s.stats().retries, 2, "prefetch={prefetch}");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn exhausted_retry_budget_panics_with_attempt_count() {
        let _g = faults::test_lock();
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let x = Dense::gaussian(5, 3, &mut rng);
        let path = std::env::temp_dir().join("srsvd_stream_test_retry_exhaust.bin");
        let src = write_matrix(&path, &x).unwrap();
        faults::arm("stream.read=err@1.0").unwrap();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let s = Streamed::with_block_rows(&src, 5)
                .with_prefetch(false)
                .with_retry(RetryPolicy {
                    max_attempts: 3,
                    backoff_base_ms: 0,
                    backoff_max_ms: 0,
                    jitter: false,
                });
            s.sweep(|_, _| {});
        }));
        faults::disarm();
        let payload = result.expect_err("sweep must panic once retries exhaust");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains(SOURCE_IO_PANIC) && msg.contains("3 attempt"),
            "{msg}"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_writes_are_reported_short() {
        let _g = faults::test_lock();
        let path = std::env::temp_dir().join("srsvd_stream_test_torn.bin");
        faults::arm("stream.write=partial_write:1@1.0").unwrap();
        let mut w = FileWriter::create(&path, 2, 3).unwrap();
        let err = w.append_rows(&[1.0; 6]).unwrap_err();
        faults::disarm();
        assert!(format!("{err}").contains("short write"), "{err}");
        // The file is truncated, not silently wrong: opening it fails.
        assert!(FileSource::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn spill_streams_any_source() {
        let src = GeneratorSource::new(31, 9, Distribution::Exponential, 7).unwrap();
        let path = std::env::temp_dir().join("srsvd_stream_test_spill.bin");
        let file = spill_to_file(&src, &path, 8).unwrap();
        assert_eq!(
            bits(&file.materialize().unwrap()),
            bits(&src.materialize().unwrap())
        );
        let _ = std::fs::remove_file(&path);
    }
}
