//! Row-major dense f64 matrix.
//!
//! Deliberately simple: contiguous `Vec<f64>`, row-major, with the
//! handful of structural ops the SVD algorithms need. Heavy compute
//! (products) lives in [`crate::linalg::gemm`].

use crate::rng::Rng;

/// A dense `rows x cols` matrix, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Dense {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Dense { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity-like matrix (1s on the main diagonal).
    pub fn eye(n: usize) -> Self {
        let mut m = Dense::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator function.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Dense { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Dense { rows, cols, data }
    }

    /// Standard-Gaussian random matrix (the test matrix Ω of Alg. 1).
    pub fn gaussian(rows: usize, cols: usize, rng: &mut dyn Rng) -> Self {
        Dense::from_fn(rows, cols, |_, _| rng.next_gaussian())
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the row-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutably borrow the row-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i` as a slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out (row-major storage makes columns strided).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Overwrite column `j` with `v` (length must equal `rows`).
    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Dense {
        let mut t = Dense::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for bi in (0..self.rows).step_by(B) {
            for bj in (0..self.cols).step_by(B) {
                for i in bi..(bi + B).min(self.rows) {
                    for j in bj..(bj + B).min(self.cols) {
                        t[(j, i)] = self[(i, j)];
                    }
                }
            }
        }
        t
    }

    /// Keep the first `k` columns.
    pub fn truncate_cols(&self, k: usize) -> Dense {
        assert!(k <= self.cols);
        Dense::from_fn(self.rows, k, |i, j| self[(i, j)])
    }

    /// Per-row mean: the PCA shifting vector μ (columns are samples).
    pub fn row_means(&self) -> Vec<f64> {
        let inv = 1.0 / self.cols as f64;
        (0..self.rows)
            .map(|i| self.row(i).iter().sum::<f64>() * inv)
            .collect()
    }

    /// Subtract `mu` from every column: the explicit densifying
    /// mean-centering (Eq. 2) the paper's algorithm avoids. Used by the
    /// RSVD baseline and by tests.
    pub fn subtract_column(&self, mu: &[f64]) -> Dense {
        assert_eq!(mu.len(), self.rows);
        let mut out = self.clone();
        for i in 0..self.rows {
            let m = mu[i];
            for x in out.row_mut(i) {
                *x -= m;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Squared L2 norm of column `j`.
    pub fn col_norm_sq(&self, j: usize) -> f64 {
        (0..self.rows).map(|i| self[(i, j)] * self[(i, j)]).sum()
    }

    /// `self * diag(d)` — scale columns (forming U·Σ).
    pub fn scale_cols(&self, d: &[f64]) -> Dense {
        assert_eq!(d.len(), self.cols);
        Dense::from_fn(self.rows, self.cols, |i, j| self[(i, j)] * d[j])
    }

    /// Matrix–vector product `self * v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>()
            })
            .collect()
    }

    /// Transposed matrix–vector product `selfᵀ * v`.
    pub fn tmatvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += vi * x;
            }
        }
        out
    }

    /// Convert to f32 row-major (the runtime artifact boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from f32 row-major data (artifact outputs).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Dense {
        assert_eq!(data.len(), rows * cols);
        Dense {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, x| m.max(x.abs()))
    }

    /// Consume the matrix and return its row-major buffer (zero-copy).
    /// The inverse of [`Dense::from_vec`]; the streaming layer uses the
    /// pair to recycle one block buffer across a whole sweep.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }
}

impl std::ops::Index<(usize, usize)> for Dense {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Dense {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn indexing_round_trip() {
        let mut m = Dense::zeros(3, 5);
        m[(2, 4)] = 7.5;
        assert_eq!(m[(2, 4)], 7.5);
        assert_eq!(m.row(2)[4], 7.5);
        assert_eq!(m.col(4)[2], 7.5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let a = Dense::gaussian(37, 53, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose()[(10, 20)], a[(20, 10)]);
    }

    #[test]
    fn row_means_and_centering() {
        let a = Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mu = a.row_means();
        assert_eq!(mu, vec![2.0, 5.0]);
        let c = a.subtract_column(&mu);
        assert_eq!(c.row(0), &[-1.0, 0.0, 1.0]);
        assert!(c.row_means().iter().all(|&m| m.abs() < 1e-15));
    }

    #[test]
    fn matvec_against_manual() {
        let a = Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.tmatvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn truncate_and_scale() {
        let a = Dense::from_fn(4, 4, |i, j| (i + j) as f64);
        let t = a.truncate_cols(2);
        assert_eq!(t.shape(), (4, 2));
        assert_eq!(t[(3, 1)], 4.0);
        let s = t.scale_cols(&[2.0, 0.5]);
        assert_eq!(s[(3, 0)], 6.0);
        assert_eq!(s[(3, 1)], 2.0);
    }

    #[test]
    fn f32_round_trip() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Dense::gaussian(8, 9, &mut rng);
        let b = Dense::from_f32(8, 9, &a.to_f32());
        assert!(crate::linalg::fro_diff(&a, &b) < 1e-5);
    }
}
