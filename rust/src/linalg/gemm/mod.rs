//! Blocked dense GEMM and the fused rank-1 variant — the native hot path.
//!
//! `matmul_rank1(A, B, u, v) = A·B − u·vᵀ` is the same primitive the
//! Pallas kernel implements (see `python/compile/kernels/`): every
//! product against the implicitly shifted matrix `X̄ = X − μ·1ᵀ` is a
//! plain product plus a rank-1 downdate, so the dense `X̄` never exists.
//!
//! Design: classic cache-blocked i-k-j loop order over row-major data.
//! The inner kernel is a j-vectorizable AXPY (`c_row += a_ik * b_row`)
//! dispatched at runtime through [`kernels`]: a portable scalar loop, an
//! AVX2 lane-exact variant (bit-identical to scalar), and an opt-in
//! packed AVX2+FMA microkernel for the [`Precision::Fast`] tier. Panels
//! are sized so a block of B and a row-strip of C stay L1/L2 resident.
//!
//! **Parallelism.** Large products are panel-parallel over rows of C on
//! the *cpu* pool of [`crate::parallel`] (sized by `SRSVD_THREADS` / the
//! `[parallel] threads` config knob; I/O work lives on the separate io
//! pool): each task runs the identical serial k-blocked kernel on a
//! disjoint row strip, so every output row is accumulated in exactly
//! the serial order and results are **bit-identical for every thread
//! count** — required, since every experiment is seeded. `Aᵀ·B`
//! products partition the *output* rows (columns of A) the same way.
//! Products below the gating thresholds run inline; the `*_pool` entry
//! points let benches pin an explicit pool.

pub mod kernels;

use super::Dense;
use crate::parallel::{self, par_row_chunks_min, ThreadPool};
use kernels::Kernel;
pub use kernels::{Precision, Simd};

/// Below this many multiply-adds a plain product runs inline — dispatch
/// overhead would swamp the win. (≈1M madds ≈ 100µs serial; the
/// perf_micro grid puts the plain-GEMM crossover between 2^19 and 2^21
/// depending on shape, EXPERIMENTS.md §Perf.)
const PAR_MIN_FLOPS: usize = 1 << 20;

/// Transpose products (`tmatmul*`, including the streaming
/// `tmatmul_acc` blocks) gate an octave earlier: the scatter kernel
/// re-reads all of A once per output pass and is memory-bound, so
/// fan-out pays for itself from ≈0.5M madds on (perf_micro crossover).
/// They previously inherited `PAR_MIN_FLOPS`, which left the mid-size
/// `X̄ᵀQ` products of every sweep serial.
const PAR_MIN_TFLOPS: usize = 1 << 19;

/// The rank-1 seed (`C = −u·vᵀ`) is a pure store pass with no reuse, so
/// splitting it only wins once the output alone overflows a private L2
/// by a wide margin (≈2M elements ≈ 16 MB). Below this it runs inline
/// on the calling thread even when the surrounding product fans out.
const PAR_MIN_SEED: usize = 1 << 21;

/// Below this many multiply-adds the Fast tier skips panel packing and
/// falls through to the exact-layout AVX2 kernel — pack setup would
/// dominate the product itself (think the small QR/Jacobi products
/// between sweeps).
#[cfg(target_arch = "x86_64")]
const FAST_PACK_MIN: usize = 1 << 14;

/// Tuning knobs for the blocked GEMM (exposed for the perf bench).
#[derive(Debug, Clone, Copy)]
pub struct MatmulPlan {
    /// Rows of A per panel (strip of C kept hot).
    pub mc: usize,
    /// Contraction-depth per panel (strip of B kept hot).
    pub kc: usize,
}

impl Default for MatmulPlan {
    fn default() -> Self {
        // f64: 256 KiB L2 / 8 bytes ≈ 32k doubles. kc×nc panel of B plus
        // mc×kc panel of A; kc=192, mc=48 measured best on this core (EXPERIMENTS.md §Perf).
        MatmulPlan { mc: 48, kc: 192 }
    }
}

/// `C = A · B` (blocked, parallel over row panels when large).
pub fn matmul(a: &Dense, b: &Dense) -> Dense {
    matmul_with_plan(a, b, MatmulPlan::default())
}

/// `C = A · B` with explicit blocking (the perf bench's plan sweep).
pub fn matmul_with_plan(a: &Dense, b: &Dense, plan: MatmulPlan) -> Dense {
    parallel::with_current(|pool| matmul_with_plan_pool(a, b, plan, pool))
}

/// `C = A · B` on an explicit pool (benches / determinism tests).
pub fn matmul_with_plan_pool(a: &Dense, b: &Dense, plan: MatmulPlan, pool: &ThreadPool) -> Dense {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, _k) = a.shape();
    let n = b.cols();
    let mut c = Dense::zeros(m, n);
    gemm_into(a, b, &mut c, plan, pool, 0);
    c
}

/// `C = A · B − u·vᵀ` — the shifted-product primitive.
pub fn matmul_rank1(a: &Dense, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
    matmul_rank1_with_plan(a, b, u, v, MatmulPlan::default())
}

/// `C = A · B − u·vᵀ` with explicit blocking.
pub fn matmul_rank1_with_plan(
    a: &Dense,
    b: &Dense,
    u: &[f64],
    v: &[f64],
    plan: MatmulPlan,
) -> Dense {
    parallel::with_current(|pool| matmul_rank1_with_plan_pool(a, b, u, v, plan, pool))
}

/// `C = A · B − u·vᵀ` on an explicit pool.
pub fn matmul_rank1_with_plan_pool(
    a: &Dense,
    b: &Dense,
    u: &[f64],
    v: &[f64],
    plan: MatmulPlan,
    pool: &ThreadPool,
) -> Dense {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, _) = a.shape();
    let n = b.cols();
    assert_eq!(u.len(), m, "u length");
    assert_eq!(v.len(), n, "v length");
    let mut c = Dense::zeros(m, n);
    // Fused epilogue: seed C with the downdate, then accumulate A·B on
    // top — one pass over C total. The O(mn) seed parallelizes on its
    // own (store-bound) threshold, and its cost is charged to the
    // product's gating work below so the fused op is gated as a whole.
    seed_downdate(&mut c, u, v, pool);
    gemm_into(a, b, &mut c, plan, pool, m.saturating_mul(n));
    c
}

/// Seed `C = −u·vᵀ` — the fused-downdate epilogue shared by both rank-1
/// kernels and the streaming path ([`crate::linalg::Streamed`]). Kept in
/// one place because the streamed byte-identical contract depends on the
/// seed being computed exactly the same way everywhere. Large seeds
/// split over disjoint row strips with the per-row arithmetic unchanged,
/// so the result stays byte-identical for every pool size.
pub(crate) fn seed_downdate(c: &mut Dense, u: &[f64], v: &[f64], pool: &ThreadPool) {
    debug_assert_eq!(u.len(), c.rows());
    debug_assert_eq!(v.len(), c.cols());
    let (m, n) = c.shape();
    if m == 0 || n == 0 {
        return;
    }
    let work = m.saturating_mul(n);
    par_row_chunks_min(pool, work, PAR_MIN_SEED, c.data_mut(), m, n, |row0, _nrows, chunk| {
        for (local, c_row) in chunk.chunks_exact_mut(n).enumerate() {
            let ui = u[row0 + local];
            if ui != 0.0 {
                for (cx, &vx) in c_row.iter_mut().zip(v) {
                    *cx = -ui * vx;
                }
            }
        }
    });
}

/// Accumulating core: `C += A · B`, cache-blocked, row-panel parallel.
/// The kernel is resolved here, once, on the calling thread (pool
/// workers would see default thread-locals) and passed by value into
/// the row-chunk closure. `extra_work` charges fused-epilogue flops to
/// the parallel-gating decision (the rank-1 paths pass `m*n`).
fn gemm_into(
    a: &Dense,
    b: &Dense,
    c: &mut Dense,
    plan: MatmulPlan,
    pool: &ThreadPool,
    extra_work: usize,
) {
    let (m, kdim) = a.shape();
    let n = b.cols();
    let kernel = kernels::select();
    let work = m
        .saturating_mul(n)
        .saturating_mul(kdim)
        .saturating_add(extra_work);
    #[cfg(target_arch = "x86_64")]
    if kernel == Kernel::Avx2Fast && work >= FAST_PACK_MIN {
        // Fast tier: pack B once (shared read-only by all chunks), then
        // run the 4x8 FMA microkernel per row strip.
        let packed = kernels::pack_b(b, plan.kc.max(1));
        par_row_chunks_min(pool, work, PAR_MIN_FLOPS, c.data_mut(), m, n, |row0, nrows, chunk| {
            let mut a_buf = Vec::new();
            kernels::gemm_rows_fast(a, &packed, row0, nrows, chunk, &mut a_buf);
        });
        return;
    }
    par_row_chunks_min(pool, work, PAR_MIN_FLOPS, c.data_mut(), m, n, |row0, nrows, chunk| {
        gemm_rows(a, b, row0, nrows, chunk, plan, kernel);
    });
}

/// The serial kernel on rows `row0 .. row0 + nrows` of C; `c_rows` is
/// that strip of C (`nrows * n` elements). Every parallel path funnels
/// here, so per-row accumulation order never depends on the pool size.
fn gemm_rows(
    a: &Dense,
    b: &Dense,
    row0: usize,
    nrows: usize,
    c_rows: &mut [f64],
    plan: MatmulPlan,
    kernel: Kernel,
) {
    let (_, kdim) = a.shape();
    let n = b.cols();
    let mc = plan.mc.max(1);
    let kc = plan.kc.max(1);

    for k0 in (0..kdim).step_by(kc) {
        let k1 = (k0 + kc).min(kdim);
        for i0 in (0..nrows).step_by(mc) {
            let i1 = (i0 + mc).min(nrows);
            for i in i0..i1 {
                let a_row = &a.row(row0 + i)[k0..k1];
                let c_row = &mut c_rows[i * n..(i + 1) * n];
                // 4-way k-unroll: quarters the number of passes over
                // c_row, the dominant memory traffic for wide C.
                // (Perf log: 2-way = 10.3 GFLOP/s, 4-way = see
                // EXPERIMENTS.md §Perf.) The AVX2 variant keeps the
                // exact per-element expression — see kernels::axpy4.
                let mut kk = 0;
                while kk + 3 < a_row.len() {
                    let av = [a_row[kk], a_row[kk + 1], a_row[kk + 2], a_row[kk + 3]];
                    kernels::axpy4(
                        kernel,
                        c_row,
                        av,
                        b.row(k0 + kk),
                        b.row(k0 + kk + 1),
                        b.row(k0 + kk + 2),
                        b.row(k0 + kk + 3),
                    );
                    kk += 4;
                }
                while kk < a_row.len() {
                    let aik = a_row[kk];
                    if aik != 0.0 {
                        kernels::axpy1(kernel, c_row, aik, b.row(k0 + kk));
                    }
                    kk += 1;
                }
            }
        }
    }
}

/// `C = Aᵀ · B` without forming Aᵀ (A is m×n, B is m×k → C is n×k).
///
/// Used for the `X̄ᵀQ` products: row-major X is traversed row-wise and
/// scattered into C. Parallelism partitions the *output* rows of C
/// (columns of A): each task scans all of A but reads only its column
/// window, so contributions to one output row always accumulate in
/// serial `i` order — thread-count invariant.
pub fn tmatmul(a: &Dense, b: &Dense) -> Dense {
    parallel::with_current(|pool| tmatmul_pool(a, b, pool))
}

/// `C = Aᵀ · B` on an explicit pool.
pub fn tmatmul_pool(a: &Dense, b: &Dense, pool: &ThreadPool) -> Dense {
    assert_eq!(a.rows(), b.rows(), "tmatmul shape mismatch");
    let (_, n) = a.shape();
    let k = b.cols();
    let mut c = Dense::zeros(n, k);
    tmatmul_into(a, b, &mut c, pool, 0);
    c
}

/// Accumulate `C += Aᵀ · B`, partitioned over output rows (A-columns).
/// Gated on [`PAR_MIN_TFLOPS`] — the scatter kernel is memory-bound and
/// wins from parallelism earlier than the plain GEMM. `extra_work`
/// charges a fused epilogue to the gating decision.
fn tmatmul_into(a: &Dense, b: &Dense, c: &mut Dense, pool: &ThreadPool, extra_work: usize) {
    let (m, n) = a.shape();
    let k = b.cols();
    let kernel = kernels::select();
    let work = m
        .saturating_mul(n)
        .saturating_mul(k)
        .saturating_add(extra_work);
    par_row_chunks_min(pool, work, PAR_MIN_TFLOPS, c.data_mut(), n, k, |j0, ncols, chunk| {
        tmatmul_cols(a, b, j0, ncols, chunk, kernel);
    });
}

/// Serial Aᵀ·B restricted to output rows (A-columns) `j0 .. j0 + ncols`;
/// `c_rows` is that strip of C (`ncols * k` elements).
fn tmatmul_cols(a: &Dense, b: &Dense, j0: usize, ncols: usize, c_rows: &mut [f64], kernel: Kernel) {
    let m = a.rows();
    let k = b.cols();
    for i in 0..m {
        let a_win = &a.row(i)[j0..j0 + ncols];
        let b_row = b.row(i);
        for (jj, &aij) in a_win.iter().enumerate() {
            if aij != 0.0 {
                kernels::axpy1(kernel, &mut c_rows[jj * k..(jj + 1) * k], aij, b_row);
            }
        }
    }
}

/// Accumulate `C += Aᵀ·B` into an existing `C` (a.cols() × b.cols()) on
/// the calling thread's pool.
///
/// This is the out-of-core building block: summing the contributions of
/// consecutive row blocks `Aᵢ` (ascending, each paired with the matching
/// rows `Bᵢ`) reproduces the one-shot [`tmatmul`] result **bit-for-bit**,
/// because every output element accumulates its `i`-terms in the same
/// serial order the in-memory kernel uses. Gated on the transpose
/// threshold ([`PAR_MIN_TFLOPS`]) rather than the plain-GEMM one, so
/// per-block products of a streamed sweep fan out as early as the
/// equivalent in-memory product would.
pub fn tmatmul_acc(a: &Dense, b: &Dense, c: &mut Dense) {
    assert_eq!(a.rows(), b.rows(), "tmatmul_acc shape mismatch");
    assert_eq!(
        c.shape(),
        (a.cols(), b.cols()),
        "tmatmul_acc output shape mismatch"
    );
    parallel::with_current(|pool| tmatmul_into(a, b, c, pool, 0));
}

/// `C = Aᵀ·B − u·vᵀ` fused (u has length n = a.cols()).
pub fn tmatmul_rank1(a: &Dense, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
    parallel::with_current(|pool| tmatmul_rank1_pool(a, b, u, v, pool))
}

/// `C = Aᵀ·B − u·vᵀ` on an explicit pool.
pub fn tmatmul_rank1_pool(
    a: &Dense,
    b: &Dense,
    u: &[f64],
    v: &[f64],
    pool: &ThreadPool,
) -> Dense {
    let (m, n) = a.shape();
    assert_eq!(m, b.rows());
    let k = b.cols();
    assert_eq!(u.len(), n);
    assert_eq!(v.len(), k);
    let mut c = Dense::zeros(n, k);
    // Seed with the downdate (O(nk), own store-bound gating), then
    // accumulate Aᵀ·B with the epilogue charged to the gating work.
    seed_downdate(&mut c, u, v, pool);
    tmatmul_into(a, b, &mut c, pool, n.saturating_mul(k));
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::fro_diff;
    use crate::rng::{Rng, Xoshiro256pp};

    fn naive_matmul(a: &Dense, b: &Dense) -> Dense {
        let (m, k) = a.shape();
        let n = b.cols();
        Dense::from_fn(m, n, |i, j| (0..k).map(|l| a[(i, l)] * b[(l, j)]).sum())
    }

    fn bits_equal(a: &Dense, b: &Dense) -> bool {
        a.data()
            .iter()
            .zip(b.data())
            .all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for (m, k, n) in [(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 70, 65), (100, 257, 31)] {
            let a = Dense::gaussian(m, k, &mut rng);
            let b = Dense::gaussian(k, n, &mut rng);
            let want = naive_matmul(&a, &b);
            assert!(fro_diff(&matmul(&a, &b), &want) < 1e-9 * (m * n) as f64 + 1e-12);
        }
    }

    #[test]
    fn plan_invariance() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Dense::gaussian(40, 90, &mut rng);
        let b = Dense::gaussian(90, 30, &mut rng);
        let base = matmul(&a, &b);
        for (mc, kc) in [(1, 1), (7, 13), (64, 256), (1000, 1000)] {
            let got = matmul_with_plan(&a, &b, MatmulPlan { mc, kc });
            assert!(fro_diff(&got, &base) < 1e-10);
        }
    }

    #[test]
    fn pool_size_invariance_is_bitwise() {
        // Large enough to clear PAR_MIN_FLOPS (160*96*120 ≈ 1.8M).
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let a = Dense::gaussian(160, 120, &mut rng);
        let b = Dense::gaussian(120, 96, &mut rng);
        let u: Vec<f64> = (0..160).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..96).map(|_| rng.next_gaussian()).collect();
        let p1 = ThreadPool::new(1);
        let base = matmul_with_plan_pool(&a, &b, MatmulPlan::default(), &p1);
        let base_r1 = matmul_rank1_with_plan_pool(&a, &b, &u, &v, MatmulPlan::default(), &p1);
        let base_t = tmatmul_pool(&a, &b, &p1);
        for threads in [2, 3, 8] {
            let p = ThreadPool::new(threads);
            let got = matmul_with_plan_pool(&a, &b, MatmulPlan::default(), &p);
            let got_r1 = matmul_rank1_with_plan_pool(&a, &b, &u, &v, MatmulPlan::default(), &p);
            let got_t = tmatmul_pool(&a, &b, &p);
            for (x, y) in [(&base, &got), (&base_r1, &got_r1), (&base_t, &got_t)] {
                assert!(bits_equal(x, y), "threads {threads}: outputs must be bit-identical");
            }
        }
    }

    #[test]
    fn simd_on_off_is_bitwise_identical_on_exact_tier() {
        // The Exact-tier contract: the AVX2 kernels reproduce the scalar
        // accumulation order per lane, so results match to the bit. On
        // hosts without AVX2 both sides run scalar and the test is
        // trivially green.
        let mut rng = Xoshiro256pp::seed_from_u64(21);
        let a = Dense::gaussian(160, 121, &mut rng); // odd k: remainder path
        let b = Dense::gaussian(121, 97, &mut rng); // odd n: j-tail path
        let u: Vec<f64> = (0..160).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..97).map(|_| rng.next_gaussian()).collect();
        let scalar = kernels::with_simd(Simd::Scalar, || {
            (matmul(&a, &b), matmul_rank1(&a, &b, &u, &v), tmatmul(&a, &b))
        });
        let simd = kernels::with_simd(Simd::Avx2, || {
            (matmul(&a, &b), matmul_rank1(&a, &b, &u, &v), tmatmul(&a, &b))
        });
        assert!(bits_equal(&scalar.0, &simd.0), "matmul diverged across simd on/off");
        assert!(bits_equal(&scalar.1, &simd.1), "matmul_rank1 diverged across simd on/off");
        assert!(bits_equal(&scalar.2, &simd.2), "tmatmul diverged across simd on/off");
    }

    #[test]
    fn fast_tier_matches_exact_within_tolerance() {
        let mut rng = Xoshiro256pp::seed_from_u64(22);
        let a = Dense::gaussian(120, 90, &mut rng);
        let b = Dense::gaussian(90, 70, &mut rng);
        let exact = matmul(&a, &b);
        let fast = kernels::with_precision(Precision::Fast, || matmul(&a, &b));
        // FMA contraction only moves the last ulps; scale-relative.
        let rel = fro_diff(&fast, &exact) / exact.fro_norm().max(1e-300);
        assert!(rel < 1e-13, "fast tier drifted: rel err {rel:e}");
    }

    #[test]
    fn fast_tier_is_pool_invariant_bitwise() {
        // Fast differs from Exact but must itself stay deterministic
        // across pool sizes: every output row owns its accumulators and
        // the k order is fixed regardless of chunk boundaries.
        let mut rng = Xoshiro256pp::seed_from_u64(23);
        let a = Dense::gaussian(150, 130, &mut rng);
        let b = Dense::gaussian(130, 88, &mut rng);
        let p1 = ThreadPool::new(1);
        let base = kernels::with_precision(Precision::Fast, || {
            matmul_with_plan_pool(&a, &b, MatmulPlan::default(), &p1)
        });
        for threads in [2, 8] {
            let p = ThreadPool::new(threads);
            let got = kernels::with_precision(Precision::Fast, || {
                matmul_with_plan_pool(&a, &b, MatmulPlan::default(), &p)
            });
            assert!(bits_equal(&base, &got), "fast tier not pool-invariant at {threads}");
        }
    }

    #[test]
    fn fast_tier_small_product_falls_through_correctly() {
        // Below FAST_PACK_MIN the Fast tier reuses the exact-layout
        // kernel; the result must still be a correct product.
        let mut rng = Xoshiro256pp::seed_from_u64(24);
        let a = Dense::gaussian(9, 11, &mut rng);
        let b = Dense::gaussian(11, 7, &mut rng);
        let want = naive_matmul(&a, &b);
        let got = kernels::with_precision(Precision::Fast, || matmul(&a, &b));
        assert!(fro_diff(&got, &want) < 1e-10);
    }

    #[test]
    fn large_seed_downdate_is_pool_invariant_bitwise() {
        // 1200*1800 = 2.16M elements clears PAR_MIN_SEED (2^21), so the
        // parallel seed path actually runs; per-row order is unchanged.
        let mut rng = Xoshiro256pp::seed_from_u64(25);
        let u: Vec<f64> = (0..1200).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..1800).map(|_| rng.next_gaussian()).collect();
        let mut base = Dense::zeros(1200, 1800);
        seed_downdate(&mut base, &u, &v, &ThreadPool::new(1));
        for threads in [2, 8] {
            let mut got = Dense::zeros(1200, 1800);
            seed_downdate(&mut got, &u, &v, &ThreadPool::new(threads));
            assert!(bits_equal(&base, &got), "seed_downdate not pool-invariant at {threads}");
        }
        // And it is the right matrix.
        for (i, j) in [(0, 0), (7, 1234), (1199, 1799)] {
            assert_eq!(base[(i, j)], -u[i] * v[j]);
        }
    }

    #[test]
    fn rank1_fusion_exact() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let a = Dense::gaussian(23, 31, &mut rng);
        let b = Dense::gaussian(31, 11, &mut rng);
        let u: Vec<f64> = (0..23).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..11).map(|_| rng.next_gaussian()).collect();
        let fused = matmul_rank1(&a, &b, &u, &v);
        let mut want = matmul(&a, &b);
        for i in 0..23 {
            for j in 0..11 {
                want[(i, j)] -= u[i] * v[j];
            }
        }
        assert!(fro_diff(&fused, &want) < 1e-10);
    }

    #[test]
    fn rank1_zero_vectors_is_plain_matmul() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let a = Dense::gaussian(12, 8, &mut rng);
        let b = Dense::gaussian(8, 6, &mut rng);
        let got = matmul_rank1(&a, &b, &vec![0.0; 12], &vec![0.0; 6]);
        assert!(fro_diff(&got, &matmul(&a, &b)) < 1e-14);
    }

    #[test]
    fn tmatmul_matches_explicit_transpose() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let a = Dense::gaussian(19, 27, &mut rng);
        let b = Dense::gaussian(19, 7, &mut rng);
        let want = matmul(&a.transpose(), &b);
        assert!(fro_diff(&tmatmul(&a, &b), &want) < 1e-10);
    }

    #[test]
    fn tmatmul_acc_blockwise_matches_one_shot_bitwise() {
        // The streaming contract: summing ascending row-block
        // contributions reproduces the one-shot product exactly.
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let a = Dense::gaussian(137, 61, &mut rng);
        let b = Dense::gaussian(137, 23, &mut rng);
        let want = tmatmul(&a, &b);
        let mut c = Dense::zeros(61, 23);
        let mut row0 = 0;
        for bl in [40usize, 50, 30, 17] {
            let nr = bl.min(137 - row0);
            let ablock = Dense::from_fn(nr, 61, |i, j| a[(row0 + i, j)]);
            let bblock = Dense::from_fn(nr, 23, |i, j| b[(row0 + i, j)]);
            tmatmul_acc(&ablock, &bblock, &mut c);
            row0 += nr;
        }
        assert_eq!(row0, 137);
        assert!(bits_equal(&want, &c), "block-accumulated tmatmul must be bit-identical");
    }

    #[test]
    fn tmatmul_rank1_matches_composition() {
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let a = Dense::gaussian(15, 21, &mut rng);
        let b = Dense::gaussian(15, 5, &mut rng);
        let u: Vec<f64> = (0..21).map(|_| rng.next_gaussian()).collect();
        let v: Vec<f64> = (0..5).map(|_| rng.next_gaussian()).collect();
        let fused = tmatmul_rank1(&a, &b, &u, &v);
        let mut want = tmatmul(&a, &b);
        for i in 0..21 {
            for j in 0..5 {
                want[(i, j)] -= u[i] * v[j];
            }
        }
        assert!(fro_diff(&fused, &want) < 1e-10);
    }

    /// The shifted-product identity the whole paper rests on:
    /// (X − μ1ᵀ)Ω == matmul_rank1(X, Ω, μ, colsum(Ω)).
    #[test]
    fn shifted_product_identity() {
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let x = Dense::from_fn(20, 35, |_, _| rng.next_uniform());
        let om = Dense::gaussian(35, 6, &mut rng);
        let mu = x.row_means();
        let colsum: Vec<f64> = (0..6).map(|j| om.col(j).iter().sum()).collect();
        let implicit = matmul_rank1(&x, &om, &mu, &colsum);
        let explicit = matmul(&x.subtract_column(&mu), &om);
        assert!(fro_diff(&implicit, &explicit) < 1e-9);
    }
}
