//! Runtime-dispatched SIMD microkernels behind the blocked GEMM.
//!
//! The dispatcher picks a [`Kernel`] once per product on the *calling*
//! thread (pool workers receive the decision by value and never re-read
//! thread-locals), from three inputs:
//!
//! * hardware — `is_x86_feature_detected!("avx2"/"fma")`, probed once
//!   per process, with the `SRSVD_SIMD=off` env override folded in;
//! * the `[parallel] simd` config switch ([`set_simd_enabled`]);
//! * the requested [`Precision`] tier, thread-scoped via
//!   [`with_precision`] (the svd layer sets it from `SvdConfig`).
//!
//! **Exact tier.** The AVX2 kernels mirror the scalar 4-way-unrolled
//! AXPY *per lane*: `t = a0·b0; t += a1·b1; t += a2·b2; t += a3·b3;
//! c += t` with plain mul/add — no FMA — which is element-for-element
//! the scalar expression `c[j] += a0*b0[j] + a1*b1[j] + a2*b2[j] +
//! a3*b3[j]` left-associated. Exact-tier results are therefore
//! **bit-identical** to the portable fallback on every host, which is
//! what lets `tests/determinism.rs` pin factors across simd on/off ×
//! pool sizes. The win comes from issuing 4 lanes per instruction
//! (the crate's baseline x86-64 codegen is SSE2-only).
//!
//! **Fast tier.** An opt-in packed 4×8 register-blocked microkernel
//! ([`MR`]×[`NR`] in 8 ymm accumulators) over zero-padded A/B panels,
//! contracted with `_mm256_fmadd_pd`. FMA skips the intermediate
//! rounding, so Fast results differ from Exact in the last ulps —
//! still deterministic and pool-partition invariant (every output row
//! owns its accumulator lanes and the k order is fixed), but not
//! bit-equal to the scalar kernel. Accuracy vs Exact is pinned to
//! ≤1e-12 relative factor error in `tests/determinism.rs`.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use super::Dense;

/// Kernel arithmetic tier. Carried by `SvdConfig` (`[svd] precision`,
/// `--precision`, wire field `precision`) and scoped onto the
/// factorization thread via [`with_precision`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Bit-identical to the portable scalar kernel (default). SIMD may
    /// still be used, but only in lane arrangements that reproduce the
    /// scalar accumulation order exactly.
    Exact,
    /// Packed-panel FMA microkernels: fastest, deterministic, but the
    /// contraction rounding differs from the scalar kernel in the last
    /// ulps, so factors are not byte-comparable across tiers.
    Fast,
}

impl Precision {
    /// Canonical config/wire spelling (`exact` / `fast`).
    pub fn name(self) -> &'static str {
        match self {
            Precision::Exact => "exact",
            Precision::Fast => "fast",
        }
    }
}

/// SIMD instruction tier the dispatcher may use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Simd {
    /// Portable scalar kernels only (LLVM auto-vectorization aside).
    Scalar,
    /// AVX2 `std::arch` kernels (+FMA on the Fast tier).
    Avx2,
}

impl Simd {
    /// Display spelling (`scalar` / `avx2`).
    pub fn name(self) -> &'static str {
        match self {
            Simd::Scalar => "scalar",
            Simd::Avx2 => "avx2",
        }
    }
}

/// `[parallel] simd = off` lands here; `SRSVD_SIMD=off` wins regardless.
static DISABLED: AtomicBool = AtomicBool::new(false);
static HW: OnceLock<Simd> = OnceLock::new();

thread_local! {
    static SIMD_OVERRIDE: Cell<Option<Simd>> = const { Cell::new(None) };
    static PRECISION: Cell<Precision> = const { Cell::new(Precision::Exact) };
}

fn hw_simd() -> Simd {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            return Simd::Avx2;
        }
    }
    Simd::Scalar
}

/// Hardware tier, probed once per process with the `SRSVD_SIMD` env
/// override folded in (`off|0|false|no|scalar` forces the portable
/// kernels before any config is read).
fn detected() -> Simd {
    *HW.get_or_init(|| match std::env::var("SRSVD_SIMD") {
        Ok(v) if matches!(
            v.trim().to_ascii_lowercase().as_str(),
            "off" | "0" | "false" | "no" | "scalar"
        ) =>
        {
            Simd::Scalar
        }
        _ => hw_simd(),
    })
}

/// Enable/disable SIMD dispatch process-wide — the `[parallel] simd`
/// config knob. The `SRSVD_SIMD=off` environment override wins even
/// when this is set to `true`.
pub fn set_simd_enabled(on: bool) {
    DISABLED.store(!on, Ordering::Relaxed);
}

/// The SIMD tier dispatch will actually use on this thread right now.
pub fn active_simd() -> Simd {
    let base = if DISABLED.load(Ordering::Relaxed) {
        Simd::Scalar
    } else {
        detected()
    };
    match SIMD_OVERRIDE.with(|c| c.get()) {
        Some(Simd::Scalar) => Simd::Scalar,
        Some(Simd::Avx2) | None => base,
    }
}

/// Run `f` with the SIMD tier pinned on this thread (benches and the
/// determinism suite). [`Simd::Scalar`] forces the portable kernels;
/// [`Simd::Avx2`] requests the best available and silently degrades to
/// scalar on hosts without AVX2/FMA (or when SIMD is disabled), so
/// simd-on/off comparisons pass trivially on any machine.
pub fn with_simd<T>(mode: Simd, f: impl FnOnce() -> T) -> T {
    struct Restore(Option<Simd>);
    impl Drop for Restore {
        fn drop(&mut self) {
            SIMD_OVERRIDE.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(SIMD_OVERRIDE.with(|c| c.replace(Some(mode))));
    f()
}

/// Run `f` with the kernel [`Precision`] pinned on this thread. The
/// factorization core wraps each job in this so every product of that
/// job dispatches on the job's configured tier.
pub fn with_precision<T>(p: Precision, f: impl FnOnce() -> T) -> T {
    struct Restore(Precision);
    impl Drop for Restore {
        fn drop(&mut self) {
            PRECISION.with(|c| c.set(self.0));
        }
    }
    let _restore = Restore(PRECISION.with(|c| c.replace(p)));
    f()
}

/// The precision tier scoped onto this thread (default `Exact`).
pub fn current_precision() -> Precision {
    PRECISION.with(|c| c.get())
}

/// Resolved kernel choice, computed once per product on the calling
/// thread and passed by value into row-chunk closures — pool workers
/// must not re-read the thread-locals (they would see defaults).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Kernel {
    /// Portable fallback; also what both AVX2 variants degrade to on
    /// hosts without the features.
    Scalar,
    /// AVX2 mul/add lanes in the scalar accumulation order.
    Avx2Exact,
    /// AVX2+FMA packed microkernel (plus FMA AXPYs for transpose
    /// products and sub-threshold fall-through).
    Avx2Fast,
}

/// Resolve the kernel for the current thread's simd/precision state.
pub(crate) fn select() -> Kernel {
    match (active_simd(), current_precision()) {
        (Simd::Scalar, _) => Kernel::Scalar,
        (Simd::Avx2, Precision::Exact) => Kernel::Avx2Exact,
        (Simd::Avx2, Precision::Fast) => Kernel::Avx2Fast,
    }
}

// ---- row AXPY kernels (Exact tier + fall-through) --------------------------

/// `c[j] += a0*b0[j] + a1*b1[j] + a2*b2[j] + a3*b3[j]` for a whole row —
/// the 4-way-unrolled inner AXPY of the blocked GEMM. The AVX2 variant
/// reproduces the scalar expression per lane (mul/add, no FMA), so
/// Exact-tier outputs stay bit-identical; a Fast-tier product that
/// falls through here (below the packing threshold) uses the same exact
/// arrangement.
#[inline]
pub(crate) fn axpy4(
    kernel: Kernel,
    c_row: &mut [f64],
    a: [f64; 4],
    b0: &[f64],
    b1: &[f64],
    b2: &[f64],
    b3: &[f64],
) {
    #[cfg(target_arch = "x86_64")]
    {
        if kernel != Kernel::Scalar {
            // SAFETY: Avx2* kernels are only selected after
            // `is_x86_feature_detected!("avx2")` succeeded.
            unsafe { axpy4_avx2(c_row, a, b0, b1, b2, b3) };
            return;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = kernel;
    let [a0, a1, a2, a3] = a;
    for j in 0..c_row.len() {
        c_row[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy4_avx2(
    c_row: &mut [f64],
    a: [f64; 4],
    b0: &[f64],
    b1: &[f64],
    b2: &[f64],
    b3: &[f64],
) {
    use std::arch::x86_64::*;
    let n = c_row.len();
    let a0 = _mm256_set1_pd(a[0]);
    let a1 = _mm256_set1_pd(a[1]);
    let a2 = _mm256_set1_pd(a[2]);
    let a3 = _mm256_set1_pd(a[3]);
    let mut j = 0;
    while j + 4 <= n {
        // Per lane this is the scalar expression left-associated:
        // ((a0*b0 + a1*b1) + a2*b2) + a3*b3, then c += t. Any other
        // association (or FMA) would break Exact-tier bit-identity.
        let mut t = _mm256_mul_pd(a0, _mm256_loadu_pd(b0.as_ptr().add(j)));
        t = _mm256_add_pd(t, _mm256_mul_pd(a1, _mm256_loadu_pd(b1.as_ptr().add(j))));
        t = _mm256_add_pd(t, _mm256_mul_pd(a2, _mm256_loadu_pd(b2.as_ptr().add(j))));
        t = _mm256_add_pd(t, _mm256_mul_pd(a3, _mm256_loadu_pd(b3.as_ptr().add(j))));
        let c = _mm256_add_pd(_mm256_loadu_pd(c_row.as_ptr().add(j)), t);
        _mm256_storeu_pd(c_row.as_mut_ptr().add(j), c);
        j += 4;
    }
    while j < n {
        c_row[j] += a[0] * b0[j] + a[1] * b1[j] + a[2] * b2[j] + a[3] * b3[j];
        j += 1;
    }
}

/// `c[l] += a * b[l]` — the single-row AXPY used by the k-remainder and
/// the transpose-product scatter. Exact AVX2 uses mul+add
/// (lane-identical to scalar); the Fast tier uses FMA.
#[inline]
pub(crate) fn axpy1(kernel: Kernel, c_row: &mut [f64], a: f64, b_row: &[f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        match kernel {
            Kernel::Scalar => {}
            Kernel::Avx2Exact => {
                // SAFETY: selected only after AVX2 detection.
                unsafe { axpy1_avx2(c_row, a, b_row) };
                return;
            }
            Kernel::Avx2Fast => {
                // SAFETY: selected only after AVX2+FMA detection.
                unsafe { axpy1_fma(c_row, a, b_row) };
                return;
            }
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = kernel;
    for (cx, &bx) in c_row.iter_mut().zip(b_row) {
        *cx += a * bx;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn axpy1_avx2(c_row: &mut [f64], a: f64, b_row: &[f64]) {
    use std::arch::x86_64::*;
    let n = c_row.len();
    let av = _mm256_set1_pd(a);
    let mut j = 0;
    while j + 4 <= n {
        let t = _mm256_mul_pd(av, _mm256_loadu_pd(b_row.as_ptr().add(j)));
        let c = _mm256_add_pd(_mm256_loadu_pd(c_row.as_ptr().add(j)), t);
        _mm256_storeu_pd(c_row.as_mut_ptr().add(j), c);
        j += 4;
    }
    while j < n {
        c_row[j] += a * b_row[j];
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy1_fma(c_row: &mut [f64], a: f64, b_row: &[f64]) {
    use std::arch::x86_64::*;
    let n = c_row.len();
    let av = _mm256_set1_pd(a);
    let mut j = 0;
    while j + 4 <= n {
        let c = _mm256_fmadd_pd(
            av,
            _mm256_loadu_pd(b_row.as_ptr().add(j)),
            _mm256_loadu_pd(c_row.as_ptr().add(j)),
        );
        _mm256_storeu_pd(c_row.as_mut_ptr().add(j), c);
        j += 4;
    }
    while j < n {
        c_row[j] = a.mul_add(b_row[j], c_row[j]);
        j += 1;
    }
}

// ---- Fast-tier packed 4x8 microkernel --------------------------------------

/// Microkernel tile rows (A panel width).
#[cfg(target_arch = "x86_64")]
pub(crate) const MR: usize = 4;
/// Microkernel tile columns (two ymm vectors of f64).
#[cfg(target_arch = "x86_64")]
pub(crate) const NR: usize = 8;

/// B packed once per Fast-tier product: for every kc-deep block,
/// [`NR`]-wide column strips stored k-major and zero-padded, so the
/// microkernel streams contiguous 8-wide vectors. Shared read-only by
/// every row chunk of the parallel dispatch.
#[cfg(target_arch = "x86_64")]
pub(crate) struct PackedB {
    data: Vec<f64>,
    /// Start of each kc-block's strip area in `data` (blocks differ in
    /// depth, so offsets are cumulative, not a fixed stride).
    block_offsets: Vec<usize>,
    kc: usize,
    k: usize,
    n: usize,
}

/// Pack all of `b` for the Fast tier with contraction blocking `kc`.
#[cfg(target_arch = "x86_64")]
pub(crate) fn pack_b(b: &Dense, kc: usize) -> PackedB {
    let (k, n) = b.shape();
    let kc = kc.max(1);
    let nstrips = n.div_ceil(NR);
    let mut data = Vec::new();
    let mut block_offsets = Vec::new();
    for k0 in (0..k).step_by(kc) {
        block_offsets.push(data.len());
        let kb = (k0 + kc).min(k) - k0;
        for s in 0..nstrips {
            let j0 = s * NR;
            let jw = NR.min(n - j0);
            for kk in 0..kb {
                data.extend_from_slice(&b.row(k0 + kk)[j0..j0 + jw]);
                data.resize(data.len() + (NR - jw), 0.0);
            }
        }
    }
    PackedB { data, block_offsets, kc, k, n }
}

/// Pack an A row-strip for one kc-block: [`MR`]-row panels stored
/// k-major ([`MR`] row-values per k step), zero-padded in the last
/// panel. `buf` is reused across blocks by the caller.
#[cfg(target_arch = "x86_64")]
fn pack_a(a: &Dense, row0: usize, nrows: usize, k0: usize, kb: usize, buf: &mut Vec<f64>) {
    let npanels = nrows.div_ceil(MR);
    buf.clear();
    buf.resize(npanels * kb * MR, 0.0);
    for p in 0..npanels {
        let panel = &mut buf[p * kb * MR..(p + 1) * kb * MR];
        let rvalid = MR.min(nrows - p * MR);
        for r in 0..rvalid {
            let a_row = &a.row(row0 + p * MR + r)[k0..k0 + kb];
            for (kk, &av) in a_row.iter().enumerate() {
                panel[kk * MR + r] = av;
            }
        }
    }
}

/// 4×8 register-blocked FMA microkernel: `out = Ap · Bp` over one
/// kc-block; `out` is a dense [`MR`]×[`NR`] row-major tile. Eight ymm
/// accumulators + two B vectors + one broadcast A register stay well
/// inside the 16-register file.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mkernel_4x8(kb: usize, ap: &[f64], bp: &[f64], out: &mut [f64; MR * NR]) {
    use std::arch::x86_64::*;
    debug_assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
    let mut c00 = _mm256_setzero_pd();
    let mut c01 = _mm256_setzero_pd();
    let mut c10 = _mm256_setzero_pd();
    let mut c11 = _mm256_setzero_pd();
    let mut c20 = _mm256_setzero_pd();
    let mut c21 = _mm256_setzero_pd();
    let mut c30 = _mm256_setzero_pd();
    let mut c31 = _mm256_setzero_pd();
    let app = ap.as_ptr();
    let bpp = bp.as_ptr();
    for kk in 0..kb {
        let b0 = _mm256_loadu_pd(bpp.add(kk * NR));
        let b1 = _mm256_loadu_pd(bpp.add(kk * NR + 4));
        let a0 = _mm256_set1_pd(*app.add(kk * MR));
        c00 = _mm256_fmadd_pd(a0, b0, c00);
        c01 = _mm256_fmadd_pd(a0, b1, c01);
        let a1 = _mm256_set1_pd(*app.add(kk * MR + 1));
        c10 = _mm256_fmadd_pd(a1, b0, c10);
        c11 = _mm256_fmadd_pd(a1, b1, c11);
        let a2 = _mm256_set1_pd(*app.add(kk * MR + 2));
        c20 = _mm256_fmadd_pd(a2, b0, c20);
        c21 = _mm256_fmadd_pd(a2, b1, c21);
        let a3 = _mm256_set1_pd(*app.add(kk * MR + 3));
        c30 = _mm256_fmadd_pd(a3, b0, c30);
        c31 = _mm256_fmadd_pd(a3, b1, c31);
    }
    let op = out.as_mut_ptr();
    _mm256_storeu_pd(op, c00);
    _mm256_storeu_pd(op.add(4), c01);
    _mm256_storeu_pd(op.add(8), c10);
    _mm256_storeu_pd(op.add(12), c11);
    _mm256_storeu_pd(op.add(16), c20);
    _mm256_storeu_pd(op.add(20), c21);
    _mm256_storeu_pd(op.add(24), c30);
    _mm256_storeu_pd(op.add(28), c31);
}

/// Fast-tier row-strip kernel: stream the pre-packed B against
/// per-strip packed A panels, one kc-block at a time, adding each
/// finished tile into the C strip. Every output row owns its
/// accumulator lanes and the k order is fixed, so results are
/// pool-partition invariant (though not bit-equal to the Exact tier).
#[cfg(target_arch = "x86_64")]
pub(crate) fn gemm_rows_fast(
    a: &Dense,
    bp: &PackedB,
    row0: usize,
    nrows: usize,
    c_rows: &mut [f64],
    a_buf: &mut Vec<f64>,
) {
    let n = bp.n;
    if n == 0 || nrows == 0 {
        return;
    }
    let nstrips = n.div_ceil(NR);
    let npanels = nrows.div_ceil(MR);
    for (bi, k0) in (0..bp.k).step_by(bp.kc).enumerate() {
        let kb = (k0 + bp.kc).min(bp.k) - k0;
        pack_a(a, row0, nrows, k0, kb, a_buf);
        let block = &bp.data[bp.block_offsets[bi]..];
        for p in 0..npanels {
            let ap = &a_buf[p * kb * MR..(p + 1) * kb * MR];
            let rvalid = MR.min(nrows - p * MR);
            for s in 0..nstrips {
                let strip = &block[s * kb * NR..(s + 1) * kb * NR];
                let mut tile = [0.0; MR * NR];
                // SAFETY: Avx2Fast is selected only after AVX2+FMA
                // detection; the panel/strip slices hold kb*MR and
                // kb*NR elements by construction of pack_a/pack_b.
                unsafe { mkernel_4x8(kb, ap, strip, &mut tile) };
                let j0 = s * NR;
                let jw = NR.min(n - j0);
                for r in 0..rvalid {
                    let c0 = (p * MR + r) * n + j0;
                    for (cx, &tx) in c_rows[c0..c0 + jw].iter_mut().zip(&tile[r * NR..]) {
                        *cx += tx;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_scope_restores() {
        assert_eq!(current_precision(), Precision::Exact);
        let inner = with_precision(Precision::Fast, current_precision);
        assert_eq!(inner, Precision::Fast);
        assert_eq!(current_precision(), Precision::Exact);
        // Nested scopes restore to the enclosing tier, not the default.
        with_precision(Precision::Fast, || {
            with_precision(Precision::Exact, || {
                assert_eq!(current_precision(), Precision::Exact);
            });
            assert_eq!(current_precision(), Precision::Fast);
        });
    }

    #[test]
    fn scalar_override_forces_portable_kernel() {
        with_simd(Simd::Scalar, || {
            assert_eq!(active_simd(), Simd::Scalar);
            assert_eq!(select(), Kernel::Scalar);
            with_precision(Precision::Fast, || {
                // Fast on scalar hardware is still the portable kernel.
                assert_eq!(select(), Kernel::Scalar);
            });
        });
    }

    #[test]
    fn avx2_request_degrades_gracefully() {
        // On AVX2 hosts this exercises real dispatch; elsewhere (or
        // under SRSVD_SIMD=off) it must degrade to scalar, not panic.
        with_simd(Simd::Avx2, || {
            let k = select();
            assert!(matches!(k, Kernel::Scalar | Kernel::Avx2Exact));
        });
    }

    #[test]
    fn axpy4_avx2_is_bit_identical_to_scalar() {
        // Meaningful only where AVX2 dispatch is live; trivially green
        // on scalar-only hosts.
        let n = 37; // covers the 4-wide body and a 1-element tail
        let b: Vec<Vec<f64>> = (0..4)
            .map(|r| (0..n).map(|j| ((r * n + j) as f64).sin()).collect())
            .collect();
        let a = [1.25, -0.5, 3.0e-3, 7.75];
        let mut c_scalar: Vec<f64> = (0..n).map(|j| (j as f64).cos()).collect();
        let mut c_simd = c_scalar.clone();
        axpy4(Kernel::Scalar, &mut c_scalar, a, &b[0], &b[1], &b[2], &b[3]);
        with_simd(Simd::Avx2, || {
            let k = select();
            axpy4(k, &mut c_simd, a, &b[0], &b[1], &b[2], &b[3]);
        });
        for (x, y) in c_scalar.iter().zip(&c_simd) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn axpy1_exact_is_bit_identical_to_scalar() {
        let n = 23;
        let b: Vec<f64> = (0..n).map(|j| (j as f64).sqrt() - 2.0).collect();
        let mut c_scalar: Vec<f64> = (0..n).map(|j| 0.1 * j as f64).collect();
        let mut c_simd = c_scalar.clone();
        axpy1(Kernel::Scalar, &mut c_scalar, -1.875, &b);
        with_simd(Simd::Avx2, || {
            let k = select();
            axpy1(k, &mut c_simd, -1.875, &b);
        });
        for (x, y) in c_scalar.iter().zip(&c_simd) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn packed_fast_product_matches_naive() {
        if hw_simd() != Simd::Avx2 {
            return; // no FMA hardware to exercise
        }
        use crate::rng::{Rng, Xoshiro256pp};
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        // Deliberately awkward shapes: panel/strip tails in every dim.
        for (m, k, n) in [(1, 1, 1), (5, 7, 9), (13, 33, 17), (50, 65, 41)] {
            let a = Dense::gaussian(m, k, &mut rng);
            let b = Dense::gaussian(k, n, &mut rng);
            let packed = pack_b(&b, 16);
            let mut c = vec![0.0; m * n];
            let mut a_buf = Vec::new();
            gemm_rows_fast(&a, &packed, 0, m, &mut c, &mut a_buf);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k).map(|l| a[(i, l)] * b[(l, j)]).sum();
                    assert!(
                        (c[i * n + j] - want).abs() <= 1e-10 * want.abs().max(1.0),
                        "({m},{k},{n}) at ({i},{j}): {} vs {want}",
                        c[i * n + j]
                    );
                }
            }
        }
    }
}
