//! QR factorizations: Householder (thin, backward stable) and Modified
//! Gram–Schmidt with re-orthogonalization.
//!
//! The randomized SVD only needs an orthonormal *basis* Q of the sample
//! matrix; Householder is the default (stable even when power iteration
//! makes the sample matrix ill-conditioned). MGS mirrors the pure-jax
//! implementation in `python/compile/linalg.py` bit-for-bit in
//! structure, which keeps the two engines comparable in tests.

use super::Dense;

/// Thin Householder QR of an `m x k` matrix (`m >= k`).
///
/// Returns `(q, r)` with `q` m×k (orthonormal columns) and `r` k×k upper
/// triangular such that `a = q · r`.
pub fn householder_qr(a: &Dense) -> (Dense, Dense) {
    let (m, k) = a.shape();
    assert!(m >= k, "householder_qr needs m >= k, got {m}x{k}");
    let mut r = a.clone(); // will carry the reduced matrix
    // Householder vectors, stored column-wise in an m×k workspace.
    let mut vs = Dense::zeros(m, k);

    for j in 0..k {
        // Build the reflector for column j below the diagonal.
        let mut norm_sq = 0.0;
        for i in j..m {
            norm_sq += r[(i, j)] * r[(i, j)];
        }
        let norm = norm_sq.sqrt();
        if norm == 0.0 {
            // Zero column: identity reflector (v = 0).
            continue;
        }
        let alpha = if r[(j, j)] >= 0.0 { -norm } else { norm };
        // v = x - alpha e1, normalized so v[j] carries the pivot.
        let mut v_norm_sq = 0.0;
        for i in j..m {
            let vi = if i == j { r[(i, j)] - alpha } else { r[(i, j)] };
            vs[(i, j)] = vi;
            v_norm_sq += vi * vi;
        }
        if v_norm_sq == 0.0 {
            continue;
        }
        let beta = 2.0 / v_norm_sq;
        // Apply H = I - beta v vᵀ to the trailing columns of r.
        for jj in j..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += vs[(i, j)] * r[(i, jj)];
            }
            let s = beta * dot;
            for i in j..m {
                r[(i, jj)] -= s * vs[(i, j)];
            }
        }
    }

    // Extract the k×k upper triangle.
    let r_out = Dense::from_fn(k, k, |i, j| if i <= j { r[(i, j)] } else { 0.0 });

    // Form thin Q by applying the reflectors to the first k columns of I,
    // in reverse order.
    let mut q = Dense::from_fn(m, k, |i, j| if i == j { 1.0 } else { 0.0 });
    for j in (0..k).rev() {
        let mut v_norm_sq = 0.0;
        for i in j..m {
            v_norm_sq += vs[(i, j)] * vs[(i, j)];
        }
        if v_norm_sq == 0.0 {
            continue;
        }
        let beta = 2.0 / v_norm_sq;
        for jj in 0..k {
            let mut dot = 0.0;
            for i in j..m {
                dot += vs[(i, j)] * q[(i, jj)];
            }
            let s = beta * dot;
            for i in j..m {
                q[(i, jj)] -= s * vs[(i, j)];
            }
        }
    }
    (q, r_out)
}

/// Orthonormal basis via two passes of Modified Gram–Schmidt
/// ("twice is enough": the second pass restores orthogonality lost to
/// cancellation). Rank-deficient columns become zero columns.
pub fn mgs_qr(a: &Dense) -> Dense {
    let q = mgs_pass(a);
    mgs_pass(&q)
}

fn mgs_pass(a: &Dense) -> Dense {
    let (m, k) = a.shape();
    let mut q = a.clone();
    for j in 0..k {
        let mut col = q.col(j);
        // Project out previous columns.
        for p in 0..j {
            let qp = q.col(p);
            let dot: f64 = qp.iter().zip(&col).map(|(x, y)| x * y).sum();
            for i in 0..m {
                col[i] -= dot * qp[i];
            }
        }
        let nrm = col.iter().map(|x| x * x).sum::<f64>().sqrt();
        if nrm > 1e-300 {
            for x in &mut col {
                *x /= nrm;
            }
        } else {
            col.iter_mut().for_each(|x| *x = 0.0);
        }
        q.set_col(j, &col);
    }
    q
}

/// Max deviation of `qᵀq` from the identity — orthonormality residual.
pub fn orthonormality_residual(q: &Dense) -> f64 {
    let k = q.cols();
    let g = super::gemm::tmatmul(q, q);
    let mut worst = 0.0f64;
    for i in 0..k {
        for j in 0..k {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((g[(i, j)] - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_diff, matmul};
    use crate::rng::Xoshiro256pp;

    #[test]
    fn householder_reconstructs_and_is_orthonormal() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        for (m, k) in [(5, 5), (30, 7), (100, 20), (64, 1)] {
            let a = Dense::gaussian(m, k, &mut rng);
            let (q, r) = householder_qr(&a);
            assert!(orthonormality_residual(&q) < 1e-12, "{m}x{k}");
            assert!(fro_diff(&matmul(&q, &r), &a) < 1e-10, "{m}x{k}");
            // R upper triangular.
            for i in 0..k {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        }
    }

    #[test]
    fn householder_handles_zero_columns() {
        let mut a = Dense::zeros(10, 3);
        a[(0, 0)] = 1.0;
        a[(1, 2)] = 2.0; // middle column all-zero
        let (q, r) = householder_qr(&a);
        assert!(fro_diff(&matmul(&q, &r), &a) < 1e-12);
        assert!(q.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn mgs_orthonormal_and_preserves_span() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let a = Dense::gaussian(50, 12, &mut rng);
        let q = mgs_qr(&a);
        assert!(orthonormality_residual(&q) < 1e-12);
        // Projection onto span(Q) reproduces A.
        let proj = matmul(&q, &super::super::gemm::tmatmul(&q, &a));
        assert!(fro_diff(&proj, &a) < 1e-9);
    }

    #[test]
    fn mgs_ill_conditioned_stays_orthonormal() {
        // sigma from 1 down to 1e-9: single-pass MGS would lose
        // orthogonality; the second pass must hold it.
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let (u, _) = householder_qr(&Dense::gaussian(60, 8, &mut rng));
        let (v, _) = householder_qr(&Dense::gaussian(8, 8, &mut rng));
        let s: Vec<f64> = (0..8).map(|i| 10f64.powi(-(i as i32 + 1) * 9 / 8)).collect();
        let a = matmul(&u.scale_cols(&s), &v.transpose());
        let q = mgs_qr(&a);
        assert!(orthonormality_residual(&q) < 1e-10);
    }

    #[test]
    fn mgs_rank_deficient_zero_columns_not_nan() {
        let mut a = Dense::zeros(10, 3);
        for i in 0..10 {
            a[(i, 0)] = 1.0;
            a[(i, 1)] = 1.0; // duplicate of column 0
            a[(i, 2)] = i as f64;
        }
        let q = mgs_qr(&a);
        assert!(q.data().iter().all(|x| x.is_finite()));
        // The duplicate column must vanish.
        assert!(q.col_norm_sq(1) < 1e-20);
    }

    #[test]
    fn householder_and_mgs_span_the_same_space() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let a = Dense::gaussian(40, 6, &mut rng);
        let (qh, _) = householder_qr(&a);
        let qm = mgs_qr(&a);
        // Projectors agree.
        let ph = matmul(&qh, &qh.transpose());
        let pm = matmul(&qm, &qm.transpose());
        assert!(fro_diff(&ph, &pm) < 1e-9);
    }
}
