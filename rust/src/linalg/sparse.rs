//! Sparse matrices in CSR form, with the shifted products that make the
//! paper's efficiency claim real.
//!
//! For a sparse `X` with non-zero mean, explicit centering `X − μ·1ᵀ`
//! is dense — O(mn) memory and O(mnk) factorization. The shifted
//! products below touch only `nnz` entries plus the rank-1 correction,
//! so S-RSVD runs in `O(nnz·k + (m+n)k²)` (paper Eq. 15).
//!
//! Large products run row-parallel on the shared [`crate::parallel`]
//! pool: `X·B` partitions CSR rows (one output row per CSR row), and
//! `Xᵀ·B` partitions *output* rows (CSR columns) — each task binary-
//! searches its column window inside every CSR row, so contributions to
//! one output row always land in serial row order and results are
//! bit-identical for every pool size.

use super::{Dense, gemm};
use crate::parallel::{self, par_row_chunks_min, ThreadPool};
use crate::rng::Rng;

/// Below this many multiply-adds (≈ nnz·k) a sparse product runs inline.
const PAR_MIN_WORK: usize = 1 << 20;

/// COO builder: accumulate (row, col, value) triplets, then seal to CSR.
#[derive(Debug, Clone, Default)]
pub struct Triplets {
    rows: usize,
    cols: usize,
    entries: Vec<(u32, u32, f64)>,
}

impl Triplets {
    /// Empty builder for an m×n matrix.
    pub fn new(rows: usize, cols: usize) -> Self {
        Triplets { rows, cols, entries: Vec::new() }
    }

    /// Record one entry (zeros are dropped; duplicates sum at seal time).
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        assert!(i < self.rows && j < self.cols, "triplet out of bounds");
        if v != 0.0 {
            self.entries.push((i as u32, j as u32, v));
        }
    }

    /// Number of recorded triplets (before duplicate merging).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether no triplets have been recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Seal into CSR, summing duplicate coordinates.
    pub fn to_csr(mut self) -> Csr {
        self.entries
            .sort_unstable_by_key(|&(i, j, _)| ((i as u64) << 32) | j as u64);
        let mut indptr = vec![0usize; self.rows + 1];
        let mut indices = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut prev: Option<(u32, u32)> = None;
        for &(i, j, v) in &self.entries {
            if prev == Some((i, j)) {
                // Duplicate coordinate: accumulate.
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(j);
                values.push(v);
                indptr[i as usize + 1] += 1;
                prev = Some((i, j));
            }
        }
        // Counts -> offsets. Note rows after the last triplet row stay 0.
        // We accumulated counts only in indptr[i+1]; rows with no entries
        // keep zero counts, so prefix-sum is correct.
        for i in 0..self.rows {
            indptr[i + 1] += indptr[i];
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

/// Compressed Sparse Row matrix (f64 values, u32 column indices).
#[derive(Debug, Clone)]
pub struct Csr {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<u32>,
    values: Vec<f64>,
}

impl Csr {
    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Number of stored (non-zero) entries.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// `nnz / (rows·cols)`.
    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Entries of row `i` as (col, value) pairs.
    pub fn row_iter(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.indptr[i];
        let hi = self.indptr[i + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.values[lo..hi])
            .map(|(&j, &v)| (j as usize, v))
    }

    /// Random sparse matrix with the given density; values from `gen`.
    pub fn random(
        rows: usize,
        cols: usize,
        density: f64,
        rng: &mut dyn Rng,
        mut gen: impl FnMut(&mut dyn Rng) -> f64,
    ) -> Csr {
        let mut t = Triplets::new(rows, cols);
        let target = ((rows * cols) as f64 * density).round() as usize;
        for _ in 0..target {
            let i = rng.next_below(rows as u64) as usize;
            let j = rng.next_below(cols as u64) as usize;
            t.push(i, j, gen(rng));
        }
        t.to_csr()
    }

    /// Densify (tests / the RSVD-baseline comparison only — this is the
    /// memory blow-up the paper's algorithm avoids).
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for (j, v) in self.row_iter(i) {
                d[(i, j)] = v;
            }
        }
        d
    }

    /// Per-row mean over *all* columns (zeros included) — the PCA
    /// shifting vector, in O(nnz).
    pub fn row_means(&self) -> Vec<f64> {
        let inv = 1.0 / self.cols as f64;
        (0..self.rows)
            .map(|i| self.row_iter(i).map(|(_, v)| v).sum::<f64>() * inv)
            .collect()
    }

    /// `X · B` for dense `B` (n×k) → dense (m×k), O(nnz·k);
    /// CSR-row-parallel when large.
    pub fn matmul_dense(&self, b: &Dense) -> Dense {
        parallel::with_current(|pool| self.matmul_dense_pool(b, pool))
    }

    /// `X · B` on an explicit pool (benches / determinism tests).
    pub fn matmul_dense_pool(&self, b: &Dense, pool: &ThreadPool) -> Dense {
        assert_eq!(self.cols, b.rows(), "spmm shape mismatch");
        let k = b.cols();
        let mut c = Dense::zeros(self.rows, k);
        let work = self.nnz().saturating_mul(k);
        let rows = self.rows;
        par_row_chunks_min(pool, work, PAR_MIN_WORK, c.data_mut(), rows, k, |r0, nr, chunk| {
            self.spmm_rows(b, r0, nr, chunk);
        });
        c
    }

    /// Serial `X·B` on CSR rows `r0 .. r0 + nrows`; `c_rows` is that
    /// strip of the output (`nrows * k` elements).
    fn spmm_rows(&self, b: &Dense, r0: usize, nrows: usize, c_rows: &mut [f64]) {
        let k = b.cols();
        for local in 0..nrows {
            let c_row = &mut c_rows[local * k..(local + 1) * k];
            for (j, v) in self.row_iter(r0 + local) {
                let b_row = b.row(j);
                for l in 0..k {
                    c_row[l] += v * b_row[l];
                }
            }
        }
    }

    /// `Xᵀ · B` for dense `B` (m×k) → dense (n×k), O(nnz·k); CSR rows
    /// scatter into the output, no transpose materialized. Parallel
    /// tasks own disjoint output-row (CSR-column) windows.
    pub fn tmatmul_dense(&self, b: &Dense) -> Dense {
        parallel::with_current(|pool| self.tmatmul_dense_pool(b, pool))
    }

    /// `Xᵀ · B` on an explicit pool.
    pub fn tmatmul_dense_pool(&self, b: &Dense, pool: &ThreadPool) -> Dense {
        assert_eq!(self.rows, b.rows(), "spmm^T shape mismatch");
        let k = b.cols();
        let mut c = Dense::zeros(self.cols, k);
        let work = self.nnz().saturating_mul(k);
        let cols = self.cols;
        par_row_chunks_min(pool, work, PAR_MIN_WORK, c.data_mut(), cols, k, |j0, nc, chunk| {
            self.tspmm_cols(b, j0, nc, chunk);
        });
        c
    }

    /// Serial `Xᵀ·B` restricted to output rows (CSR columns)
    /// `j0 .. j0 + ncols`. Column indices are sorted within each CSR
    /// row (guaranteed by [`Triplets::to_csr`]), so the window is found
    /// by binary search — O(nnz_window + rows·log nnz_row) per task.
    fn tspmm_cols(&self, b: &Dense, j0: usize, ncols: usize, c_rows: &mut [f64]) {
        let k = b.cols();
        let j1 = j0 + ncols;
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            let idx = &self.indices[lo..hi];
            let start = idx.partition_point(|&j| (j as usize) < j0);
            let end = idx.partition_point(|&j| (j as usize) < j1);
            if start == end {
                continue;
            }
            let b_row = b.row(i);
            for t in start..end {
                let j = idx[t] as usize;
                let v = self.values[lo + t];
                let c_row = &mut c_rows[(j - j0) * k..(j - j0 + 1) * k];
                for l in 0..k {
                    c_row[l] += v * b_row[l];
                }
            }
        }
    }

    /// `(X − u·vᵀ_sel)·B` fused: `X·B − u·(vᵀB)`-style downdate where the
    /// rank-1 right factor is supplied directly (length k).
    pub fn matmul_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), b.cols());
        let mut c = self.matmul_dense(b);
        for i in 0..self.rows {
            let ui = u[i];
            if ui != 0.0 {
                for (cx, &vx) in c.row_mut(i).iter_mut().zip(v) {
                    *cx -= ui * vx;
                }
            }
        }
        c
    }

    /// `Xᵀ·B − u·vᵀ` fused (u length n, v length k).
    pub fn tmatmul_rank1(&self, b: &Dense, u: &[f64], v: &[f64]) -> Dense {
        assert_eq!(u.len(), self.cols);
        assert_eq!(v.len(), b.cols());
        let mut c = self.tmatmul_dense(b);
        for j in 0..self.cols {
            let uj = u[j];
            if uj != 0.0 {
                for (cx, &vx) in c.row_mut(j).iter_mut().zip(v) {
                    *cx -= uj * vx;
                }
            }
        }
        c
    }

    /// Squared Frobenius norm of `(X − μ1ᵀ) − U·diag(s)·Vᵀ` divided by n —
    /// the paper's MSE — computed in O(nnz·k + (m+n)k²) without
    /// densifying either the centered matrix or the reconstruction.
    ///
    /// Expansion: ‖X̄ − R‖² = ‖X‖² − 2⟨X, M⟩ + ‖M‖² where M = μ1ᵀ + R and
    /// ‖M‖² and ⟨X, M⟩ decompose over the low-rank structure.
    pub fn shifted_mse(&self, mu: &[f64], u: &Dense, s: &[f64], v: &Dense) -> f64 {
        let (m, n) = self.shape();
        let k = s.len();
        assert_eq!(u.shape(), (m, k));
        assert_eq!(v.shape(), (n, k));
        assert_eq!(mu.len(), m);

        // ‖X‖²
        let x_sq: f64 = self.values.iter().map(|v| v * v).sum();

        // us = U·diag(s)
        let us = u.scale_cols(s);

        // ⟨X, μ1ᵀ⟩ = Σᵢ μᵢ · rowsumᵢ ; ⟨X, R⟩ = Σ_(i,j) x_ij (us_i · v_j)
        let mut x_dot_m = 0.0;
        for i in 0..m {
            let mut row_sum = 0.0;
            let us_row = us.row(i);
            let mut dot_r = 0.0;
            for (j, xv) in self.row_iter(i) {
                row_sum += xv;
                let v_row = v.row(j);
                let mut d = 0.0;
                for l in 0..k {
                    d += us_row[l] * v_row[l];
                }
                dot_r += xv * d;
            }
            x_dot_m += mu[i] * row_sum + dot_r;
        }

        // ‖M‖² = ‖μ1ᵀ‖² + 2⟨μ1ᵀ, R⟩ + ‖R‖²
        let mu_sq: f64 = mu.iter().map(|x| x * x).sum::<f64>() * n as f64;
        // ⟨μ1ᵀ, R⟩ = μᵀ·US·(Vᵀ1) = (μᵀUS)·colsum(V)
        let mu_us = us.tmatvec(mu); // k
        let v_colsum: Vec<f64> = (0..k)
            .map(|l| (0..n).map(|j| v[(j, l)]).sum())
            .collect();
        let cross: f64 = mu_us.iter().zip(&v_colsum).map(|(a, b)| a * b).sum();
        // ‖R‖² = tr(S Uᵀ U S Vᵀ V); with exactly orthonormal U, V this is
        // Σ s², but the factors are numerical so compute the Gram product.
        let ug = gemm::tmatmul(&us, &us); // k×k
        let vg = gemm::tmatmul(v, v); // k×k
        let mut r_sq = 0.0;
        for i in 0..k {
            for j in 0..k {
                r_sq += ug[(i, j)] * vg[(i, j)];
            }
        }

        let total = x_sq - 2.0 * x_dot_m + mu_sq + 2.0 * cross + r_sq;
        total.max(0.0) / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_diff, matmul};
    use crate::rng::Xoshiro256pp;

    fn sample(rng: &mut Xoshiro256pp) -> Csr {
        Csr::random(30, 80, 0.05, rng, |r| r.next_uniform() + 0.1)
    }

    #[test]
    fn triplets_roundtrip_and_duplicates_sum() {
        let mut t = Triplets::new(3, 4);
        t.push(0, 1, 2.0);
        t.push(2, 3, 1.0);
        t.push(0, 1, 3.0); // duplicate -> 5.0
        t.push(1, 0, -1.0);
        let c = t.to_csr();
        assert_eq!(c.nnz(), 3);
        let d = c.to_dense();
        assert_eq!(d[(0, 1)], 5.0);
        assert_eq!(d[(1, 0)], -1.0);
        assert_eq!(d[(2, 3)], 1.0);
    }

    #[test]
    fn empty_rows_ok() {
        let mut t = Triplets::new(5, 5);
        t.push(4, 4, 1.0);
        let c = t.to_csr();
        assert_eq!(c.row_iter(0).count(), 0);
        assert_eq!(c.row_iter(4).count(), 1);
        assert_eq!(c.row_means()[4], 0.2);
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = sample(&mut rng);
        let b = Dense::gaussian(80, 7, &mut rng);
        let want = matmul(&x.to_dense(), &b);
        assert!(fro_diff(&x.matmul_dense(&b), &want) < 1e-10);
    }

    #[test]
    fn spmm_transpose_matches_dense() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let x = sample(&mut rng);
        let b = Dense::gaussian(30, 5, &mut rng);
        let want = matmul(&x.to_dense().transpose(), &b);
        assert!(fro_diff(&x.tmatmul_dense(&b), &want) < 1e-10);
    }

    #[test]
    fn shifted_products_never_densify_but_match() {
        let mut rng = Xoshiro256pp::seed_from_u64(2);
        let x = sample(&mut rng);
        let mu = x.row_means();
        let om = Dense::gaussian(80, 6, &mut rng);
        let colsum: Vec<f64> = (0..6).map(|j| om.col(j).iter().sum()).collect();
        let implicit = x.matmul_rank1(&om, &mu, &colsum);
        let explicit = matmul(&x.to_dense().subtract_column(&mu), &om);
        assert!(fro_diff(&implicit, &explicit) < 1e-9);
    }

    #[test]
    fn shifted_mse_matches_dense_computation() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x = sample(&mut rng);
        let mu = x.row_means();
        // A plausible low-rank factorization (from the dense oracle).
        let xd = x.to_dense().subtract_column(&mu);
        let (u, s, v) = crate::linalg::jacobi::jacobi_svd(
            &xd.transpose(),
            crate::linalg::JacobiOpts::default(),
        );
        // xdᵀ = u s vᵀ → xd = v s uᵀ: left = v, right = u.
        let k = 5;
        let left = v.truncate_cols(k);
        let right = u.truncate_cols(k);
        let sk = &s[..k];
        let got = x.shifted_mse(&mu, &left, sk, &right);
        let rec = matmul(&left.scale_cols(sk), &right.transpose());
        let want = {
            let d = fro_diff(&xd, &rec);
            d * d / x.cols() as f64
        };
        assert!(
            (got - want).abs() < 1e-8 * want.max(1.0),
            "got {got} want {want}"
        );
    }

    #[test]
    fn pool_size_invariance_is_bitwise() {
        // nnz·k must clear PAR_MIN_WORK: ~60k nnz × 24 ≈ 1.4M.
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let x = Csr::random(600, 4000, 0.025, &mut rng, |r| r.next_uniform() + 0.1);
        let b = Dense::gaussian(4000, 24, &mut rng);
        let bt = Dense::gaussian(600, 24, &mut rng);
        let p1 = crate::parallel::ThreadPool::new(1);
        let base = x.matmul_dense_pool(&b, &p1);
        let base_t = x.tmatmul_dense_pool(&bt, &p1);
        for threads in [2, 8] {
            let p = crate::parallel::ThreadPool::new(threads);
            let got = x.matmul_dense_pool(&b, &p);
            let got_t = x.tmatmul_dense_pool(&bt, &p);
            for (want, have) in [(&base, &got), (&base_t, &got_t)] {
                let same = want
                    .data()
                    .iter()
                    .zip(have.data())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "threads {threads}: CSR products must be bit-identical");
            }
        }
    }

    #[test]
    fn density_and_nnz_accounting() {
        let mut rng = Xoshiro256pp::seed_from_u64(4);
        let x = Csr::random(100, 100, 0.01, &mut rng, |r| r.next_uniform());
        // Collisions make nnz <= target.
        assert!(x.nnz() <= 100);
        assert!(x.nnz() > 50);
        assert!((x.density() - x.nnz() as f64 / 1e4).abs() < 1e-12);
    }
}
