//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Deterministic, seed-replayable generators over [`crate::rng`]: a
//! failing case prints its case index and seed so it can be replayed
//! exactly. Supports a lightweight shrink: on failure the runner retries
//! the property on "smaller" cases produced by the generator's own
//! `shrink` hint.
//!
//! ```no_run
//! use srsvd::prop::{forall, Gen};
//! forall("matmul associative-ish", 50, |g| {
//!     let m = g.usize_in(1, 20);
//!     // ... build inputs from g, return Ok(()) or Err(message)
//!     Ok(())
//! });
//! ```

use crate::rng::{Rng, SplitMix64, Xoshiro256pp};

/// Per-case generator handle: derives all values from a case-specific
/// seed so any failure is replayable.
pub struct Gen {
    rng: Xoshiro256pp,
    /// The seed this case derives every draw from (printed on failure).
    pub case_seed: u64,
    /// Shrink level 0 = full-size cases; higher levels should generate
    /// smaller inputs. Generators honor it through the sizing helpers.
    pub shrink_level: u32,
}

impl Gen {
    fn new(case_seed: u64, shrink_level: u32) -> Gen {
        Gen {
            rng: Xoshiro256pp::seed_from_u64(case_seed),
            case_seed,
            shrink_level,
        }
    }

    /// Uniform usize in [lo, hi] (inclusive), shrunk toward `lo`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let (lo64, hi64) = (lo as u64, hi as u64);
        let span = hi64 - lo64 + 1;
        let shrunk_span = match self.shrink_level {
            0 => span,
            1 => (span / 4).max(1),
            _ => 1,
        };
        (lo64 + self.rng.next_below(shrunk_span)) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.next_range(lo, hi)
    }

    /// Standard normal draw.
    pub fn gaussian(&mut self) -> f64 {
        self.rng.next_gaussian()
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        self.rng.next_uniform()
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.next_below(items.len() as u64) as usize]
    }

    /// A fresh RNG derived from this case (for seeding algorithms under
    /// test without coupling them to generator draws).
    pub fn derived_rng(&mut self) -> Xoshiro256pp {
        Xoshiro256pp::seed_from_u64(self.rng.next_u64())
    }
}

/// Run `cases` random cases of `property`. Panics (with replay info) on
/// the first failure after attempting shrunk repetitions.
///
/// The master seed comes from `SRSVD_PROP_SEED` (default 0xC0FFEE) so CI
/// is deterministic; set it to replay a reported failure.
pub fn forall(
    name: &str,
    cases: usize,
    mut property: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    let master = std::env::var("SRSVD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0xC0FFEE);
    let mut seeder = SplitMix64::new(master ^ hash_name(name));
    for case in 0..cases {
        let case_seed = seeder.next_u64();
        let mut g = Gen::new(case_seed, 0);
        if let Err(msg) = property(&mut g) {
            // Try shrunk variants of the same seed for a smaller report.
            let mut final_msg = msg;
            let mut final_level = 0;
            for level in [2u32, 1] {
                let mut sg = Gen::new(case_seed, level);
                if let Err(m) = property(&mut sg) {
                    final_msg = m;
                    final_level = level;
                    break;
                }
            }
            panic!(
                "property {name:?} failed at case {case} \
                 (seed {case_seed:#x}, shrink level {final_level}): {final_msg}\n\
                 replay with SRSVD_PROP_SEED={master}"
            );
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("always true", 25, |g| {
            let _ = g.usize_in(1, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        forall("always false", 5, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        forall("bounds", 100, |g| {
            let x = g.usize_in(3, 9);
            if !(3..=9).contains(&x) {
                return Err(format!("usize_in out of bounds: {x}"));
            }
            let f = g.f64_in(-1.0, 1.0);
            if !(-1.0..1.0).contains(&f) {
                return Err(format!("f64_in out of bounds: {f}"));
            }
            let c = *g.choose(&[1, 2, 3]);
            if !(1..=3).contains(&c) {
                return Err("choose out of slice".into());
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_fixed_env_seed() {
        // Two identical runs draw identical values.
        let mut first = Vec::new();
        forall("det-a", 10, |g| {
            first.push(g.usize_in(0, 1000));
            Ok(())
        });
        let mut second = Vec::new();
        forall("det-a", 10, |g| {
            second.push(g.usize_in(0, 1000));
            Ok(())
        });
        assert_eq!(first, second);
    }
}
