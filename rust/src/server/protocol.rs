//! The JSON wire schema of the factorization service, built on the
//! in-tree [`Json`] value (no serde — the crate is zero-dependency).
//!
//! The paper's point makes this protocol thin: S-RSVD factorizes the
//! shifted matrix *without constructing it*, so a client ships a
//! compact job **spec** — a generator seed, a server-side file path, a
//! sparse CSR skeleton — rather than a dense payload. Only the
//! `"dense"` input kind carries the matrix itself.
//!
//! ## Submit request (`POST /v1/jobs`)
//!
//! ```json
//! {
//!   "input":       {"kind": "dense", "m": 2, "n": 3, "data": [..6 numbers..]},
//!   "k": 10,
//!   "oversample":  10,            // optional, default k  (paper: K = 2k)
//!   "power_iters": 0,             // optional, default 0 (fixed sweep count;
//!                                 //   exclusive with pve_tol)
//!   "pve_tol":     1e-3,          // optional: dashSVD accuracy control — sweep
//!                                 //   until the PVE estimates settle (adaptive)
//!   "max_sweeps":  32,            // optional: adaptive sweep ceiling (needs pve_tol)
//!   "basis":       "direct",      // optional: direct | qr-update-paper | qr-update-exact
//!   "small_svd":   "jacobi",      // optional: jacobi | gram
//!   "pass_policy": "exact",       // optional: exact | fused (source-pass schedule;
//!                                 //   fused caps streamed jobs at q+2 passes)
//!   "precision":   "exact",       // optional: exact | fast (kernel tier; fast =
//!                                 //   packed AVX2/FMA, last-ulp differences)
//!   "shift":       "mean-center", // optional: "none" | "mean-center" | [mu_0, ..]
//!   "engine":      "auto",        // optional: auto | native | artifact
//!   "seed": 0,                    // optional, default 0 (u64 below 2^53)
//!   "score": true,                // optional, default true (compute MSE)
//!   "wait": false                 // optional: answer with the finished result
//! }
//! ```
//!
//! Input kinds:
//!
//! * `dense` — `m`, `n`, `data` (row-major, `m·n` numbers);
//! * `csr` — `m`, `n`, `indptr` (`m+1`), `indices`, `values`;
//! * `generator` — `m`, `n`, `dist` (`uniform|normal|exponential`),
//!   `seed`, and optional `block_rows`/`budget_mb`: an out-of-core
//!   [`GeneratorSource`] job, nothing is ever materialized;
//! * `file` — `path` (resolved **server-side**, never densified) plus
//!   optional `block_rows`/`budget_mb`: an out-of-core [`FileSource`]
//!   job over the `SRSV` on-disk format.
//!
//! Unknown fields are rejected (strict schema: a typo fails loudly with
//! `400` instead of silently running a default).
//!
//! ## Result (`200` from a waited submit or `GET /v1/jobs/{id}`)
//!
//! ```json
//! {"id": 1, "engine": "native", "exec_s": 0.01, "queue_s": 0.001,
//!  "ok": true,
//!  "output": {"m": 2, "n": 3, "k": 1, "u": [..], "s": [..], "v": [..],
//!             "mse": 0.5, "sweeps_used": 4, "achieved_pve": 0.93}}
//! ```
//!
//! `sweeps_used` reports the power sweeps the engine executed;
//! `achieved_pve` is `null` except under the adaptive tolerance mode.
//!
//! `u`/`s`/`v` travel as JSON numbers; render → parse reproduces the
//! exact `f64` bits (shortest-repr `Display`, correctly-rounded parse —
//! pinned by `rust/tests/props.rs`), so a factorization fetched over
//! the wire is **byte-identical** to the same spec run in-process
//! (pinned by `rust/tests/server.rs`).

use crate::config::{parse_basis, parse_pass_policy, parse_precision, parse_small_svd, stop_criterion};
use crate::coordinator::{EnginePreference, JobResult, JobSpec, MatrixInput, ShiftSpec};
use crate::data::Distribution;
use crate::linalg::stream::{FileSource, GeneratorSource, StreamConfig};
use crate::linalg::{Csr, Dense, Triplets};
use crate::svd::{
    BasisMethod, PassPolicy, Precision, SmallSvdMethod, StopCriterion, SvdConfig, SvdEngine,
};
use crate::util::json::Json;
use crate::util::{Error, Result};

/// A parsed submit request: the job plus the submit mode.
#[derive(Debug)]
pub struct SubmitRequest {
    /// The job to run.
    pub spec: JobSpec,
    /// `true`: answer the `POST` with the finished result;
    /// `false`: answer `202` with the id for a later blocking `GET`.
    pub wait: bool,
}

fn unknown_keys(obj: &Json, allowed: &[&str], what: &str) -> Result<()> {
    for key in obj.as_obj()?.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(Error::Json(format!("unknown {what} field {key:?}")));
        }
    }
    Ok(())
}

fn get_usize_or(obj: &Json, key: &str, default: usize) -> Result<usize> {
    match obj.as_obj()?.get(key) {
        Some(v) => v.as_usize(),
        None => Ok(default),
    }
}

fn f64_array(v: &Json, what: &str) -> Result<Vec<f64>> {
    v.as_arr()?
        .iter()
        .map(|x| x.as_f64())
        .collect::<Result<Vec<f64>>>()
        .map_err(|e| Error::Json(format!("{what}: {e}")))
}

fn usize_array(v: &Json, what: &str) -> Result<Vec<usize>> {
    v.as_arr()?
        .iter()
        .map(|x| x.as_usize())
        .collect::<Result<Vec<usize>>>()
        .map_err(|e| Error::Json(format!("{what}: {e}")))
}

/// Parse the `input` object into a [`MatrixInput`]. Streamed kinds take
/// their default block policy from `stream_defaults` (the `[stream]`
/// config section), overridable per job via `block_rows`/`budget_mb`.
fn parse_input(input: &Json, stream_defaults: &StreamConfig) -> Result<MatrixInput> {
    let kind = input.get("kind")?.as_str()?;
    let stream_config = |input: &Json| -> Result<StreamConfig> {
        Ok(StreamConfig {
            block_rows: get_usize_or(input, "block_rows", stream_defaults.block_rows)?,
            budget_mb: get_usize_or(input, "budget_mb", stream_defaults.budget_mb)?.max(1),
            // Pipelining is a server deployment choice ([stream]
            // prefetch), not a per-job wire field — it cannot change
            // results, only how reads overlap compute.
            prefetch: stream_defaults.prefetch,
        })
    };
    match kind {
        "dense" => {
            unknown_keys(input, &["kind", "m", "n", "data"], "dense input")?;
            let m = input.get("m")?.as_usize()?;
            let n = input.get("n")?.as_usize()?;
            let len = m
                .checked_mul(n)
                .ok_or_else(|| Error::Json(format!("dense shape {m}x{n} overflows")))?;
            let data = f64_array(input.get("data")?, "dense data")?;
            crate::ensure!(
                data.len() == len,
                "dense data has {} values, shape {m}x{n} needs {len}",
                data.len()
            );
            Ok(MatrixInput::Dense(Dense::from_vec(m, n, data)))
        }
        "csr" => {
            unknown_keys(
                input,
                &["kind", "m", "n", "indptr", "indices", "values"],
                "csr input",
            )?;
            let m = input.get("m")?.as_usize()?;
            let n = input.get("n")?.as_usize()?;
            crate::ensure!(
                m < u32::MAX as usize && n < u32::MAX as usize,
                "csr shape {m}x{n} exceeds u32 indices"
            );
            let indptr = usize_array(input.get("indptr")?, "csr indptr")?;
            let indices = usize_array(input.get("indices")?, "csr indices")?;
            let values = f64_array(input.get("values")?, "csr values")?;
            crate::ensure!(
                indptr.len() == m + 1,
                "csr indptr has {} entries, need m+1 = {}",
                indptr.len(),
                m + 1
            );
            crate::ensure!(
                indices.len() == values.len(),
                "csr indices/values lengths differ ({} vs {})",
                indices.len(),
                values.len()
            );
            crate::ensure!(
                indptr.first() == Some(&0) && indptr.last() == Some(&values.len()),
                "csr indptr must start at 0 and end at nnz {}",
                values.len()
            );
            let mut t = Triplets::new(m, n);
            for i in 0..m {
                crate::ensure!(
                    indptr[i] <= indptr[i + 1],
                    "csr indptr not monotone at row {i}"
                );
                for idx in indptr[i]..indptr[i + 1] {
                    crate::ensure!(
                        indices[idx] < n,
                        "csr column {} out of bounds for n = {n}",
                        indices[idx]
                    );
                    t.push(i, indices[idx], values[idx]);
                }
            }
            Ok(MatrixInput::Sparse(t.to_csr()))
        }
        "generator" => {
            unknown_keys(
                input,
                &["kind", "m", "n", "dist", "seed", "block_rows", "budget_mb"],
                "generator input",
            )?;
            let m = input.get("m")?.as_usize()?;
            let n = input.get("n")?.as_usize()?;
            let dist_name = input.get("dist")?.as_str()?;
            let dist = Distribution::parse(dist_name)
                .ok_or_else(|| Error::Json(format!("unknown dist {dist_name:?}")))?;
            let seed = match input.as_obj()?.get("seed") {
                Some(v) => v.as_u64()?,
                None => 0,
            };
            let src = GeneratorSource::new(m, n, dist, seed)?;
            Ok(MatrixInput::streamed(src, &stream_config(input)?))
        }
        "file" => {
            unknown_keys(input, &["kind", "path", "block_rows", "budget_mb"], "file input")?;
            // The path is resolved on the server: the client names data
            // the service can already reach; the matrix never crosses
            // the wire and is never densified.
            let path = input.get("path")?.as_str()?;
            let src = FileSource::open(std::path::Path::new(path))?;
            Ok(MatrixInput::streamed(src, &stream_config(input)?))
        }
        other => Err(Error::Json(format!(
            "unknown input kind {other:?} (dense | csr | generator | file)"
        ))),
    }
}

fn parse_shift(v: &Json) -> Result<ShiftSpec> {
    match v {
        Json::Str(s) => match s.as_str() {
            "none" => Ok(ShiftSpec::None),
            "mean-center" => Ok(ShiftSpec::MeanCenter),
            other => Err(Error::Json(format!(
                "unknown shift {other:?} (none | mean-center | [numbers])"
            ))),
        },
        Json::Arr(_) => Ok(ShiftSpec::Vector(f64_array(v, "shift vector")?)),
        other => Err(Error::Json(format!("bad shift {other:?}"))),
    }
}

fn parse_engine(s: &str) -> Result<EnginePreference> {
    match s {
        "auto" => Ok(EnginePreference::Auto),
        "native" => Ok(EnginePreference::Native),
        "artifact" => Ok(EnginePreference::ArtifactOnly),
        other => Err(Error::Json(format!(
            "unknown engine {other:?} (auto | native | artifact)"
        ))),
    }
}

/// Parse a submit body into a [`SubmitRequest`]. Every error is a
/// client error (the server answers `400`).
pub fn parse_submit(body: &Json, stream_defaults: &StreamConfig) -> Result<SubmitRequest> {
    unknown_keys(
        body,
        &[
            "input", "k", "oversample", "power_iters", "pve_tol", "max_sweeps", "basis",
            "small_svd", "pass_policy", "precision", "shift", "engine", "seed", "score", "wait",
        ],
        "job",
    )?;
    let obj = body.as_obj()?;
    let input = parse_input(body.get("input")?, stream_defaults)?;
    let k = body.get("k")?.as_usize()?;
    crate::ensure!(k >= 1, "k must be >= 1");
    // The three stopping fields share the config/CLI conversion point:
    // absent fields mean "unset", so omitting all of them keeps the
    // pre-redesign fixed q = 0 and existing clients are untouched.
    let stop = stop_criterion(
        match obj.get("power_iters") {
            Some(v) => Some(v.as_usize()?),
            None => None,
        },
        match obj.get("pve_tol") {
            Some(v) => Some(v.as_f64()?),
            None => None,
        },
        match obj.get("max_sweeps") {
            Some(v) => Some(v.as_usize()?),
            None => None,
        },
    )?;
    let config = SvdConfig {
        k,
        oversample: get_usize_or(body, "oversample", k)?,
        stop,
        basis: match obj.get("basis") {
            Some(v) => parse_basis(v.as_str()?)?,
            None => BasisMethod::Direct,
        },
        small_svd: match obj.get("small_svd") {
            Some(v) => parse_small_svd(v.as_str()?)?,
            None => SmallSvdMethod::Jacobi,
        },
        pass_policy: match obj.get("pass_policy") {
            Some(v) => parse_pass_policy(v.as_str()?)?,
            None => PassPolicy::Exact,
        },
        precision: match obj.get("precision") {
            Some(v) => parse_precision(v.as_str()?)?,
            None => Precision::Exact,
        },
    };
    let shift = match obj.get("shift") {
        Some(v) => parse_shift(v)?,
        None => ShiftSpec::MeanCenter,
    };
    let engine = match obj.get("engine") {
        Some(v) => parse_engine(v.as_str()?)?,
        None => EnginePreference::Auto,
    };
    let seed = match obj.get("seed") {
        Some(v) => v.as_u64()?,
        None => 0,
    };
    let score = match obj.get("score") {
        Some(v) => v.as_bool()?,
        None => true,
    };
    let wait = match obj.get("wait") {
        Some(v) => v.as_bool()?,
        None => false,
    };
    Ok(SubmitRequest {
        spec: JobSpec { input, config, shift, engine, seed, score },
        wait,
    })
}

// ---------------------------------------------------------------------------
// Request builders (client side)
// ---------------------------------------------------------------------------

/// Client-side job description; renders the submit body with
/// [`JobRequest::to_json`]. Mirrors [`JobSpec`] field-for-field so the
/// loopback tests can build both from the same parameters.
#[derive(Debug, Clone)]
pub struct JobRequest {
    /// The `input` object (see the input builders below).
    pub input: Json,
    /// Rank / oversampling / power-iteration configuration.
    pub config: SvdConfig,
    /// What to shift by.
    pub shift: ShiftSpec,
    /// Engine routing preference.
    pub engine: EnginePreference,
    /// Seed for Ω (deterministic replay).
    pub seed: u64,
    /// Also compute the paper's MSE metric.
    pub score: bool,
    /// Submit-and-wait in one round trip.
    pub wait: bool,
}

impl JobRequest {
    /// A request with the paper's defaults (K = 2k, q = 0, mean-center).
    pub fn new(input: Json, k: usize) -> JobRequest {
        JobRequest {
            input,
            config: SvdConfig::paper(k),
            shift: ShiftSpec::MeanCenter,
            engine: EnginePreference::Auto,
            seed: 0,
            score: true,
            wait: false,
        }
    }

    /// Render the submit body.
    pub fn to_json(&self) -> Json {
        let shift = match &self.shift {
            ShiftSpec::None => Json::str("none"),
            ShiftSpec::MeanCenter => Json::str("mean-center"),
            ShiftSpec::Vector(v) => Json::arr(v.iter().map(|&x| Json::num(x))),
        };
        let engine = match self.engine {
            EnginePreference::Auto => "auto",
            EnginePreference::Native => "native",
            EnginePreference::ArtifactOnly => "artifact",
        };
        let basis = match self.config.basis {
            BasisMethod::Direct => "direct",
            BasisMethod::QrUpdatePaper => "qr-update-paper",
            BasisMethod::QrUpdateExact => "qr-update-exact",
        };
        let small_svd = match self.config.small_svd {
            SmallSvdMethod::Jacobi => "jacobi",
            SmallSvdMethod::GramEig => "gram",
        };
        let mut pairs = vec![
            ("input", self.input.clone()),
            ("k", Json::num(self.config.k as f64)),
            ("oversample", Json::num(self.config.oversample as f64)),
        ];
        // Render exactly the fields the criterion owns: a fixed-q request
        // never mentions pve_tol (and vice versa), so the server's
        // mutual-exclusion check can stay strict.
        match self.config.stop {
            StopCriterion::FixedPower { q } => {
                pairs.push(("power_iters", Json::num(q as f64)));
            }
            StopCriterion::Tolerance { pve_tol, max_sweeps } => {
                pairs.push(("pve_tol", Json::num(pve_tol)));
                pairs.push(("max_sweeps", Json::num(max_sweeps as f64)));
            }
        }
        pairs.extend([
            ("basis", Json::str(basis)),
            ("small_svd", Json::str(small_svd)),
            ("pass_policy", Json::str(self.config.pass_policy.name())),
            ("precision", Json::str(self.config.precision.name())),
            ("shift", shift),
            ("engine", Json::str(engine)),
            ("seed", Json::num(self.seed as f64)),
            ("score", Json::Bool(self.score)),
            ("wait", Json::Bool(self.wait)),
        ]);
        Json::obj(pairs)
    }
}

/// `input` object for a resident dense matrix (the only kind that
/// ships the data itself).
pub fn dense_input(x: &Dense) -> Json {
    Json::obj(vec![
        ("kind", Json::str("dense")),
        ("m", Json::num(x.rows() as f64)),
        ("n", Json::num(x.cols() as f64)),
        ("data", Json::arr(x.data().iter().map(|&v| Json::num(v)))),
    ])
}

/// `input` object for a sparse CSR matrix.
pub fn csr_input(x: &Csr) -> Json {
    let (m, n) = x.shape();
    let mut indptr = Vec::with_capacity(m + 1);
    let mut indices = Vec::new();
    let mut values = Vec::new();
    indptr.push(Json::num(0.0));
    for i in 0..m {
        for (j, v) in x.row_iter(i) {
            indices.push(Json::num(j as f64));
            values.push(Json::num(v));
        }
        indptr.push(Json::num(indices.len() as f64));
    }
    Json::obj(vec![
        ("kind", Json::str("csr")),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("indptr", Json::Arr(indptr)),
        ("indices", Json::Arr(indices)),
        ("values", Json::Arr(values)),
    ])
}

/// `input` object for a server-generated streamed matrix: the job is a
/// seed, not a payload.
pub fn generator_input(
    m: usize,
    n: usize,
    dist: Distribution,
    seed: u64,
    block_rows: Option<usize>,
    budget_mb: Option<usize>,
) -> Json {
    let mut pairs = vec![
        ("kind", Json::str("generator")),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("dist", Json::str(dist.name())),
        ("seed", Json::num(seed as f64)),
    ];
    if let Some(b) = block_rows {
        pairs.push(("block_rows", Json::num(b as f64)));
    }
    if let Some(b) = budget_mb {
        pairs.push(("budget_mb", Json::num(b as f64)));
    }
    Json::obj(pairs)
}

/// `input` object for a server-side matrix file (`SRSV` format),
/// streamed block-at-a-time — never shipped, never densified.
pub fn file_input(path: &str, block_rows: Option<usize>, budget_mb: Option<usize>) -> Json {
    let mut pairs = vec![("kind", Json::str("file")), ("path", Json::str(path))];
    if let Some(b) = block_rows {
        pairs.push(("block_rows", Json::num(b as f64)));
    }
    if let Some(b) = budget_mb {
        pairs.push(("budget_mb", Json::num(b as f64)));
    }
    Json::obj(pairs)
}

// ---------------------------------------------------------------------------
// Result rendering (server side) and parsing (client side)
// ---------------------------------------------------------------------------

/// Render a completed job as the wire result object.
pub fn job_result_to_json(r: &JobResult) -> Json {
    let engine = match r.engine {
        SvdEngine::Native => "native",
        SvdEngine::Artifact => "artifact",
    };
    let mut pairs = vec![
        ("id", Json::num(r.id.0 as f64)),
        ("engine", Json::str(engine)),
        ("exec_s", Json::num(r.exec_s)),
        ("queue_s", Json::num(r.queue_s)),
        ("ok", Json::Bool(r.outcome.is_ok())),
    ];
    match &r.outcome {
        Ok(out) => {
            let f = &out.factorization;
            pairs.push((
                "output",
                Json::obj(vec![
                    ("m", Json::num(f.u.rows() as f64)),
                    ("n", Json::num(f.v.rows() as f64)),
                    ("k", Json::num(f.rank() as f64)),
                    ("u", Json::arr(f.u.data().iter().map(|&x| Json::num(x)))),
                    ("s", Json::arr(f.s.iter().map(|&x| Json::num(x)))),
                    ("v", Json::arr(f.v.data().iter().map(|&x| Json::num(x)))),
                    (
                        "mse",
                        match out.mse {
                            Some(m) => Json::num(m),
                            None => Json::Null,
                        },
                    ),
                    ("sweeps_used", Json::num(out.sweeps_used as f64)),
                    (
                        "achieved_pve",
                        match out.achieved_pve {
                            Some(p) => Json::num(p),
                            None => Json::Null,
                        },
                    ),
                ]),
            ));
        }
        Err(e) => pairs.push(("error", Json::str(&format!("{e}")))),
    }
    Json::obj(pairs)
}

/// The factors of a wire result, reassembled client-side.
#[derive(Debug, Clone)]
pub struct WireOutput {
    /// Left singular vectors, m×k.
    pub u: Dense,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// Right singular vectors, n×k.
    pub v: Dense,
    /// The paper's MSE, when scoring was requested.
    pub mse: Option<f64>,
    /// Power sweeps the engine executed; `None` when talking to a
    /// server that predates the stopping-criterion fields.
    pub sweeps_used: Option<u64>,
    /// Achieved PVE (adaptive tolerance mode only).
    pub achieved_pve: Option<f64>,
}

/// A completed job as seen by the client.
#[derive(Debug, Clone)]
pub struct WireResult {
    /// Job id assigned at submit time.
    pub id: u64,
    /// Engine that ran the job (`"native"` / `"artifact"`).
    pub engine: String,
    /// Seconds spent executing.
    pub exec_s: f64,
    /// Seconds spent queued.
    pub queue_s: f64,
    /// The factors, or the server-reported job error.
    pub outcome: std::result::Result<WireOutput, String>,
}

/// Parse a wire result object (the client half of
/// [`job_result_to_json`]).
pub fn parse_result(body: &Json) -> Result<WireResult> {
    let id = body.get("id")?.as_u64()?;
    let engine = body.get("engine")?.as_str()?.to_string();
    let exec_s = body.get("exec_s")?.as_f64()?;
    let queue_s = body.get("queue_s")?.as_f64()?;
    let outcome = if body.get("ok")?.as_bool()? {
        let out = body.get("output")?;
        let m = out.get("m")?.as_usize()?;
        let n = out.get("n")?.as_usize()?;
        let k = out.get("k")?.as_usize()?;
        let u = f64_array(out.get("u")?, "u")?;
        let s = f64_array(out.get("s")?, "s")?;
        let v = f64_array(out.get("v")?, "v")?;
        crate::ensure!(
            u.len() == m * k && v.len() == n * k && s.len() == k,
            "factor shapes disagree with m={m} n={n} k={k}"
        );
        let mse = match out.get("mse")? {
            Json::Null => None,
            other => Some(other.as_f64()?),
        };
        // Lenient: absent on results from servers that predate the
        // stopping-criterion API.
        let sweeps_used = match out.as_obj()?.get("sweeps_used") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_u64()?),
        };
        let achieved_pve = match out.as_obj()?.get("achieved_pve") {
            None | Some(Json::Null) => None,
            Some(v) => Some(v.as_f64()?),
        };
        Ok(WireOutput {
            u: Dense::from_vec(m, k, u),
            s,
            v: Dense::from_vec(n, k, v),
            mse,
            sweeps_used,
            achieved_pve,
        })
    } else {
        Err(body.get("error")?.as_str()?.to_string())
    };
    Ok(WireResult { id, engine, exec_s, queue_s, outcome })
}

/// Render a metrics snapshot for `GET /metrics`.
pub fn metrics_to_json(m: &crate::coordinator::MetricsSnapshot) -> Json {
    Json::obj(vec![
        ("submitted", Json::num(m.submitted as f64)),
        ("completed", Json::num(m.completed as f64)),
        ("failed", Json::num(m.failed as f64)),
        ("native_jobs", Json::num(m.native_jobs as f64)),
        ("artifact_jobs", Json::num(m.artifact_jobs as f64)),
        ("queue_depth", Json::num(m.queue_depth as f64)),
        ("in_flight", Json::num(m.in_flight as f64)),
        ("http_accepted", Json::num(m.http_accepted as f64)),
        ("http_rejected", Json::num(m.http_rejected as f64)),
        ("http_bytes_in", Json::num(m.http_bytes_in as f64)),
        ("http_bytes_out", Json::num(m.http_bytes_out as f64)),
        ("stream_passes", Json::num(m.stream_passes as f64)),
        ("stream_bytes_read", Json::num(m.stream_bytes_read as f64)),
        ("stream_retries", Json::num(m.stream_retries as f64)),
        ("sweeps_used", Json::num(m.sweeps_used as f64)),
        ("mean_achieved_pve", Json::num(m.mean_achieved_pve)),
        ("mean_exec_s", Json::num(m.mean_exec_s)),
        ("mean_queue_s", Json::num(m.mean_queue_s)),
        ("max_exec_s", Json::num(m.max_exec_s)),
        ("pool_threads", Json::num(m.pool_threads as f64)),
        ("pool_parallel_ops", Json::num(m.pool_parallel_ops as f64)),
        ("pool_serial_ops", Json::num(m.pool_serial_ops as f64)),
        ("pool_chunks", Json::num(m.pool_chunks as f64)),
        ("pool_spawned", Json::num(m.pool_spawned as f64)),
        ("io_threads", Json::num(m.io_threads as f64)),
        ("io_parallel_ops", Json::num(m.io_parallel_ops as f64)),
        ("io_serial_ops", Json::num(m.io_serial_ops as f64)),
        ("io_chunks", Json::num(m.io_chunks as f64)),
        ("io_spawned", Json::num(m.io_spawned as f64)),
        ("cancelled", Json::num(m.cancelled as f64)),
        ("evicted", Json::num(m.evicted as f64)),
        ("cache_hits", Json::num(m.cache_hits as f64)),
        ("cache_misses", Json::num(m.cache_misses as f64)),
        ("cache_bytes", Json::num(m.cache_bytes as f64)),
        ("faults_injected", Json::num(m.faults_injected as f64)),
        ("checkpoints_written", Json::num(m.checkpoints_written as f64)),
        ("checkpoints_resumed", Json::num(m.checkpoints_resumed as f64)),
        ("journal_replayed", Json::num(m.journal_replayed as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Rng, Xoshiro256pp};
    use crate::svd::MatVecOps;

    fn defaults() -> StreamConfig {
        StreamConfig::default()
    }

    #[test]
    fn dense_submit_round_trips() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let x = Dense::from_fn(4, 6, |_, _| rng.next_uniform());
        let mut req = JobRequest::new(dense_input(&x), 2);
        req.seed = 9;
        req.wait = true;
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        assert!(parsed.wait);
        assert_eq!(parsed.spec.seed, 9);
        assert_eq!(parsed.spec.config.k, 2);
        assert_eq!(parsed.spec.config.sample_width(), 4);
        let MatrixInput::Dense(back) = &parsed.spec.input else {
            panic!("expected dense input");
        };
        let same = back
            .data()
            .iter()
            .zip(x.data())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "dense payload changed across the wire");
    }

    #[test]
    fn csr_submit_round_trips() {
        let mut rng = Xoshiro256pp::seed_from_u64(1);
        let sp = Csr::random(8, 12, 0.3, &mut rng, |r| r.next_uniform() + 0.1);
        let req = JobRequest::new(csr_input(&sp), 3);
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        let MatrixInput::Sparse(back) = &parsed.spec.input else {
            panic!("expected sparse input");
        };
        assert_eq!(back.shape(), sp.shape());
        assert_eq!(back.nnz(), sp.nnz());
        let bits = |x: &Dense| -> Vec<u64> { x.data().iter().map(|v| v.to_bits()).collect() };
        assert_eq!(bits(&back.to_dense()), bits(&sp.to_dense()));
    }

    #[test]
    fn generator_submit_builds_streamed() {
        let req = JobRequest::new(
            generator_input(40, 30, Distribution::Uniform, 5, Some(7), None),
            2,
        );
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        let MatrixInput::Streamed(s) = &parsed.spec.input else {
            panic!("expected streamed input");
        };
        assert_eq!(MatVecOps::shape(s), (40, 30));
        assert_eq!(s.block_rows(), 7);
    }

    #[test]
    fn generator_defaults_come_from_stream_config() {
        let req = JobRequest::new(
            generator_input(100, 10, Distribution::Normal, 1, None, None),
            2,
        );
        let tight = StreamConfig { block_rows: 13, ..Default::default() };
        let parsed = parse_submit(&req.to_json(), &tight).unwrap();
        let MatrixInput::Streamed(s) = &parsed.spec.input else {
            panic!("expected streamed input");
        };
        assert_eq!(s.block_rows(), 13);
    }

    #[test]
    fn pass_policy_round_trips_and_rejects_unknowns() {
        let mut req = JobRequest::new(
            generator_input(8, 8, Distribution::Uniform, 0, None, None),
            2,
        );
        // Default: exact.
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        assert_eq!(parsed.spec.config.pass_policy, PassPolicy::Exact);
        // Fused survives the wire.
        req.config.pass_policy = PassPolicy::Fused;
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        assert_eq!(parsed.spec.config.pass_policy, PassPolicy::Fused);
        // An unknown value is a 400-class error, not a silent default.
        let mut bad = req.to_json().as_obj().unwrap().clone();
        bad.insert("pass_policy".into(), Json::str("warp"));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
        // A non-string value is rejected too.
        let mut bad = req.to_json().as_obj().unwrap().clone();
        bad.insert("pass_policy".into(), Json::num(1.0));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
    }

    #[test]
    fn strict_schema_rejects_unknowns_and_garbage() {
        let ok = JobRequest::new(generator_input(4, 4, Distribution::Uniform, 0, None, None), 1)
            .to_json();
        assert!(parse_submit(&ok, &defaults()).is_ok());
        // Unknown top-level field.
        let mut bad = ok.as_obj().unwrap().clone();
        bad.insert("rank".into(), Json::num(3.0));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
        // Missing input / k.
        assert!(parse_submit(&Json::obj(vec![("k", Json::num(1.0))]), &defaults()).is_err());
        // Unknown input kind, bad dist, zipf (not streamable).
        for (kind, extra) in [
            ("teleport", vec![]),
            ("generator", vec![("dist", Json::str("cauchy"))]),
            ("generator", vec![("dist", Json::str("zipf"))]),
        ] {
            let mut input = vec![
                ("kind", Json::str(kind)),
                ("m", Json::num(4.0)),
                ("n", Json::num(4.0)),
            ];
            input.extend(extra);
            let req = JobRequest::new(Json::obj(input), 1);
            assert!(parse_submit(&req.to_json(), &defaults()).is_err(), "{kind}");
        }
        // Dense payload length mismatch.
        let input = Json::obj(vec![
            ("kind", Json::str("dense")),
            ("m", Json::num(2.0)),
            ("n", Json::num(2.0)),
            ("data", Json::arr([1.0, 2.0].map(Json::num))),
        ]);
        assert!(parse_submit(&JobRequest::new(input, 1).to_json(), &defaults()).is_err());
        // Broken CSR skeleton: indptr end != nnz.
        let input = Json::obj(vec![
            ("kind", Json::str("csr")),
            ("m", Json::num(2.0)),
            ("n", Json::num(2.0)),
            ("indptr", Json::arr([0.0, 1.0, 3.0].map(Json::num))),
            ("indices", Json::arr([0.0, 1.0].map(Json::num))),
            ("values", Json::arr([1.0, 2.0].map(Json::num))),
        ]);
        assert!(parse_submit(&JobRequest::new(input, 1).to_json(), &defaults()).is_err());
    }

    #[test]
    fn result_round_trips_bitwise() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let fact = crate::svd::deterministic_svd(&Dense::gaussian(6, 9, &mut rng), 3);
        let r = JobResult {
            id: crate::coordinator::JobId(11),
            outcome: Ok(crate::coordinator::JobOutput {
                factorization: fact.clone(),
                mse: Some(0.125),
                sweeps_used: 4,
                achieved_pve: Some(0.5),
            }),
            engine: SvdEngine::Native,
            exec_s: 0.5,
            queue_s: 0.25,
        };
        // Through text: exactly what the server writes and the client reads.
        let text = job_result_to_json(&r).to_string();
        let back = parse_result(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.id, 11);
        assert_eq!(back.engine, "native");
        let out = back.outcome.unwrap();
        assert_eq!(out.mse, Some(0.125));
        assert_eq!(out.sweeps_used, Some(4));
        assert_eq!(out.achieved_pve, Some(0.5));
        let bits = |x: &Dense| -> Vec<u64> { x.data().iter().map(|v| v.to_bits()).collect() };
        assert_eq!(bits(&out.u), bits(&fact.u));
        assert_eq!(bits(&out.v), bits(&fact.v));
        assert_eq!(
            out.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fact.s.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );

        // Failed jobs carry the error text.
        let r = JobResult {
            id: crate::coordinator::JobId(12),
            outcome: Err(Error::Invalid("bad shift".into())),
            engine: SvdEngine::Native,
            exec_s: 0.0,
            queue_s: 0.0,
        };
        let back =
            parse_result(&Json::parse(&job_result_to_json(&r).to_string()).unwrap()).unwrap();
        assert!(back.outcome.unwrap_err().contains("bad shift"));
    }

    #[test]
    fn metrics_render() {
        let m = crate::coordinator::Metrics::default();
        let j = metrics_to_json(&m.snapshot());
        assert_eq!(j.get("submitted").unwrap().as_usize().unwrap(), 0);
        assert!(j.get("http_rejected").is_ok());
        assert!(j.get("in_flight").is_ok());
        assert!(j.get("stream_passes").is_ok());
        assert!(j.get("stream_bytes_read").is_ok());
        assert!(j.get("sweeps_used").is_ok());
        assert!(j.get("mean_achieved_pve").is_ok());
        // Lifecycle + cache counters (tentpole of the job-lifecycle PR).
        assert!(j.get("cancelled").is_ok());
        assert!(j.get("evicted").is_ok());
        assert!(j.get("cache_hits").is_ok());
        assert!(j.get("cache_misses").is_ok());
        assert!(j.get("cache_bytes").is_ok());
        // Both pools are reported (split cpu/io pool PR).
        assert!(j.get("pool_spawned").is_ok());
        assert!(j.get("io_threads").is_ok());
        assert!(j.get("io_spawned").is_ok());
        // Resilience counters (fault-injection + checkpoint/resume PR).
        assert!(j.get("stream_retries").is_ok());
        assert!(j.get("faults_injected").is_ok());
        assert!(j.get("checkpoints_written").is_ok());
        assert!(j.get("checkpoints_resumed").is_ok());
        assert!(j.get("journal_replayed").is_ok());
    }

    #[test]
    fn precision_round_trips_and_rejects_unknowns() {
        let mut req = JobRequest::new(
            generator_input(8, 8, Distribution::Uniform, 0, None, None),
            2,
        );
        // Default: exact.
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        assert_eq!(parsed.spec.config.precision, Precision::Exact);
        // Fast survives the wire.
        req.config.precision = Precision::Fast;
        let parsed = parse_submit(&req.to_json(), &defaults()).unwrap();
        assert_eq!(parsed.spec.config.precision, Precision::Fast);
        // An unknown value is a 400-class error, not a silent default.
        let mut bad = req.to_json().as_obj().unwrap().clone();
        bad.insert("precision".into(), Json::str("warp"));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
        // A non-string value is rejected too.
        let mut bad = req.to_json().as_obj().unwrap().clone();
        bad.insert("precision".into(), Json::num(1.0));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
    }

    #[test]
    fn tolerance_fields_round_trip_and_exclude_power_iters() {
        let mut req = JobRequest::new(
            generator_input(8, 8, Distribution::Uniform, 0, None, None),
            2,
        );
        req.config = req.config.with_tolerance(1e-3, 8);
        let body = req.to_json();
        // The adaptive request never mentions power_iters on the wire.
        let obj = body.as_obj().unwrap();
        assert!(obj.get("power_iters").is_none());
        let parsed = parse_submit(&body, &defaults()).unwrap();
        assert_eq!(
            parsed.spec.config.stop,
            StopCriterion::Tolerance { pve_tol: 1e-3, max_sweeps: 8 }
        );
        // And the fixed-q request never mentions pve_tol.
        req.config = req.config.with_fixed_power(3);
        let body = req.to_json();
        assert!(body.as_obj().unwrap().get("pve_tol").is_none());
        let parsed = parse_submit(&body, &defaults()).unwrap();
        assert_eq!(parsed.spec.config.stop, StopCriterion::FixedPower { q: 3 });
        // Omitting all three keeps the pre-redesign default q = 0.
        let legacy = JobRequest::new(
            generator_input(8, 8, Distribution::Uniform, 0, None, None),
            2,
        );
        let mut obj = legacy.to_json().as_obj().unwrap().clone();
        obj.remove("power_iters");
        let parsed = parse_submit(&Json::Obj(obj), &defaults()).unwrap();
        assert_eq!(parsed.spec.config.stop, StopCriterion::FixedPower { q: 0 });
    }

    #[test]
    fn contradictory_stop_fields_are_rejected() {
        let ok = JobRequest::new(generator_input(4, 4, Distribution::Uniform, 0, None, None), 1)
            .to_json();
        // power_iters + pve_tol together: mutually exclusive.
        let mut both = ok.as_obj().unwrap().clone();
        both.insert("pve_tol".into(), Json::num(1e-3));
        let err = parse_submit(&Json::Obj(both), &defaults()).unwrap_err();
        assert!(format!("{err}").contains("mutually exclusive"), "{err}");
        // max_sweeps without pve_tol is meaningless.
        let mut orphan = ok.as_obj().unwrap().clone();
        orphan.remove("power_iters");
        orphan.insert("max_sweeps".into(), Json::num(8.0));
        assert!(parse_submit(&Json::Obj(orphan), &defaults()).is_err());
        // Non-positive tolerance is rejected.
        let mut bad = ok.as_obj().unwrap().clone();
        bad.remove("power_iters");
        bad.insert("pve_tol".into(), Json::num(0.0));
        assert!(parse_submit(&Json::Obj(bad), &defaults()).is_err());
    }

    #[test]
    fn results_from_older_servers_still_parse() {
        // A result object without sweeps_used / achieved_pve (the
        // pre-redesign wire shape) must parse; the new fields read None.
        let text = r#"{"id": 7, "engine": "native", "exec_s": 0.1, "queue_s": 0.0,
                       "ok": true,
                       "output": {"m": 2, "n": 2, "k": 1,
                                  "u": [1.0, 0.0], "s": [2.0], "v": [0.0, 1.0],
                                  "mse": null}}"#;
        let back = parse_result(&Json::parse(text).unwrap()).unwrap();
        let out = back.outcome.unwrap();
        assert_eq!(out.sweeps_used, None);
        assert_eq!(out.achieved_pve, None);
        assert_eq!(out.mse, None);
    }
}
