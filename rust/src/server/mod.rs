//! The network service layer — HTTP in front of the
//! [`Coordinator`](crate::coordinator::Coordinator).
//!
//! `srsvd serve --listen ADDR` turns the in-process factorization
//! service into a wire service: clients `POST` compact job specs
//! (dense payloads, CSR skeletons, generator seeds, or server-side
//! file paths — see [`protocol`]) and read factors back as JSON. The
//! stack is std-only ([`std::net::TcpListener`] + the in-tree
//! [`crate::util::json`]), matching the crate's zero-dependency policy.
//!
//! ## Architecture
//!
//! One **accept thread** pushes connections into a bounded channel; a
//! small pool of **connection workers** (the `[server] workers` knob)
//! drains it, mirroring the shared-queue pattern of
//! [`crate::parallel`]. Each worker speaks HTTP/1.1 with keep-alive
//! ([`http`]), polling between requests so shutdown and idle limits
//! are enforced without interrupting an in-flight exchange.
//!
//! ## Endpoints
//!
//! | Method | Path | Meaning |
//! |--------|------|---------|
//! | `POST` | `/v1/jobs` | Submit a job spec. `"wait": true` answers with the finished result; otherwise `202` + id. |
//! | `GET` | `/v1/jobs/{id}` | Block (up to the request timeout, or `?timeout_s=`) for a submitted job's result. Retryable: a claimed result whose response write fails is re-parked, not dropped. |
//! | `GET` | `/metrics` | Service counters + gauges as JSON ([`protocol::metrics_to_json`]). |
//! | `GET` | `/healthz` | Liveness probe. |
//!
//! ## Backpressure
//!
//! Admission control is the coordinator's own bounded queue: the
//! server submits with
//! [`try_submit`](crate::coordinator::Coordinator::try_submit) and maps
//! queue-full to **`503 Service Unavailable`** — a saturated service
//! sheds load immediately instead of stacking blocked connections. The
//! `queue_depth`/`in_flight` gauges in `/metrics` expose the same
//! signal to pollers.
//!
//! ## Shutdown
//!
//! [`Server::shutdown`] stops accepting, lets every in-flight request
//! finish (responses are written before the connection closes), then
//! joins all threads. Queued-but-unclaimed job handles are dropped;
//! the coordinator still completes those jobs.

pub mod client;
pub mod http;
pub mod protocol;

pub use client::Client;

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::coordinator::{Coordinator, JobHandle, Metrics};
use crate::linalg::stream::StreamConfig;
use crate::util::json::Json;
use crate::util::{Error, Result};

use http::{HttpError, HttpLimits, ReadOutcome, Request, Response};

/// How often idle connections poll for data / shutdown.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Network service configuration — the `[server]` config section.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Maximum accepted request body, bytes (`[server] max_body_mb`).
    pub max_body_bytes: usize,
    /// Connection worker threads.
    pub workers: usize,
    /// Per-request timeout in seconds: reading a request, waiting on a
    /// blocking `GET`, and the keep-alive idle limit.
    pub request_timeout_s: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            max_body_bytes: 64 << 20,
            workers: 4,
            request_timeout_s: 30,
        }
    }
}

/// A parked entry awaiting a claiming `GET /v1/jobs/{id}`.
enum Pending {
    /// Still executing (or queued): the live job handle.
    Running(JobHandle),
    /// Completed, but the claiming response write failed: the rendered
    /// result body, re-parked so the GET is safely retryable.
    Done(Vec<u8>),
}

struct Shared {
    coord: Arc<Coordinator>,
    metrics: Arc<Metrics>,
    /// Accepted-but-unclaimed jobs, keyed by id, awaiting a blocking
    /// `GET /v1/jobs/{id}` — live handles, plus completed results whose
    /// claiming write failed ([`Pending::Done`]).
    pending: Mutex<HashMap<u64, Pending>>,
    shutdown: AtomicBool,
    limits: HttpLimits,
    request_timeout: Duration,
    stream_defaults: StreamConfig,
}

/// A running HTTP server bound to a socket.
pub struct Server {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind `config.addr` and start the accept loop plus connection
    /// workers in front of `coord`. `stream_defaults` (the `[stream]`
    /// config section) governs generator/file jobs that don't pin their
    /// own block policy.
    pub fn bind(
        coord: Arc<Coordinator>,
        config: &ServerConfig,
        stream_defaults: StreamConfig,
    ) -> Result<Server> {
        crate::util::logging::init();
        let listener = TcpListener::bind(config.addr.as_str())
            .map_err(|e| Error::Service(format!("bind {}: {e}", config.addr)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::Service(format!("local_addr: {e}")))?;
        let metrics = coord.metrics_shared();
        let shared = Arc::new(Shared {
            coord,
            metrics,
            pending: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            limits: HttpLimits {
                max_body_bytes: config.max_body_bytes,
                ..Default::default()
            },
            request_timeout: Duration::from_secs(config.request_timeout_s.max(1)),
            stream_defaults,
        });

        let workers = config.workers.max(1);
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(workers * 2);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut worker_handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let rx = Arc::clone(&conn_rx);
            let sh = Arc::clone(&shared);
            worker_handles.push(
                std::thread::Builder::new()
                    .name(format!("srsvd-http-{w}"))
                    .spawn(move || worker_loop(rx, sh))
                    .map_err(|e| Error::Service(format!("spawn http worker: {e}")))?,
            );
        }
        let sh = Arc::clone(&shared);
        let accept_handle = std::thread::Builder::new()
            .name("srsvd-http-accept".into())
            .spawn(move || accept_loop(listener, conn_tx, sh))
            .map_err(|e| Error::Service(format!("spawn accept loop: {e}")))?;

        crate::log_info!("server: listening on http://{local_addr} ({workers} connection workers)");
        Ok(Server {
            local_addr,
            shared,
            accept_handle: Some(accept_handle),
            worker_handles,
        })
    }

    /// The bound address (with the actual port when `addr` used port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, finish every in-flight
    /// request, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    /// Block until the server stops (another thread calling
    /// [`Server::shutdown`], or a fatal listener error). Used by
    /// `srsvd serve --listen`, which runs until killed.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }

    fn shutdown_inner(&mut self) {
        if self.accept_handle.is_none() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocked accept() with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // The accept thread owned the connection sender; its exit closes
        // the channel, so workers drain what was queued and stop.
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
        self.shared.pending.lock().expect("pending jobs mutex").clear();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, tx: SyncSender<TcpStream>, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            // A full worker channel blocks here; the OS accept backlog
            // absorbs the burst.
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => {
                // Back off briefly: a persistent accept error (e.g.
                // EMFILE under fd exhaustion) must not become a hot
                // spin + log flood.
                crate::log_warn!("server accept: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<TcpStream>>>, shared: Arc<Shared>) {
    loop {
        let stream = {
            let guard = rx.lock().expect("connection queue mutex");
            guard.recv()
        };
        let Ok(stream) = stream else { return };
        handle_connection(&shared, stream);
    }
}

/// Serve one connection: keep-alive request loop with an idle-poll
/// phase (so shutdown is honored between requests, never during one).
/// All reads run under the short [`IDLE_POLL`] socket timeout; during
/// a request the parser re-checks a whole-exchange deadline on every
/// slow slice, so a byte-trickling client is cut off with `408` after
/// `request_timeout` no matter how it paces its bytes.
fn handle_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let _ = stream.set_write_timeout(Some(shared.request_timeout));
    'conn: loop {
        // Idle phase: wait for the next request's first byte in short
        // slices, checking the shutdown flag between slices.
        let mut idled = Duration::ZERO;
        let mut probe = [0u8; 1];
        loop {
            if shared.shutdown.load(Ordering::SeqCst) {
                break 'conn;
            }
            match stream.peek(&mut probe) {
                Ok(0) => break 'conn, // peer closed
                Ok(_) => break,
                Err(e) if http::is_timeout(&e) => {
                    idled += IDLE_POLL;
                    if idled >= shared.request_timeout {
                        break 'conn; // keep-alive idle limit
                    }
                }
                Err(_) => break 'conn,
            }
        }

        // Request phase: one hard deadline for the whole exchange.
        let deadline = Some(std::time::Instant::now() + shared.request_timeout);
        match http::read_request(&mut stream, &shared.limits, deadline) {
            Ok(ReadOutcome::Closed) => break,
            Ok(ReadOutcome::Request(req)) => {
                shared
                    .metrics
                    .http_bytes_in
                    .fetch_add(req.bytes_read, Ordering::Relaxed);
                let response = route(shared, &req);
                // Stop reusing connections once shutdown begins, but
                // only after the in-flight response is written.
                let keep = req.keep_alive && !shared.shutdown.load(Ordering::SeqCst);
                match response.write_to(&mut stream, keep) {
                    Ok(n) => {
                        shared.metrics.http_bytes_out.fetch_add(n, Ordering::Relaxed);
                        if !keep {
                            break;
                        }
                    }
                    Err(_) => {
                        // A claimed result must survive a failed write:
                        // re-park it so the GET can be retried.
                        repark_failed_write(shared, response);
                        break;
                    }
                }
            }
            Err(HttpError::Respond { status, msg }) => {
                let response = Response::error(status, &msg);
                if let Ok(n) = response.write_to(&mut stream, false) {
                    shared.metrics.http_bytes_out.fetch_add(n, Ordering::Relaxed);
                }
                break;
            }
            Err(HttpError::Drop(_)) => break,
        }
    }
}

/// Put a claimed-but-undelivered result back into the pending map (as
/// rendered bytes). Closes the ROADMAP gap where a response-write
/// failure dropped the result: the claiming `GET /v1/jobs/{id}` is now
/// safely retryable. Entries live until claimed or shutdown, like any
/// other parked job.
fn repark_failed_write(shared: &Shared, response: Response) {
    if let Some(id) = response.repark_id {
        shared
            .pending
            .lock()
            .expect("pending jobs mutex")
            .insert(id, Pending::Done(response.body));
    }
}

/// Value of `key` in a raw query string (`a=1&b=2`).
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

/// Whether a submit error is the coordinator's queue-full signal
/// (`try_submit` backpressure) rather than a bad request.
fn is_backpressure(e: &Error) -> bool {
    matches!(e, Error::Busy(_))
}

fn route(shared: &Shared, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            Response::json(200, &Json::obj(vec![("status", Json::str("ok"))]))
        }
        ("GET", "/metrics") => {
            Response::json(200, &protocol::metrics_to_json(&shared.coord.metrics()))
        }
        ("POST", "/v1/jobs") => submit_job(shared, req),
        ("GET", path) if path.strip_prefix("/v1/jobs/").is_some() => wait_job(shared, req),
        (_, "/healthz" | "/metrics" | "/v1/jobs") => {
            Response::error(405, "method not allowed")
        }
        (_, path) if path.strip_prefix("/v1/jobs/").is_some() => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, "no such endpoint"),
    }
}

fn submit_job(shared: &Shared, req: &Request) -> Response {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return Response::error(400, "body is not UTF-8");
    };
    let parsed =
        Json::parse(text).and_then(|j| protocol::parse_submit(&j, &shared.stream_defaults));
    let sub = match parsed {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e}")),
    };
    let handle = match shared.coord.try_submit(sub.spec) {
        Ok(h) => h,
        Err(e) if is_backpressure(&e) => {
            shared.metrics.http_rejected.fetch_add(1, Ordering::Relaxed);
            return Response::error(503, &format!("{e}"));
        }
        Err(e) => return Response::error(400, &format!("{e}")),
    };
    shared.metrics.http_accepted.fetch_add(1, Ordering::Relaxed);
    let id = handle.id.0;
    if sub.wait {
        // wait=true responses are not re-parked on a failed write: the
        // client never learned the id, so it resubmits (seeded jobs
        // replay exactly) instead of fishing for an orphaned entry.
        finish_wait_with(shared, id, handle, shared.request_timeout, false)
    } else {
        shared
            .pending
            .lock()
            .expect("pending jobs mutex")
            .insert(id, Pending::Running(handle));
        Response::json(
            202,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("status", Json::str("queued")),
            ]),
        )
    }
}

fn wait_job(shared: &Shared, req: &Request) -> Response {
    let id_text = req.path.strip_prefix("/v1/jobs/").unwrap_or("");
    let Ok(id) = id_text.parse::<u64>() else {
        return Response::error(400, &format!("bad job id {id_text:?}"));
    };
    let entry = shared
        .pending
        .lock()
        .expect("pending jobs mutex")
        .remove(&id);
    let handle = match entry {
        None => {
            return Response::error(404, &format!("unknown (or already claimed) job {id}"))
        }
        // A result re-parked after a failed write: serve it as-is (and
        // keep it retryable should this write fail too).
        Some(Pending::Done(body)) => return Response::json_bytes(200, body).with_repark(id),
        Some(Pending::Running(handle)) => handle,
    };
    // An explicit ?timeout_s= can only shorten the server-wide cap.
    // (The range guard also keeps Duration::from_secs_f64 panic-free on
    // hostile values like 1e300 or NaN.)
    let timeout = match query_param(&req.query, "timeout_s").map(str::parse::<f64>) {
        Some(Ok(s)) if (0.0..=86_400.0).contains(&s) => {
            shared.request_timeout.min(Duration::from_secs_f64(s))
        }
        Some(_) => return Response::error(400, "bad timeout_s"),
        None => shared.request_timeout,
    };
    finish_wait_with(shared, id, handle, timeout, true)
}

/// Block on a job handle; on timeout the handle goes (back) into the
/// pending map and the client gets `202 running` to retry the `GET`.
///
/// With `repark` set (the claiming-GET path), a completed result is
/// tagged with its id so a failed response write re-parks the rendered
/// body ([`repark_failed_write`]) instead of dropping it.
fn finish_wait_with(
    shared: &Shared,
    id: u64,
    handle: JobHandle,
    timeout: Duration,
    repark: bool,
) -> Response {
    match handle.wait_timeout(timeout) {
        Ok(result) => {
            let response = Response::json(200, &protocol::job_result_to_json(&result));
            if repark {
                response.with_repark(id)
            } else {
                response
            }
        }
        Err(Error::Timeout(_)) => {
            shared
                .pending
                .lock()
                .expect("pending jobs mutex")
                .insert(id, Pending::Running(handle));
            Response::json(
                202,
                &Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("status", Json::str("running")),
                ]),
            )
        }
        Err(e) => Response::error(500, &format!("{e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_param_lookup() {
        assert_eq!(query_param("timeout_s=2.5&x=1", "timeout_s"), Some("2.5"));
        assert_eq!(query_param("x=1", "timeout_s"), None);
        assert_eq!(query_param("", "timeout_s"), None);
        assert_eq!(query_param("timeout_s", "timeout_s"), None);
    }

    #[test]
    fn backpressure_detection() {
        assert!(is_backpressure(&Error::Busy("queue full".into())));
        assert!(!is_backpressure(&Error::Service("worker died".into())));
        assert!(!is_backpressure(&Error::Timeout("job still running".into())));
        assert!(!is_backpressure(&Error::Invalid("k must be >= 1".into())));
        // The Display text is part of the wire contract (clients grep
        // for it in 503 bodies) — pinned here.
        assert!(format!("{}", Error::Busy("queue full".into())).contains("backpressure"));
    }

    #[test]
    fn default_config_is_sane() {
        let c = ServerConfig::default();
        assert!(c.workers >= 1);
        assert!(c.max_body_bytes >= 1 << 20);
        assert!(c.request_timeout_s >= 1);
    }
}
