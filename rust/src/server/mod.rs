//! The network service layer — HTTP in front of the
//! [`Coordinator`](crate::coordinator::Coordinator).
//!
//! `srsvd serve --listen ADDR` turns the in-process factorization
//! service into a wire service: clients `POST` compact job specs
//! (dense payloads, CSR skeletons, generator seeds, or server-side
//! file paths — see [`protocol`]) and read factors back as JSON. The
//! stack is std-only ([`std::net::TcpListener`] + the in-tree
//! [`crate::util::json`]), matching the crate's zero-dependency policy.
//!
//! ## Architecture
//!
//! One **accept thread** pushes connections into a bounded channel; a
//! small set of **connection workers** (the `[server] workers` knob)
//! drains it. The workers are not dedicated threads: each drain loop
//! runs as a job on the coordinator's **io pool**
//! ([`Coordinator::io_pool`]) alongside streamed-prefetch readers, so
//! blocking network time shares the pool sized for blocking work and
//! never occupies a compute worker. Each worker speaks HTTP/1.1 with
//! keep-alive ([`http`]), polling between requests so shutdown and
//! idle limits are enforced without interrupting an in-flight
//! exchange. Shutdown quiesces through a done channel: every drain
//! loop signals exit, so [`Server::shutdown`] still joins all
//! connection work without owning the threads.
//!
//! ## Endpoints
//!
//! | Method | Path | Meaning |
//! |--------|------|---------|
//! | `POST` | `/v1/jobs` | Submit a job spec. `"wait": true` answers with the finished result; otherwise `202` + id. |
//! | `GET` | `/v1/jobs/{id}` | Block (up to the request timeout, or `?timeout_s=`) for a submitted job's result. Retryable: a claimed result whose response write fails is re-parked, not dropped. |
//! | `DELETE` | `/v1/jobs/{id}` | Cancel: `200` for a pending/running job (cooperative — the engine abandons work at its next sweep checkpoint, the job fails with [`Error::Cancelled`], and the claiming `GET` answers `410 Gone`), `404` unknown, `409` already delivered. |
//! | `GET` | `/metrics` | Service counters + gauges as JSON ([`protocol::metrics_to_json`]). |
//! | `GET` | `/healthz` | Liveness probe: `200` whenever the process answers. Health-loop target for the routing tier. |
//! | `GET` | `/readyz` | Readiness probe: `200` while the bounded job queue has headroom, `503` once `queue_depth` has reached the configured capacity — a router sheds load to a sibling replica *before* a submit eats the 503. |
//!
//! ## Job lifecycle
//!
//! A submitted job is *parked* until claimed: the pending map holds the
//! live handle (or, after a failed response write, the rendered result
//! body). Every parked entry carries a deadline — `[server]
//! result_ttl_s` past its (re-)parking — and the keep-alive idle poll
//! doubles as the TTL reaper: an abandoned entry is evicted, a
//! still-running evicted job is cancelled cooperatively, and the
//! `evicted` counter ticks. All timestamps flow through an injectable
//! [`Clock`], so the lifecycle tests drive eviction with a fake clock
//! instead of sleeping. In front of the coordinator sits a
//! content-addressed **result cache** ([`cache`]): a waited submit
//! whose canonical spec hash is cached replays the exact cold-run bytes
//! without touching the coordinator (`cache_hits` vs `native_jobs` in
//! `/metrics` makes the bypass observable).
//!
//! ## Backpressure
//!
//! Admission control is the coordinator's own bounded queue: the
//! server submits with
//! [`try_submit`](crate::coordinator::Coordinator::try_submit) and maps
//! queue-full to **`503 Service Unavailable`** — a saturated service
//! sheds load immediately instead of stacking blocked connections. The
//! `queue_depth`/`in_flight` gauges in `/metrics` expose the same
//! signal to pollers.
//!
//! ## Shutdown
//!
//! [`Server::shutdown`] stops accepting, lets every in-flight request
//! finish (responses are written before the connection closes), then
//! joins all threads. Queued-but-unclaimed job handles are dropped;
//! the coordinator still completes those jobs.

pub mod cache;
pub mod client;
pub mod http;
pub mod protocol;

pub use client::Client;

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::{Coordinator, JobHandle, Metrics};
use crate::linalg::stream::StreamConfig;
use crate::util::json::Json;
use crate::util::{Error, Result};

use http::{HttpError, HttpLimits, ReadOutcome, Request, Response};

/// How often idle connections poll for data / shutdown.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// Network service configuration — the `[server]` config section.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Listen address (`host:port`; port 0 picks a free port).
    pub addr: String,
    /// Maximum accepted request body, bytes (`[server] max_body_mb`).
    pub max_body_bytes: usize,
    /// Connection worker drain loops, run as jobs on the coordinator's
    /// io pool. More loops than io threads is allowed — the excess
    /// queue until a pool worker frees up.
    pub workers: usize,
    /// Per-request timeout in seconds: reading a request, waiting on a
    /// blocking `GET`, and the keep-alive idle limit.
    pub request_timeout_s: u64,
    /// Seconds an unclaimed parked entry — a running job handle or a
    /// re-parked result body — survives before the TTL reaper evicts it
    /// (`[server] result_ttl_s`).
    pub result_ttl_s: u64,
    /// Directory persisting the content-addressed result cache across
    /// restarts (`[server] cache_dir`); `None` keeps it memory-only.
    pub cache_dir: Option<std::path::PathBuf>,
    /// Capacity of the completed-result cache, in entries
    /// (`[server] cache_entries`); `0` disables caching.
    pub cache_entries: usize,
    /// Directory journaling accepted-but-undelivered submit bodies.
    /// On bind, surviving entries are re-submitted through the
    /// coordinator — which, under `[svd] checkpoint_dir`, resumes each
    /// from its last completed sweep. `None` disables journaling.
    pub journal_dir: Option<std::path::PathBuf>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7878".into(),
            max_body_bytes: 64 << 20,
            workers: 4,
            request_timeout_s: 30,
            result_ttl_s: 600,
            cache_dir: None,
            cache_entries: 256,
            journal_dir: None,
        }
    }
}

/// Injectable time source for parked-entry TTL bookkeeping. The server
/// only ever compares differences of [`Clock::now_ms`] values, so any
/// monotonic origin works — and the lifecycle tests substitute a
/// hand-advanced fake to exercise eviction without sleeping.
pub trait Clock: Send + Sync {
    /// Monotonic milliseconds since an arbitrary fixed origin.
    fn now_ms(&self) -> u64;
}

/// The production [`Clock`]: [`Instant`]-based monotonic milliseconds.
#[derive(Debug)]
pub struct MonotonicClock {
    origin: Instant,
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock { origin: Instant::now() }
    }
}

impl Clock for MonotonicClock {
    fn now_ms(&self) -> u64 {
        self.origin.elapsed().as_millis() as u64
    }
}

/// A parked entry awaiting a claiming `GET /v1/jobs/{id}`.
enum Pending {
    /// Still executing (or queued): the live job handle, plus the
    /// spec's content hash (when cacheable) so the claiming GET can
    /// feed the result cache.
    Running {
        /// The live handle.
        handle: JobHandle,
        /// [`cache::spec_hash`] of the submitted spec.
        hash: Option<u64>,
    },
    /// Completed, but the claiming response write failed: the rendered
    /// result body, re-parked so the GET is safely retryable.
    Done(Vec<u8>),
}

/// A [`Pending`] state plus its eviction deadline ([`Clock`] time).
struct Parked {
    state: Pending,
    expires_at_ms: u64,
}

struct Shared {
    coord: Arc<Coordinator>,
    metrics: Arc<Metrics>,
    /// Accepted-but-unclaimed jobs, keyed by id, awaiting a blocking
    /// `GET /v1/jobs/{id}` — live handles, plus completed results whose
    /// claiming write failed ([`Pending::Done`]). Entries expire
    /// (`result_ttl_s`) and are reaped by [`sweep_expired`].
    pending: Mutex<HashMap<u64, Parked>>,
    /// Ids whose result was delivered, kept (until their TTL passes) so
    /// a late `DELETE` answers `409` instead of an indistinguishable
    /// `404`. Values are expiry deadlines.
    delivered: Mutex<HashMap<u64, u64>>,
    /// Content-addressed cache of rendered completed-result bodies.
    cache: Mutex<cache::ResultCache>,
    shutdown: AtomicBool,
    limits: HttpLimits,
    request_timeout: Duration,
    /// Parked-entry lifetime, milliseconds.
    ttl_ms: u64,
    clock: Arc<dyn Clock>,
    stream_defaults: StreamConfig,
    /// Crash journal for accepted-but-undelivered submits (see
    /// [`ServerConfig::journal_dir`]).
    journal_dir: Option<std::path::PathBuf>,
}

/// A running HTTP server bound to a socket.
pub struct Server {
    local_addr: SocketAddr,
    shared: Arc<Shared>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    /// One `()` per connection worker on exit; the channel closing
    /// means every drain loop (io-pool job) has finished.
    worker_done: Option<Receiver<()>>,
}

impl Server {
    /// Bind `config.addr` and start the accept loop plus connection
    /// workers in front of `coord`. `stream_defaults` (the `[stream]`
    /// config section) governs generator/file jobs that don't pin their
    /// own block policy.
    pub fn bind(
        coord: Arc<Coordinator>,
        config: &ServerConfig,
        stream_defaults: StreamConfig,
    ) -> Result<Server> {
        Server::bind_with_clock(
            coord,
            config,
            stream_defaults,
            Arc::new(MonotonicClock::default()),
        )
    }

    /// [`Server::bind`] with an explicit [`Clock`] driving parked-entry
    /// TTLs — the seam the lifecycle tests use to evict without
    /// sleeping. Production callers want [`Server::bind`].
    pub fn bind_with_clock(
        coord: Arc<Coordinator>,
        config: &ServerConfig,
        stream_defaults: StreamConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Server> {
        crate::util::logging::init();
        // Arm the fail-point registry from SRSVD_FAULTS (no-op when the
        // variable is unset) so chaos runs need no code changes. A
        // malformed spec is a hard error: a chaos run silently testing
        // nothing is worse than a refusal to start.
        crate::util::faults::init_from_env()?;
        let listener = TcpListener::bind(config.addr.as_str())
            .map_err(|e| Error::Service(format!("bind {}: {e}", config.addr)))?;
        let local_addr = listener
            .local_addr()
            .map_err(|e| Error::Service(format!("local_addr: {e}")))?;
        let metrics = coord.metrics_shared();
        let result_cache =
            cache::ResultCache::new(config.cache_entries, config.cache_dir.clone());
        metrics.cache_bytes.store(result_cache.bytes(), Ordering::Relaxed);
        let shared = Arc::new(Shared {
            coord,
            metrics,
            pending: Mutex::new(HashMap::new()),
            delivered: Mutex::new(HashMap::new()),
            cache: Mutex::new(result_cache),
            shutdown: AtomicBool::new(false),
            limits: HttpLimits {
                max_body_bytes: config.max_body_bytes,
                ..Default::default()
            },
            request_timeout: Duration::from_secs(config.request_timeout_s.max(1)),
            ttl_ms: config.result_ttl_s.max(1).saturating_mul(1000),
            clock,
            stream_defaults,
            journal_dir: config.journal_dir.clone(),
        });
        // Re-run whatever a previous process accepted but never
        // delivered — with checkpointing on, each replayed job resumes
        // from its last completed sweep instead of starting over.
        replay_journal(&shared);

        let workers = config.workers.max(1);
        let (conn_tx, conn_rx) = sync_channel::<TcpStream>(workers * 2);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        // Connection workers are io-pool jobs, not dedicated threads:
        // blocking network time lands on the pool sized for blocking
        // work, next to streamed-prefetch readers. Each loop signals
        // `done` on exit; the sender clones dropping (normal exit or a
        // panic unwinding the closure) is what closes the channel, so
        // shutdown can quiesce without thread handles.
        let io = shared.coord.io_pool();
        let (done_tx, done_rx) = channel::<()>();
        for _ in 0..workers {
            let rx = Arc::clone(&conn_rx);
            let sh = Arc::clone(&shared);
            let done = done_tx.clone();
            io.spawn(move || {
                worker_loop(rx, sh);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        let sh = Arc::clone(&shared);
        let accept_handle = std::thread::Builder::new()
            .name("srsvd-http-accept".into())
            .spawn(move || accept_loop(listener, conn_tx, sh))
            .map_err(|e| Error::Service(format!("spawn accept loop: {e}")))?;

        crate::log_info!(
            "server: listening on http://{local_addr} ({workers} connection workers on the io pool)"
        );
        Ok(Server {
            local_addr,
            shared,
            accept_handle: Some(accept_handle),
            worker_done: Some(done_rx),
        })
    }

    /// The bound address (with the actual port when `addr` used port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Graceful shutdown: stop accepting, finish every in-flight
    /// request, join all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    /// Block until the server stops (another thread calling
    /// [`Server::shutdown`], or a fatal listener error). Used by
    /// `srsvd serve --listen`, which runs until killed.
    pub fn join(mut self) {
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        self.drain_workers();
    }

    fn shutdown_inner(&mut self) {
        if self.accept_handle.is_none() {
            return;
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocked accept() with a throwaway connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        // The accept thread owned the connection sender; its exit closes
        // the channel, so workers drain what was queued and stop.
        self.drain_workers();
        self.shared.pending.lock().expect("pending jobs mutex").clear();
    }

    /// Block until every connection worker loop has exited. The loops
    /// are io-pool jobs, so there are no thread handles to join;
    /// instead each loop's done-sender drops on exit (even under a
    /// panic) and the channel closing is the quiescence signal.
    fn drain_workers(&mut self) {
        if let Some(rx) = self.worker_done.take() {
            while rx.recv().is_ok() {}
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, tx: SyncSender<TcpStream>, shared: Arc<Shared>) {
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            // A full worker channel blocks here; the OS accept backlog
            // absorbs the burst.
            Ok(s) => {
                if tx.send(s).is_err() {
                    break;
                }
            }
            Err(e) => {
                // Back off briefly: a persistent accept error (e.g.
                // EMFILE under fd exhaustion) must not become a hot
                // spin + log flood.
                crate::log_warn!("server accept: {e}");
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<TcpStream>>>, shared: Arc<Shared>) {
    loop {
        let stream = {
            let guard = rx.lock().expect("connection queue mutex");
            guard.recv()
        };
        let Ok(stream) = stream else { return };
        handle_connection(&shared, stream);
    }
}

/// Serve one connection: keep-alive request loop with an idle-poll
/// phase (so shutdown is honored between requests, never during one).
/// All reads run under the short [`IDLE_POLL`] socket timeout; during
/// a request the parser re-checks a whole-exchange deadline on every
/// slow slice, so a byte-trickling client is cut off with `408` after
/// `request_timeout` no matter how it paces its bytes.
fn handle_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let _ = stream.set_write_timeout(Some(shared.request_timeout));
    loop {
        // Idle phase ([`http::idle_wait`]): wait for the next request's
        // first byte in short slices; each slice boundary checks the
        // shutdown flag and runs the TTL reaper over parked entries.
        let mut probe = [0u8; 1];
        let idle = http::idle_wait(
            &mut || stream.peek(&mut probe),
            IDLE_POLL,
            shared.request_timeout,
            &mut || {
                sweep_expired(shared);
                shared.shutdown.load(Ordering::SeqCst)
            },
        );
        if idle == http::IdleOutcome::Close {
            break;
        }

        // Request phase: one hard deadline for the whole exchange.
        let deadline = Some(std::time::Instant::now() + shared.request_timeout);
        match http::read_request(&mut stream, &shared.limits, deadline) {
            Ok(ReadOutcome::Closed) => break,
            Ok(ReadOutcome::Request(req)) => {
                shared
                    .metrics
                    .http_bytes_in
                    .fetch_add(req.bytes_read, Ordering::Relaxed);
                let response = route(shared, &req);
                // Stop reusing connections once shutdown begins, but
                // only after the in-flight response is written.
                let keep = req.keep_alive && !shared.shutdown.load(Ordering::SeqCst);
                match response.write_to(&mut stream, keep) {
                    Ok(n) => {
                        shared.metrics.http_bytes_out.fetch_add(n, Ordering::Relaxed);
                        if !keep {
                            break;
                        }
                    }
                    Err(_) => {
                        // A claimed result must survive a failed write:
                        // re-park it so the GET can be retried.
                        repark_failed_write(shared, response);
                        break;
                    }
                }
            }
            Err(HttpError::Respond { status, msg }) => {
                let response = Response::error(status, &msg);
                if let Ok(n) = response.write_to(&mut stream, false) {
                    shared.metrics.http_bytes_out.fetch_add(n, Ordering::Relaxed);
                }
                break;
            }
            Err(HttpError::Drop(_)) => break,
        }
    }
}

/// Put a claimed-but-undelivered result back into the pending map (as
/// rendered bytes). Closes the ROADMAP gap where a response-write
/// failure dropped the result: the claiming `GET /v1/jobs/{id}` is now
/// safely retryable. The entry gets a fresh TTL, and the premature
/// delivered record is withdrawn (the peer never got the bytes).
fn repark_failed_write(shared: &Shared, response: Response) {
    if let Some(id) = response.repark_id {
        shared
            .delivered
            .lock()
            .expect("delivered ids mutex")
            .remove(&id);
        park(shared, id, Pending::Done(response.body));
    }
}

/// Insert a pending entry under a fresh `result_ttl_s` deadline.
fn park(shared: &Shared, id: u64, state: Pending) {
    let expires_at_ms = shared.clock.now_ms().saturating_add(shared.ttl_ms);
    shared
        .pending
        .lock()
        .expect("pending jobs mutex")
        .insert(id, Parked { state, expires_at_ms });
}

/// Remember that `id`'s result went out, so a late `DELETE` can answer
/// `409 Conflict` instead of `404`. Records expire like parked entries.
/// Delivery is also the end of the job's crash-journal life: the spec
/// no longer needs replaying.
fn record_delivered(shared: &Shared, id: u64) {
    let expires = shared.clock.now_ms().saturating_add(shared.ttl_ms);
    shared
        .delivered
        .lock()
        .expect("delivered ids mutex")
        .insert(id, expires);
    journal_remove(shared, id);
}

/// Journal file for job `id` under the journal directory.
fn journal_file(dir: &std::path::Path, id: u64) -> std::path::PathBuf {
    dir.join(format!("job-{id:016}.json"))
}

/// Journal an accepted submit body so a restarted server can re-run it
/// (best-effort: a failed journal write is logged, never fails the
/// submit — the journal adds durability, it is not on the ack path).
fn journal_record(shared: &Shared, id: u64, body: &[u8]) {
    let Some(dir) = &shared.journal_dir else { return };
    if let Err(e) = journal_write(dir, id, body) {
        crate::log_warn!("journal: recording job {id}: {e}");
    }
}

/// Temp-then-rename journal write; the `journal.write` fail-point can
/// tear it, leaving only a `.tmp` that replay discards.
fn journal_write(dir: &std::path::Path, id: u64, body: &[u8]) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = journal_file(dir, id);
    let tmp = path.with_extension("json.tmp");
    let cap = crate::util::faults::write_len("journal.write", body.len())?;
    std::fs::write(&tmp, &body[..cap])?;
    if cap < body.len() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::WriteZero,
            "injected partial journal write",
        ));
    }
    std::fs::rename(&tmp, &path)
}

/// Drop `id`'s journal entry (delivered, cancelled, or evicted — no
/// one is left to want a replay).
fn journal_remove(shared: &Shared, id: u64) {
    if let Some(dir) = &shared.journal_dir {
        let _ = std::fs::remove_file(journal_file(dir, id));
    }
}

/// Re-submit every journaled spec a previous process accepted but never
/// delivered. Each replayed job runs through the normal coordinator
/// path — under `[svd] checkpoint_dir` that means resuming from the
/// last completed sweep — and an io-pool waiter feeds the result cache
/// and clears the journal entry when it completes. Old job ids are not
/// preserved (clients that lost an id resubmit; seeded jobs replay
/// exactly), so the point is completing the *work*, not the delivery.
fn replay_journal(shared: &Arc<Shared>) {
    let Some(dir) = shared.journal_dir.clone() else { return };
    let Ok(entries) = std::fs::read_dir(&dir) else { return };
    let io = shared.coord.io_pool();
    for entry in entries.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            // A `.tmp` torn off mid-journal by a crash: never replayable.
            let _ = std::fs::remove_file(&path);
            continue;
        }
        let sub = std::fs::read(&path)
            .ok()
            .and_then(|b| String::from_utf8(b).ok())
            .and_then(|t| Json::parse(&t).ok())
            .and_then(|j| protocol::parse_submit(&j, &shared.stream_defaults).ok());
        let Some(sub) = sub else {
            crate::log_warn!("journal: dropping unparseable entry {}", path.display());
            let _ = std::fs::remove_file(&path);
            continue;
        };
        let hash = cache::spec_hash(&sub.spec);
        // Queue-full at restart leaves the entry for the next boot.
        let Ok(handle) = shared.coord.try_submit(sub.spec) else { continue };
        shared.metrics.journal_replayed.fetch_add(1, Ordering::Relaxed);
        crate::log_info!(
            "journal: replaying {} as job {}",
            path.display(),
            handle.id.0
        );
        let sh = Arc::clone(shared);
        io.spawn(move || {
            if let Ok(result) = handle.wait() {
                if result.outcome.is_ok() {
                    if let Some(h) = hash {
                        let body =
                            protocol::job_result_to_json(&result).to_string().into_bytes();
                        let mut cache = sh.cache.lock().expect("result cache mutex");
                        cache.insert(h, body);
                        sh.metrics.cache_bytes.store(cache.bytes(), Ordering::Relaxed);
                    }
                }
            }
            let _ = std::fs::remove_file(&path);
        });
    }
}

/// The TTL reaper: drop every parked entry and delivered record whose
/// deadline passed. An evicted still-running job is cancelled
/// cooperatively (its eventual result has no one left to claim it) and
/// counted under `evicted`. Runs from every idle-poll slice and before
/// every routed request, so eviction needs no dedicated thread.
fn sweep_expired(shared: &Shared) {
    let now = shared.clock.now_ms();
    let mut evicted_ids = Vec::new();
    {
        let mut pending = shared.pending.lock().expect("pending jobs mutex");
        pending.retain(|id, parked| {
            if parked.expires_at_ms > now {
                return true;
            }
            if let Pending::Running { handle, .. } = &parked.state {
                handle.cancel();
            }
            shared.metrics.evicted.fetch_add(1, Ordering::Relaxed);
            evicted_ids.push(*id);
            false
        });
    }
    // An evicted job has no claimant left; its journal entry would only
    // resurrect abandoned work on the next restart.
    for id in evicted_ids {
        journal_remove(shared, id);
    }
    shared
        .delivered
        .lock()
        .expect("delivered ids mutex")
        .retain(|_, expires| *expires > now);
}

/// Value of `key` in a raw query string (`a=1&b=2`).
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

/// Whether a submit error is the coordinator's queue-full signal
/// (`try_submit` backpressure) rather than a bad request.
fn is_backpressure(e: &Error) -> bool {
    matches!(e, Error::Busy(_))
}

fn route(shared: &Shared, req: &Request) -> Response {
    // The reaper also runs request-side, so a deployment whose workers
    // are all mid-request (no idle pollers) still evicts on time.
    sweep_expired(shared);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            Response::json(200, &Json::obj(vec![("status", Json::str("ok"))]))
        }
        ("GET", "/readyz") => readyz(shared),
        ("GET", "/metrics") => {
            Response::json(200, &protocol::metrics_to_json(&shared.coord.metrics()))
        }
        ("POST", "/v1/jobs") => submit_job(shared, req),
        ("GET", path) if path.strip_prefix("/v1/jobs/").is_some() => wait_job(shared, req),
        ("DELETE", path) if path.strip_prefix("/v1/jobs/").is_some() => {
            cancel_job(shared, req)
        }
        (_, "/healthz" | "/readyz" | "/metrics" | "/v1/jobs") => {
            Response::error(405, "method not allowed")
        }
        (_, path) if path.strip_prefix("/v1/jobs/").is_some() => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, "no such endpoint"),
    }
}

/// `GET /readyz`: readiness, as distinct from `/healthz` liveness. The
/// probe answers `503` once the bounded job queue is at capacity, so a
/// routing tier can steer submits at a saturated replica toward a
/// sibling *before* a submit eats the queue-full 503.
fn readyz(shared: &Shared) -> Response {
    let depth = shared.metrics.queue_depth.load(Ordering::Relaxed);
    let capacity = shared.coord.queue_capacity() as u64;
    let status = if depth >= capacity { 503 } else { 200 };
    let state = if depth >= capacity { "saturated" } else { "ready" };
    let response = Response::json(
        status,
        &Json::obj(vec![
            ("status", Json::str(state)),
            ("queue_depth", Json::num(depth as f64)),
            ("queue_capacity", Json::num(capacity as f64)),
        ]),
    );
    if status == 503 {
        response.with_retry_after(retry_after_secs(depth, capacity))
    } else {
        response
    }
}

/// `Retry-After` hint for `503`s, from queue pressure: one second per
/// queue-capacity multiple of backlog, capped so the hint stays a
/// backoff, not a blackout.
fn retry_after_secs(depth: u64, capacity: u64) -> u64 {
    (depth / capacity.max(1)).clamp(1, 30)
}

/// `DELETE /v1/jobs/{id}`: cancel a parked job. A pending or running
/// entry answers `200` — the shared cancel flag makes the engine
/// abandon work at its next between-sweep checkpoint, failing the job
/// with [`Error::Cancelled`]. The entry stays parked so the claiming
/// `GET` observes the cancelled outcome as **`410 Gone`** instead of an
/// indistinguishable `404` (repeat `DELETE`s are idempotent `200`s). A
/// re-parked finished body is simply discarded. An already-delivered
/// result answers `409 Conflict`; an unknown id `404`.
fn cancel_job(shared: &Shared, req: &Request) -> Response {
    let id_text = req.path.strip_prefix("/v1/jobs/").unwrap_or("");
    let Ok(id) = id_text.parse::<u64>() else {
        return Response::error(400, &format!("bad job id {id_text:?}"));
    };
    let known = {
        let mut pending = shared.pending.lock().expect("pending jobs mutex");
        match pending.get(&id).map(|parked| &parked.state) {
            Some(Pending::Running { handle, .. }) => {
                handle.cancel();
                true
            }
            Some(Pending::Done(_)) => {
                pending.remove(&id);
                journal_remove(shared, id);
                true
            }
            None => false,
        }
    };
    if known {
        shared.metrics.cancelled.fetch_add(1, Ordering::Relaxed);
        return Response::json(
            200,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("status", Json::str("cancelled")),
            ]),
        );
    }
    if shared
        .delivered
        .lock()
        .expect("delivered ids mutex")
        .contains_key(&id)
    {
        Response::error(409, &format!("job {id} result already delivered"))
    } else {
        Response::error(404, &format!("unknown job {id}"))
    }
}

fn submit_job(shared: &Shared, req: &Request) -> Response {
    let Ok(text) = std::str::from_utf8(&req.body) else {
        return Response::error(400, "body is not UTF-8");
    };
    let parsed =
        Json::parse(text).and_then(|j| protocol::parse_submit(&j, &shared.stream_defaults));
    let sub = match parsed {
        Ok(s) => s,
        Err(e) => return Response::error(400, &format!("{e}")),
    };
    // Content-addressed result cache: a waited submit whose canonical
    // spec hash is cached replays the cold run's exact bytes and never
    // touches the coordinator. Fire-and-forget submits skip the lookup
    // — their contract is `202` + a pollable id. Uncacheable specs
    // (file-backed sources) hash to None and count neither way.
    let hash = cache::spec_hash(&sub.spec);
    if sub.wait {
        if let Some(h) = hash {
            let hit = shared.cache.lock().expect("result cache mutex").get(h);
            if let Some(body) = hit {
                shared.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                return Response::json_bytes(200, body);
            }
            shared.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }
    let handle = match shared.coord.try_submit(sub.spec) {
        Ok(h) => h,
        Err(e) if is_backpressure(&e) => {
            shared.metrics.http_rejected.fetch_add(1, Ordering::Relaxed);
            let depth = shared.metrics.queue_depth.load(Ordering::Relaxed);
            let capacity = shared.coord.queue_capacity() as u64;
            return Response::error(503, &format!("{e}"))
                .with_retry_after(retry_after_secs(depth, capacity));
        }
        Err(e) => return Response::error(400, &format!("{e}")),
    };
    shared.metrics.http_accepted.fetch_add(1, Ordering::Relaxed);
    let id = handle.id.0;
    // Crash journal: the accepted spec survives a process death until
    // its result is delivered (or it is cancelled / evicted).
    journal_record(shared, id, &req.body);
    if sub.wait {
        // wait=true responses are not re-parked on a failed write: the
        // client never learned the id, so it resubmits (seeded jobs
        // replay exactly) instead of fishing for an orphaned entry.
        finish_wait_with(shared, id, handle, hash, shared.request_timeout, false)
    } else {
        park(shared, id, Pending::Running { handle, hash });
        Response::json(
            202,
            &Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("status", Json::str("queued")),
            ]),
        )
    }
}

fn wait_job(shared: &Shared, req: &Request) -> Response {
    let id_text = req.path.strip_prefix("/v1/jobs/").unwrap_or("");
    let Ok(id) = id_text.parse::<u64>() else {
        return Response::error(400, &format!("bad job id {id_text:?}"));
    };
    let entry = shared
        .pending
        .lock()
        .expect("pending jobs mutex")
        .remove(&id);
    let (handle, hash) = match entry {
        None => {
            return Response::error(404, &format!("unknown (or already claimed) job {id}"))
        }
        // A result re-parked after a failed write: serve it as-is (and
        // keep it retryable should this write fail too).
        Some(Parked { state: Pending::Done(body), .. }) => {
            record_delivered(shared, id);
            return Response::json_bytes(200, body).with_repark(id);
        }
        Some(Parked { state: Pending::Running { handle, hash }, .. }) => (handle, hash),
    };
    // An explicit ?timeout_s= can only shorten the server-wide cap.
    // (The range guard also keeps Duration::from_secs_f64 panic-free on
    // hostile values like 1e300 or NaN.)
    let timeout = match query_param(&req.query, "timeout_s").map(str::parse::<f64>) {
        Some(Ok(s)) if (0.0..=86_400.0).contains(&s) => {
            shared.request_timeout.min(Duration::from_secs_f64(s))
        }
        Some(_) => return Response::error(400, "bad timeout_s"),
        None => shared.request_timeout,
    };
    finish_wait_with(shared, id, handle, hash, timeout, true)
}

/// Block on a job handle; on timeout the handle goes (back) into the
/// pending map — under a fresh TTL — and the client gets `202 running`
/// to retry the `GET`.
///
/// A completed result is rendered once: an `ok` outcome feeds the
/// content-addressed cache (when the spec hashed), a cancelled outcome
/// goes out as `410 Gone`, and in either case the id is recorded as
/// delivered so a late `DELETE` answers `409`.
///
/// With `repark` set (the claiming-GET path), a delivered `200` is
/// tagged with its id so a failed response write re-parks the rendered
/// body ([`repark_failed_write`]) instead of dropping it.
fn finish_wait_with(
    shared: &Shared,
    id: u64,
    handle: JobHandle,
    hash: Option<u64>,
    timeout: Duration,
    repark: bool,
) -> Response {
    match handle.wait_timeout(timeout) {
        Ok(result) => {
            let cancelled = matches!(result.outcome, Err(Error::Cancelled(_)));
            let status = if cancelled { 410 } else { 200 };
            let body = protocol::job_result_to_json(&result).to_string().into_bytes();
            if result.outcome.is_ok() {
                if let Some(h) = hash {
                    let mut cache = shared.cache.lock().expect("result cache mutex");
                    cache.insert(h, body.clone());
                    shared
                        .metrics
                        .cache_bytes
                        .store(cache.bytes(), Ordering::Relaxed);
                }
            }
            record_delivered(shared, id);
            let response = Response::json_bytes(status, body);
            if repark && status == 200 {
                response.with_repark(id)
            } else {
                response
            }
        }
        Err(Error::Timeout(_)) => {
            park(shared, id, Pending::Running { handle, hash });
            Response::json(
                202,
                &Json::obj(vec![
                    ("id", Json::num(id as f64)),
                    ("status", Json::str("running")),
                ]),
            )
        }
        Err(e) => Response::error(500, &format!("{e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn query_param_lookup() {
        assert_eq!(query_param("timeout_s=2.5&x=1", "timeout_s"), Some("2.5"));
        assert_eq!(query_param("x=1", "timeout_s"), None);
        assert_eq!(query_param("", "timeout_s"), None);
        assert_eq!(query_param("timeout_s", "timeout_s"), None);
    }

    #[test]
    fn retry_after_scales_with_backlog_and_stays_bounded() {
        assert_eq!(retry_after_secs(0, 8), 1);
        assert_eq!(retry_after_secs(8, 8), 1);
        assert_eq!(retry_after_secs(40, 8), 5);
        assert_eq!(retry_after_secs(10_000, 8), 30);
        // A zero capacity must not divide by zero.
        assert_eq!(retry_after_secs(5, 0), 5);
    }

    #[test]
    fn journal_files_are_per_id_and_ordered(){
        let dir = std::path::Path::new("/tmp/j");
        let a = journal_file(dir, 7);
        let b = journal_file(dir, 8);
        assert_ne!(a, b);
        assert!(a.to_string_lossy().ends_with(".json"));
    }

    #[test]
    fn backpressure_detection() {
        assert!(is_backpressure(&Error::Busy("queue full".into())));
        assert!(!is_backpressure(&Error::Service("worker died".into())));
        assert!(!is_backpressure(&Error::Timeout("job still running".into())));
        assert!(!is_backpressure(&Error::Invalid("k must be >= 1".into())));
        // The Display text is part of the wire contract (clients grep
        // for it in 503 bodies) — pinned here.
        assert!(format!("{}", Error::Busy("queue full".into())).contains("backpressure"));
    }

    #[test]
    fn default_config_is_sane() {
        let c = ServerConfig::default();
        assert!(c.workers >= 1);
        assert!(c.max_body_bytes >= 1 << 20);
        assert!(c.request_timeout_s >= 1);
        assert!(c.result_ttl_s >= 1);
        assert!(c.cache_entries >= 1);
        assert!(c.cache_dir.is_none());
    }

    /// A hand-advanced [`Clock`] (shared with `tests/lifecycle.rs` in
    /// spirit): `now_ms` is whatever the test last stored.
    struct FakeClock(std::sync::atomic::AtomicU64);

    impl Clock for FakeClock {
        fn now_ms(&self) -> u64 {
            self.0.load(Ordering::Relaxed)
        }
    }

    #[test]
    fn monotonic_clock_advances_and_fake_clock_obeys() {
        let real = MonotonicClock::default();
        let a = real.now_ms();
        let b = real.now_ms();
        assert!(b >= a);
        let fake = FakeClock(std::sync::atomic::AtomicU64::new(5));
        assert_eq!(fake.now_ms(), 5);
        fake.0.store(1_000, Ordering::Relaxed);
        assert_eq!(fake.now_ms(), 1_000);
    }
}
