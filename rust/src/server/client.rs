//! A std-only blocking HTTP client for the factorization service.
//!
//! Used by the CLI, the loopback tests (`rust/tests/server.rs`),
//! `examples/remote_jobs.rs` and `benches/serve_throughput.rs` — no
//! external HTTP crate exists in the offline environment. One
//! [`Client`] owns one keep-alive connection.
//!
//! ## Retry semantics
//!
//! All transport retries run under a typed [`RetryPolicy`]
//! ([`Client::with_retry`]): connect attempts and idempotent `GET`s
//! back off exponentially up to `max_attempts`. A failed `POST` is
//! **never** resubmitted after the connection carried it — the server
//! may have accepted the job before the transport died, and a blind
//! resubmit would run it twice. A `503` **is** safely retryable (the
//! server rejected the job *before* accepting it); [`Client::submit`]
//! honors the server's `Retry-After` hint, capped by the policy's
//! `backoff_max_ms`.

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::util::json::Json;
use crate::util::retry::RetryPolicy;
use crate::util::{Error, Result};

use super::http::read_line_raw;
use super::protocol::{parse_result, JobRequest, WireResult};

/// Maximum header/status line the client accepts from a server.
const MAX_LINE: usize = 8 << 10;

/// What a non-waiting submit yielded.
#[derive(Debug)]
pub enum SubmitOutcome {
    /// Accepted (`202`): fetch the result later with [`Client::wait`].
    Queued(u64),
    /// The server answered with the finished result (`"wait": true`).
    Done(WireResult),
}

/// What a blocking `GET /v1/jobs/{id}` yielded.
#[derive(Debug)]
pub enum WaitOutcome {
    /// The finished result; the server forgot the id.
    Done(WireResult),
    /// Still running when the wait timed out — call again.
    Running,
}

/// Blocking JSON-over-HTTP client with one keep-alive connection.
#[derive(Debug)]
pub struct Client {
    addr: String,
    stream: Option<TcpStream>,
    /// Requests already served on the current connection — when > 0 a
    /// transport failure is plausibly a server-side idle close of the
    /// keep-alive connection rather than a real fault.
    served_on_stream: u64,
    /// Socket read/write timeout.
    timeout: Duration,
    /// Bound on each TCP connect attempt; `None` leaves the OS default
    /// (which can block for minutes against a dead host). The routing
    /// tier always sets this so probes and failover stay bounded.
    connect_timeout: Option<Duration>,
    /// Largest response body the client will buffer.
    max_body_bytes: usize,
    /// Transport retry/backoff policy (connects, idempotent `GET`s,
    /// pre-acceptance `503`s).
    retry: RetryPolicy,
    /// `Retry-After` seconds from the most recent response carrying the
    /// header (the server's `503` backoff hint).
    last_retry_after: Option<u64>,
}

impl Client {
    /// Connect to `host:port` (eagerly, so a bad address fails here).
    pub fn connect(addr: &str) -> Result<Client> {
        Client::with_timeout(addr, Duration::from_secs(60))
    }

    /// [`Client::connect`] with an explicit socket timeout. Keep it
    /// above the server's request timeout: a blocking `GET` is answered
    /// (`202 running`) when the *server* side expires.
    pub fn with_timeout(addr: &str, timeout: Duration) -> Result<Client> {
        Client::with_timeouts(addr, None, timeout)
    }

    /// [`Client::with_timeout`] plus an explicit connect bound. With
    /// `Some(d)` every (re)connect resolves the address and gives each
    /// candidate at most `d` to complete the TCP handshake, so a dead
    /// replica costs a bounded wait instead of the OS default.
    pub fn with_timeouts(
        addr: &str,
        connect_timeout: Option<Duration>,
        timeout: Duration,
    ) -> Result<Client> {
        Client::with_policy(addr, connect_timeout, timeout, RetryPolicy::default())
    }

    /// [`Client::with_timeouts`] plus an explicit [`RetryPolicy`],
    /// applied from the very first (eager) connect attempt —
    /// [`RetryPolicy::none`] gives a fail-fast probe client.
    pub fn with_policy(
        addr: &str,
        connect_timeout: Option<Duration>,
        timeout: Duration,
        retry: RetryPolicy,
    ) -> Result<Client> {
        let mut c = Client {
            addr: addr.to_string(),
            stream: None,
            served_on_stream: 0,
            timeout,
            connect_timeout,
            max_body_bytes: 1 << 30,
            retry,
            last_retry_after: None,
        };
        c.reconnect()?;
        Ok(c)
    }

    /// Replace the transport retry/backoff policy
    /// ([`RetryPolicy::none`] restores fail-fast single attempts).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Client {
        self.retry = retry;
        self
    }

    /// `Retry-After` seconds from the most recent response that carried
    /// the header, if any (`503` backoff hint).
    pub fn last_retry_after(&self) -> Option<u64> {
        self.last_retry_after
    }

    /// Deterministic per-destination jitter seed: two clients hammering
    /// different replicas must not back off in lockstep.
    fn retry_seed(&self) -> u64 {
        self.addr
            .bytes()
            .fold(0xA5A5_5A5A_u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
    }

    fn connect_stream(&self) -> std::io::Result<TcpStream> {
        match self.connect_timeout {
            None => TcpStream::connect(self.addr.as_str()),
            Some(bound) => {
                let mut last = None;
                for resolved in self.addr.as_str().to_socket_addrs()? {
                    match TcpStream::connect_timeout(&resolved, bound) {
                        Ok(s) => return Ok(s),
                        Err(e) => last = Some(e),
                    }
                }
                Err(last.unwrap_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::NotFound,
                        "address resolved to nothing",
                    )
                }))
            }
        }
    }

    /// (Re)establish the connection under the retry policy: each failed
    /// attempt backs off exponentially, up to `max_attempts` total. The
    /// `client.connect` fail-point injects connect failures here.
    fn reconnect(&mut self) -> Result<()> {
        let mut attempt: u32 = 0;
        loop {
            let connected = crate::util::faults::check("client.connect")
                .and_then(|()| self.connect_stream());
            match connected {
                Ok(stream) => {
                    stream
                        .set_read_timeout(Some(self.timeout))
                        .and_then(|()| stream.set_write_timeout(Some(self.timeout)))
                        .map_err(|e| Error::Service(format!("socket timeout: {e}")))?;
                    let _ = stream.set_nodelay(true);
                    self.stream = Some(stream);
                    self.served_on_stream = 0;
                    return Ok(());
                }
                Err(e) => {
                    attempt += 1;
                    if !self.retry.allows(attempt) {
                        return Err(Error::Service(format!(
                            "connect {} (attempt {attempt}): {e}",
                            self.addr
                        )));
                    }
                    self.retry.sleep_backoff(attempt, self.retry_seed());
                }
            }
        }
    }

    /// One request/response exchange; returns `(status, parsed body)`.
    ///
    /// Retry policy: only an idempotent (`GET`) request is retried
    /// (under the typed [`RetryPolicy`], with backoff). A failed `POST`
    /// is **never** resubmitted automatically — the server may have
    /// accepted the job before the connection died, and a blind
    /// resubmit would run it twice; the caller decides.
    pub fn request(&mut self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let payload = body.map(|j| j.to_string());
        let (status, bytes) = self.request_raw(method, path, payload.as_deref().map(str::as_bytes))?;
        let text = String::from_utf8(bytes)
            .map_err(|_| Error::Service(format!("{method} {path}: non-UTF-8 response")))?;
        let json = if text.is_empty() {
            Json::Null
        } else {
            Json::parse(&text)
                .map_err(|e| Error::Service(format!("{method} {path}: bad response JSON: {e}")))?
        };
        Ok((status, json))
    }

    /// [`Client::request`] without the JSON layer: the body is sent and
    /// returned as raw bytes. The routing tier proxies responses through
    /// this so cached replays stay byte-identical end to end (a parse +
    /// re-render round trip would canonicalize key order). Same retry
    /// policy as [`Client::request`].
    pub fn request_raw(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<(u16, Vec<u8>)> {
        let mut attempt: u32 = 0;
        loop {
            match self.request_once(method, path, body) {
                Ok(r) => return Ok(r),
                Err(e) => {
                    self.stream = None;
                    attempt += 1;
                    // Non-idempotent verbs fail fast: the request may
                    // have been acted on before the transport died.
                    if method != "GET" || !self.retry.allows(attempt) {
                        return Err(e);
                    }
                    self.retry.sleep_backoff(attempt, self.retry_seed());
                }
            }
        }
    }

    fn request_once(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
    ) -> Result<(u16, Vec<u8>)> {
        let addr = self.addr.clone();
        let max_body = self.max_body_bytes;
        let payload = body.unwrap_or_default();
        if self.stream.is_none() {
            self.reconnect()?;
        }
        let stream = self.stream.as_mut().expect("stream just established");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {addr}\r\ncontent-type: application/json\r\n\
             content-length: {}\r\nconnection: keep-alive\r\n\r\n",
            payload.len()
        );
        let io = |e: std::io::Error| Error::Service(format!("{method} {path}: {e}"));
        stream.write_all(head.as_bytes()).map_err(io)?;
        stream.write_all(payload).map_err(io)?;
        stream.flush().map_err(io)?;

        let (status, body, keep, retry_after) = read_response(stream, max_body).map_err(io)?;
        self.served_on_stream += 1;
        self.last_retry_after = retry_after;
        if !keep {
            self.stream = None;
        }
        Ok((status, body))
    }

    // ----- endpoint wrappers -----------------------------------------------

    /// `GET /healthz`; `Ok` when the service answers 200.
    pub fn health(&mut self) -> Result<()> {
        let (status, body) = self.request("GET", "/healthz", None)?;
        crate::ensure!(status == 200, "healthz: http {status}: {}", error_text(&body));
        Ok(())
    }

    /// `GET /metrics`: the service counters as JSON.
    pub fn metrics(&mut self) -> Result<Json> {
        let (status, body) = self.request("GET", "/metrics", None)?;
        crate::ensure!(status == 200, "metrics: http {status}: {}", error_text(&body));
        Ok(body)
    }

    /// `POST /v1/jobs`, single-shot. Queue-full surfaces as an `Err`
    /// whose message carries `http 503` (the server's backpressure
    /// signal) — callers that want automatic backoff use
    /// [`Client::submit_retrying`].
    pub fn submit(&mut self, job: &JobRequest) -> Result<SubmitOutcome> {
        let (status, body) = self.request("POST", "/v1/jobs", Some(&job.to_json()))?;
        match status {
            200 => Ok(SubmitOutcome::Done(parse_result(&body)?)),
            202 => Ok(SubmitOutcome::Queued(body.get("id")?.as_u64()?)),
            _ => Err(Error::Service(format!(
                "submit: http {status}: {}",
                error_text(&body)
            ))),
        }
    }

    /// [`Client::submit`] that rides out backpressure: a `503` is
    /// retried under the policy — it happens *before* the server
    /// accepts the job, so resubmission cannot double-run it — sleeping
    /// the server's `Retry-After` hint capped by the policy's
    /// `backoff_max_ms` (blind exponential backoff when no hint came).
    /// Transport failures still follow [`Client::request`]'s rule:
    /// a `POST` that may have been accepted is never resent.
    pub fn submit_retrying(&mut self, job: &JobRequest) -> Result<SubmitOutcome> {
        let body = job.to_json();
        let mut attempt: u32 = 0;
        loop {
            let (status, resp) = self.request("POST", "/v1/jobs", Some(&body))?;
            match status {
                200 => return Ok(SubmitOutcome::Done(parse_result(&resp)?)),
                202 => return Ok(SubmitOutcome::Queued(resp.get("id")?.as_u64()?)),
                503 => {
                    attempt += 1;
                    if !self.retry.allows(attempt) {
                        return Err(Error::Service(format!(
                            "submit: http 503: {}",
                            error_text(&resp)
                        )));
                    }
                    // Prefer the server's hint over blind backoff; the
                    // policy's ceiling keeps a hostile hint bounded.
                    let ms = match self.last_retry_after {
                        Some(secs) => {
                            secs.saturating_mul(1000).min(self.retry.backoff_max_ms)
                        }
                        None => self.retry.backoff_ms(attempt, self.retry_seed()),
                    };
                    if ms > 0 {
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                }
                _ => {
                    return Err(Error::Service(format!(
                        "submit: http {status}: {}",
                        error_text(&resp)
                    )))
                }
            }
        }
    }

    /// Submit with `"wait": true` and insist on a finished result,
    /// retrying the blocking `GET` if the server's per-request timeout
    /// expires first.
    pub fn submit_wait(&mut self, job: &JobRequest) -> Result<WireResult> {
        let mut job = job.clone();
        job.wait = true;
        match self.submit(&job)? {
            SubmitOutcome::Done(r) => Ok(r),
            SubmitOutcome::Queued(id) => loop {
                if let WaitOutcome::Done(r) = self.wait(id)? {
                    return Ok(r);
                }
            },
        }
    }

    /// Blocking `GET /v1/jobs/{id}` (server-side request timeout).
    pub fn wait(&mut self, id: u64) -> Result<WaitOutcome> {
        self.wait_path(&format!("/v1/jobs/{id}"))
    }

    /// [`Client::wait`] with an explicit `?timeout_s=` (seconds, capped
    /// by the server's request timeout).
    pub fn wait_timeout(&mut self, id: u64, seconds: f64) -> Result<WaitOutcome> {
        self.wait_path(&format!("/v1/jobs/{id}?timeout_s={seconds}"))
    }

    /// `DELETE /v1/jobs/{id}`: cancel a parked job. `Ok(true)` when the
    /// server cancelled it (`200`), `Ok(false)` when the result had
    /// already been delivered (`409`); an unknown id (`404`) surfaces
    /// as the typed [`Error::NotFound`] — distinguishable from a
    /// transport failure — and every other status as `Err`.
    pub fn cancel(&mut self, id: u64) -> Result<bool> {
        let (status, body) = self.request("DELETE", &format!("/v1/jobs/{id}"), None)?;
        match status {
            200 => Ok(true),
            409 => Ok(false),
            404 => Err(Error::NotFound(format!(
                "cancel: http 404: {}",
                error_text(&body)
            ))),
            _ => Err(Error::Service(format!(
                "cancel: http {status}: {}",
                error_text(&body)
            ))),
        }
    }

    fn wait_path(&mut self, path: &str) -> Result<WaitOutcome> {
        let (status, body) = self.request("GET", path, None)?;
        match status {
            200 => Ok(WaitOutcome::Done(parse_result(&body)?)),
            202 => Ok(WaitOutcome::Running),
            _ => Err(Error::Service(format!(
                "wait: http {status}: {}",
                error_text(&body)
            ))),
        }
    }
}

fn error_text(body: &Json) -> String {
    body.get("error")
        .and_then(|e| e.as_str().map(str::to_string))
        .unwrap_or_else(|_| body.to_string())
}

/// Parse one HTTP response: `(status, body, keep_alive, retry_after)`.
fn read_response(
    stream: &mut TcpStream,
    max_body: usize,
) -> std::io::Result<(u16, Vec<u8>, bool, Option<u64>)> {
    let bad = |msg: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string());
    let status_line = read_line_raw(stream, MAX_LINE, None)?
        .ok_or_else(|| bad("connection closed before the status line"))?;
    let status_line = String::from_utf8(status_line).map_err(|_| bad("non-UTF-8 status line"))?;
    let mut parts = status_line.split_whitespace();
    let (version, status) = match (parts.next(), parts.next()) {
        (Some(v), Some(s)) => (v, s),
        _ => return Err(bad("malformed status line")),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(bad("not an HTTP response"));
    }
    let status: u16 = status.parse().map_err(|_| bad("bad status code"))?;

    let mut content_length: Option<usize> = None;
    let mut keep_alive = true;
    let mut retry_after: Option<u64> = None;
    loop {
        let line = read_line_raw(stream, MAX_LINE, None)?.ok_or_else(|| bad("eof in headers"))?;
        if line.is_empty() {
            break;
        }
        let line = String::from_utf8(line).map_err(|_| bad("non-UTF-8 header"))?;
        let Some((name, value)) = line.split_once(':') else {
            return Err(bad("malformed header"));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        if name == "content-length" {
            content_length = Some(value.parse().map_err(|_| bad("bad content-length"))?);
        } else if name == "connection" && value.eq_ignore_ascii_case("close") {
            keep_alive = false;
        } else if name == "retry-after" {
            // Lenient: a non-numeric hint (HTTP-date form) is ignored
            // rather than failing the exchange.
            retry_after = value.parse().ok();
        }
    }
    let len = content_length.ok_or_else(|| bad("response without content-length"))?;
    if len > max_body {
        return Err(bad("response body too large"));
    }
    let mut body = vec![0u8; len];
    stream.read_exact(&mut body)?;
    Ok((status, body, keep_alive, retry_after))
}
