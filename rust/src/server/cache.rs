//! Content-addressed cache of completed job results.
//!
//! A job spec that fully determines its output — every input byte plus
//! every accuracy-relevant knob — is serialized to a **canonical byte
//! string** ([`canonical_spec_bytes`]) and hashed ([`content_hash`],
//! SplitMix64-style mixing, no external hasher). The hash keys an LRU
//! of **rendered result bodies**: a cache hit replays the exact bytes
//! the cold run wrote, so a hit is byte-identical to recomputing and
//! never touches the coordinator.
//!
//! ## What the key covers — and deliberately omits
//!
//! The canonical form covers the matrix content (dense/CSR payload
//! bits, or a streamed source's [`MatrixSource::cache_key`]), the full
//! [`SvdConfig`], the shift, the engine preference, the seed, and the
//! `score` flag. It **excludes** execution policy — `block_rows`,
//! `budget_mb`, prefetch, pool size — because the engine's
//! bit-determinism contract (pinned by `rust/tests/stream.rs`)
//! guarantees those cannot change a single output bit. Sources that
//! cannot prove their content from the handle alone (server-side
//! files) return `None` from [`MatrixSource::cache_key`] and are
//! simply never cached.
//!
//! ## Persistence
//!
//! With a cache directory configured (`[server] cache_dir`), each body
//! is written to `<hash>.json` and an index to `cache-manifest.json`,
//! in the style of the artifact registry's manifest: load-time errors
//! of any kind (missing file, truncated body, corrupt JSON) silently
//! drop the affected entries and rebuild from empty — the cache is an
//! optimization, never a correctness dependency.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::coordinator::{EnginePreference, JobSpec, MatrixInput, ShiftSpec};
use crate::linalg::stream::MatrixSource;
use crate::svd::{BasisMethod, PassPolicy, Precision, SmallSvdMethod, StopCriterion};
use crate::util::json::Json;

/// Name of the index file inside the cache directory.
const MANIFEST: &str = "cache-manifest.json";
/// Manifest format version.
const MANIFEST_VERSION: f64 = 1.0;

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Canonical byte serialization of a job spec, or `None` when the spec
/// is not cacheable (a streamed source without a stable
/// [`MatrixSource::cache_key`]).
///
/// The encoding is fixed-order and tag-prefixed, so it is independent
/// of the JSON field order a submission arrived with; floats are
/// encoded by their `f64` bit patterns (no text round-trip).
pub fn canonical_spec_bytes(spec: &JobSpec) -> Option<Vec<u8>> {
    canonical_bytes(spec, false)
}

fn canonical_bytes(spec: &JobSpec, for_checkpoint: bool) -> Option<Vec<u8>> {
    let mut b = Vec::new();
    b.extend_from_slice(b"srsvd-job-v1");

    // Input content.
    match &spec.input {
        MatrixInput::Dense(x) => {
            b.push(0);
            push_u64(&mut b, x.rows() as u64);
            push_u64(&mut b, x.cols() as u64);
            for &v in x.data() {
                push_u64(&mut b, v.to_bits());
            }
        }
        MatrixInput::Sparse(x) => {
            b.push(1);
            let (m, n) = x.shape();
            push_u64(&mut b, m as u64);
            push_u64(&mut b, n as u64);
            for i in 0..m {
                let row: Vec<(usize, f64)> = x.row_iter(i).collect();
                push_u64(&mut b, row.len() as u64);
                for (j, v) in row {
                    push_u64(&mut b, j as u64);
                    push_u64(&mut b, v.to_bits());
                }
            }
        }
        // Only the source's content key enters the hash — block size,
        // memory budget and prefetch are execution policy and cannot
        // change output bits (the crate's determinism contract).
        MatrixInput::Streamed(s) => {
            b.push(2);
            // Checkpoint tagging accepts the weaker *claimed* identity
            // (e.g. a file's path + shape) that caching must refuse —
            // see [`MatrixSource::checkpoint_key`] for the contract.
            let key = if for_checkpoint {
                s.source().checkpoint_key()?
            } else {
                s.source().cache_key()?
            };
            push_u64(&mut b, key.len() as u64);
            b.extend_from_slice(&key);
        }
    }

    // Accuracy-relevant configuration, fixed order.
    push_u64(&mut b, spec.config.k as u64);
    push_u64(&mut b, spec.config.oversample as u64);
    match spec.config.stop {
        StopCriterion::FixedPower { q } => {
            b.push(0);
            push_u64(&mut b, q as u64);
        }
        StopCriterion::Tolerance { pve_tol, max_sweeps } => {
            b.push(1);
            push_u64(&mut b, pve_tol.to_bits());
            push_u64(&mut b, max_sweeps as u64);
        }
    }
    b.push(match spec.config.basis {
        BasisMethod::Direct => 0,
        BasisMethod::QrUpdatePaper => 1,
        BasisMethod::QrUpdateExact => 2,
    });
    b.push(match spec.config.small_svd {
        SmallSvdMethod::Jacobi => 0,
        SmallSvdMethod::GramEig => 1,
    });
    b.push(match spec.config.pass_policy {
        PassPolicy::Exact => 0,
        PassPolicy::Fused => 1,
    });
    // The kernel tier is accuracy-relevant: Fast factors differ from
    // Exact in the last ulps, so the two must never share a cache slot.
    b.push(match spec.config.precision {
        Precision::Exact => 0,
        Precision::Fast => 1,
    });
    match &spec.shift {
        ShiftSpec::None => b.push(0),
        ShiftSpec::MeanCenter => b.push(1),
        ShiftSpec::Vector(v) => {
            b.push(2);
            push_u64(&mut b, v.len() as u64);
            for &x in v {
                push_u64(&mut b, x.to_bits());
            }
        }
    }
    b.push(match spec.engine {
        EnginePreference::Auto => 0,
        EnginePreference::Native => 1,
        EnginePreference::ArtifactOnly => 2,
    });
    push_u64(&mut b, spec.seed);
    b.push(spec.score as u8);
    Some(b)
}

/// SplitMix64's finalizer (the `rng/` seeding mixer): the avalanche
/// stage that makes every input bit flip ~half the output bits.
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Hash a canonical byte string: SplitMix64-style mixing folded over
/// 8-byte little-endian chunks, seeded with the length (std-only; not
/// cryptographic — an in-process cache key, not an integrity check).
pub fn content_hash(bytes: &[u8]) -> u64 {
    const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
    let mut h = mix((bytes.len() as u64).wrapping_add(GOLDEN));
    for chunk in bytes.chunks(8) {
        let mut word = [0u8; 8];
        word[..chunk.len()].copy_from_slice(chunk);
        h = mix(h.wrapping_add(GOLDEN) ^ u64::from_le_bytes(word));
    }
    h
}

/// [`canonical_spec_bytes`] + [`content_hash`] in one step: the cache
/// key of a spec, or `None` when the spec is not cacheable.
pub fn spec_hash(spec: &JobSpec) -> Option<u64> {
    canonical_spec_bytes(spec).map(|b| content_hash(&b))
}

/// The checkpoint tag of a spec: the same canonical encoding as
/// [`spec_hash`] but keyed by [`MatrixSource::checkpoint_key`] for
/// streamed inputs, so file-backed jobs — uncacheable by design — still
/// get a stable identity for crash/resume. `None` means the job cannot
/// be checkpointed (no identity at all).
pub fn checkpoint_spec_hash(spec: &JobSpec) -> Option<u64> {
    canonical_bytes(spec, true).map(|b| content_hash(&b))
}

struct CacheEntry {
    body: Vec<u8>,
    last_used: u64,
}

/// LRU cache of rendered result bodies keyed by [`spec_hash`], with
/// optional on-disk persistence (see the module docs).
pub struct ResultCache {
    capacity: usize,
    dir: Option<PathBuf>,
    entries: HashMap<u64, CacheEntry>,
    seq: u64,
    bytes: u64,
}

impl ResultCache {
    /// An empty cache holding at most `capacity` bodies; with `dir`
    /// set, previously persisted entries are reloaded (corrupt or
    /// partial state is ignored and rebuilt from empty).
    pub fn new(capacity: usize, dir: Option<PathBuf>) -> ResultCache {
        let mut cache = ResultCache {
            capacity,
            dir: None,
            entries: HashMap::new(),
            seq: 0,
            bytes: 0,
        };
        if capacity == 0 {
            return cache;
        }
        if let Some(d) = dir {
            if let Err(e) = fs::create_dir_all(&d) {
                crate::log_warn!("result cache: create {}: {e}; persistence off", d.display());
            } else {
                cache.dir = Some(d);
                cache.load();
            }
        }
        cache
    }

    /// Number of cached bodies.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the cache holds nothing.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total bytes of cached bodies (the `cache_bytes` gauge).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// The body cached under `hash`, refreshing its recency.
    pub fn get(&mut self, hash: u64) -> Option<Vec<u8>> {
        let seq = self.next_seq();
        let entry = self.entries.get_mut(&hash)?;
        entry.last_used = seq;
        Some(entry.body.clone())
    }

    /// Cache `body` under `hash`, evicting least-recently-used entries
    /// beyond capacity and persisting when a directory is configured.
    pub fn insert(&mut self, hash: u64, body: Vec<u8>) {
        if self.capacity == 0 {
            return;
        }
        let seq = self.next_seq();
        if let Some(existing) = self.entries.get_mut(&hash) {
            // Deterministic jobs re-render identical bodies; just
            // refresh recency.
            existing.last_used = seq;
            return;
        }
        if let Some(d) = &self.dir {
            if let Err(e) = persist_bytes(&body_path(d, hash), "cache.body", &body) {
                crate::log_warn!("result cache: persist {hash:016x}: {e}");
            }
        }
        self.bytes += body.len() as u64;
        self.entries.insert(hash, CacheEntry { body, last_used: seq });
        while self.entries.len() > self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&h, _)| h);
            let Some(h) = oldest else { break };
            if let Some(e) = self.entries.remove(&h) {
                self.bytes -= e.body.len() as u64;
            }
            if let Some(d) = &self.dir {
                let _ = fs::remove_file(body_path(d, h));
            }
        }
        self.persist_manifest();
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// Best-effort reload from the manifest; any inconsistency drops
    /// the affected entries (or the whole index) silently.
    fn load(&mut self) {
        let Some(d) = self.dir.clone() else { return };
        let Ok(text) = fs::read_to_string(d.join(MANIFEST)) else {
            return; // first run, or unreadable: start empty
        };
        let Ok(json) = Json::parse(&text) else {
            crate::log_warn!("result cache: corrupt manifest ignored; rebuilding");
            return;
        };
        let Ok(rows) = json.get("entries").and_then(|e| e.as_arr()) else {
            crate::log_warn!("result cache: corrupt manifest ignored; rebuilding");
            return;
        };
        for row in rows {
            let Some((hash, bytes, last_used)) = parse_manifest_row(row) else {
                continue;
            };
            let Ok(body) = fs::read(body_path(&d, hash)) else {
                continue; // body file lost: drop the entry
            };
            if body.len() as u64 != bytes {
                // Torn body write (crash or injected fault): the
                // manifest's declared length is the integrity check.
                crate::log_warn!("result cache: truncated body {hash:016x} dropped");
                continue;
            }
            self.seq = self.seq.max(last_used);
            self.bytes += body.len() as u64;
            self.entries.insert(hash, CacheEntry { body, last_used });
        }
        // Reloaded state may exceed a shrunken capacity; trim via the
        // normal LRU path.
        while self.entries.len() > self.capacity {
            let oldest = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&h, _)| h);
            let Some(h) = oldest else { break };
            if let Some(e) = self.entries.remove(&h) {
                self.bytes -= e.body.len() as u64;
            }
            let _ = fs::remove_file(body_path(&d, h));
        }
    }

    fn persist_manifest(&self) {
        let Some(d) = &self.dir else { return };
        let rows: Vec<Json> = self
            .entries
            .iter()
            .map(|(h, e)| {
                Json::obj(vec![
                    ("hash", Json::str(&format!("{h:016x}"))),
                    ("bytes", Json::num(e.body.len() as f64)),
                    ("last_used", Json::num(e.last_used as f64)),
                ])
            })
            .collect();
        let manifest = Json::obj(vec![
            ("version", Json::num(MANIFEST_VERSION)),
            ("entries", Json::Arr(rows)),
        ]);
        if let Err(e) = persist_bytes(&d.join(MANIFEST), "cache.manifest", manifest.to_string().as_bytes()) {
            crate::log_warn!("result cache: write manifest: {e}");
        }
    }
}

fn body_path(dir: &Path, hash: u64) -> PathBuf {
    dir.join(format!("{hash:016x}.json"))
}

/// Write `bytes` through a fault-injection window: chaos runs truncate
/// or fail cache persistence here (`cache.body` / `cache.manifest`),
/// and the loader must treat whatever lands on disk as disposable.
fn persist_bytes(path: &Path, site: &str, bytes: &[u8]) -> std::io::Result<()> {
    let take = crate::util::faults::write_len(site, bytes.len())?;
    fs::write(path, &bytes[..take])?;
    if take < bytes.len() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::WriteZero,
            format!("short cache write: {take} of {} bytes", bytes.len()),
        ));
    }
    Ok(())
}

fn parse_manifest_row(row: &Json) -> Option<(u64, u64, u64)> {
    let hash = u64::from_str_radix(row.get("hash").ok()?.as_str().ok()?, 16).ok()?;
    let bytes = row.get("bytes").ok()?.as_u64().ok()?;
    let last_used = row.get("last_used").ok()?.as_u64().ok()?;
    Some((hash, bytes, last_used))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::JobSpec;
    use crate::data::Distribution;
    use crate::linalg::stream::{FileWriter, GeneratorSource, StreamConfig};
    use crate::linalg::Dense;
    use crate::rng::Xoshiro256pp;

    fn generator_spec(seed: u64, block_rows: usize) -> JobSpec {
        let src = GeneratorSource::new(40, 30, Distribution::Uniform, seed).unwrap();
        let cfg = StreamConfig { block_rows, ..Default::default() };
        JobSpec::pca(MatrixInput::streamed(src, &cfg), 3, 7)
    }

    #[test]
    fn block_policy_is_excluded_from_the_key() {
        // Same content, different execution policy: identical hash (the
        // determinism contract makes the outputs identical too).
        let a = spec_hash(&generator_spec(5, 4)).unwrap();
        let b = spec_hash(&generator_spec(5, 16)).unwrap();
        assert_eq!(a, b);
        // Different content: different hash.
        let c = spec_hash(&generator_spec(6, 4)).unwrap();
        assert_ne!(a, c);
    }

    #[test]
    fn file_sources_are_not_cacheable() {
        let path = std::env::temp_dir().join("srsvd_cache_test_filesource.bin");
        let mut w = FileWriter::create(&path, 2, 2).unwrap();
        w.append_rows(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        let src = w.finish().unwrap();
        let spec = JobSpec::pca(
            MatrixInput::streamed(src, &StreamConfig::default()),
            1,
            0,
        );
        assert_eq!(spec_hash(&spec), None);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn every_knob_perturbs_a_dense_hash() {
        let mut rng = Xoshiro256pp::seed_from_u64(0);
        let base = JobSpec::pca(MatrixInput::Dense(Dense::gaussian(6, 9, &mut rng)), 2, 3);
        let h0 = spec_hash(&base).unwrap();
        let mut seed = base.clone();
        seed.seed = 4;
        let mut shift = base.clone();
        shift.shift = ShiftSpec::None;
        let mut rank = base.clone();
        rank.config.k = 3;
        let mut stop = base.clone();
        stop.config = stop.config.with_tolerance(1e-3, 8);
        let mut policy = base.clone();
        policy.config.pass_policy = PassPolicy::Fused;
        let mut tier = base.clone();
        tier.config.precision = Precision::Fast;
        for (what, spec) in [
            ("seed", seed),
            ("shift", shift),
            ("k", rank),
            ("stop", stop),
            ("pass_policy", policy),
            ("precision", tier),
        ] {
            assert_ne!(spec_hash(&spec).unwrap(), h0, "{what} not in the key");
        }
    }

    #[test]
    fn lru_evicts_the_least_recently_used() {
        let mut cache = ResultCache::new(2, None);
        cache.insert(1, b"one".to_vec());
        cache.insert(2, b"two".to_vec());
        assert_eq!(cache.get(1), Some(b"one".to_vec())); // 2 is now LRU
        cache.insert(3, b"three".to_vec());
        assert_eq!(cache.get(2), None);
        assert_eq!(cache.get(1), Some(b"one".to_vec()));
        assert_eq!(cache.get(3), Some(b"three".to_vec()));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.bytes(), 8);
        // Zero capacity: inserts are no-ops.
        let mut off = ResultCache::new(0, None);
        off.insert(1, b"x".to_vec());
        assert!(off.is_empty());
    }

    #[test]
    fn manifest_round_trips_and_corruption_rebuilds() {
        let dir = std::env::temp_dir().join("srsvd_cache_test_manifest");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut cache = ResultCache::new(4, Some(dir.clone()));
            cache.insert(10, b"{\"ok\":true}".to_vec());
            cache.insert(11, b"{\"ok\":false}".to_vec());
        }
        let mut back = ResultCache::new(4, Some(dir.clone()));
        assert_eq!(back.len(), 2);
        assert_eq!(back.get(10), Some(b"{\"ok\":true}".to_vec()));
        assert_eq!(back.get(11), Some(b"{\"ok\":false}".to_vec()));
        // A lost body file drops that entry only.
        let _ = fs::remove_file(body_path(&dir, 10));
        let mut partial = ResultCache::new(4, Some(dir.clone()));
        assert_eq!(partial.get(10), None);
        assert_eq!(partial.get(11), Some(b"{\"ok\":false}".to_vec()));
        // A corrupt manifest rebuilds from empty instead of failing.
        fs::write(dir.join(MANIFEST), "not json{{{").unwrap();
        let broken = ResultCache::new(4, Some(dir.clone()));
        assert!(broken.is_empty());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_sources_are_checkpointable_but_not_cacheable() {
        let path = std::env::temp_dir().join("srsvd_cache_test_ckpt_key.bin");
        let mut w = FileWriter::create(&path, 2, 2).unwrap();
        w.append_rows(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        let src = w.finish().unwrap();
        let spec = JobSpec::pca(
            MatrixInput::streamed(src, &StreamConfig::default()),
            1,
            0,
        );
        assert_eq!(spec_hash(&spec), None, "content cannot be proven stable");
        let tag = checkpoint_spec_hash(&spec).expect("claimed identity suffices");
        // The tag covers the accuracy knobs too.
        let mut other = spec.clone();
        other.seed = 99;
        assert_ne!(checkpoint_spec_hash(&other).unwrap(), tag);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_body_writes_are_dropped_on_reload() {
        let _g = crate::util::faults::test_lock();
        let dir = std::env::temp_dir().join("srsvd_cache_test_torn_body");
        let _ = fs::remove_dir_all(&dir);
        {
            let mut cache = ResultCache::new(4, Some(dir.clone()));
            cache.insert(20, b"{\"whole\":true}".to_vec());
            // The next body write is torn mid-file.
            crate::util::faults::arm("cache.body=partial_write:1@1.0").unwrap();
            cache.insert(21, b"{\"torn\":true}".to_vec());
            crate::util::faults::disarm();
        }
        let mut back = ResultCache::new(4, Some(dir.clone()));
        assert_eq!(back.get(20), Some(b"{\"whole\":true}".to_vec()));
        assert_eq!(back.get(21), None, "torn body must not be served");
        let _ = fs::remove_dir_all(&dir);
    }
}
