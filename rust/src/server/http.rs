//! Minimal HTTP/1.1 over `std::net` (no hyper/tokio — the crate is
//! zero-dependency by policy).
//!
//! This is the subset the factorization service needs, hardened as a
//! network attack surface:
//!
//! * request line + headers are read byte-wise with hard caps on line
//!   length and header count (no unbounded buffering on hostile input);
//! * bodies require `Content-Length` and are capped by
//!   [`HttpLimits::max_body_bytes`] (an oversized request is answered
//!   with `413` without reading the payload);
//! * `Transfer-Encoding` is not implemented and answered with `501`
//!   rather than misparsed;
//! * the caller supplies a whole-exchange deadline: reads run under a
//!   short per-read socket timeout and re-check the deadline on every
//!   slow slice, so a byte-trickling client gets `408` when the
//!   deadline passes instead of pinning a connection worker (see
//!   `server/mod.rs` for the idle-poll scheme);
//! * keep-alive follows HTTP/1.1 defaults (`Connection: close` /
//!   HTTP/1.0 opt-in honored).
//!
//! Parsing is transport-agnostic (`impl Read`/`impl Write`), so the
//! unit tests drive it from in-memory cursors and the client
//! ([`crate::server::client`]) reuses the line reader for responses.

use std::io::{ErrorKind, Read, Write};
use std::time::Instant;

use crate::util::json::Json;

/// Hard limits applied while parsing one request.
#[derive(Debug, Clone, Copy)]
pub struct HttpLimits {
    /// Maximum accepted `Content-Length`; larger bodies get `413`.
    pub max_body_bytes: usize,
    /// Maximum length of one header (or request) line, bytes.
    pub max_line_bytes: usize,
    /// Maximum number of headers.
    pub max_headers: usize,
}

impl Default for HttpLimits {
    fn default() -> Self {
        HttpLimits {
            max_body_bytes: 64 << 20,
            max_line_bytes: 8 << 10,
            max_headers: 64,
        }
    }
}

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    /// Method verb, uppercase (`GET`, `POST`, ...).
    pub method: String,
    /// Path component of the target (no query string).
    pub path: String,
    /// Raw query string (without the `?`), empty when absent.
    pub query: String,
    /// Headers in arrival order; names lowercased, values trimmed.
    pub headers: Vec<(String, String)>,
    /// The body (empty unless `Content-Length` was given).
    pub body: Vec<u8>,
    /// Whether the connection should stay open after the response.
    pub keep_alive: bool,
    /// Bytes consumed off the wire by this request (for metrics).
    pub bytes_read: u64,
}

impl Request {
    /// First header value with the given (lowercase) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// What reading a request yielded.
#[derive(Debug)]
pub enum ReadOutcome {
    /// A complete request.
    Request(Request),
    /// The peer closed the connection before sending anything.
    Closed,
}

/// Why a request could not be read.
#[derive(Debug)]
pub enum HttpError {
    /// Protocol-level problem: answer with this status, then close.
    Respond {
        /// HTTP status to answer with.
        status: u16,
        /// Human-readable reason (becomes the JSON error body).
        msg: String,
    },
    /// Transport-level problem: drop the connection silently.
    Drop(String),
}

impl HttpError {
    fn respond(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError::Respond { status, msg: msg.into() }
    }
}

/// On a short-timeout read error: keep going while a whole-exchange
/// `deadline` lies ahead, fail with `TimedOut` once it has passed (or
/// immediately when no deadline was given).
fn timeout_gate(deadline: Option<Instant>) -> std::io::Result<()> {
    match deadline {
        Some(d) if Instant::now() < d => Ok(()),
        _ => Err(std::io::Error::new(
            ErrorKind::TimedOut,
            "request deadline exceeded",
        )),
    }
}

/// Read one line (terminated by `\n`, `\r` stripped) byte-wise.
/// `Ok(None)` means clean EOF before any byte. Only header-sized data
/// comes through here — bodies use [`read_full`] below. The server
/// passes a short per-read socket timeout plus a whole-exchange
/// `deadline`: each slow read slice re-checks the deadline, so a
/// byte-trickling client cannot pin a connection worker past it.
pub(crate) fn read_line_raw<R: Read>(
    r: &mut R,
    max_len: usize,
    deadline: Option<Instant>,
) -> std::io::Result<Option<Vec<u8>>> {
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(std::io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "eof mid-line",
                ));
            }
            Ok(_) => {
                if byte[0] == b'\n' {
                    if line.last() == Some(&b'\r') {
                        line.pop();
                    }
                    return Ok(Some(line));
                }
                line.push(byte[0]);
                if line.len() > max_len {
                    return Err(std::io::Error::new(
                        ErrorKind::InvalidData,
                        "line too long",
                    ));
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => timeout_gate(deadline)?,
            Err(e) => return Err(e),
        }
    }
}

/// Fill `buf` completely (deadline-aware `read_exact`).
fn read_full<R: Read>(
    r: &mut R,
    buf: &mut [u8],
    deadline: Option<Instant>,
) -> std::io::Result<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(std::io::Error::new(ErrorKind::UnexpectedEof, "eof in body"))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => timeout_gate(deadline)?,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Whether an IO error is a (socket) timeout rather than a real fault.
/// Shared with the connection handler's idle poll in `server/mod.rs`.
pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

/// Outcome of the keep-alive idle phase between requests.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum IdleOutcome {
    /// The next request's first byte is waiting: go parse it.
    Ready,
    /// Close the connection: the peer hung up, an unrecoverable error
    /// hit the socket, `tick` asked to stop (shutdown), or the
    /// keep-alive idle limit passed without a byte.
    Close,
}

/// The keep-alive idle phase of one connection, factored out of the
/// socket loop so the slot-release policy is unit-testable without
/// sleeping: `peek` probes the transport under a short (`poll`) socket
/// timeout, `tick` runs between slices (shutdown checks, TTL sweeps —
/// returning `true` closes), and a connection idle past `idle_limit`
/// is closed so it stops consuming a connection-worker slot.
///
/// Time is virtual here — elapsed idle time is `poll` per timed-out
/// probe, which matches wall time on a real socket and costs nothing
/// under a test fake.
pub(crate) fn idle_wait(
    peek: &mut dyn FnMut() -> std::io::Result<usize>,
    poll: std::time::Duration,
    idle_limit: std::time::Duration,
    tick: &mut dyn FnMut() -> bool,
) -> IdleOutcome {
    let mut idled = std::time::Duration::ZERO;
    loop {
        if tick() {
            return IdleOutcome::Close;
        }
        match peek() {
            Ok(0) => return IdleOutcome::Close, // peer closed
            Ok(_) => return IdleOutcome::Ready,
            Err(e) if is_timeout(&e) => {
                idled += poll;
                if idled >= idle_limit {
                    return IdleOutcome::Close; // keep-alive idle limit
                }
            }
            Err(_) => return IdleOutcome::Close,
        }
    }
}

fn line_err(e: std::io::Error, what: &str) -> HttpError {
    if is_timeout(&e) {
        HttpError::respond(408, format!("timed out reading {what}"))
    } else if e.kind() == ErrorKind::InvalidData {
        HttpError::respond(431, format!("{what} line too long"))
    } else {
        HttpError::Drop(format!("reading {what}: {e}"))
    }
}

/// Read and parse one request. The caller owns the socket's (short,
/// per-read) timeout; `deadline` bounds the **whole exchange** — once
/// it passes, the next slow read fails and maps to `408`. `None` makes
/// any single read timeout immediately fatal.
pub fn read_request<R: Read>(
    r: &mut R,
    limits: &HttpLimits,
    deadline: Option<Instant>,
) -> Result<ReadOutcome, HttpError> {
    // Fail-point on the request read path: an injected error models a
    // connection dying mid-request (dropped, not answered).
    if let Err(e) = crate::util::faults::check("http.read") {
        return Err(HttpError::Drop(format!("{e}")));
    }
    let mut bytes_read: u64 = 0;

    // Request line.
    let line = match read_line_raw(r, limits.max_line_bytes, deadline) {
        Ok(None) => return Ok(ReadOutcome::Closed),
        Ok(Some(l)) => l,
        Err(e) => return Err(line_err(e, "request")),
    };
    bytes_read += line.len() as u64 + 2;
    let line = String::from_utf8(line)
        .map_err(|_| HttpError::respond(400, "request line is not UTF-8"))?;
    let mut parts = line.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(t), Some(v)) if parts.next().is_none() => {
            (m.to_string(), t.to_string(), v.to_string())
        }
        _ => {
            return Err(HttpError::respond(
                400,
                format!("malformed request line {line:?}"),
            ))
        }
    };
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::respond(
            505,
            format!("unsupported version {version:?}"),
        ));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target, String::new()),
    };

    // Headers.
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line_raw(r, limits.max_line_bytes, deadline) {
            Ok(None) => return Err(HttpError::Drop("eof in headers".into())),
            Ok(Some(l)) => l,
            Err(e) => return Err(line_err(e, "header")),
        };
        bytes_read += line.len() as u64 + 2;
        if line.is_empty() {
            break;
        }
        if headers.len() >= limits.max_headers {
            return Err(HttpError::respond(431, "too many headers"));
        }
        let line = String::from_utf8(line)
            .map_err(|_| HttpError::respond(400, "header is not UTF-8"))?;
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::respond(400, format!("malformed header {line:?}")));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }

    // Keep-alive: HTTP/1.1 defaults on, HTTP/1.0 defaults off.
    let mut keep_alive = version == "HTTP/1.1";
    if let Some(c) = headers.iter().find(|(n, _)| n == "connection") {
        match c.1.to_ascii_lowercase().as_str() {
            "close" => keep_alive = false,
            "keep-alive" => keep_alive = true,
            _ => {}
        }
    }

    // Body.
    let header_of = |name: &str| {
        headers
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.clone())
    };
    if header_of("transfer-encoding").is_some() {
        return Err(HttpError::respond(501, "transfer-encoding not supported"));
    }
    let mut body = Vec::new();
    match header_of("content-length") {
        Some(v) => {
            let len: usize = v
                .parse()
                .map_err(|_| HttpError::respond(400, format!("bad content-length {v:?}")))?;
            if len > limits.max_body_bytes {
                return Err(HttpError::respond(
                    413,
                    format!(
                        "body of {len} bytes exceeds the {}-byte limit",
                        limits.max_body_bytes
                    ),
                ));
            }
            body.resize(len, 0);
            if let Err(e) = read_full(r, &mut body, deadline) {
                return Err(if is_timeout(&e) {
                    HttpError::respond(408, "timed out reading body")
                } else {
                    HttpError::Drop(format!("reading body: {e}"))
                });
            }
            bytes_read += len as u64;
        }
        None => {
            if method == "POST" || method == "PUT" {
                return Err(HttpError::respond(411, "content-length required"));
            }
        }
    }

    Ok(ReadOutcome::Request(Request {
        method,
        path,
        query,
        headers,
        body,
        keep_alive,
        bytes_read,
    }))
}

/// A response ready to serialize.
#[derive(Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Response payload.
    pub body: Vec<u8>,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// When set, this response carries the claimed result of job `id`:
    /// if the write fails, the connection handler re-parks the body so
    /// a retried `GET /v1/jobs/{id}` can claim it again instead of the
    /// result being dropped.
    pub repark_id: Option<u64>,
    /// When set, a `Retry-After: <secs>` header is emitted — the
    /// server's backoff hint on `503` responses, computed from queue
    /// depth so a saturated replica tells clients *when* to come back
    /// instead of letting them hammer it.
    pub retry_after: Option<u64>,
}

impl Response {
    /// A JSON response.
    pub fn json(status: u16, v: &Json) -> Response {
        Response::json_bytes(status, v.to_string().into_bytes())
    }

    /// A JSON response from pre-rendered body bytes (re-parked results
    /// are stored rendered).
    pub fn json_bytes(status: u16, body: Vec<u8>) -> Response {
        Response {
            status,
            body,
            content_type: "application/json",
            repark_id: None,
            retry_after: None,
        }
    }

    /// A JSON error envelope `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    /// Mark this response as carrying claimed job result `id` (see
    /// [`Response::repark_id`]).
    pub fn with_repark(mut self, id: u64) -> Response {
        self.repark_id = Some(id);
        self
    }

    /// Attach a `Retry-After` hint, seconds (see
    /// [`Response::retry_after`]).
    pub fn with_retry_after(mut self, secs: u64) -> Response {
        self.retry_after = Some(secs);
        self
    }

    /// Serialize status line, headers and body; returns bytes written.
    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<u64> {
        let retry = match self.retry_after {
            Some(secs) => format!("retry-after: {secs}\r\n"),
            None => String::new(),
        };
        let head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n{}connection: {}\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            retry,
            if keep_alive { "keep-alive" } else { "close" },
        );
        // Torn-write fail-point ("http.write"): a truncated head makes
        // the peer's parse fail, exercising the re-park path for
        // claimed results.
        let cap = crate::util::faults::write_len("http.write", head.len())?;
        if cap < head.len() {
            w.write_all(&head.as_bytes()[..cap])?;
            w.flush()?;
            return Err(std::io::Error::new(
                ErrorKind::WriteZero,
                "injected partial response write",
            ));
        }
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()?;
        Ok((head.len() + self.body.len()) as u64)
    }
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        410 => "Gone",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Status",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(text: &str) -> Result<ReadOutcome, HttpError> {
        read_request(
            &mut Cursor::new(text.as_bytes().to_vec()),
            &HttpLimits::default(),
            None,
        )
    }

    fn request(text: &str) -> Request {
        match parse(text).unwrap() {
            ReadOutcome::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        }
    }

    #[test]
    fn parses_get() {
        let r = request("GET /v1/jobs/7?timeout_s=2 HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(r.method, "GET");
        assert_eq!(r.path, "/v1/jobs/7");
        assert_eq!(r.query, "timeout_s=2");
        assert_eq!(r.header("host"), Some("x"));
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let r = request("POST /v1/jobs HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcd");
        assert_eq!(r.method, "POST");
        assert_eq!(r.body, b"abcd");
        assert!(r.bytes_read >= 4);
    }

    #[test]
    fn keep_alive_rules() {
        assert!(request("GET / HTTP/1.1\r\n\r\n").keep_alive);
        assert!(!request("GET / HTTP/1.1\r\nConnection: close\r\n\r\n").keep_alive);
        assert!(!request("GET / HTTP/1.0\r\n\r\n").keep_alive);
        assert!(request("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").keep_alive);
    }

    fn respond_status(r: Result<ReadOutcome, HttpError>) -> u16 {
        match r {
            Err(HttpError::Respond { status, .. }) => status,
            other => panic!("expected Respond, got {other:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert_eq!(respond_status(parse("GARBAGE\r\n\r\n")), 400);
        assert_eq!(respond_status(parse("GET / SMTP/9\r\n\r\n")), 505);
        assert_eq!(respond_status(parse("GET / HTTP/1.1\r\nnocolon\r\n\r\n")), 400);
        assert_eq!(respond_status(parse("POST / HTTP/1.1\r\n\r\n")), 411);
        assert_eq!(
            respond_status(parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n")),
            501
        );
    }

    #[test]
    fn caps_body_size() {
        let limits = HttpLimits { max_body_bytes: 8, ..Default::default() };
        let text = "POST / HTTP/1.1\r\nContent-Length: 9\r\n\r\n123456789";
        let err = read_request(&mut Cursor::new(text.as_bytes().to_vec()), &limits, None);
        assert_eq!(respond_status(err), 413);
    }

    #[test]
    fn caps_header_line_and_count() {
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(100_000));
        assert_eq!(respond_status(parse(&long)), 431);
        let many: String = (0..100).map(|i| format!("h{i}: v\r\n")).collect();
        let text = format!("GET / HTTP/1.1\r\n{many}\r\n");
        assert_eq!(respond_status(parse(&text)), 431);
    }

    #[test]
    fn clean_eof_is_closed() {
        assert!(matches!(parse("").unwrap(), ReadOutcome::Closed));
    }

    /// Yields its bytes one at a time, then stalls with `WouldBlock`
    /// forever — a byte-trickling (slow-loris) client.
    struct Trickle {
        data: Vec<u8>,
        pos: usize,
        stall_between: bool,
        stalled: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.stall_between && !self.stalled {
                self.stalled = true;
                return Err(std::io::Error::new(ErrorKind::WouldBlock, "stall"));
            }
            self.stalled = false;
            if self.pos < self.data.len() {
                buf[0] = self.data[self.pos];
                self.pos += 1;
                Ok(1)
            } else {
                Err(std::io::Error::new(ErrorKind::WouldBlock, "stall"))
            }
        }
    }

    #[test]
    fn expired_deadline_stops_a_trickling_request() {
        // Partial request line, then an endless stall: the first slow
        // slice after the deadline maps to 408 — the parser never spins.
        let mut r = Trickle {
            data: b"GET / HT".to_vec(),
            pos: 0,
            stall_between: false,
            stalled: false,
        };
        let err = read_request(&mut r, &HttpLimits::default(), Some(Instant::now()));
        assert_eq!(respond_status(err), 408);
    }

    #[test]
    fn future_deadline_rides_out_slow_slices() {
        // A timeout slice between every byte is fine while the
        // whole-exchange deadline lies ahead.
        let mut r = Trickle {
            data: b"POST /v1/jobs HTTP/1.1\r\nContent-Length: 2\r\n\r\nok".to_vec(),
            pos: 0,
            stall_between: true,
            stalled: false,
        };
        let deadline = Instant::now() + std::time::Duration::from_secs(60);
        match read_request(&mut r, &HttpLimits::default(), Some(deadline)).unwrap() {
            ReadOutcome::Request(req) => assert_eq!(req.body, b"ok"),
            other => panic!("expected a request, got {other:?}"),
        }
    }

    #[test]
    fn idle_past_the_limit_releases_the_slot_without_spinning() {
        // Regression for the keep-alive gap: a connection that goes
        // idle and never sends another byte must be closed once the
        // idle limit passes — not poll forever on a worker slot. The
        // fake peek stalls like an idle socket; no real time passes.
        let poll = std::time::Duration::from_millis(200);
        let limit = std::time::Duration::from_secs(1);
        let mut probes = 0u32;
        let out = idle_wait(
            &mut || {
                probes += 1;
                Err(std::io::Error::new(ErrorKind::WouldBlock, "idle"))
            },
            poll,
            limit,
            &mut || false,
        );
        assert_eq!(out, IdleOutcome::Close);
        // Exactly limit/poll probes: the loop neither spins past the
        // limit nor gives up early.
        assert_eq!(probes, 5);
    }

    #[test]
    fn idle_wait_ready_shutdown_and_hangup() {
        let poll = std::time::Duration::from_millis(200);
        let limit = std::time::Duration::from_secs(1);
        // A waiting byte wins immediately.
        let out = idle_wait(&mut || Ok(1), poll, limit, &mut || false);
        assert_eq!(out, IdleOutcome::Ready);
        // A shutdown tick closes before the transport is even probed.
        let mut probed = false;
        let out = idle_wait(
            &mut || {
                probed = true;
                Ok(1)
            },
            poll,
            limit,
            &mut || true,
        );
        assert_eq!(out, IdleOutcome::Close);
        assert!(!probed);
        // Peer hangup (peek reads 0 bytes) closes.
        let out = idle_wait(&mut || Ok(0), poll, limit, &mut || false);
        assert_eq!(out, IdleOutcome::Close);
        // A non-timeout socket error closes.
        let out = idle_wait(
            &mut || Err(std::io::Error::new(ErrorKind::ConnectionReset, "rst")),
            poll,
            limit,
            &mut || false,
        );
        assert_eq!(out, IdleOutcome::Close);
    }

    #[test]
    fn lifecycle_status_reasons() {
        assert_eq!(reason(409), "Conflict");
        assert_eq!(reason(410), "Gone");
    }

    #[test]
    fn response_serializes() {
        let mut out = Vec::new();
        let n = Response::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .write_to(&mut out, true)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 11"), "{text}");
        assert!(text.contains("connection: keep-alive"), "{text}");
        assert!(text.ends_with("{\"ok\":true}"), "{text}");
        assert_eq!(n, text.len() as u64);
        let mut out = Vec::new();
        Response::error(503, "queue full").write_to(&mut out, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"), "{text}");
        assert!(text.contains("connection: close"), "{text}");
        assert!(!text.contains("retry-after"), "{text}");
    }

    #[test]
    fn retry_after_header_renders_when_set() {
        let mut out = Vec::new();
        Response::error(503, "queue full")
            .with_retry_after(7)
            .write_to(&mut out, false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("retry-after: 7\r\n"), "{text}");
        // The header lands before the blank line separating the body.
        let head_end = text.find("\r\n\r\n").unwrap();
        assert!(text.find("retry-after").unwrap() < head_end);
    }
}
