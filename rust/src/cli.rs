//! Declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, and
//! subcommands, with generated `--help` text. Used by `rust/src/main.rs`
//! and the examples.

use std::collections::BTreeMap;

use crate::util::{Error, Result};

/// One declared option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Option name (matched as `--name`).
    pub name: &'static str,
    /// Help text shown in `--help` output.
    pub help: &'static str,
    /// `true` for boolean flags (no value).
    pub is_flag: bool,
    /// Default value; `None` makes the option required.
    pub default: Option<&'static str>,
}

/// A declarative argument parser.
#[derive(Debug, Clone, Default)]
pub struct ArgSpec {
    /// One-line tool description shown at the top of `--help`.
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl ArgSpec {
    /// Start a spec with the given description.
    pub fn new(about: &'static str) -> ArgSpec {
        ArgSpec { about, opts: Vec::new() }
    }

    /// Declare a boolean flag (`--name`, no value).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: true, default: None });
        self
    }

    /// Declare a valued option with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.opts
            .push(OptSpec { name, help, is_flag: false, default: Some(default) });
        self
    }

    /// Required option (no default).
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, is_flag: false, default: None });
        self
    }

    /// Render the `--help` text for `prog`.
    pub fn usage(&self, prog: &str) -> String {
        let mut out = format!("{}\n\nUSAGE: {prog} [OPTIONS]\n\nOPTIONS:\n", self.about);
        for o in &self.opts {
            let lhs = if o.is_flag {
                format!("--{}", o.name)
            } else if let Some(d) = o.default {
                format!("--{} <value = {d}>", o.name)
            } else {
                format!("--{} <value, required>", o.name)
            };
            out.push_str(&format!("  {lhs:<34} {}\n", o.help));
        }
        out.push_str("  --help                             show this message\n");
        out
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positional: Vec<String> = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Ok(Args { help: true, ..Args::default() });
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| Error::Invalid(format!("unknown option --{name}")))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(Error::Invalid(format!("--{name} takes no value")));
                    }
                    flags.push(name);
                } else {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Invalid(format!("--{name} needs a value")))?
                        }
                    };
                    values.insert(name, value);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults; detect missing required options.
        for o in &self.opts {
            if o.is_flag || values.contains_key(o.name) {
                continue;
            }
            match o.default {
                Some(d) => {
                    values.insert(o.name.to_string(), d.to_string());
                }
                None => {
                    return Err(Error::Invalid(format!("missing required --{}", o.name)));
                }
            }
        }
        Ok(Args { values, flags, positional, help: false })
    }
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Positional (non-option) arguments, in order.
    pub positional: Vec<String>,
    /// `true` when `--help`/`-h` was seen (parsing short-circuits).
    pub help: bool,
}

impl Args {
    /// Raw string value of a declared option (panics on undeclared names
    /// — that is a programming error in the spec, not user input).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option {name} not declared"))
    }

    /// Parse an option's value as `usize`.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)
            .parse()
            .map_err(|_| {
                Error::Invalid(format!("--{name}: expected integer, got {:?}", self.get(name)))
            })
    }

    /// Parse an option's value as `u64`.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)
            .parse()
            .map_err(|_| {
                Error::Invalid(format!("--{name}: expected integer, got {:?}", self.get(name)))
            })
    }

    /// Parse an option's value as `f64`.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)
            .parse()
            .map_err(|_| {
                Error::Invalid(format!("--{name}: expected number, got {:?}", self.get(name)))
            })
    }

    /// Whether a declared flag was present.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test tool")
            .opt("k", "10", "rank")
            .opt("seed", "0", "rng seed")
            .req("input", "input path")
            .flag("quick", "thin grids")
    }

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_all_forms() {
        let a = spec()
            .parse(&sv(&["--k", "25", "--quick", "--input=data.bin", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 25);
        assert_eq!(a.get("seed"), "0"); // default
        assert_eq!(a.get("input"), "data.bin");
        assert!(a.has_flag("quick"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&sv(&["--k", "3"])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(spec().parse(&sv(&["--bogus", "1", "--input", "x"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(spec().parse(&sv(&["--quick=1", "--input", "x"])).is_err());
    }

    #[test]
    fn help_short_circuits() {
        let a = spec().parse(&sv(&["--help"])).unwrap();
        assert!(a.help);
        assert!(spec().usage("prog").contains("--input"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = spec().parse(&sv(&["--k", "lots", "--input", "x"])).unwrap();
        assert!(a.get_usize("k").is_err());
    }
}
