//! # srsvd — Shifted Randomized Singular Value Decomposition
//!
//! A production-shaped reproduction of *"Shifted Randomized Singular
//! Value Decomposition"* (Ali Basirat, 2019), which extends the
//! randomized SVD of Halko, Martinsson & Tropp (2011) to factorize a
//! shifted matrix `X̄ = X − μ·1ᵀ` **without ever materializing `X̄`** —
//! the key case being PCA of large sparse matrices whose mean-centering
//! would densify them.
//!
//! ## Layout
//!
//! * [`linalg`] — from-scratch dense & sparse linear algebra: blocked
//!   GEMM, Householder/MGS QR, rank-1 QR-update, one-sided Jacobi SVD,
//!   CSR sparse kernels. No BLAS/LAPACK dependency. Includes
//!   [`linalg::stream`], the out-of-core layer: a [`linalg::MatrixSource`]
//!   yields row blocks on demand (on-disk file, chunked generator, or
//!   in-memory adapter) and [`linalg::Streamed`] runs every product
//!   block-at-a-time under a `[stream]` memory budget with results
//!   byte-identical to the in-memory path — with a double-buffered
//!   prefetch pipeline (reads overlap the GEMM) and, under
//!   [`svd::PassPolicy::Fused`], a fused Gram sweep that cuts a
//!   factorization from `2 + 2q` source passes to `q + 2`.
//! * [`parallel`] — the execution subsystem: chunked, self-scheduling
//!   thread pools (std threads + channels only), split into a **cpu
//!   pool** for compute (`SRSVD_THREADS` / `[parallel] threads`,
//!   default all cores) and an **io pool** for blocking work —
//!   streamed-prefetch readers and the server's connection workers
//!   (`SRSVD_IO_THREADS` / `[parallel] io_threads`). The GEMM /
//!   rank-1 / CSR hot paths partition their *output rows* over the cpu
//!   pool, which keeps results bit-identical across every pool size —
//!   seeded experiments stay reproducible no matter the machine. The
//!   GEMM inner loops themselves dispatch to runtime-detected SIMD
//!   microkernels ([`linalg::gemm::kernels`]): the default
//!   [`svd::Precision::Exact`] tier preserves scalar evaluation order
//!   exactly, while [`svd::Precision::Fast`] trades last-ulps
//!   reproducibility for packed AVX2/FMA panels (`SRSVD_SIMD=off`
//!   forces the portable scalar path).
//! * [`svd`] — the paper's algorithms: deterministic SVD oracle,
//!   the RSVD baseline, and [`svd::ShiftedRsvd`] (Algorithm 1) with
//!   dense and sparse paths.
//! * [`rng`] — PRNG suite (xoshiro256++, Gaussian, Zipf) seeding every
//!   experiment deterministically.
//! * [`data`] — synthetic workload generators standing in for the
//!   paper's datasets (see DESIGN.md §Substitutions).
//! * [`stats`] — paired t-tests (Student-t CDF via incomplete beta),
//!   win-rates, descriptive statistics.
//! * [`runtime`] — PJRT executor: loads the AOT HLO artifacts produced
//!   by `python/compile/aot.py` and runs them on the CPU client. The
//!   PJRT bindings need the external `xla` wrapper crate, so the real
//!   executor sits behind the off-by-default `pjrt` cargo feature; the
//!   default (zero-dependency) build ships a stub that reports the
//!   runtime as unavailable and the service runs native-only.
//! * [`coordinator`] — the factorization service: job queue, worker
//!   pool, config router (artifact vs native engine), metrics.
//! * [`server`] — the network service layer: a zero-dependency
//!   HTTP/1.1 server (`std::net` + the in-tree JSON) in front of the
//!   coordinator, plus the blocking client. Clients ship compact job
//!   *specs* — generator seeds, server-side file paths, CSR skeletons —
//!   because S-RSVD never needs the shifted matrix materialized;
//!   queue-full maps to `503` backpressure. `srsvd serve --listen`.
//! * [`router`] — the routing tier: a sharding reverse proxy in front
//!   of several coordinator replicas. Cacheable specs go to their
//!   rendezvous-hash owner (so result caches stay warm), uncacheable
//!   ones round-robin; a background health loop marks dead replicas
//!   down and submits fail over to the next candidate.
//!   `srsvd route --listen --replicas a,b,c`.
//! * [`util::faults`] / [`util::retry`] / [`svd::checkpoint`] — the
//!   resilience layer: a process-wide fail-point registry (zero-cost
//!   when disarmed; armed via `SRSVD_FAULTS`, `[faults] spec`, or
//!   `--faults`) drives chaos tests against every I/O boundary; a
//!   typed [`util::retry::RetryPolicy`] (`[retry]` config) backs
//!   transient-read, client, and router retries — applied only where
//!   at-most-once semantics permit; and sweep-granular checkpoints
//!   ([`svd::Checkpointer`], `[svd] checkpoint_dir`) plus the server's
//!   accepted-job journal (`[server] journal_dir`) make streamed
//!   factorizations crash-safe with byte-identical resume.
//! * [`experiments`] — one runner per paper figure/table, shared by
//!   `examples/` and `benches/`.
//! * [`bench`] / [`prop`] — mini criterion / proptest substitutes
//!   (the build environment is offline; see DESIGN.md).
//!
//! ## Quickstart
//!
//! ```no_run
//! use srsvd::prelude::*;
//!
//! let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let x = Dense::from_fn(100, 1000, |_, _| rng.next_uniform());
//! let cfg = SvdConfig::paper(10).with_fixed_power(1);
//! let fact = ShiftedRsvd::new(cfg).factorize_mean_centered(&x, &mut rng).unwrap();
//! println!("top singular values: {:?}", &fact.s[..5]);
//! ```
//!
//! Prefer accuracy over a hand-picked sweep count? Swap the fixed `q`
//! for the adaptive stopping criterion and let the dynamic-shift loop
//! decide when the spectrum has settled:
//!
//! ```no_run
//! use srsvd::prelude::*;
//!
//! let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let x = Dense::from_fn(100, 1000, |_, _| rng.next_uniform());
//! let cfg = SvdConfig::paper(10).with_tolerance(1e-3, 32);
//! let (fact, report) = ShiftedRsvd::new(cfg)
//!     .factorize_with_report(&x, &x.row_means(), &mut rng)
//!     .unwrap();
//! println!("{} sweeps, pve {:?}", report.sweeps_used, report.achieved_pve);
//! # let _ = fact;
//! ```
//!
//! For matrices that do not fit in RAM, swap the [`linalg::Dense`] input
//! for a [`linalg::Streamed`] source — same API, same (byte-identical)
//! results:
//!
//! ```no_run
//! use srsvd::prelude::*;
//!
//! let src = GeneratorSource::new(200_000, 4_096, Distribution::Uniform, 0).unwrap();
//! let x = Streamed::new(src, &StreamConfig { block_rows: 0, budget_mb: 64, prefetch: true });
//! let mut rng = Xoshiro256pp::seed_from_u64(0);
//! let fact = ShiftedRsvd::new(SvdConfig::paper(10))
//!     .factorize_mean_centered(&x, &mut rng)
//!     .unwrap();
//! println!("top singular values: {:?}", &fact.s[..5]);
//! ```
//!
//! The repository-level companion documents — `README.md` for the tour
//! and `docs/ARCHITECTURE.md` for the layer-by-layer manual (L0 kernels
//! → L1 algorithms → L2 runtime → L3 service, the job lifecycle, and
//! the determinism guarantee) — are the places to start reading.

#![warn(missing_docs)]

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod router;
pub mod runtime;
pub mod server;
pub mod stats;
pub mod svd;
pub mod util;

/// Convenience re-exports for the common API surface.
pub mod prelude {
    pub use crate::data::{DataSpec, Distribution};
    pub use crate::linalg::{Dense, Csr};
    pub use crate::linalg::stream::{
        FileSource, GeneratorSource, InMemorySource, MatrixSource, StreamConfig, Streamed,
    };
    pub use crate::rng::{Rng, Xoshiro256pp};
    pub use crate::svd::{
        Checkpointer, Factorization, MatVecOps, PassPolicy, Pca, Precision, Rsvd, ShiftedRsvd,
        StopCriterion, SvdConfig, SvdEngine, SweepReport,
    };
    pub use crate::util::retry::RetryPolicy;
}
