//! Table 1: image data (digits, faces) and word data, with the paper's
//! statistics — MSE per algorithm, t-test p-values for H₀¹ (MSE pairs
//! over repeated runs) and H₀² (per-column errors), and win-rates.

use crate::bench::{fmt_sci, Table};
use crate::data::{
    cooccurrence_matrix, digits_matrix, faces_matrix, CorpusSpec, DigitsSpec, FacesSpec,
};
use crate::linalg::{Csr, Dense};
use crate::rng::Xoshiro256pp;
use crate::stats::{mean, paired_t_test, win_rate};
use crate::svd::{column_errors, Rsvd, ShiftedRsvd, SvdConfig};

use super::RunMetrics;

/// One Table-1 cell: aggregate statistics over `runs` repetitions.
#[derive(Debug, Clone)]
pub struct Table1Stats {
    /// Dataset label (table row name).
    pub name: String,
    /// Mean MSE of S-RSVD over the runs.
    pub mse_srsvd: f64,
    /// Mean MSE of RSVD over the runs.
    pub mse_rsvd: f64,
    /// H₀¹ p-value: paired t-test on the per-run MSE pairs.
    pub p1: f64,
    /// H₀² p-value: paired t-test on per-column errors (final run).
    pub p2: f64,
    /// Win-rate of S-RSVD over columns (final run).
    pub wr_srsvd: f64,
    /// Number of repetitions aggregated.
    pub runs: usize,
}

impl Table1Stats {
    /// RSVD's complementary win-rate.
    pub fn wr_rsvd(&self) -> f64 {
        1.0 - self.wr_srsvd
    }
}

/// Run the Table-1 protocol on a dense matrix: `runs` repetitions with
/// different seeds, both algorithms scored per the §5 protocol.
pub fn table1_dense(name: &str, x: &Dense, k: usize, runs: usize, seed: u64) -> Table1Stats {
    let cfg = SvdConfig::paper(k);
    let mut mses_s = Vec::with_capacity(runs);
    let mut mses_r = Vec::with_capacity(runs);
    let mut last: Option<(RunMetrics, RunMetrics)> = None;
    for t in 0..runs {
        let s = super::run_srsvd(x, cfg, seed ^ (t as u64 * 0x9E37));
        let r = super::run_rsvd(x, cfg, seed ^ (t as u64 * 0x9E37));
        mses_s.push(s.mse);
        mses_r.push(r.mse);
        last = Some((s, r));
    }
    let (s_last, r_last) = last.expect("runs >= 1");
    let p1 = if runs >= 2 {
        paired_t_test(&mses_s, &mses_r).p
    } else {
        f64::NAN
    };
    let p2 = paired_t_test(&s_last.col_errors, &r_last.col_errors).p;
    Table1Stats {
        name: name.to_string(),
        mse_srsvd: mean(&mses_s),
        mse_rsvd: mean(&mses_r),
        p1,
        p2,
        wr_srsvd: win_rate(&s_last.col_errors, &r_last.col_errors),
        runs,
    }
}

/// Word-data variant: sparse input, S-RSVD stays sparse; RSVD factorizes
/// the off-center matrix through the same operator (no densification
/// needed since μ = 0 for RSVD — the *centered* RSVD baseline is what
/// the efficiency bench measures).
pub fn table1_sparse(name: &str, x: &Csr, k: usize, runs: usize, seed: u64) -> Table1Stats {
    let cfg = SvdConfig::paper(k);
    let mu = x.row_means();
    let mut mses_s = Vec::with_capacity(runs);
    let mut mses_r = Vec::with_capacity(runs);
    let mut last_cols: Option<(Vec<f64>, Vec<f64>)> = None;
    for t in 0..runs {
        let run_seed = seed ^ (t as u64 * 0x9E37);
        // S-RSVD on the implicitly centered matrix.
        let mut rng = Xoshiro256pp::seed_from_u64(run_seed);
        let f_s = ShiftedRsvd::new(cfg).factorize(x, &mu, &mut rng).expect("srsvd");
        mses_s.push(x.shifted_mse(&mu, &f_s.u, &f_s.s, &f_s.v));
        // RSVD on the off-center matrix, scored against X (μ = 0).
        let mut rng = Xoshiro256pp::seed_from_u64(run_seed);
        let f_r = Rsvd::new(cfg).factorize(x, &mut rng).expect("rsvd");
        let zeros = vec![0.0; x.rows()];
        mses_r.push(x.shifted_mse(&zeros, &f_r.u, &f_r.s, &f_r.v));
        if t + 1 == runs {
            // Per-column errors via the dense path (scoring only; kept
            // feasible by the reduced default sizes — the factorizations
            // above never densify).
            let xd = x.to_dense();
            let cols_s = column_errors(&xd, &mu, &f_s);
            let cols_r = column_errors(&xd, &zeros, &f_r);
            last_cols = Some((cols_s, cols_r));
        }
    }
    let (cols_s, cols_r) = last_cols.expect("runs >= 1");
    let p1 = if runs >= 2 {
        paired_t_test(&mses_s, &mses_r).p
    } else {
        f64::NAN
    };
    Table1Stats {
        name: name.to_string(),
        mse_srsvd: mean(&mses_s),
        mse_rsvd: mean(&mses_r),
        p1,
        p2: paired_t_test(&cols_s, &cols_r).p,
        wr_srsvd: win_rate(&cols_s, &cols_r),
        runs,
    }
}

/// The digits experiment (Table 1 left, col 1). Paper: 64×1979, k=10.
pub fn digits_stats(count: usize, runs: usize, seed: u64) -> Table1Stats {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let x = digits_matrix(DigitsSpec { count, ..Default::default() }, &mut rng);
    table1_dense("digits", &x, 10, runs, seed ^ 0xD161)
}

/// The faces experiment (Table 1 left, col 2). Paper: 62500×13233 LFW;
/// default here 1024×400 synthetic (same regime, see DESIGN.md).
pub fn faces_stats(spec: FacesSpec, runs: usize, seed: u64) -> Table1Stats {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let x = faces_matrix(spec, &mut rng);
    table1_dense("faces", &x, 10, runs, seed ^ 0xFACE)
}

/// One word-data column of Table 1 right: m=1000 contexts × n targets.
pub fn words_stats(targets: usize, pairs: usize, k: usize, runs: usize, seed: u64) -> Table1Stats {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let x = cooccurrence_matrix(
        CorpusSpec { targets, pairs, ..Default::default() },
        &mut rng,
    );
    table1_sparse(&format!("words n={targets}"), &x, k, runs, seed ^ 0x30D5)
}

/// Render a set of Table-1 cells in the paper's row layout.
pub fn render(stats: &[Table1Stats]) -> String {
    let mut header = vec!["metric".to_string()];
    header.extend(stats.iter().map(|s| s.name.clone()));
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let row = |label: &str, f: &dyn Fn(&Table1Stats) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(stats.iter().map(|s| f(s)));
        cells
    };
    t.row(&row("MSE of S-RSVD", &|s| fmt_sci(s.mse_srsvd)));
    t.row(&row("MSE of RSVD", &|s| fmt_sci(s.mse_rsvd)));
    t.row(&row("p1-value", &|s| format!("{:.3}", s.p1)));
    t.row(&row("p2-value", &|s| format!("{:.3}", s.p2)));
    t.row(&row("WR of S-RSVD", &|s| format!("{:.0}%", s.wr_srsvd * 100.0)));
    t.row(&row("WR of RSVD", &|s| format!("{:.0}%", s.wr_rsvd() * 100.0)));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_srsvd_wins() {
        let s = digits_stats(200, 3, 1);
        assert!(s.mse_srsvd < s.mse_rsvd, "{s:?}");
        assert!(s.wr_srsvd > 0.5, "{s:?}");
        assert!(s.p2 < 0.05, "{s:?}");
    }

    #[test]
    fn faces_srsvd_wins_big() {
        let spec = FacesSpec { side: 16, count: 80, rank: 10, noise: 5.0 };
        let s = faces_stats(spec, 3, 2);
        assert!(s.mse_srsvd < s.mse_rsvd, "{s:?}");
        // The faces regime has the largest centering advantage.
        assert!(s.wr_srsvd > 0.6, "wr {}", s.wr_srsvd);
    }

    #[test]
    fn words_srsvd_wins() {
        let s = words_stats(500, 40_000, 16, 3, 3);
        assert!(s.mse_srsvd < s.mse_rsvd, "{s:?}");
        assert!(s.wr_srsvd > 0.5, "{s:?}");
    }

    #[test]
    fn render_has_paper_rows() {
        let s = digits_stats(100, 2, 4);
        let out = render(&[s]);
        for needle in ["MSE of S-RSVD", "p1-value", "WR of RSVD"] {
            assert!(out.contains(needle), "{out}");
        }
    }
}
