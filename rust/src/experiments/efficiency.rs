//! §4's efficiency claim — the experiment the paper argues but does not
//! plot: for sparse X with non-zero mean, S-RSVD runs in
//! O(nnz·k + (m+n)k²) while RSVD must densify X̄ and pay O(mnk), plus
//! the O(mn) materialization itself.
//!
//! The bench sweeps n (and density) and times three legs:
//!   1. S-RSVD on sparse X (implicit shift) — the paper's algorithm;
//!   2. RSVD on the densified X̄ (materialize + factorize) — the baseline;
//!   3. RSVD on sparse X *without* centering — the accuracy-losing dodge.

use crate::linalg::Csr;
use crate::rng::Xoshiro256pp;
use crate::svd::{Rsvd, ShiftedRsvd, SvdConfig};
use crate::util::timer::Timer;

/// Timing row for one (n, density) point.
#[derive(Debug, Clone)]
pub struct EffRow {
    /// Column count of this sweep point.
    pub n: usize,
    /// Stored entries of the sparse input.
    pub nnz: usize,
    /// Seconds: S-RSVD on sparse X with implicit mean shift.
    pub srsvd_sparse_s: f64,
    /// Seconds: densify X̄ then RSVD (includes materialization).
    pub rsvd_densified_s: f64,
    /// Seconds: RSVD on sparse X, no centering (accuracy baseline).
    pub rsvd_sparse_s: f64,
    /// Peak extra f64s the densified path allocates (m·n).
    pub densified_elems: usize,
}

impl EffRow {
    /// S-RSVD speedup over the densify-then-RSVD baseline.
    pub fn speedup(&self) -> f64 {
        self.rsvd_densified_s / self.srsvd_sparse_s.max(1e-12)
    }
}

/// Run the sweep: m fixed, n and density per point.
pub fn sweep(m: usize, points: &[(usize, f64)], k: usize, seed: u64) -> Vec<EffRow> {
    let cfg = SvdConfig::paper(k);
    points
        .iter()
        .map(|&(n, density)| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed ^ n as u64);
            let x = Csr::random(m, n, density, &mut rng, |r| r.next_uniform() + 0.05);
            let mu = x.row_means();

            let t = Timer::start();
            let mut r1 = Xoshiro256pp::seed_from_u64(seed ^ 1);
            ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut r1).expect("srsvd");
            let srsvd_sparse_s = t.elapsed_secs();

            let t = Timer::start();
            let mut r2 = Xoshiro256pp::seed_from_u64(seed ^ 1);
            Rsvd::new(cfg)
                .factorize_centered_sparse(&x, &mut r2)
                .expect("rsvd densified");
            let rsvd_densified_s = t.elapsed_secs();

            let t = Timer::start();
            let mut r3 = Xoshiro256pp::seed_from_u64(seed ^ 1);
            Rsvd::new(cfg).factorize(&x, &mut r3).expect("rsvd sparse");
            let rsvd_sparse_s = t.elapsed_secs();

            EffRow {
                n,
                nnz: x.nnz(),
                srsvd_sparse_s,
                rsvd_densified_s,
                rsvd_sparse_s,
                densified_elems: m * n,
            }
        })
        .collect()
}

/// Render the sweep as a table with the headline speedup column.
pub fn render(rows: &[EffRow]) -> String {
    let mut t = crate::bench::Table::new(&[
        "n", "nnz", "S-RSVD(sparse)", "RSVD(densified)", "RSVD(no-center)", "speedup",
    ]);
    for r in rows {
        t.row(&[
            r.n.to_string(),
            r.nnz.to_string(),
            crate::util::timer::fmt_duration(r.srsvd_sparse_s),
            crate::util::timer::fmt_duration(r.rsvd_densified_s),
            crate::util::timer::fmt_duration(r.rsvd_sparse_s),
            format!("{:.1}x", r.speedup()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparse_shifted_beats_densified_at_scale() {
        // Modest scale so the test stays fast; the full sweep lives in
        // the `efficiency` bench.
        let rows = sweep(200, &[(4000, 0.005)], 8, 1);
        let r = &rows[0];
        assert!(
            r.speedup() > 1.5,
            "expected sparse-shifted to win: {r:?}"
        );
    }

    #[test]
    fn render_contains_speedup() {
        let rows = sweep(50, &[(500, 0.02)], 4, 2);
        assert!(render(&rows).contains('x'));
    }
}
