//! Experiment runners — one per figure/table in the paper's §5.
//!
//! Shared by `examples/full_eval.rs` and every `rust/benches/*` target
//! so the numbers in EXPERIMENTS.md regenerate from a single code path.
//!
//! ## Scoring protocol (paper §5)
//!
//! Both algorithms are scored on how well k components reconstruct the
//! *data*, each with its own model of it:
//!
//! * **S-RSVD** factorizes `X̄ = X − μ1ᵀ` implicitly; its reconstruction
//!   is `μ1ᵀ + UΣVᵀ`, so the error is `‖X̄ − UΣVᵀ‖²/n`.
//! * **RSVD** factorizes the off-center `X` directly; its reconstruction
//!   is `UΣVᵀ`, so the error is `‖X − UΣVᵀ‖²/n`.
//!
//! With off-center data RSVD must spend basis directions on the mean
//! component; that is precisely the gap the paper measures. Figure 1d
//! instead feeds RSVD the explicitly centered matrix — same target as
//! S-RSVD — demonstrating the implicit and explicit paths coincide.

pub mod efficiency;
pub mod fig1;
pub mod table1;

use crate::linalg::Dense;
use crate::rng::Xoshiro256pp;
use crate::svd::{Factorization, Rsvd, ShiftedRsvd, SvdConfig};
use crate::util::timer::Timer;

/// Result of one scored factorization run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// Mean squared column reconstruction error (the paper's MSE).
    pub mse: f64,
    /// Per-column squared errors (win-rates, H₀² t-test).
    pub col_errors: Vec<f64>,
    /// Wall-clock seconds for the factorization itself.
    pub secs: f64,
}

/// Factorize mean-centered (S-RSVD) and score against `X̄`.
pub fn run_srsvd(x: &Dense, cfg: SvdConfig, seed: u64) -> RunMetrics {
    let mu = x.row_means();
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let f = ShiftedRsvd::new(cfg)
        .factorize(x, &mu, &mut rng)
        .expect("srsvd");
    let secs = t.elapsed_secs();
    let xbar = x.subtract_column(&mu);
    metrics_against(&xbar, &f, secs)
}

/// Factorize the off-center `X` (plain RSVD) and score against `X`.
pub fn run_rsvd(x: &Dense, cfg: SvdConfig, seed: u64) -> RunMetrics {
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let f = Rsvd::new(cfg).factorize(x, &mut rng).expect("rsvd");
    let secs = t.elapsed_secs();
    metrics_against(x, &f, secs)
}

/// Figure 1d protocol: RSVD on the **explicitly** centered matrix.
pub fn run_rsvd_centered(x: &Dense, cfg: SvdConfig, seed: u64) -> RunMetrics {
    let mu = x.row_means();
    let xbar = x.subtract_column(&mu);
    let t = Timer::start();
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let f = Rsvd::new(cfg).factorize(&xbar, &mut rng).expect("rsvd");
    let secs = t.elapsed_secs();
    metrics_against(&xbar, &f, secs)
}

fn metrics_against(target: &Dense, f: &Factorization, secs: f64) -> RunMetrics {
    let rec = f.reconstruct();
    let (m, n) = target.shape();
    let mut col_errors = vec![0.0; n];
    for i in 0..m {
        let tr = target.row(i);
        let rr = rec.row(i);
        for j in 0..n {
            let d = tr[j] - rr[j];
            col_errors[j] += d * d;
        }
    }
    let mse = col_errors.iter().sum::<f64>() / n as f64;
    RunMetrics { mse, col_errors, secs }
}

/// The paper's second comparison metric: the sum of MSE values over a
/// range of component counts (each k gets its own factorization with
/// K = 2k, the paper's parameterization).
pub fn mse_sum(
    x: &Dense,
    ks: &[usize],
    q: usize,
    seed: u64,
    algo: Algo,
) -> f64 {
    ks.iter()
        .map(|&k| {
            let cfg = SvdConfig::paper(k).with_fixed_power(q);
            match algo {
                Algo::Srsvd => run_srsvd(x, cfg, seed ^ (k as u64) << 17).mse,
                Algo::Rsvd => run_rsvd(x, cfg, seed ^ (k as u64) << 17).mse,
            }
        })
        .sum()
}

/// Which algorithm an experiment leg runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algo {
    /// Shifted RSVD (the paper's Algorithm 1).
    Srsvd,
    /// The plain RSVD baseline.
    Rsvd,
}

/// The k-grid used for MSE-SUM plots. The paper uses 1..=100; `quick`
/// thins it (identical shape, ~8× cheaper) for CI runs.
pub fn k_grid(max_k: usize, quick: bool) -> Vec<usize> {
    if quick {
        let mut ks: Vec<usize> = vec![1, 2, 3, 5, 8, 12, 20, 35, 60, 100];
        ks.retain(|&k| k <= max_k);
        ks
    } else {
        (1..=max_k).collect()
    }
}

/// `SRSVD_QUICK=1` switches every experiment to its thinned grid.
pub fn quick_mode() -> bool {
    std::env::var("SRSVD_QUICK").as_deref() == Ok("1")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn uniform(m: usize, n: usize, seed: u64) -> Dense {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        Dense::from_fn(m, n, |_, _| rng.next_uniform())
    }

    #[test]
    fn srsvd_beats_rsvd_on_offcenter_data() {
        // The paper's core claim at test scale, k small.
        let x = uniform(40, 200, 0);
        let cfg = SvdConfig::paper(3);
        let s = run_srsvd(&x, cfg, 1);
        let r = run_rsvd(&x, cfg, 1);
        assert!(s.mse < r.mse, "srsvd {} rsvd {}", s.mse, r.mse);
        assert_eq!(s.col_errors.len(), 200);
    }

    #[test]
    fn explicit_and_implicit_centering_agree() {
        let x = uniform(30, 120, 2);
        let cfg = SvdConfig::paper(5);
        let a = run_srsvd(&x, cfg, 3);
        let b = run_rsvd_centered(&x, cfg, 3);
        assert!(
            (a.mse - b.mse).abs() < 1e-9 * b.mse.max(1.0),
            "{} vs {}",
            a.mse,
            b.mse
        );
    }

    #[test]
    fn mse_sum_decreasing_in_power() {
        let x = uniform(30, 120, 4);
        let ks = [2, 4, 8];
        let q0 = mse_sum(&x, &ks, 0, 5, Algo::Rsvd);
        let q2 = mse_sum(&x, &ks, 2, 5, Algo::Rsvd);
        assert!(q2 <= q0 * 1.05, "q0 {} q2 {}", q0, q2);
    }

    #[test]
    fn k_grid_modes() {
        assert_eq!(k_grid(100, false).len(), 100);
        let quick = k_grid(100, true);
        assert!(quick.len() <= 10);
        assert!(quick.iter().all(|&k| k <= 100));
        assert_eq!(k_grid(6, true), vec![1, 2, 3, 5]);
    }
}
