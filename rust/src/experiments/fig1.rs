//! Figure 1 (a–f): the random-data experiments of §5.1.
//!
//! Each runner returns the series the corresponding panel plots, so a
//! bench target (or `examples/full_eval.rs`) just formats rows.

use crate::bench::Table;
use crate::data::{random_matrix, DataSpec, Distribution};
use crate::linalg::Dense;
use crate::rng::Xoshiro256pp;
use crate::svd::SvdConfig;

use super::{mse_sum, run_rsvd, run_rsvd_centered, run_srsvd, Algo};

/// Default data shape of §5.1: 100×1000 uniform in [0, 1).
pub fn default_matrix(seed: u64) -> Dense {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    random_matrix(
        DataSpec { m: 100, n: 1000, dist: Distribution::Uniform },
        &mut rng,
    )
}

/// Fig. 1a: MSE vs number of principal components (fixed data).
/// Returns rows of (k, mse_srsvd, mse_rsvd).
pub fn fig1a(ks: &[usize], seed: u64) -> Vec<(usize, f64, f64)> {
    let x = default_matrix(seed);
    ks.iter()
        .map(|&k| {
            let cfg = SvdConfig::paper(k);
            let s = run_srsvd(&x, cfg, seed ^ 0xA5).mse;
            let r = run_rsvd(&x, cfg, seed ^ 0xA5).mse;
            (k, s, r)
        })
        .collect()
}

/// Fig. 1b: MSE-SUM vs sample size n. Returns (n, sum_srsvd, sum_rsvd).
pub fn fig1b(ns: &[usize], ks: &[usize], seed: u64) -> Vec<(usize, f64, f64)> {
    ns.iter()
        .map(|&n| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed ^ n as u64);
            let x = random_matrix(
                DataSpec { m: 100, n, dist: Distribution::Uniform },
                &mut rng,
            );
            let s = mse_sum(&x, ks, 0, seed, Algo::Srsvd);
            let r = mse_sum(&x, ks, 0, seed, Algo::Rsvd);
            (n, s, r)
        })
        .collect()
}

/// Fig. 1c: MSE-SUM vs data distribution. Returns (name, sum_s, sum_r).
pub fn fig1c(ks: &[usize], seed: u64) -> Vec<(&'static str, f64, f64)> {
    Distribution::ALL
        .iter()
        .map(|&dist| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed ^ dist.name().len() as u64);
            let x = random_matrix(DataSpec { m: 100, n: 1000, dist }, &mut rng);
            let s = mse_sum(&x, ks, 0, seed, Algo::Srsvd);
            let r = mse_sum(&x, ks, 0, seed, Algo::Rsvd);
            (dist.name(), s, r)
        })
        .collect()
}

/// Fig. 1d: implicit (S-RSVD on X) vs explicit (RSVD on materialized X̄)
/// centering. Returns (k, mse_implicit, mse_explicit) — the two curves
/// must coincide (Eq. 11).
pub fn fig1d(ks: &[usize], seed: u64) -> Vec<(usize, f64, f64)> {
    let x = default_matrix(seed ^ 0xD);
    ks.iter()
        .map(|&k| {
            let cfg = SvdConfig::paper(k);
            let implicit = run_srsvd(&x, cfg, seed ^ 0x1D).mse;
            let explicit = run_rsvd_centered(&x, cfg, seed ^ 0x1D).mse;
            (k, implicit, explicit)
        })
        .collect()
}

/// Fig. 1e: MSE-SUM vs power iteration count q (uniform data).
/// Returns (q, sum_srsvd, sum_rsvd).
pub fn fig1e(qs: &[usize], ks: &[usize], seed: u64) -> Vec<(usize, f64, f64)> {
    let x = default_matrix(seed ^ 0xE);
    qs.iter()
        .map(|&q| {
            let s = mse_sum(&x, ks, q, seed, Algo::Srsvd);
            let r = mse_sum(&x, ks, q, seed, Algo::Rsvd);
            (q, s, r)
        })
        .collect()
}

/// Fig. 1f: MSE-SUM(S-RSVD) − MSE-SUM(RSVD) vs q, per distribution
/// (negative everywhere = S-RSVD more accurate; Zipf stays negative).
pub fn fig1f(
    qs: &[usize],
    ks: &[usize],
    seed: u64,
) -> Vec<(&'static str, Vec<(usize, f64)>)> {
    Distribution::ALL
        .iter()
        .map(|&dist| {
            let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xF0 ^ dist.name().len() as u64);
            let x = random_matrix(DataSpec { m: 100, n: 1000, dist }, &mut rng);
            let series = qs
                .iter()
                .map(|&q| {
                    let s = mse_sum(&x, ks, q, seed, Algo::Srsvd);
                    let r = mse_sum(&x, ks, q, seed, Algo::Rsvd);
                    (q, s - r)
                })
                .collect();
            (dist.name(), series)
        })
        .collect()
}

/// Render fig1a-style rows as a table (helper for benches/examples).
pub fn render_k_table(title: &str, rows: &[(usize, f64, f64)]) -> String {
    let mut t = Table::new(&["k", "S-RSVD", "RSVD", "ratio"]);
    for &(k, s, r) in rows {
        t.row(&[
            k.to_string(),
            format!("{s:.5}"),
            format!("{r:.5}"),
            format!("{:.3}", s / r.max(1e-300)),
        ]);
    }
    format!("{title}\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1a_centering_wins_at_small_k() {
        let rows = fig1a(&[1, 2, 5], 7);
        for (k, s, r) in rows {
            assert!(s < r, "k={k}: srsvd {s} rsvd {r}");
        }
    }

    #[test]
    fn fig1d_curves_coincide() {
        for (k, imp, exp) in fig1d(&[2, 6], 11) {
            assert!(
                (imp - exp).abs() < 1e-9 * exp.max(1.0),
                "k={k}: {imp} vs {exp}"
            );
        }
    }

    #[test]
    fn fig1f_all_negative_at_q0() {
        let rows = fig1f(&[0], &[1, 2, 4], 13);
        for (name, series) in rows {
            assert!(series[0].1 < 0.0, "{name}: diff {}", series[0].1);
        }
    }
}
