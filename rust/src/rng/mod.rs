//! Pseudo-random number generation built from scratch (the offline
//! environment has no `rand` crate).
//!
//! * [`Xoshiro256pp`] — xoshiro256++ (Blackman & Vigna 2019), fast,
//!   64-bit, 2^256-1 period, with `jump()` for independent streams.
//! * [`SplitMix64`] — seeding and cheap derived streams.
//! * Gaussian variates via the polar (Marsaglia) method.
//! * Zipf variates via Hörmann & Derflinger rejection-inversion.
//!
//! All experiment randomness flows through [`Rng`] so every figure and
//! table in the paper reproduction is replayable from a single `u64`
//! seed.

mod distributions;
mod xoshiro;

pub use distributions::ZipfSampler;
pub use xoshiro::{SplitMix64, Xoshiro256pp};

/// Uniform random source + derived distributions.
///
/// Implementors only provide [`Rng::next_u64`]; everything else is
/// derived. Keep implementations `Send` so worker threads can own one.
pub trait Rng: Send {
    /// Next raw 64 random bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform in `[0, 1)` with 53-bit resolution.
    fn next_uniform(&mut self) -> f64 {
        // Take the top 53 bits -> exactly representable in f64.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    fn next_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_uniform()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    fn next_below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Marsaglia's polar method.
    ///
    /// Stateless (discards the second variate) to keep the trait
    /// object-safe without interior caching; GEMM-level fills dominate
    /// cost anyway.
    fn next_gaussian(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_uniform() - 1.0;
            let v = 2.0 * self.next_uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Exponential with rate 1 (inverse CDF).
    fn next_exponential(&mut self) -> f64 {
        -(1.0 - self.next_uniform()).ln()
    }
}

/// Fisher–Yates shuffle (free function to keep `Rng` dyn-compatible).
pub fn shuffle<T>(rng: &mut dyn Rng, items: &mut [T]) {
    for i in (1..items.len()).rev() {
        let j = rng.next_below(i as u64 + 1) as usize;
        items.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_in_unit_interval_with_decent_mean() {
        let mut r = Xoshiro256pp::seed_from_u64(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_uniform();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::seed_from_u64(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean_one() {
        let mut r = Xoshiro256pp::seed_from_u64(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.next_exponential()).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn next_below_unbiased_small_n() {
        let mut r = Xoshiro256pp::seed_from_u64(4);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 400.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256pp::seed_from_u64(5);
        let mut v: Vec<u32> = (0..100).collect();
        shuffle(&mut r, &mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn deterministic_from_seed() {
        let a: Vec<u64> = {
            let mut r = Xoshiro256pp::seed_from_u64(9);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Xoshiro256pp::seed_from_u64(9);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
