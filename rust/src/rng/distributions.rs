//! Non-uniform distributions beyond the `Rng` trait basics.

use super::Rng;

/// Zipf(s, n) sampler over `{1, ..., n}` using rejection-inversion
/// (Hörmann & Derflinger 1996) — O(1) per sample for any exponent
/// `s > 0`, `s != 1` handled via the generalized harmonic integral.
///
/// Word frequencies in the paper's §5.3 co-occurrence experiments are
/// Zipfian; this sampler drives both the synthetic corpus generator and
/// the "Zipfian" random-matrix distribution of Figure 1c/1f.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    dummy: f64,
}

impl ZipfSampler {
    /// Precompute the sampler for ranks `{1, ..., n}` with exponent `s`.
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n >= 1, "Zipf needs n >= 1");
        assert!(s > 0.0, "Zipf needs s > 0");
        let h_x1 = Self::h(1.5, s) - 1.0;
        let h_n = Self::h(n as f64 + 0.5, s);
        let dummy = 2.0 - Self::h_inv(Self::h(2.5, s) - (2.0f64).powf(-s), s);
        ZipfSampler { n, s, h_x1, h_n, dummy }
    }

    /// H(x) = integral of x^-s: (x^(1-s) - 1)/(1-s), with the s=1 limit ln x.
    fn h(x: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            x.ln()
        } else {
            (x.powf(1.0 - s) - 1.0) / (1.0 - s)
        }
    }

    fn h_inv(y: f64, s: f64) -> f64 {
        if (s - 1.0).abs() < 1e-12 {
            y.exp()
        } else {
            (1.0 + (1.0 - s) * y).powf(1.0 / (1.0 - s))
        }
    }

    /// Draw a rank in `{1, ..., n}` (rank 1 most probable).
    pub fn sample(&self, rng: &mut dyn Rng) -> u64 {
        if self.n == 1 {
            return 1;
        }
        loop {
            let u = self.h_x1 + rng.next_uniform() * (self.h_n - self.h_x1);
            let x = Self::h_inv(u, self.s);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.dummy
                || u >= Self::h(k + 0.5, self.s) - k.powf(-self.s)
            {
                return k as u64;
            }
        }
    }

    /// The normalized probability of rank `k` (for tests / analysis).
    pub fn pmf(&self, k: u64) -> f64 {
        let z: f64 = (1..=self.n).map(|i| (i as f64).powf(-self.s)).sum();
        (k as f64).powf(-self.s) / z
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256pp;

    #[test]
    fn zipf_ranks_in_range_and_head_heavy() {
        let z = ZipfSampler::new(1000, 1.1);
        let mut rng = Xoshiro256pp::seed_from_u64(11);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            let k = z.sample(&mut rng);
            assert!((1..=1000).contains(&k));
            if k <= 10 {
                head += 1;
            }
        }
        // P(rank <= 10) for Zipf(1.1, 1000) is ~0.5; be generous.
        let frac = head as f64 / n as f64;
        assert!(frac > 0.35 && frac < 0.75, "head mass {frac}");
    }

    #[test]
    fn zipf_empirical_matches_pmf() {
        let z = ZipfSampler::new(50, 1.5);
        let mut rng = Xoshiro256pp::seed_from_u64(13);
        let n = 100_000;
        let mut counts = vec![0usize; 51];
        for _ in 0..n {
            counts[z.sample(&mut rng) as usize] += 1;
        }
        for k in [1u64, 2, 5, 10] {
            let emp = counts[k as usize] as f64 / n as f64;
            let want = z.pmf(k);
            assert!(
                (emp - want).abs() < 0.01 + 0.1 * want,
                "rank {k}: emp {emp} vs pmf {want}"
            );
        }
    }

    #[test]
    fn zipf_s_equals_one_limit() {
        let z = ZipfSampler::new(100, 1.0);
        let mut rng = Xoshiro256pp::seed_from_u64(17);
        for _ in 0..1000 {
            let k = z.sample(&mut rng);
            assert!((1..=100).contains(&k));
        }
    }

    #[test]
    fn zipf_n_one_degenerate() {
        let z = ZipfSampler::new(1, 2.0);
        let mut rng = Xoshiro256pp::seed_from_u64(19);
        assert_eq!(z.sample(&mut rng), 1);
    }
}
