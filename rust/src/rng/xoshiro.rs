//! xoshiro256++ and SplitMix64 (public-domain algorithms by Blackman &
//! Vigna / Steele et al.), implemented from the reference C sources.

use super::Rng;

/// SplitMix64: used to seed xoshiro and to derive cheap substreams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Start a stream at `seed`.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator for all experiments.
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed the full 256-bit state through SplitMix64 (the recommended
    /// seeding procedure; avoids the all-zero state).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256pp {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Jump ahead 2^128 steps: yields an independent stream for a worker.
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180EC6D33CFD0ABA,
            0xD5A61266F0C9392C,
            0xA9582618E03FC9AA,
            0x39ABDC4529B1661C,
        ];
        let mut acc = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    for (a, s) in acc.iter_mut().zip(self.s.iter()) {
                        *a ^= s;
                    }
                }
                let _ = self.next_u64();
            }
        }
        self.s = acc;
    }

    /// A new generator 2^128 steps ahead, leaving `self` advanced too.
    pub fn split(&mut self) -> Xoshiro256pp {
        let mut child = self.clone();
        child.jump();
        child
    }
}

impl Rng for Xoshiro256pp {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Reference values for xoshiro256++ seeded with s = [1, 2, 3, 4],
    /// from the public reference implementation.
    #[test]
    fn matches_reference_vector() {
        let mut g = Xoshiro256pp { s: [1, 2, 3, 4] };
        let got: Vec<u64> = (0..6).map(|_| g.next_u64()).collect();
        assert_eq!(
            got,
            vec![
                41943041,
                58720359,
                3588806011781223,
                3591011842654386,
                9228616714210784205,
                9973669472204895162,
            ]
        );
    }

    #[test]
    fn splitmix_reference_vector() {
        // SplitMix64(seed=0) reference outputs.
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(g.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn jump_produces_disjoint_stream() {
        let mut a = Xoshiro256pp::seed_from_u64(7);
        let b = a.split();
        let mut a2 = a.clone();
        let mut b2 = b.clone();
        let xs: Vec<u64> = (0..64).map(|_| a2.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b2.next_u64()).collect();
        assert!(xs.iter().zip(ys.iter()).all(|(x, y)| x != y));
    }
}
