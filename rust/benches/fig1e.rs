//! Bench: regenerate Figure 1e — MSE-SUM vs power-iteration count q on
//! uniform data; power iteration narrows but does not close the gap.
//!
//! Run: `cargo bench --bench fig1e`.

use srsvd::bench::Table;
use srsvd::experiments::{fig1, k_grid, quick_mode};

fn main() {
    let ks = k_grid(100, true);
    let qs: Vec<usize> = if quick_mode() {
        vec![0, 1, 2, 4]
    } else {
        vec![0, 1, 2, 3, 4, 6, 8]
    };
    println!("== Fig 1e: MSE-SUM vs power value q (100x1000 uniform) ==");
    let mut t = Table::new(&["q", "S-RSVD", "RSVD", "gap"]);
    for (q, s, r) in fig1::fig1e(&qs, &ks, 42) {
        t.row(&[
            q.to_string(),
            format!("{s:.3}"),
            format!("{r:.3}"),
            format!("{:+.3}", s - r),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: RSVD improves sharply with q; S-RSVD only slightly (already centered).");
}
