//! Bench: regenerate Figure 1c — MSE-SUM vs data distribution
//! (uniform / normal / exponential / Zipf, 100×1000).
//!
//! Run: `cargo bench --bench fig1c`.

use srsvd::bench::Table;
use srsvd::experiments::{fig1, k_grid};

fn main() {
    let ks = k_grid(100, true);
    println!("== Fig 1c: MSE-SUM vs data distribution (100x1000) ==");
    let mut t = Table::new(&["distribution", "S-RSVD", "RSVD", "RSVD/S-RSVD"]);
    for (d, s, r) in fig1::fig1c(&ks, 42) {
        t.row(&[
            d.to_string(),
            format!("{s:.4}"),
            format!("{r:.4}"),
            format!("{:.3}", r / s.max(1e-300)),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: S-RSVD more accurate regardless of the distribution.");
}
