//! Bench: regenerate Table 1 (right) — word co-occurrence matrices at
//! several target-vocabulary sizes n, with the sparse (never-densified)
//! S-RSVD path.
//!
//! Run: `cargo bench --bench table1_words`
//! (SRSVD_FULL=1 runs the paper's n grid up to 3e5 — slow.)

use srsvd::experiments::table1;

fn main() {
    let quick = srsvd::experiments::quick_mode();
    let full = std::env::var("SRSVD_FULL").as_deref() == Ok("1");
    let (ns, runs): (Vec<usize>, usize) = if quick {
        (vec![1000, 4000], 3)
    } else if full {
        (vec![1000, 10_000, 100_000, 300_000], 30)
    } else {
        (vec![1000, 4000, 10_000], 8)
    };

    println!("== Table 1 (right): word data (m=1000 contexts), {runs} runs ==");
    let stats: Vec<_> = ns
        .iter()
        .map(|&n| {
            let pairs = (n * 50).min(4_000_000);
            let k = 100.min(n / 4);
            eprintln!("  building + factorizing n={n} (k={k}, pairs={pairs}) ...");
            table1::words_stats(n, pairs, k, runs, 42)
        })
        .collect();
    print!("{}", table1::render(&stats));
    println!("\npaper: S-RSVD MSE below RSVD at every n; p1=p2=0.00; WR 70-77%.");
}
