//! Bench: fixed-q vs dynamic-shift accuracy control (the dashSVD-style
//! `StopCriterion::Tolerance`, arXiv:2404.09276) on the paper's fig1 /
//! table1 workloads, emitting `BENCH_dash.json` for the CI trajectory.
//!
//! Per workload (uniform / normal / exponential fig1 matrices, the
//! table1 digits images), the bench runs:
//!
//! * fixed-q **Fused** legs at q ∈ {1, 2, 4, 8} — the hand-tuned sweep
//!   counts a client would pick today;
//! * adaptive legs at pve_tol ∈ {1e-3, 1e-5} — the accuracy-control
//!   path, which reports its own `sweeps_used` + `achieved_pve`.
//!
//! Every row carries MSE (scored against the centered `X̄`, the
//! paper's metric), sweep count and wall-clock. Each adaptive row also
//! records its MSE ratio against the conservative fixed q = 8 baseline
//! and whether it matched that accuracy in strictly fewer sweeps —
//! the headline claim evaluated from the artifact.
//!
//! Run: `cargo bench --bench dash_accuracy`.
//! Env: `SRSVD_BENCH_QUICK=1` (CI smoke), `SRSVD_BENCH_DASH_JSON=<path>`
//! (default `BENCH_dash.json`).

use srsvd::bench::{fmt_sci, Bencher, Table};
use srsvd::data::{digits_matrix, random_matrix, DataSpec, DigitsSpec, Distribution};
use srsvd::linalg::{fro_diff, Dense};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{PassPolicy, ShiftedRsvd, SvdConfig};
use srsvd::util::json::Json;
use srsvd::util::timer::fmt_duration;

const FIXED_QS: [usize; 4] = [1, 2, 4, 8];
const BASELINE_Q: usize = 8;
const TOLERANCES: [f64; 2] = [1e-3, 1e-5];
const MAX_SWEEPS: usize = 32;

/// Paper MSE of a factorization of `X̄`: `‖X̄ − UΣVᵀ‖²F / n`.
fn mse_against(xbar: &Dense, f: &srsvd::svd::Factorization) -> f64 {
    let d = fro_diff(&f.reconstruct(), xbar);
    d * d / xbar.cols() as f64
}

struct Leg {
    label: String,
    mse: f64,
    sweeps: usize,
    pve: Option<f64>,
    mean_s: f64,
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1");
    let seed = 42u64;
    let k = 10usize;

    // fig1 workloads (100×1000 random, each distribution) + the table1
    // digits images (64 × count, one vectorized image per column).
    let mut workloads: Vec<(&str, Dense)> = Vec::new();
    let (m, n) = if quick { (60, 400) } else { (100, 1000) };
    for dist in [
        Distribution::Uniform,
        Distribution::Normal,
        Distribution::Exponential,
    ] {
        let mut rng = Xoshiro256pp::seed_from_u64(seed);
        let name = match dist {
            Distribution::Uniform => "fig1-uniform",
            Distribution::Normal => "fig1-normal",
            _ => "fig1-exponential",
        };
        workloads.push((name, random_matrix(DataSpec { m, n, dist }, &mut rng)));
    }
    {
        let count = if quick { 400 } else { 1979 };
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xD1);
        let spec = DigitsSpec { count, ..Default::default() };
        workloads.push(("table1-digits", digits_matrix(spec, &mut rng)));
    }

    let mut cases: Vec<Json> = Vec::new();
    for (name, x) in &workloads {
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);
        println!("== {name}: {}x{} k={k} K={} ==", x.rows(), x.cols(), 2 * k);

        let mut legs: Vec<Leg> = Vec::new();
        for q in FIXED_QS {
            let cfg = SvdConfig::paper(k)
                .with_fixed_power(q)
                .with_pass_policy(PassPolicy::Fused);
            let label = format!("{name} fixed q={q}");
            let fact = {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                ShiftedRsvd::new(cfg).factorize(x, &mu, &mut rng).unwrap()
            };
            let stats = b.run(&label, || {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                ShiftedRsvd::new(cfg).factorize(x, &mu, &mut rng).unwrap()
            });
            legs.push(Leg {
                label: format!("fixed q={q}"),
                mse: mse_against(&xbar, &fact),
                sweeps: q,
                pve: None,
                mean_s: stats.mean_s,
            });
        }
        for tol in TOLERANCES {
            let cfg = SvdConfig::paper(k).with_tolerance(tol, MAX_SWEEPS);
            let label = format!("{name} adaptive tol={tol:e}");
            let (fact, report) = {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                ShiftedRsvd::new(cfg)
                    .factorize_with_report(x, &mu, &mut rng)
                    .unwrap()
            };
            let stats = b.run(&label, || {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                ShiftedRsvd::new(cfg)
                    .factorize_with_report(x, &mu, &mut rng)
                    .unwrap()
            });
            legs.push(Leg {
                label: format!("adaptive tol={tol:e}"),
                mse: mse_against(&xbar, &fact),
                sweeps: report.sweeps_used,
                pve: report.achieved_pve,
                mean_s: stats.mean_s,
            });
        }

        let baseline_mse = legs
            .iter()
            .find(|l| l.label == format!("fixed q={BASELINE_Q}"))
            .map(|l| l.mse)
            .unwrap();
        let mut t = Table::new(&["leg", "sweeps", "mse", "pve", "time", "vs q=8 mse"]);
        for leg in &legs {
            let ratio = leg.mse / baseline_mse.max(1e-300);
            let wins = leg.pve.is_some() && leg.sweeps < BASELINE_Q && ratio <= 1.0 + 1e-6;
            t.row(&[
                leg.label.clone(),
                leg.sweeps.to_string(),
                fmt_sci(leg.mse),
                leg.pve.map(|p| format!("{p:.4}")).unwrap_or_else(|| "-".into()),
                fmt_duration(leg.mean_s),
                format!("{ratio:.4}x"),
            ]);
            cases.push(Json::obj(vec![
                ("workload", Json::str(name)),
                ("leg", Json::str(&leg.label)),
                ("sweeps", Json::num(leg.sweeps as f64)),
                ("mse", Json::num(leg.mse)),
                (
                    "achieved_pve",
                    match leg.pve {
                        Some(p) => Json::num(p),
                        None => Json::Null,
                    },
                ),
                ("mean_s", Json::num(leg.mean_s)),
                ("mse_vs_fixed_q8", Json::num(ratio)),
                (
                    "matches_q8_in_fewer_sweeps",
                    if leg.pve.is_some() { Json::Bool(wins) } else { Json::Null },
                ),
            ]));
        }
        print!("{}", t.render());
        println!();
    }

    let report = Json::obj(vec![
        ("bench", Json::str("dash_accuracy")),
        ("quick", Json::Bool(quick)),
        ("k", Json::num(k as f64)),
        ("baseline_q", Json::num(BASELINE_Q as f64)),
        ("max_sweeps", Json::num(MAX_SWEEPS as f64)),
        ("cases", Json::Arr(cases)),
    ]);
    let json_path = std::env::var("SRSVD_BENCH_DASH_JSON")
        .unwrap_or_else(|_| "BENCH_dash.json".into());
    match std::fs::write(&json_path, report.to_string_pretty()) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
