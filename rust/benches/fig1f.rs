//! Bench: regenerate Figure 1f — MSE-SUM(S-RSVD) − MSE-SUM(RSVD) as a
//! function of q, per distribution. All values negative; the Zipfian
//! curve stays clearly negative at every q (power iteration cannot fully
//! recover the off-center loss on heavy-tailed data).
//!
//! Run: `cargo bench --bench fig1f`.

use srsvd::experiments::{fig1, k_grid, quick_mode};

fn main() {
    let ks = k_grid(100, true);
    let qs: Vec<usize> = if quick_mode() {
        vec![0, 1, 2, 4]
    } else {
        vec![0, 1, 2, 4, 8, 16, 32]
    };
    println!("== Fig 1f: MSE-SUM difference vs q per distribution ==");
    println!("(negative = S-RSVD more accurate)\n");
    let rows = fig1::fig1f(&qs, &ks, 42);
    let mut all_negative = true;
    for (dist, series) in &rows {
        let cells: Vec<String> = series
            .iter()
            .map(|(q, d)| format!("q={q}:{d:+.4}"))
            .collect();
        println!("  {dist:<12} {}", cells.join("  "));
        all_negative &= series.iter().all(|&(_, d)| d < 0.0);
    }
    println!(
        "\nall points negative: {} (paper: yes — S-RSVD never loses)",
        if all_negative { "YES" } else { "NO" }
    );
}
