//! Bench: regenerate Figure 1d — implicit (S-RSVD on X) vs explicit
//! (RSVD on a materialized X̄) centering. The curves must coincide
//! (paper Eq. 11); we also time both legs to show the implicit path is
//! not slower on dense data.
//!
//! Run: `cargo bench --bench fig1d`.

use srsvd::bench::{Bencher, Table};
use srsvd::experiments::{fig1, quick_mode, run_rsvd_centered, run_srsvd};
use srsvd::svd::SvdConfig;

fn main() {
    let ks: Vec<usize> = if quick_mode() {
        vec![1, 5, 20, 80]
    } else {
        vec![1, 2, 5, 10, 20, 40, 80, 100]
    };
    let seed = 42;
    println!("== Fig 1d: implicit vs explicit mean-centering ==");
    let mut t = Table::new(&["k", "implicit (S-RSVD)", "explicit (RSVD Xbar)", "|diff|"]);
    for (k, i, e) in fig1::fig1d(&ks, seed) {
        t.row(&[
            k.to_string(),
            format!("{i:.6}"),
            format!("{e:.6}"),
            format!("{:.2e}", (i - e).abs()),
        ]);
    }
    print!("{}", t.render());

    let x = fig1::default_matrix(seed ^ 0xD);
    let cfg = SvdConfig::paper(10);
    let b = Bencher::from_env();
    let si = b.run("implicit", || run_srsvd(&x, cfg, seed));
    let se = b.run("explicit", || run_rsvd_centered(&x, cfg, seed));
    println!("\ntiming: implicit {} vs explicit {} (dense input — parity expected)",
        srsvd::util::timer::fmt_duration(si.mean_s),
        srsvd::util::timer::fmt_duration(se.mean_s));
    println!("paper: S-RSVD is as accurate as RSVD applied to the pre-centered matrix.");
}
