//! Service throughput bench: jobs/sec through the full network stack
//! (client → HTTP parse → JSON wire → coordinator → factorize → JSON
//! response) as a function of the HTTP connection-worker count,
//! emitting `BENCH_serve.json` for the perf trajectory (uploaded as a
//! CI artifact next to the gemm/stream trajectories).
//!
//! Jobs are deliberately small so the wire + dispatch overhead is what
//! moves: the interesting number is how throughput scales when more
//! connection workers drain concurrent keep-alive clients. Every
//! response is checked byte-identical to an in-process baseline before
//! its leg is reported (the server must never change the math).
//!
//! The sharded leg scales out instead of up: one routing tier
//! (`srsvd route`) in front of 1/2/4 in-process replicas, submitting a
//! family of distinct specs that rendezvous-spread over the shards. It
//! emits its own `BENCH_router.json` trajectory, and every leg's
//! factors are checked bit-identical to the single-replica leg's —
//! sharding must never change the math.
//!
//! Run: `cargo bench --bench serve_throughput`.
//! Env: `SRSVD_BENCH_QUICK=1` (CI smoke),
//! `SRSVD_BENCH_SERVE_JSON=<path>` (default `BENCH_serve.json`),
//! `SRSVD_BENCH_ROUTER_JSON=<path>` (default `BENCH_router.json`).

use std::sync::Arc;

use srsvd::bench::Table;
use srsvd::coordinator::{Coordinator, CoordinatorConfig, EnginePreference};
use srsvd::data::Distribution;
use srsvd::linalg::stream::StreamConfig;
use srsvd::linalg::Dense;
use srsvd::rng::{Rng, Xoshiro256pp};
use srsvd::router::{Router, RouterConfig};
use srsvd::server::protocol::{dense_input, generator_input, JobRequest};
use srsvd::server::{Client, Server, ServerConfig};
use srsvd::svd::{Factorization, ShiftedRsvd, SvdConfig};
use srsvd::util::json::Json;
use srsvd::util::timer::Timer;

fn identical(a: &Factorization, b: &srsvd::server::protocol::WireOutput) -> bool {
    a.s.iter().zip(&b.s).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.u.data().iter().zip(b.u.data()).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.v.data().iter().zip(b.v.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn wire_identical(
    a: &srsvd::server::protocol::WireOutput,
    b: &srsvd::server::protocol::WireOutput,
) -> bool {
    a.s.iter().zip(&b.s).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.u.data().iter().zip(b.u.data()).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.v.data().iter().zip(b.v.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() {
    let quick = std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1");
    let worker_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let clients = if quick { 2 } else { 4 };
    let jobs_per_client = if quick { 8 } else { 40 };
    let (m, n, k) = (48, 128, 4);
    let seed = 42u64;

    // The job every client submits, and the in-process truth it must
    // reproduce bit-for-bit.
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let x = Dense::from_fn(m, n, |_, _| rng.next_uniform());
    let cfg = SvdConfig::paper(k);
    let baseline = {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        ShiftedRsvd::new(cfg).factorize_mean_centered(&x, &mut rng).unwrap()
    };
    let baseline = Arc::new(baseline);

    println!(
        "== serve throughput: {clients} clients x {jobs_per_client} jobs of {m}x{n} k={k} ==",
    );
    let mut t = Table::new(&["conn workers", "jobs", "wall", "jobs/s"]);
    let mut rows: Vec<Json> = Vec::new();

    for &workers in worker_counts {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                native_workers: 4,
                queue_capacity: 256,
                artifact_dir: None,
                pool_threads: Some(1),
                io_threads: None,
                ..Default::default()
            })
            .unwrap(),
        );
        let server = Server::bind(
            Arc::clone(&coord),
            &ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers,
                // Every client submits the same spec; the cold legs must
                // measure the coordinator, not the result cache.
                cache_entries: 0,
                ..Default::default()
            },
            StreamConfig::default(),
        )
        .unwrap();
        let addr = server.local_addr().to_string();

        let timer = Timer::start();
        let mut handles = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            let x = x.clone();
            let baseline = Arc::clone(&baseline);
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let mut req = JobRequest::new(dense_input(&x), k);
                req.config = cfg;
                req.engine = EnginePreference::Native;
                req.seed = seed ^ 0xFA;
                for j in 0..jobs_per_client {
                    let wire = client.submit_wait(&req).unwrap();
                    let out = wire.outcome.expect("job failed");
                    assert!(
                        identical(&baseline, &out),
                        "client {c} job {j}: wire factors diverged from in-process"
                    );
                }
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked");
        }
        let wall = timer.elapsed_secs();
        let total = clients * jobs_per_client;
        let rate = total as f64 / wall;
        t.row(&[
            workers.to_string(),
            total.to_string(),
            format!("{wall:.3}s"),
            format!("{rate:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("case", Json::str("cold")),
            ("conn_workers", Json::num(workers as f64)),
            ("clients", Json::num(clients as f64)),
            ("jobs", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("jobs_per_s", Json::num(rate)),
            ("bit_identical", Json::Bool(true)),
        ]));
        let metrics = coord.metrics();
        println!("workers={workers}: {metrics}");
        server.shutdown();
    }

    // Warm-cache leg: one cold fill, then the identical spec re-submitted
    // against the content-addressed result cache — responses replay the
    // cold run's exact bytes without touching the coordinator.
    {
        let warm_jobs = if quick { 16 } else { 200 };
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                native_workers: 4,
                queue_capacity: 256,
                artifact_dir: None,
                pool_threads: Some(1),
                io_threads: None,
                ..Default::default()
            })
            .unwrap(),
        );
        let server = Server::bind(
            Arc::clone(&coord),
            &ServerConfig { addr: "127.0.0.1:0".into(), workers: 2, ..Default::default() },
            StreamConfig::default(),
        )
        .unwrap();
        let mut client = Client::connect(&server.local_addr().to_string()).unwrap();
        let mut req = JobRequest::new(dense_input(&x), k);
        req.config = cfg;
        req.engine = EnginePreference::Native;
        req.seed = seed ^ 0xFA;
        let cold = client.submit_wait(&req).unwrap().outcome.expect("cold fill failed");
        assert!(identical(&baseline, &cold), "warm leg: cold fill diverged");

        let timer = Timer::start();
        for j in 0..warm_jobs {
            let out = client.submit_wait(&req).unwrap().outcome.expect("warm job failed");
            assert!(identical(&baseline, &out), "warm job {j}: cached factors diverged");
        }
        let wall = timer.elapsed_secs();
        let rate = warm_jobs as f64 / wall;
        let metrics = client.metrics().unwrap();
        let hits = metrics.get("cache_hits").unwrap().as_u64().unwrap();
        let native = metrics.get("native_jobs").unwrap().as_u64().unwrap();
        assert!(hits >= warm_jobs as u64, "warm jobs must be served from the cache");
        t.row(&[
            "2 (warm cache)".to_string(),
            warm_jobs.to_string(),
            format!("{wall:.3}s"),
            format!("{rate:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("case", Json::str("warm_cache")),
            ("conn_workers", Json::num(2.0)),
            ("clients", Json::num(1.0)),
            ("jobs", Json::num(warm_jobs as f64)),
            ("wall_s", Json::num(wall)),
            ("jobs_per_s", Json::num(rate)),
            ("cache_hits", Json::num(hits as f64)),
            ("native_jobs", Json::num(native as f64)),
            ("bit_identical", Json::Bool(true)),
        ]));
        println!("warm cache: {rate:.1} jobs/s ({hits} hits, {native} native jobs)");
        server.shutdown();
    }

    // Mixed-load leg: streamed (generator-source) and dense jobs run
    // concurrently through one service. The streamed jobs' blocking
    // prefetch reads land on the io pool, the GEMM chunks on the cpu
    // pool — the number to watch is the dense lane's throughput holding
    // up while the streamed lane grinds through its passes.
    {
        let mixed_jobs = if quick { 4 } else { 16 };
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                native_workers: 4,
                queue_capacity: 256,
                artifact_dir: None,
                pool_threads: Some(1),
                io_threads: Some(2),
                ..Default::default()
            })
            .unwrap(),
        );
        let server = Server::bind(
            Arc::clone(&coord),
            &ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers: 4,
                cache_entries: 0,
                ..Default::default()
            },
            StreamConfig::default(),
        )
        .unwrap();
        let addr = server.local_addr().to_string();

        let timer = Timer::start();
        let dense_lane = {
            let addr = addr.clone();
            let x = x.clone();
            let baseline = Arc::clone(&baseline);
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let mut req = JobRequest::new(dense_input(&x), k);
                req.config = cfg;
                req.engine = EnginePreference::Native;
                req.seed = seed ^ 0xFA;
                for j in 0..mixed_jobs {
                    let out = client.submit_wait(&req).unwrap().outcome.expect("dense job");
                    assert!(
                        identical(&baseline, &out),
                        "mixed leg dense job {j}: factors diverged"
                    );
                }
            })
        };
        let streamed_lane = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut client = Client::connect(&addr).unwrap();
                let mut req = JobRequest::new(
                    generator_input(400, 256, Distribution::Uniform, 7, Some(48), None),
                    4,
                );
                req.engine = EnginePreference::Native;
                req.seed = 11;
                let first = client.submit_wait(&req).unwrap().outcome.expect("streamed job");
                for j in 1..mixed_jobs {
                    let out = client.submit_wait(&req).unwrap().outcome.expect("streamed job");
                    // Same seeded spec, same bytes — streamed jobs stay
                    // deterministic through the wire under mixed load.
                    let same = first
                        .s
                        .iter()
                        .zip(&out.s)
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "mixed leg streamed job {j}: factors diverged");
                }
            })
        };
        dense_lane.join().expect("dense lane panicked");
        streamed_lane.join().expect("streamed lane panicked");
        let wall = timer.elapsed_secs();
        let total = 2 * mixed_jobs;
        let rate = total as f64 / wall;
        t.row(&[
            "4 (mixed load)".to_string(),
            total.to_string(),
            format!("{wall:.3}s"),
            format!("{rate:.1}"),
        ]);
        rows.push(Json::obj(vec![
            ("case", Json::str("mixed_load")),
            ("conn_workers", Json::num(4.0)),
            ("clients", Json::num(2.0)),
            ("jobs", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("jobs_per_s", Json::num(rate)),
            ("bit_identical", Json::Bool(true)),
        ]));
        let metrics = coord.metrics();
        println!("mixed load: {rate:.1} jobs/s\n{metrics}");
        server.shutdown();
    }
    print!("{}", t.render());

    let report = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("quick", Json::Bool(quick)),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("cases", Json::Arr(rows)),
    ]);
    let json_path = std::env::var("SRSVD_BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".into());
    match std::fs::write(&json_path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }

    // Sharded leg: the routing tier in front of 1/2/4 in-process
    // replicas. Clients submit a family of distinct generator specs
    // that rendezvous-spread over the shards (replica caches are off:
    // the number is sharded dispatch, not cache replay). Every spec's
    // factors are pinned against the 1-replica leg bit-for-bit.
    let replica_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4] };
    let shard_clients = if quick { 2 } else { 4 };
    let shard_jobs_per_client = if quick { 8 } else { 24 };
    let distinct_specs = 8usize;
    let mut references: Vec<Option<srsvd::server::protocol::WireOutput>> =
        (0..distinct_specs).map(|_| None).collect();
    let mut rt = Table::new(&["replicas", "jobs", "wall", "jobs/s"]);
    let mut router_rows: Vec<Json> = Vec::new();
    println!(
        "\n== sharded throughput: {shard_clients} clients x {shard_jobs_per_client} jobs \
         over {distinct_specs} specs, via one router =="
    );
    for &replicas in replica_counts {
        let mut backends = Vec::new();
        for _ in 0..replicas {
            let coord = Arc::new(
                Coordinator::start(CoordinatorConfig {
                    native_workers: 2,
                    queue_capacity: 256,
                    artifact_dir: None,
                    pool_threads: Some(1),
                    io_threads: None,
                    ..Default::default()
                })
                .unwrap(),
            );
            let server = Server::bind(
                Arc::clone(&coord),
                &ServerConfig {
                    addr: "127.0.0.1:0".into(),
                    workers: 2,
                    cache_entries: 0,
                    ..Default::default()
                },
                StreamConfig::default(),
            )
            .unwrap();
            backends.push((coord, server));
        }
        let router = Router::bind(
            &RouterConfig {
                listen: "127.0.0.1:0".into(),
                replicas: backends.iter().map(|(_, s)| s.local_addr().to_string()).collect(),
                workers: 4,
                ..Default::default()
            },
            StreamConfig::default(),
        )
        .unwrap();
        let raddr = router.local_addr().to_string();

        let timer = Timer::start();
        let mut handles = Vec::new();
        for c in 0..shard_clients {
            let raddr = raddr.clone();
            handles.push(std::thread::spawn(move || {
                let mut client = Client::connect(&raddr).unwrap();
                let mut outs = Vec::new();
                for j in 0..shard_jobs_per_client {
                    let spec = (c * shard_jobs_per_client + j) % distinct_specs;
                    let mut req = JobRequest::new(
                        generator_input(48, 128, Distribution::Uniform, spec as u64, None, None),
                        4,
                    );
                    req.engine = EnginePreference::Native;
                    req.seed = 17;
                    let out =
                        client.submit_wait(&req).unwrap().outcome.expect("sharded job failed");
                    outs.push((spec, out));
                }
                outs
            }));
        }
        let mut outcomes = Vec::new();
        for h in handles {
            outcomes.extend(h.join().expect("sharded client panicked"));
        }
        let wall = timer.elapsed_secs();

        for (spec, out) in outcomes {
            if let Some(reference) = &references[spec] {
                assert!(
                    wire_identical(reference, &out),
                    "replicas={replicas} spec {spec}: factors diverged across shards"
                );
            } else {
                references[spec] = Some(out);
            }
        }

        let total = shard_clients * shard_jobs_per_client;
        let rate = total as f64 / wall;
        rt.row(&[
            replicas.to_string(),
            total.to_string(),
            format!("{wall:.3}s"),
            format!("{rate:.1}"),
        ]);
        router_rows.push(Json::obj(vec![
            ("case", Json::str("sharded")),
            ("replicas", Json::num(replicas as f64)),
            ("clients", Json::num(shard_clients as f64)),
            ("jobs", Json::num(total as f64)),
            ("wall_s", Json::num(wall)),
            ("jobs_per_s", Json::num(rate)),
            ("bit_identical", Json::Bool(true)),
        ]));
        println!("replicas={replicas}: {rate:.1} jobs/s");
        router.shutdown();
        for (_, server) in backends {
            server.shutdown();
        }
    }
    print!("{}", rt.render());

    let router_report = Json::obj(vec![
        ("bench", Json::str("router_throughput")),
        ("quick", Json::Bool(quick)),
        ("distinct_specs", Json::num(distinct_specs as f64)),
        ("cases", Json::Arr(router_rows)),
    ]);
    let router_path = std::env::var("SRSVD_BENCH_ROUTER_JSON")
        .unwrap_or_else(|_| "BENCH_router.json".into());
    match std::fs::write(&router_path, router_report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {router_path}"),
        Err(e) => eprintln!("\ncould not write {router_path}: {e}"),
    }
}
