//! Ablation: the three basis methods of Alg. 1 Lines 4-6 —
//!   direct          qr(XΩ − μ(1ᵀΩ))      (fused; our default)
//!   qr-update-paper qr-update with v = 1  (the paper's literal Line 6)
//!   qr-update-exact qr-update with v = Ωᵀ1 (exact shifted sample)
//!
//! Quantifies DESIGN.md's "paper erratum": all three recover the same
//! accuracy (each basis contains span{μ}); the update routes cost an
//! extra O(mK) pass but reuse an existing QR.
//!
//! Run: `cargo bench --bench ablation_qr_update`.

use srsvd::bench::{Bencher, Table};
use srsvd::experiments::fig1;
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{BasisMethod, ShiftedRsvd, SvdConfig};

fn main() {
    let x = fig1::default_matrix(42);
    let mu = x.row_means();
    let xbar = x.subtract_column(&mu);
    let b = Bencher::from_env();

    println!("== Ablation: QR-update basis variants (100x1000 uniform, k=10, K=20) ==");
    let mut t = Table::new(&["basis", "mse", "rel. to direct", "time"]);
    let mut direct_mse = None;
    for (name, basis) in [
        ("direct", BasisMethod::Direct),
        ("qr-update-paper", BasisMethod::QrUpdatePaper),
        ("qr-update-exact", BasisMethod::QrUpdateExact),
    ] {
        let cfg = SvdConfig { k: 10, oversample: 10, basis, ..Default::default() };
        let engine = ShiftedRsvd::new(cfg);
        // Accuracy: average over several seeds.
        let mut mses = Vec::new();
        for seed in 0..10u64 {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let f = engine.factorize(&x, &mu, &mut rng).unwrap();
            mses.push(f.mse_against(&xbar));
        }
        let mse = srsvd::stats::mean(&mses);
        let dm = *direct_mse.get_or_insert(mse);
        // Latency.
        let stats = b.run(name, || {
            let mut rng = Xoshiro256pp::seed_from_u64(1);
            engine.factorize(&x, &mu, &mut rng).unwrap()
        });
        t.row(&[
            name.to_string(),
            format!("{mse:.5}"),
            format!("{:+.3}%", (mse / dm - 1.0) * 100.0),
            srsvd::util::timer::fmt_duration(stats.mean_s),
        ]);
    }
    print!("{}", t.render());
    println!("\nconclusion: the paper's v=1 update loses no accuracy (span{{mu}} is all");
    println!("that matters for the basis), validating DESIGN.md's erratum analysis.");
}
