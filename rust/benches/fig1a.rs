//! Bench: regenerate Figure 1a — MSE vs number of principal components
//! on a 100×1000 uniform matrix (K = 2k, q = 0), plus the wall-clock of
//! each factorization leg.
//!
//! Run: `cargo bench --bench fig1a` (SRSVD_QUICK=1 thins the grid).

use srsvd::bench::{Bencher, Table};
use srsvd::experiments::{fig1, quick_mode, run_rsvd, run_srsvd};
use srsvd::svd::SvdConfig;

fn main() {
    let quick = quick_mode();
    let ks: Vec<usize> = if quick {
        vec![1, 5, 10, 25, 50]
    } else {
        vec![1, 2, 5, 10, 20, 25, 50, 75, 100]
    };
    let seed = 42;

    println!("== Fig 1a: MSE vs #components (100x1000 uniform, K=2k, q=0) ==");
    let rows = fig1::fig1a(&ks, seed);
    print!("{}", fig1::render_k_table("accuracy:", &rows));

    println!("\ntiming (per factorization):");
    let x = fig1::default_matrix(seed);
    let b = Bencher::from_env();
    let mut t = Table::new(&["k", "S-RSVD", "RSVD"]);
    for &k in &[10usize, 50] {
        let cfg = SvdConfig::paper(k);
        let s = b.run(&format!("srsvd k={k}"), || run_srsvd(&x, cfg, seed));
        let r = b.run(&format!("rsvd k={k}"), || run_rsvd(&x, cfg, seed));
        t.row(&[
            k.to_string(),
            srsvd::util::timer::fmt_duration(s.mean_s),
            srsvd::util::timer::fmt_duration(r.mean_s),
        ]);
    }
    print!("{}", t.render());
    println!("\npaper: S-RSVD error well below RSVD at small k; curves converge as k grows.");
}
