//! Ablation: the small projected SVD backend (Alg. 1 Line 13) —
//! one-sided Jacobi on Yᵀ (accurate, O(nK²·sweeps)) vs the K×K
//! Gram-matrix eigendecomposition (fast for large n, squares the
//! condition number).
//!
//! Run: `cargo bench --bench ablation_small_svd`.

use srsvd::bench::{Bencher, Table};
use srsvd::data::{random_matrix, DataSpec, Distribution};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{ShiftedRsvd, SmallSvdMethod, SvdConfig};

fn main() {
    let b = Bencher::from_env();
    println!("== Ablation: small-SVD backend (k=16, K=32, q=0) ==");
    let mut t = Table::new(&["n", "backend", "mse", "max |Δσ| vs jacobi", "time"]);
    for &n in &[1000usize, 4000, 16000] {
        let mut rng = Xoshiro256pp::seed_from_u64(n as u64);
        let x = random_matrix(DataSpec { m: 200, n, dist: Distribution::Uniform }, &mut rng);
        let mu = x.row_means();
        let xbar = x.subtract_column(&mu);

        let run = |method: SmallSvdMethod| {
            let cfg = SvdConfig { k: 16, oversample: 16, small_svd: method, ..Default::default() };
            let mut rng = Xoshiro256pp::seed_from_u64(7);
            ShiftedRsvd::new(cfg).factorize(&x, &mu, &mut rng).unwrap()
        };
        let fj = run(SmallSvdMethod::Jacobi);
        let fg = run(SmallSvdMethod::GramEig);
        let dsv = fj
            .s
            .iter()
            .zip(&fg.s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);

        for (name, method, f) in [
            ("jacobi", SmallSvdMethod::Jacobi, &fj),
            ("gram", SmallSvdMethod::GramEig, &fg),
        ] {
            let stats = b.run(&format!("{name} n={n}"), || run(method));
            t.row(&[
                n.to_string(),
                name.to_string(),
                format!("{:.5}", f.mse_against(&xbar)),
                if name == "gram" { format!("{dsv:.2e}") } else { "-".into() },
                srsvd::util::timer::fmt_duration(stats.mean_s),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\nconclusion: gram matches jacobi's top-k factors to f64 noise and wins");
    println!("increasingly as n grows — it is the right default for the wide word matrices.");
}
