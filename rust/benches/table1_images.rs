//! Bench: regenerate Table 1 (left) — digits and faces image matrices:
//! MSE per algorithm, p-values for H₀¹/H₀², win-rates, and timing.
//!
//! Run: `cargo bench --bench table1_images`
//! (SRSVD_QUICK=1 for a fast pass; SRSVD_FULL=1 for paper-sized runs).

use srsvd::bench::Bencher;
use srsvd::data::FacesSpec;
use srsvd::experiments::table1;

fn main() {
    let quick = srsvd::experiments::quick_mode();
    let full = std::env::var("SRSVD_FULL").as_deref() == Ok("1");
    let runs = if quick { 5 } else if full { 30 } else { 15 };
    let digit_count = if full { 1979 } else { 600 };
    let faces_spec = if full {
        FacesSpec::default() // 32x32 x 400
    } else {
        FacesSpec { side: 20, count: 200, rank: 14, noise: 5.0 }
    };

    println!("== Table 1 (left): image data, {runs} runs ==");
    let digits = table1::digits_stats(digit_count, runs, 42);
    let faces = table1::faces_stats(faces_spec, runs, 43);
    print!("{}", table1::render(&[digits, faces]));

    println!("\ntiming (one factorization pair):");
    let b = Bencher::from_env();
    let s = b.run("digits pair", || table1::digits_stats(digit_count, 1, 7));
    println!("  digits: {}", srsvd::util::timer::fmt_duration(s.mean_s));

    println!(
        "\npaper: digits 415.7 vs 430.6 (WR 66/34), faces 15.3e7 vs 16.1e7 (WR 82/18), all p=0.00"
    );
}
