//! Streaming-scale bench: in-memory vs out-of-core factorization
//! throughput per block size, emitting `BENCH_stream.json` for the perf
//! trajectory (uploaded as a CI artifact next to `BENCH_gemm.json`).
//!
//! Three legs per block size:
//!   * `dense`      — the in-memory [`srsvd::linalg::Dense`] baseline;
//!   * `stream-mem` — `Streamed<InMemorySource>`: pure sweep overhead;
//!   * `stream-file`— `Streamed<FileSource>`: sweep + disk IO.
//!
//! Every streamed run is checked byte-identical to the dense baseline
//! (the module contract) before its timing is reported.
//!
//! Run: `cargo bench --bench stream_scale`.
//! Env: `SRSVD_BENCH_QUICK=1` (CI smoke), `SRSVD_BENCH_STREAM_JSON=<path>`
//! (default `BENCH_stream.json`).

use srsvd::bench::{Bencher, Table};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, GeneratorSource, InMemorySource, MatrixSource, Streamed,
};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{Factorization, ShiftedRsvd, SvdConfig};
use srsvd::util::json::Json;
use srsvd::util::timer::fmt_duration;

fn identical(a: &Factorization, b: &Factorization) -> bool {
    a.s.iter().zip(&b.s).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.u.data().iter().zip(b.u.data()).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.v.data().iter().zip(b.v.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1");
    let (m, n, k) = if quick { (600, 500, 6) } else { (2400, 1600, 10) };
    let block_sizes: &[usize] = if quick { &[64, 600] } else { &[64, 256, 1024, 2400] };
    let cfg = SvdConfig::paper(k).with_power(1);
    let seed = 42u64;

    let gen = GeneratorSource::new(m, n, Distribution::Uniform, seed).unwrap();
    let dense = gen.materialize().unwrap();
    let path = std::env::temp_dir().join(format!("srsvd_stream_scale_{m}x{n}.bin"));
    let file = spill_to_file(&gen, &path, 256).unwrap();

    let factorize = |x: &dyn srsvd::svd::MatVecOps| {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        ShiftedRsvd::new(cfg).factorize_mean_centered(x, &mut rng).unwrap()
    };

    println!("== stream scale: {m}x{n} uniform, k={k} q=1 ==");
    let baseline = factorize(&dense);
    let s_dense = b.run("dense in-memory", || factorize(&dense));

    let mut rows: Vec<Json> = Vec::new();
    rows.push(Json::obj(vec![
        ("leg", Json::str("dense")),
        ("block_rows", Json::num(m as f64)),
        ("mean_s", Json::num(s_dense.mean_s)),
        ("p95_s", Json::num(s_dense.p95_s)),
        ("slowdown_vs_dense", Json::num(1.0)),
        ("bit_identical", Json::Bool(true)),
    ]));

    let mut t = Table::new(&["leg", "block_rows", "time", "vs dense", "bit-identical"]);
    t.row(&[
        "dense".into(),
        m.to_string(),
        fmt_duration(s_dense.mean_s),
        "1.00x".into(),
        "-".into(),
    ]);

    let mem_src = InMemorySource::new(dense.clone());
    for &bl in block_sizes {
        let bl = bl.min(m);
        let mem = Streamed::with_block_rows(&mem_src, bl);
        let fil = Streamed::with_block_rows(&file, bl);
        let legs: [(&str, &dyn srsvd::svd::MatVecOps); 2] =
            [("stream-mem", &mem), ("stream-file", &fil)];
        for (leg, x) in legs {
            let fact_now = factorize(x);
            let ok = identical(&baseline, &fact_now);
            assert!(ok, "{leg} bl={bl}: streamed factors diverged from dense");
            let stats = b.run(&format!("{leg} bl={bl}"), || factorize(x));
            let slowdown = stats.mean_s / s_dense.mean_s.max(1e-12);
            t.row(&[
                leg.into(),
                bl.to_string(),
                fmt_duration(stats.mean_s),
                format!("{slowdown:.2}x"),
                ok.to_string(),
            ]);
            rows.push(Json::obj(vec![
                ("leg", Json::str(leg)),
                ("block_rows", Json::num(bl as f64)),
                ("mean_s", Json::num(stats.mean_s)),
                ("p95_s", Json::num(stats.p95_s)),
                ("slowdown_vs_dense", Json::num(slowdown)),
                ("bit_identical", Json::Bool(ok)),
            ]));
        }
    }
    print!("{}", t.render());

    let report = Json::obj(vec![
        ("bench", Json::str("stream_scale")),
        ("quick", Json::Bool(quick)),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("cases", Json::Arr(rows)),
    ]);
    let json_path = std::env::var("SRSVD_BENCH_STREAM_JSON")
        .unwrap_or_else(|_| "BENCH_stream.json".into());
    match std::fs::write(&json_path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }
    let _ = std::fs::remove_file(&path);
}
