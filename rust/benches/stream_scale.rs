//! Streaming-scale bench: in-memory vs out-of-core factorization
//! throughput per block size, pass policy (exact vs fused) and prefetch
//! (on vs off), emitting `BENCH_stream.json` for the perf trajectory
//! (uploaded as a CI artifact next to `BENCH_gemm.json`).
//!
//! Legs per (block size × policy × prefetch) cell:
//!   * `stream-mem` — `Streamed<InMemorySource>`: pure sweep overhead;
//!   * `stream-file`— `Streamed<FileSource>`: sweep + disk IO;
//! plus the in-memory [`srsvd::linalg::Dense`] baseline (`dense`) and a
//! `crash_resume` leg (checkpointed run killed by an injected crash,
//! restarted, pass savings and bit-identity reported).
//!
//! Every `exact` streamed run is checked byte-identical to the dense
//! baseline (the module contract) before its timing is reported. For
//! `fused` runs byte-identity is out of contract (accuracy is pinned in
//! `rust/tests/stream.rs`); each row instead carries the measured
//! source-pass count (`passes`: `2 + 2q` exact vs `q + 2` fused — the
//! wall-clock lever for file-backed runs, where every pass is a disk
//! sweep).
//!
//! Run: `cargo bench --bench stream_scale`.
//! Env: `SRSVD_BENCH_QUICK=1` (CI smoke), `SRSVD_BENCH_STREAM_JSON=<path>`
//! (default `BENCH_stream.json`).

use srsvd::bench::{Bencher, Table};
use srsvd::data::Distribution;
use srsvd::linalg::stream::{
    spill_to_file, GeneratorSource, InMemorySource, MatrixSource, Streamed,
};
use srsvd::rng::Xoshiro256pp;
use srsvd::svd::{Checkpointer, Factorization, PassPolicy, ShiftedRsvd, SvdConfig};
use srsvd::util::faults;
use srsvd::util::json::Json;
use srsvd::util::timer::fmt_duration;

fn identical(a: &Factorization, b: &Factorization) -> bool {
    a.s.iter().zip(&b.s).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.u.data().iter().zip(b.u.data()).all(|(x, y)| x.to_bits() == y.to_bits())
        && a.v.data().iter().zip(b.v.data()).all(|(x, y)| x.to_bits() == y.to_bits())
}

struct LegStats {
    passes: u64,
    mean_s: f64,
    p95_s: f64,
    /// `Some` for exact legs (asserted true); `None` for fused legs.
    bit_identical: Option<bool>,
}

/// Time one streamed leg: parity/pass-count check on a first run, then
/// the measured repetitions. μ is precomputed by the caller so
/// `passes` reads exactly the factorization schedule (`2 + 2q` exact,
/// `q + 2` fused) with no mean-centering sweep folded in.
#[allow(clippy::too_many_arguments)]
fn run_leg<S: MatrixSource>(
    b: &Bencher,
    label: &str,
    src: &S,
    bl: usize,
    prefetch: bool,
    cfg: SvdConfig,
    mu: &[f64],
    seed: u64,
    baseline: &Factorization,
) -> LegStats {
    let factorize = |w: &Streamed<&S>| {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        ShiftedRsvd::new(cfg).factorize(w, mu, &mut rng).unwrap()
    };
    let w = Streamed::with_block_rows(src, bl).with_prefetch(prefetch);
    let fact = factorize(&w);
    let passes = w.stats().passes; // exactly one factorization's schedule
    let bit_identical = match cfg.pass_policy {
        PassPolicy::Exact => {
            let ok = identical(baseline, &fact);
            assert!(ok, "{label}: exact streamed factors diverged from dense");
            Some(ok)
        }
        PassPolicy::Fused => None,
    };
    let stats = b.run(label, || factorize(&w));
    LegStats { passes, mean_s: stats.mean_s, p95_s: stats.p95_s, bit_identical }
}

fn main() {
    let b = Bencher::from_env();
    let quick = std::env::var("SRSVD_BENCH_QUICK").as_deref() == Ok("1");
    let (m, n, k) = if quick { (600, 500, 6) } else { (2400, 1600, 10) };
    let block_sizes: &[usize] = if quick { &[64, 600] } else { &[64, 256, 1024, 2400] };
    let seed = 42u64;

    let gen = GeneratorSource::new(m, n, Distribution::Uniform, seed).unwrap();
    let dense = gen.materialize().unwrap();
    let path = std::env::temp_dir().join(format!("srsvd_stream_scale_{m}x{n}.bin"));
    let file = spill_to_file(&gen, &path, 256).unwrap();

    let exact_cfg = SvdConfig::paper(k).with_fixed_power(1);
    println!("== stream scale: {m}x{n} uniform, k={k} q=1 ==");
    // μ once, up front: every leg then runs the pure factorization
    // schedule (streamed row_means is byte-identical to this anyway).
    let mu = dense.row_means();
    let baseline = {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        ShiftedRsvd::new(exact_cfg).factorize(&dense, &mu, &mut rng).unwrap()
    };
    let s_dense = b.run("dense in-memory", || {
        let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
        ShiftedRsvd::new(exact_cfg).factorize(&dense, &mu, &mut rng).unwrap()
    });

    let mut rows: Vec<Json> = Vec::new();
    rows.push(Json::obj(vec![
        ("leg", Json::str("dense")),
        ("block_rows", Json::num(m as f64)),
        ("pass_policy", Json::str("exact")),
        ("prefetch", Json::Bool(false)),
        ("passes", Json::Null),
        ("mean_s", Json::num(s_dense.mean_s)),
        ("p95_s", Json::num(s_dense.p95_s)),
        ("slowdown_vs_dense", Json::num(1.0)),
        ("bit_identical", Json::Bool(true)),
    ]));

    let mut t = Table::new(&[
        "leg", "policy", "prefetch", "block_rows", "passes", "time", "vs dense",
    ]);
    t.row(&[
        "dense".into(),
        "exact".into(),
        "-".into(),
        m.to_string(),
        "-".into(),
        fmt_duration(s_dense.mean_s),
        "1.00x".into(),
    ]);

    let mem_src = InMemorySource::new(dense.clone());
    for &bl in block_sizes {
        let bl = bl.min(m);
        for policy in [PassPolicy::Exact, PassPolicy::Fused] {
            let cfg = exact_cfg.with_pass_policy(policy);
            for prefetch in [true, false] {
                for leg in ["stream-mem", "stream-file"] {
                    let label = format!(
                        "{leg} {} prefetch={prefetch} bl={bl}",
                        policy.name()
                    );
                    let r = if leg == "stream-mem" {
                        run_leg(&b, &label, &mem_src, bl, prefetch, cfg, &mu, seed, &baseline)
                    } else {
                        run_leg(&b, &label, &file, bl, prefetch, cfg, &mu, seed, &baseline)
                    };
                    let slowdown = r.mean_s / s_dense.mean_s.max(1e-12);
                    t.row(&[
                        leg.into(),
                        policy.name().into(),
                        prefetch.to_string(),
                        bl.to_string(),
                        r.passes.to_string(),
                        fmt_duration(r.mean_s),
                        format!("{slowdown:.2}x"),
                    ]);
                    rows.push(Json::obj(vec![
                        ("leg", Json::str(leg)),
                        ("block_rows", Json::num(bl as f64)),
                        ("pass_policy", Json::str(policy.name())),
                        ("prefetch", Json::Bool(prefetch)),
                        ("passes", Json::num(r.passes as f64)),
                        ("mean_s", Json::num(r.mean_s)),
                        ("p95_s", Json::num(r.p95_s)),
                        ("slowdown_vs_dense", Json::num(slowdown)),
                        (
                            "bit_identical",
                            match r.bit_identical {
                                Some(v) => Json::Bool(v),
                                None => Json::Null,
                            },
                        ),
                    ]));
                }
            }
        }
    }
    // Mixed-load leg: a file-backed streamed factorization loops on a
    // second thread while the dense baseline is re-timed on this one.
    // The streamed lane's blocking reads sit on the io pool, so the
    // dense lane keeps its cpu-pool workers — `vs dense` here measures
    // how much compute the concurrent streamed job actually steals.
    {
        let reps = if quick { 2 } else { 4 };
        let stop = std::sync::atomic::AtomicBool::new(false);
        let bl = 256.min(m);
        let mut dense_loaded_mean = 0.0;
        let mut stream_runs = 0u64;
        std::thread::scope(|scope| {
            let streamer = scope.spawn(|| {
                let mut runs = 0u64;
                loop {
                    let w = Streamed::with_block_rows(&file, bl).with_prefetch(true);
                    let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                    let f =
                        ShiftedRsvd::new(exact_cfg).factorize(&w, &mu, &mut rng).unwrap();
                    assert!(
                        identical(&baseline, &f),
                        "mixed leg: streamed factors diverged under load"
                    );
                    runs += 1;
                    if stop.load(std::sync::atomic::Ordering::Relaxed) {
                        break;
                    }
                }
                runs
            });
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
                let f = ShiftedRsvd::new(exact_cfg).factorize(&dense, &mu, &mut rng).unwrap();
                assert!(
                    identical(&baseline, &f),
                    "mixed leg: dense factors diverged under load"
                );
            }
            dense_loaded_mean = t0.elapsed().as_secs_f64() / reps as f64;
            stop.store(true, std::sync::atomic::Ordering::Relaxed);
            stream_runs = streamer.join().expect("streamed lane panicked");
        });
        let slowdown = dense_loaded_mean / s_dense.mean_s.max(1e-12);
        t.row(&[
            "dense+stream".into(),
            "exact".into(),
            "true".into(),
            bl.to_string(),
            "-".into(),
            fmt_duration(dense_loaded_mean),
            format!("{slowdown:.2}x"),
        ]);
        rows.push(Json::obj(vec![
            ("leg", Json::str("mixed_load")),
            ("block_rows", Json::num(bl as f64)),
            ("pass_policy", Json::str("exact")),
            ("prefetch", Json::Bool(true)),
            ("passes", Json::Null),
            ("mean_s", Json::num(dense_loaded_mean)),
            ("p95_s", Json::Null),
            ("slowdown_vs_dense", Json::num(slowdown)),
            ("concurrent_stream_runs", Json::num(stream_runs as f64)),
            ("bit_identical", Json::Bool(true)),
        ]));
        println!(
            "mixed load: dense mean {} ({slowdown:.2}x solo) with {stream_runs} concurrent \
             streamed runs",
            fmt_duration(dense_loaded_mean)
        );
    }
    // Crash/resume leg: a checkpointed file-backed run is killed at the
    // top of sweep 2 by an injected crash, then restarted on the same
    // checkpoint directory with the same seed. The row reports how much
    // of the pass schedule the resume skipped; the recovered factors
    // must stay bit-identical to an uninterrupted run.
    {
        let bl = 256.min(m);
        let resume_cfg = exact_cfg.with_fixed_power(3);
        let ckpt_dir = std::env::temp_dir().join(format!("srsvd_stream_scale_ckpt_{m}x{n}"));
        let _ = std::fs::create_dir_all(&ckpt_dir);
        let run = |engine: ShiftedRsvd| {
            let w = Streamed::with_block_rows(&file, bl).with_prefetch(true);
            let mut rng = Xoshiro256pp::seed_from_u64(seed ^ 0xFA);
            let t0 = std::time::Instant::now();
            let f = engine.factorize(&w, &mu, &mut rng).unwrap();
            (f, t0.elapsed().as_secs_f64(), w.stats().passes)
        };
        let (full_f, full_s, full_passes) = run(ShiftedRsvd::new(resume_cfg));
        let ckpt = Checkpointer::new(&ckpt_dir, 0xBE4C);
        faults::arm("svd.sweep=die_after:2").unwrap();
        let crashed = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run(ShiftedRsvd::new(resume_cfg).with_checkpoint(ckpt.clone()))
        }));
        faults::disarm();
        assert!(crashed.is_err(), "crash_resume: injected crash never fired");
        let (res_f, res_s, res_passes) = run(ShiftedRsvd::new(resume_cfg).with_checkpoint(ckpt));
        assert!(
            identical(&full_f, &res_f),
            "crash_resume: resumed factors diverged from the uninterrupted run"
        );
        let saved = full_passes.saturating_sub(res_passes);
        t.row(&[
            "crash_resume".into(),
            "exact".into(),
            "true".into(),
            bl.to_string(),
            format!("{res_passes} (-{saved})"),
            fmt_duration(res_s),
            format!("{:.2}x", res_s / full_s.max(1e-12)),
        ]);
        rows.push(Json::obj(vec![
            ("leg", Json::str("crash_resume")),
            ("block_rows", Json::num(bl as f64)),
            ("pass_policy", Json::str("exact")),
            ("prefetch", Json::Bool(true)),
            ("passes", Json::num(res_passes as f64)),
            ("passes_full_run", Json::num(full_passes as f64)),
            ("passes_saved_by_resume", Json::num(saved as f64)),
            ("mean_s", Json::num(res_s)),
            ("full_run_s", Json::num(full_s)),
            ("p95_s", Json::Null),
            ("slowdown_vs_dense", Json::num(res_s / s_dense.mean_s.max(1e-12))),
            ("bit_identical", Json::Bool(true)),
        ]));
        println!(
            "crash resume: {res_passes} passes after restart vs {full_passes} uninterrupted \
             ({saved} saved), {} vs {}",
            fmt_duration(res_s),
            fmt_duration(full_s)
        );
        let _ = std::fs::remove_dir_all(&ckpt_dir);
    }
    print!("{}", t.render());

    let report = Json::obj(vec![
        ("bench", Json::str("stream_scale")),
        ("quick", Json::Bool(quick)),
        ("m", Json::num(m as f64)),
        ("n", Json::num(n as f64)),
        ("k", Json::num(k as f64)),
        ("cases", Json::Arr(rows)),
    ]);
    let json_path = std::env::var("SRSVD_BENCH_STREAM_JSON")
        .unwrap_or_else(|_| "BENCH_stream.json".into());
    match std::fs::write(&json_path, report.to_string_pretty()) {
        Ok(()) => println!("\nwrote {json_path}"),
        Err(e) => eprintln!("\ncould not write {json_path}: {e}"),
    }
    let _ = std::fs::remove_file(&path);
}
